"""Summarize results/dryrun/*.json into the EXPERIMENTS.md roofline table."""

from __future__ import annotations

import glob
import json
import sys


def fmt(x, digits=3):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) >= 1000 or abs(x) < 0.001:
            return f"{x:.{digits}g}"
        return f"{x:.{digits}g}"
    return str(x)


def load(out_dir="results/dryrun"):
    rows = []
    for path in sorted(glob.glob(f"{out_dir}/*.json")):
        with open(path) as f:
            r = json.load(f)
        rows.append(r)
    return rows


def main():
    mp = "multipod" if "--multipod" in sys.argv else "pod"
    rows = [r for r in load()
            if (r["chips"] == 512) == (mp == "multipod")]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "MODEL_FLOPS | useful | roofline_frac | peak GB/dev |")
    print(hdr)
    print("|" + "---|" * 10)
    for r in rows:
        rf = r["roofline"]
        peak = (r["memory"]["peak_bytes"] or 0) / 1e9
        print("| {a} | {s} | {c} | {m} | {k} | {d} | {mf} | {u} | {rfr} | {p:.2f} |".format(
            a=r["arch"], s=r["shape"], c=fmt(rf["compute_s"]),
            m=fmt(rf["memory_s"]), k=fmt(rf["collective_s"]),
            d=rf["dominant"].replace("_s", ""),
            mf=fmt(rf["model_flops"], 3), u=fmt(rf["useful_flops_ratio"]),
            rfr=fmt(rf["roofline_fraction"]), p=peak))


if __name__ == "__main__":
    main()
