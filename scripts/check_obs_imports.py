"""CI lint: ``repro.obs`` must not import the rest of ``repro``.

The observability layer (metrics registry, span tracer, explain records)
is deliberately one-directional: engines and the workload server push
values *into* it, and nothing in ``repro.obs`` reaches back into the
engine, scheduler, or serving planes.  That keeps the plain-float explain
surface (e.g. ``RoundSample.groups``) importable from analysis scripts
with no jax or engine dependency, and makes the dependency direction
checkable.

The check is an AST walk over ``src/repro/obs/*.py``: any ``import`` or
``from ... import`` that resolves to a ``repro.*`` module outside
``repro.obs`` fails — including relative imports that climb out of the
package (``from .. import engine``).

Usage::

    python scripts/check_obs_imports.py [--root src/repro/obs]
"""

from __future__ import annotations

import argparse
import ast
import os
import sys

ALLOWED_PREFIX = "repro.obs"


def violations_in(path: str) -> list[tuple[int, str]]:
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    bad: list[tuple[int, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                name = alias.name
                if name.startswith("repro") and not (
                    name == ALLOWED_PREFIX
                    or name.startswith(ALLOWED_PREFIX + ".")
                ):
                    bad.append((node.lineno, f"import {name}"))
        elif isinstance(node, ast.ImportFrom):
            if node.level >= 2:
                # "from .. import x" escapes repro.obs by construction
                bad.append(
                    (node.lineno, "from " + "." * node.level + " import ...")
                )
                continue
            name = node.module or ""
            if node.level == 0 and name.startswith("repro") and not (
                name == ALLOWED_PREFIX
                or name.startswith(ALLOWED_PREFIX + ".")
            ):
                bad.append((node.lineno, f"from {name} import ..."))
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="forbid repro.obs -> repro.* imports"
    )
    default_root = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src",
        "repro",
        "obs",
    )
    ap.add_argument("--root", default=default_root)
    args = ap.parse_args(argv)

    failures = 0
    files = sorted(
        os.path.join(args.root, f)
        for f in os.listdir(args.root)
        if f.endswith(".py")
    )
    if not files:
        print(f"no python files under {args.root}", file=sys.stderr)
        return 1
    for path in files:
        for lineno, desc in violations_in(path):
            print(f"{path}:{lineno}: repro.obs imports engine-side code "
                  f"({desc})", file=sys.stderr)
            failures += 1
    if failures:
        print(f"{failures} forbidden import(s): repro.obs must stay "
              "import-clean of the rest of repro", file=sys.stderr)
        return 1
    print(f"repro.obs import boundary OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
