"""CI benchmark regression gate.

Compares the freshly produced smoke-lane benchmark artifacts
(``BENCH_workload.json`` / ``BENCH_slot_kernel.json`` in the working tree)
against the *committed* baselines (read from git, default ``HEAD:<file>``)
and fails when a headline metric regresses past its tolerance band:

* ``slo_hit_rate`` fields may not drop more than 2 percentage points
  (absolute) — the scheduler's core promise;
* ``rollup.rollup_hit_rate`` may not drop more than 5 percentage points —
  the Tier-1 answer cache's core promise (hot repeats answered without
  scan rounds);
* latency percentiles (``p95_latency_s``, ``rollup.tier1_p95_latency_s``)
  may not grow more than 25% — modeled-clock latencies are deterministic
  per seed, so the band absorbs intentional policy shifts, not noise.  A
  zero baseline (tier-1 answers are scan-free, their modeled latency can
  be exactly 0) gets a small absolute ceiling instead of the vacuous
  ``0 * 1.25``;
* ``rescan.*.decoded_hit_rate`` (parse-once decoded-chunk cache) may not
  drop more than 5 percentage points, and the ASCII
  ``rescan.ascii.hot_rescan_speedup`` not more than 20% relative — the
  cache's core promise (hot re-scans skip tokenize/parse);
* ``speedup_pallas_vs_ref`` may not drop more than 20% relative — but only
  when the compiled kernel lane actually ran (see ``compiled`` below);
* peak-RSS fields may not grow more than 15% — real memory, the band
  absorbs runner-to-runner variance.

Checks are tagged ``modeled`` (deterministic Eq. (4) clock metrics —
machine-independent, always gated), ``machine`` (RSS — only comparable
when the committed baseline came from a similar runner), or ``compiled``
(compiled-pallas metrics — SKIPped, not silently absent, when the fresh
run recorded ``null`` or is ``interpret_exempt`` because only the Pallas
interpreter lane ran, e.g. off-TPU CI).  Every benchmark writes a
``fingerprint`` (CPU model, core count, python/jax versions) into its
artifact; when the baseline's fingerprint is absent or disagrees with the
fresh run's, ``machine`` checks are SKIPped instead of failing spuriously.

A metric with *no baseline yet* (new benchmark field, first PR that adds
it) is reported ``INFO`` and does not gate — adding fields must not break
unrelated PRs.  A metric present in the baseline but missing from the
fresh run still FAILs: silently dropping a gated metric is itself a
regression.

Exit code 0 = within bands (INFO/SKIP lines are reported but do not
fail); 1 = at least one regression.  ``--self-test`` proves the gate can
fail: it seeds a synthetic regression (baseline ``*_hit_rate`` bumped by
twice its band, latency/RSS shrunk 40%) against the real fresh artifacts
and exits 0 only if the comparator catches it.

Re-baselining: benchmark results are committed at the repo root, so a PR
that intentionally shifts a gated metric re-runs the smoke lanes and
commits the refreshed ``BENCH_*.json`` — the gate then compares CI's
fresh run against the new baseline.  One command does all of it::

    PYTHONPATH=src python scripts/check_bench_regression.py --update-baselines

(equivalent to ``python -m benchmarks.bench_workload --smoke --no-sched
--no-rollup``, then ``--smoke --sched-only``, then ``--smoke
--rollup-only``, then ``--smoke --chaos``, then ``--smoke --rescan``,
then ``--smoke --obs``, then ``--smoke --groups``, then ``python -m
benchmarks.bench_slot_kernel --smoke``).
See README "Re-baselining benchmarks".

Usage::

    python scripts/check_bench_regression.py [--baseline-ref HEAD]
        [--baseline-dir DIR] [--fresh-dir .] [--self-test]
        [--update-baselines]
"""

from __future__ import annotations

import argparse
import copy
import json
import os
import subprocess
import sys

WORKLOAD = "BENCH_workload.json"
KERNEL = "BENCH_slot_kernel.json"

# (file, dotted path, rule, tolerance, kind).  Rules: "abs_drop" fails when
# fresh < baseline - tol; "abs_grow" fails when fresh > baseline + tol;
# "rel_grow" fails when fresh > baseline * (1+tol) (or, for a non-positive
# baseline, fresh > REL_GROW_ZERO_CEIL); "rel_drop" fails when
# fresh < baseline * (1-tol).  Kinds: "modeled" metrics come off
# the deterministic Eq. (4) clock and gate on any runner; "machine" metrics
# (RSS) gate only when the baseline's runner fingerprint matches the fresh
# run's; "compiled" metrics exist only when the compiled pallas lane ran —
# a fresh run that recorded null (or is flagged ``interpret_exempt``: only
# the interpreter lane ran, e.g. off-TPU CI) SKIPs instead of failing,
# mirroring how fingerprint-gated machine bands degrade.
CHECKS = [
    (WORKLOAD, "sched.open_loop.scheduled.slo_hit_rate", "abs_drop", 0.02, "modeled"),
    (
        WORKLOAD,
        "sched.closed_loop.scheduled.slo_hit_rate",
        "abs_drop",
        0.02,
        "modeled",
    ),
    (
        WORKLOAD,
        "sched.closed_loop.unscheduled.slo_hit_rate",
        "abs_drop",
        0.02,
        "modeled",
    ),
    (WORKLOAD, "server.p95_latency_s", "rel_grow", 0.25, "modeled"),
    (WORKLOAD, "server_stream.p95_latency_s", "rel_grow", 0.25, "modeled"),
    (
        WORKLOAD,
        "sched.closed_loop.scheduled.p95_latency_s",
        "rel_grow",
        0.25,
        "modeled",
    ),
    (WORKLOAD, "rollup.rollup_hit_rate", "abs_drop", 0.05, "modeled"),
    (WORKLOAD, "rollup.tier1_p95_latency_s", "rel_grow", 0.25, "modeled"),
    # fault-tolerant scan plane: SLO hits under a 10% seeded transient-fault
    # rate may not drop more than 2pp, and the retried-read recovery
    # overhead (retries per hundred chunk reads at that rate) may not grow
    # more than 25% — both deterministic (fixed injector seed)
    (WORKLOAD, "chaos.slo_hit_rate_under_faults", "abs_drop", 0.02, "modeled"),
    (WORKLOAD, "chaos.recovery_overhead_pct", "rel_grow", 0.25, "modeled"),
    # parse-once decoded-chunk cache, repeated-scan lane: hot-chunk hit rate
    # may not drop more than 5pp (deterministic counters), and the ASCII
    # hot-rescan speedup — the tentpole's headline, a wall-time ratio taken
    # on one runner so it ports across machines — not more than 20%
    (WORKLOAD, "rescan.ascii.decoded_hit_rate", "abs_drop", 0.05, "modeled"),
    (WORKLOAD, "rescan.binary.decoded_hit_rate", "abs_drop", 0.05, "modeled"),
    (WORKLOAD, "rescan.ascii.hot_rescan_speedup", "rel_drop", 0.20, "modeled"),
    # grouped-query lane: the discovery plane's top-K recall (tracked cells
    # at retirement vs exact per-group totals, deterministic per seed) may
    # not drop more than 5pp, and the grouped modeled p95 latency not grow
    # more than 25%
    (WORKLOAD, "groups.topk_recall", "abs_drop", 0.05, "modeled"),
    (WORKLOAD, "groups.p95_latency_s", "rel_grow", 0.25, "modeled"),
    # observability lane: tracing overhead (traced vs untraced wall time on
    # the same runner, best-of-N, a ratio so it ports across machines) may
    # not grow more than 5 percentage points past the committed baseline —
    # the issue's <=5% instrumentation budget.  INFO until a baseline with
    # the section lands.
    (WORKLOAD, "obs.trace_overhead_pct", "abs_grow", 5.0, "modeled"),
    # compiled-kernel speedup: gates only when the compiled lane ran (TPU);
    # interpret-only runs record null and SKIP — never silently absent
    (KERNEL, "speedup_pallas_vs_ref", "rel_drop", 0.20, "compiled"),
    (WORKLOAD, "memory.peak_host_rss_bytes", "rel_grow", 0.15, "machine"),
    (KERNEL, "memory.peak_host_rss_bytes", "rel_grow", 0.15, "machine"),
]

#: Fingerprint fields that must agree for "machine" checks to gate.
#: ``platform`` is recorded but deliberately not compared — kernel build
#: strings churn without changing memory behavior.
FINGERPRINT_KEYS = ("cpu_model", "cpu_count", "python", "jax")

#: Absolute latency ceiling (modeled seconds) used by "rel_grow" when the
#: baseline is non-positive: tier-1 answers consume no scan time, so their
#: modeled p95 can be exactly 0.0 and a relative band would be vacuous.
#: Any fresh value under this ceiling is still "scan-free" territory (real
#: scan latencies in the smoke lane are >= ~1e-3 s).
REL_GROW_ZERO_CEIL = 1e-4

#: The smoke lanes whose artifacts the gate checks, in run order — the
#: single source of truth for --update-baselines (and the CI bench-smoke
#: job mirrors the same sequence).
SMOKE_LANES = [
    ["-m", "benchmarks.bench_workload", "--smoke", "--no-sched", "--no-rollup"],
    ["-m", "benchmarks.bench_workload", "--smoke", "--sched-only"],
    ["-m", "benchmarks.bench_workload", "--smoke", "--rollup-only"],
    ["-m", "benchmarks.bench_workload", "--smoke", "--chaos"],
    ["-m", "benchmarks.bench_workload", "--smoke", "--rescan"],
    ["-m", "benchmarks.bench_workload", "--smoke", "--obs"],
    ["-m", "benchmarks.bench_workload", "--smoke", "--groups"],
    ["-m", "benchmarks.bench_slot_kernel", "--smoke"],
]


def get_path(doc, dotted):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def load_baseline(name, ref, baseline_dir):
    """Baseline JSON for ``name``: from a directory when given, else from
    git (``ref:name`` — the committed artifact, untouched by the fresh
    benchmark run that overwrote the working tree).  None when absent."""
    if baseline_dir is not None:
        path = os.path.join(baseline_dir, name)
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{name}"],
            capture_output=True,
            text=True,
            check=True,
            cwd=repo,
        )
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, ValueError, OSError):
        return None


def fingerprints_match(fresh_docs, baseline_docs) -> bool:
    """True iff every artifact pair that exists on both sides carries a
    runner fingerprint agreeing on :data:`FINGERPRINT_KEYS`.  A missing
    fingerprint on either side counts as a mismatch — a baseline that
    predates fingerprinting (or a doctored one) must not silently gate
    machine-dependent bands."""
    for name, fresh_doc in fresh_docs.items():
        base_doc = baseline_docs.get(name)
        if fresh_doc is None or base_doc is None:
            continue
        fp_fresh = fresh_doc.get("fingerprint")
        fp_base = base_doc.get("fingerprint")
        if not isinstance(fp_fresh, dict) or not isinstance(fp_base, dict):
            return False
        for key in FINGERPRINT_KEYS:
            if fp_fresh.get(key) != fp_base.get(key):
                return False
    return True


def compare(fresh_docs, baseline_docs, checks=CHECKS, same_runner=True):
    """Evaluate every check; returns (failures, lines) where ``lines`` is
    the human-readable report and ``failures`` the failing subset.
    ``same_runner=False`` (fingerprint mismatch) turns "machine"-kind
    checks into SKIPs — modeled-clock checks gate regardless."""
    failures, lines = [], []
    for name, path, rule, tol, kind in checks:
        base_doc = baseline_docs.get(name)
        fresh_doc = fresh_docs.get(name)
        label = f"{name}:{path}"
        if kind == "machine" and not same_runner:
            lines.append(f"SKIP  {label}: runner fingerprint mismatch")
            continue
        if base_doc is None:
            lines.append(f"INFO  {label}: no baseline yet")
            continue
        base = get_path(base_doc, path)
        if base is None:
            lines.append(f"INFO  {label}: no baseline yet (field absent)")
            continue
        if fresh_doc is None:
            failures.append(label)
            lines.append(f"FAIL  {label}: fresh artifact missing")
            continue
        fresh = get_path(fresh_doc, path)
        if kind == "compiled" and (fresh is None
                                   or fresh_doc.get("interpret_exempt")):
            lines.append(f"SKIP  {label}: compiled lane did not run "
                         "(interpret-only / off-TPU)")
            continue
        if fresh is None:
            failures.append(label)
            lines.append(f"FAIL  {label}: dropped from the fresh run")
            continue
        base, fresh = float(base), float(fresh)
        if rule == "abs_drop":
            ok = fresh >= base - tol
            floor = base - tol
            detail = f"baseline {base:.4f} fresh {fresh:.4f} (floor {floor:.4f})"
        elif rule == "abs_grow":
            ceil = base + tol
            ok = fresh <= ceil
            detail = f"baseline {base:.4f} fresh {fresh:.4f} (ceiling {ceil:.4f})"
        elif rule == "rel_grow":
            ceil = base * (1.0 + tol) if base > 0 else REL_GROW_ZERO_CEIL
            ok = fresh <= ceil
            detail = f"baseline {base:.6g} fresh {fresh:.6g} (ceiling {ceil:.6g})"
        elif rule == "rel_drop":
            floor = base * (1.0 - tol)
            ok = fresh >= floor
            detail = f"baseline {base:.6g} fresh {fresh:.6g} (floor {floor:.6g})"
        else:  # pragma: no cover - spec typo guard
            raise ValueError(f"unknown rule {rule!r}")
        if ok:
            lines.append(f"OK    {label}: {detail}")
        else:
            failures.append(label)
            lines.append(f"FAIL  {label}: {detail}")
    return failures, lines


def seeded_regression(fresh_docs):
    """Synthesize a baseline the fresh artifacts must FAIL against: every
    gated hit-rate bumped by *twice its band* (so the fresh value lands
    strictly below the floor, whatever the band), every gated abs_grow
    metric lowered by twice its band (the fresh value overshoots the
    ceiling), every gated rel_drop metric doubled, every gated latency/RSS
    shrunk 40%.  Used by
    --self-test to prove the comparator has teeth.  A zero-valued rel_grow
    leaf cannot be seeded (no baseline makes a fresh 0 exceed a grow
    ceiling) and is left alone, as is a null compiled-lane leaf (the fresh
    null SKIPs by design)."""
    out = {}
    for name, doc in fresh_docs.items():
        if doc is None:
            continue
        doc = copy.deepcopy(doc)
        for cname, path, rule, tol, _kind in CHECKS:
            if cname != name:
                continue
            parts = path.split(".")
            parent = get_path(doc, ".".join(parts[:-1])) if parts[:-1] else doc
            leaf = parts[-1]
            if not isinstance(parent, dict) or parent.get(leaf) is None:
                continue
            if rule == "abs_drop":
                parent[leaf] = float(parent[leaf]) + 2.0 * tol
            elif rule == "abs_grow":
                parent[leaf] = float(parent[leaf]) - 2.0 * tol
            elif rule == "rel_drop":
                if float(parent[leaf]) > 0:
                    parent[leaf] = float(parent[leaf]) * 2.0
            elif float(parent[leaf]) > 0:
                parent[leaf] = float(parent[leaf]) * 0.6
        out[name] = doc
    return out


def update_baselines(runner=subprocess.run) -> int:
    """Re-run every gated smoke lane and rewrite the BENCH_*.json
    baselines in place (the one-command re-baselining flow).  ``runner``
    is injectable for tests.  Returns a process exit code."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(repo, "src"), env.get("PYTHONPATH")] if p
    )
    for lane in SMOKE_LANES:
        cmd = [sys.executable, *lane]
        print(f"[update-baselines] {' '.join(lane)}")
        proc = runner(cmd, cwd=repo, env=env)
        code = getattr(proc, "returncode", 0)
        if code != 0:
            print(f"[update-baselines] lane failed (exit {code})", file=sys.stderr)
            return code
    print(
        f"[update-baselines] refreshed {WORKLOAD} and {KERNEL}; "
        "review and `git add` them to commit the new baselines"
    )
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="benchmark regression gate")
    ap.add_argument(
        "--baseline-ref",
        default="HEAD",
        help="git ref holding the committed baselines",
    )
    ap.add_argument(
        "--baseline-dir",
        default=None,
        help="read baselines from a directory instead of git",
    )
    ap.add_argument(
        "--fresh-dir",
        default=".",
        help="directory holding the fresh BENCH_*.json files",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="seed a synthetic regression and require the gate to catch it",
    )
    ap.add_argument(
        "--update-baselines",
        action="store_true",
        help="re-run all gated smoke lanes and rewrite the committed "
        "BENCH_*.json baselines in place",
    )
    args = ap.parse_args(argv)

    if args.update_baselines:
        return update_baselines()

    names = sorted({c[0] for c in CHECKS})
    fresh_docs = {}
    for name in names:
        try:
            with open(os.path.join(args.fresh_dir, name)) as f:
                fresh_docs[name] = json.load(f)
        except (OSError, ValueError):
            fresh_docs[name] = None

    if args.self_test:
        seeded = seeded_regression(fresh_docs)
        if not seeded:
            print("self-test: no fresh artifacts to seed from", file=sys.stderr)
            return 1
        failures, lines = compare(fresh_docs, seeded)
        print("\n".join(lines))
        if failures:
            print(f"self-test OK: caught {len(failures)} seeded regression(s)")
            return 0
        print("self-test FAILED: gate passed a seeded regression", file=sys.stderr)
        return 1

    baseline_docs = {
        name: load_baseline(name, args.baseline_ref, args.baseline_dir)
        for name in names
    }
    same_runner = fingerprints_match(fresh_docs, baseline_docs)
    if not same_runner:
        print(
            "runner fingerprint mismatch vs baseline: machine-dependent "
            "checks (RSS) will be skipped; modeled-clock checks still gate"
        )
    failures, lines = compare(fresh_docs, baseline_docs, same_runner=same_runner)
    print("\n".join(lines))
    if failures:
        print(
            f"{len(failures)} benchmark regression(s); see README "
            "'Re-baselining benchmarks' if the shift is intentional",
            file=sys.stderr,
        )
        return 1
    print("benchmarks within tolerance bands")
    return 0


if __name__ == "__main__":
    sys.exit(main())
