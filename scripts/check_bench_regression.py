"""CI benchmark regression gate.

Compares the freshly produced smoke-lane benchmark artifacts
(``BENCH_workload.json`` / ``BENCH_slot_kernel.json`` in the working tree)
against the *committed* baselines (read from git, default ``HEAD:<file>``)
and fails when a headline metric regresses past its tolerance band:

* ``slo_hit_rate`` fields may not drop more than 2 percentage points
  (absolute) — the scheduler's core promise;
* latency percentiles (``p95_latency_s``) may not grow more than 25% —
  modeled-clock latencies are deterministic per seed, so the band absorbs
  intentional policy shifts, not noise;
* peak-RSS fields may not grow more than 15% — real memory, the band
  absorbs runner-to-runner variance.

Exit code 0 = within bands (skipped checks are reported but do not fail);
1 = at least one regression.  ``--self-test`` proves the gate can fail: it
seeds a synthetic regression (baseline ``slo_hit_rate`` bumped +5pp /
latency shrunk) against the real fresh artifacts and exits 0 only if the
comparator catches it.

Re-baselining: benchmark results are committed at the repo root, so a PR
that intentionally shifts a gated metric re-runs the smoke lanes locally
(``python -m benchmarks.bench_workload --smoke --no-sched``, then
``--sched-only``, then ``python -m benchmarks.bench_slot_kernel --smoke``)
and commits the refreshed ``BENCH_*.json`` — the gate then compares CI's
fresh run against the new baseline.  See README "Re-baselining benchmarks".

Usage::

    python scripts/check_bench_regression.py [--baseline-ref HEAD]
        [--baseline-dir DIR] [--fresh-dir .] [--self-test]
"""

from __future__ import annotations

import argparse
import copy
import json
import os
import subprocess
import sys

WORKLOAD = "BENCH_workload.json"
KERNEL = "BENCH_slot_kernel.json"

# (file, dotted path, rule, tolerance).  Rules: "abs_drop" fails when
# fresh < baseline - tol; "rel_grow" fails when fresh > baseline * (1+tol).
# Paths missing from the baseline are skipped (older baselines predate some
# fields); paths present in the baseline but missing from the fresh run
# fail — a silently dropped metric is itself a regression.
CHECKS = [
    (WORKLOAD, "sched.open_loop.scheduled.slo_hit_rate", "abs_drop", 0.02),
    (WORKLOAD, "sched.closed_loop.scheduled.slo_hit_rate", "abs_drop", 0.02),
    (WORKLOAD, "sched.closed_loop.unscheduled.slo_hit_rate", "abs_drop", 0.02),
    (WORKLOAD, "server.p95_latency_s", "rel_grow", 0.25),
    (WORKLOAD, "server_stream.p95_latency_s", "rel_grow", 0.25),
    (WORKLOAD, "sched.closed_loop.scheduled.p95_latency_s", "rel_grow", 0.25),
    (WORKLOAD, "memory.peak_host_rss_bytes", "rel_grow", 0.15),
    (KERNEL, "memory.peak_host_rss_bytes", "rel_grow", 0.15),
]


def get_path(doc, dotted):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def load_baseline(name, ref, baseline_dir):
    """Baseline JSON for ``name``: from a directory when given, else from
    git (``ref:name`` — the committed artifact, untouched by the fresh
    benchmark run that overwrote the working tree).  None when absent."""
    if baseline_dir is not None:
        path = os.path.join(baseline_dir, name)
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{name}"],
            capture_output=True,
            text=True,
            check=True,
            cwd=repo,
        )
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, ValueError, OSError):
        return None


def compare(fresh_docs, baseline_docs, checks=CHECKS):
    """Evaluate every check; returns (failures, lines) where ``lines`` is
    the human-readable report and ``failures`` the failing subset."""
    failures, lines = [], []
    for name, path, rule, tol in checks:
        base_doc = baseline_docs.get(name)
        fresh_doc = fresh_docs.get(name)
        label = f"{name}:{path}"
        if base_doc is None:
            lines.append(f"SKIP  {label}: no baseline")
            continue
        base = get_path(base_doc, path)
        if base is None:
            lines.append(f"SKIP  {label}: field absent in baseline")
            continue
        if fresh_doc is None:
            failures.append(label)
            lines.append(f"FAIL  {label}: fresh artifact missing")
            continue
        fresh = get_path(fresh_doc, path)
        if fresh is None:
            failures.append(label)
            lines.append(f"FAIL  {label}: dropped from the fresh run")
            continue
        base, fresh = float(base), float(fresh)
        if rule == "abs_drop":
            ok = fresh >= base - tol
            floor = base - tol
            detail = f"baseline {base:.4f} fresh {fresh:.4f} (floor {floor:.4f})"
        elif rule == "rel_grow":
            if base <= 0:
                lines.append(f"SKIP  {label}: non-positive baseline {base}")
                continue
            ceil = base * (1.0 + tol)
            ok = fresh <= ceil
            detail = f"baseline {base:.6g} fresh {fresh:.6g} (ceiling {ceil:.6g})"
        else:  # pragma: no cover - spec typo guard
            raise ValueError(f"unknown rule {rule!r}")
        if ok:
            lines.append(f"OK    {label}: {detail}")
        else:
            failures.append(label)
            lines.append(f"FAIL  {label}: {detail}")
    return failures, lines


def seeded_regression(fresh_docs):
    """Synthesize a baseline the fresh artifacts must FAIL against: every
    gated slo_hit_rate bumped +5pp, every gated latency/RSS shrunk 40%.
    Used by --self-test to prove the comparator has teeth."""
    out = {}
    for name, doc in fresh_docs.items():
        if doc is None:
            continue
        doc = copy.deepcopy(doc)
        for cname, path, rule, _tol in CHECKS:
            if cname != name:
                continue
            parts = path.split(".")
            parent = get_path(doc, ".".join(parts[:-1])) if parts[:-1] else doc
            leaf = parts[-1]
            if not isinstance(parent, dict) or parent.get(leaf) is None:
                continue
            if rule == "abs_drop":
                parent[leaf] = float(parent[leaf]) + 0.05
            else:
                parent[leaf] = float(parent[leaf]) * 0.6
        out[name] = doc
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="benchmark regression gate")
    ap.add_argument(
        "--baseline-ref",
        default="HEAD",
        help="git ref holding the committed baselines",
    )
    ap.add_argument(
        "--baseline-dir",
        default=None,
        help="read baselines from a directory instead of git",
    )
    ap.add_argument(
        "--fresh-dir",
        default=".",
        help="directory holding the fresh BENCH_*.json files",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="seed a synthetic regression and require the gate to catch it",
    )
    args = ap.parse_args(argv)

    names = sorted({c[0] for c in CHECKS})
    fresh_docs = {}
    for name in names:
        try:
            with open(os.path.join(args.fresh_dir, name)) as f:
                fresh_docs[name] = json.load(f)
        except (OSError, ValueError):
            fresh_docs[name] = None

    if args.self_test:
        seeded = seeded_regression(fresh_docs)
        if not seeded:
            print("self-test: no fresh artifacts to seed from", file=sys.stderr)
            return 1
        failures, lines = compare(fresh_docs, seeded)
        print("\n".join(lines))
        if failures:
            print(f"self-test OK: caught {len(failures)} seeded regression(s)")
            return 0
        print("self-test FAILED: gate passed a seeded regression", file=sys.stderr)
        return 1

    baseline_docs = {
        name: load_baseline(name, args.baseline_ref, args.baseline_dir)
        for name in names
    }
    failures, lines = compare(fresh_docs, baseline_docs)
    print("\n".join(lines))
    if failures:
        print(
            f"{len(failures)} benchmark regression(s); see README "
            "'Re-baselining benchmarks' if the shift is intentional",
            file=sys.stderr,
        )
        return 1
    print("benchmarks within tolerance bands")
    return 0


if __name__ == "__main__":
    sys.exit(main())
