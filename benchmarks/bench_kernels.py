"""EXTRACT hot-spot microbenchmarks.

Times the production CPU path (pure-jnp oracle compiled by XLA — what the
engine executes on this host) for the three kernels, and reports the
interpret-mode Pallas checksum agreement.  TPU wall-times come from the
target hardware; on CPU the value of the Pallas kernels is validated
semantics + the VMEM-tiled structure the dry-run lowers.
"""

from __future__ import annotations

import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.queries import Linear, Query, Range, TRUE, linear_plan
from repro.data.formats import AsciiFixedFormat
from repro.kernels import chunk_agg, extract_parse, round_stats


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(fast: bool = False) -> str:
    c = 8
    t = 4096 if fast else 16384
    fmt = AsciiFixedFormat(c)
    rng = np.random.default_rng(0)
    vals = rng.uniform(-1e6, 1e6, (t, c))
    raw = jnp.asarray(fmt.encode(vals))
    qs = [Query(agg="sum", expr=Linear((1.0,) * c), pred=Range(0, -1e5, 1e5)),
          Query(agg="count", pred=TRUE)]
    plan = linear_plan(qs, c)

    out = {}
    t_parse = _time(lambda r: extract_parse(r, c, backend="ref"), raw)
    out["extract_parse"] = {
        "us_per_call": round(t_parse * 1e6, 1),
        "mtuples_per_s": round(t / t_parse / 1e6, 2),
    }

    n = 8
    m = t // n
    raw3 = jnp.asarray(np.stack([fmt.encode(vals[i * m:(i + 1) * m])
                                 for i in range(n)]))
    sizes = jnp.full((n,), m, jnp.int32)
    t_agg = _time(lambda r: chunk_agg(r, sizes, plan.coeffs, plan.lo, plan.hi,
                                      backend="ref"), raw3)
    out["chunk_agg"] = {"us_per_call": round(t_agg * 1e6, 1),
                        "mtuples_per_s": round(t / t_agg / 1e6, 2)}

    w, b = 8, 256
    slab = jnp.asarray(np.stack([fmt.encode(vals[i * b:(i + 1) * b])
                                 for i in range(w)]))
    beff = jnp.full((w,), b, jnp.int32)
    t_rs = _time(lambda s: round_stats(s, beff, plan.coeffs, plan.lo, plan.hi,
                                       backend="ref"), slab)
    out["round_stats"] = {"us_per_call": round(t_rs * 1e6, 1),
                          "mtuples_per_s": round(w * b / t_rs / 1e6, 2)}

    # pallas interpret-mode agreement (semantics checksum)
    a = extract_parse(raw[:256], c, backend="pallas")
    r = extract_parse(raw[:256], c, backend="ref")
    out["pallas_interpret_max_err"] = float(jnp.max(jnp.abs(a - r)))

    with open("results/bench_kernels.json", "w") as f:
        json.dump(out, f, indent=1)
    return json.dumps(out)
