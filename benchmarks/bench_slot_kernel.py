"""Round-step extraction throughput: ref vs pallas vs pallas-interpret.

Times one engine round step (gather + parse + slot eval + merge) of the
slot-table plane over the default synthetic table, sweeping the slot count S
and the per-worker tuple budget B.  Headline metrics are tuples/s and bytes/s
of raw extraction per round step — the system's scarce resource.

Backends:

* ``ref``              — the decode_ref + ``slot_evaluate`` composition
                         (materializes the (S, W, B) eval tensor);
* ``pallas``           — the fused ``kernels/slot_extract.py`` kernel,
                         compiled (TPU only — skipped off-TPU);
* ``pallas-interpret`` — the same kernel under the Pallas interpreter
                         (correctness mode; numbers reported for visibility
                         but exempt from any speedup bar).

The acceptance bar — fused pallas ≥ 2× ref round-step throughput at
S=8, B=256 — applies to the *compiled* kernel; off-TPU the result file
records ``speedup_pallas_vs_ref: null`` with ``interpret_exempt: true``.

The ``calibration`` block (measured aggregate extraction tuples/s of the
production backend plus measured raw-read bytes/s) is what
``repro.serve.ola_server.load_measured_rates`` feeds into the Eq. (4) plan
selector in place of the modeled constants.  It also records the linear fit
of the S sweep — ``round_us(S) = round_base_us + round_slot_us · S`` — from
which the workload scheduler derives its *measured* per-round slot capacity
(``repro.sched.fairness.measured_slot_capacity``): the base term is the
scan-side cost of one round, the slope the marginal cost of one
fully-counted slot evaluation.

Results land in ``BENCH_slot_kernel.json`` at the repo root.

Standalone:  PYTHONPATH=src python -m benchmarks.bench_slot_kernel [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.engine import EngineConfig, SlotOLAEngine, _Collectives
from repro.core.queries import (
    Linear,
    Query,
    Range,
    empty_slot_table,
    encode_slot,
    slot_table_set,
)
from repro.data.generator import make_synthetic_zipf, store_dataset

NUM_COLS = 8
WORKERS = 8


def _slot_table(s: int, seed: int = 1):
    """S active linear+range slots with varied selectivities."""
    rng = np.random.default_rng(seed)
    coeffs = tuple(1.0 / (k + 1) for k in range(NUM_COLS))
    table = empty_slot_table(s, NUM_COLS)
    for i in range(s):
        q = Query(agg=("sum", "count", "avg")[i % 3], expr=Linear(coeffs),
                  pred=Range(i % NUM_COLS, 0.0,
                             float(rng.uniform(0.3, 1.0)) * 1e8),
                  epsilon=0.05, name=f"s{i}")
        table = slot_table_set(table, i, encode_slot(q, NUM_COLS))
    return table


def _make_step(engine: SlotOLAEngine, b: int):
    """Non-donating jitted round step (state is reused across timing reps)."""
    coll = _Collectives()

    def step(state, table, packed, speeds):
        return engine.program.round_body(state, packed, speeds, b, coll,
                                         slots=table)

    return jax.jit(step)


def _time_round_step(store, backend: str, s: int, b: int, iters: int):
    # backend is a valid EngineConfig.extract_backend value; in particular
    # "pallas-interpret" forces the Pallas interpreter even on TPU, keeping
    # the three lanes distinct there
    cfg = EngineConfig(num_workers=WORKERS, budget_init=b, budget_min=b,
                       budget_max=b, seed=7, extract_backend=backend)
    engine = SlotOLAEngine(store, s, cfg)
    table = _slot_table(s)
    state0 = engine.init_state()
    step = _make_step(engine, b)
    # one round advances claims so every worker holds a chunk; time from there
    state, rep = step(state0, table, engine.packed, engine.speeds)
    jax.block_until_ready(rep)
    tuples_round = float(rep.tuples_round)
    t0 = time.perf_counter()
    for _ in range(iters):
        _, rep = step(state, table, engine.packed, engine.speeds)
    jax.block_until_ready(rep)
    dt = (time.perf_counter() - t0) / iters
    tuples_round = max(float(rep.tuples_round), tuples_round)
    return {
        "backend": backend, "S": s, "B": b,
        "us_per_round": round(dt * 1e6, 1),
        "tuples_per_round": int(tuples_round),
        "tuples_per_sec": round(tuples_round / dt, 1),
        "bytes_per_sec": round(
            tuples_round * store.codec.record_bytes / dt, 1),
    }


def _round_cost_fit(entries, backend: str, b: int) -> tuple:
    """Least-squares fit ``round_us(S) = base + slot_us·S`` over the S sweep
    of one ``(backend, B)`` lane — the scheduler's measured-capacity input.
    Returns ``(base_us, slot_us)``, or ``(0.0, 0.0)`` when the sweep has
    fewer than two S points or the fit is degenerate (non-positive base or
    slope: timing noise measured extra slots as free)."""
    pts = sorted({(e["S"], e["us_per_round"]) for e in entries
                  if e["backend"] == backend and e["B"] == b})
    if len(pts) < 2:
        return 0.0, 0.0
    s = np.asarray([p[0] for p in pts], float)
    us = np.asarray([p[1] for p in pts], float)
    slot_us, base_us = np.polyfit(s, us, 1)
    if not (np.isfinite(base_us) and np.isfinite(slot_us)
            and base_us > 0.0 and slot_us > 0.0):
        return 0.0, 0.0
    return float(base_us), float(slot_us)


def _measure_read_bw(store, iters: int = 5) -> float:
    """Raw READ bandwidth proxy: a full reduction over the packed device
    buffer (the chunks are memory-resident — the NoDB cache — so READ is
    memory traffic, not disk)."""
    packed, _ = store.packed_device_view()
    import jax.numpy as jnp

    buf = jnp.asarray(packed)
    red = jax.jit(lambda x: jnp.sum(x.astype(jnp.uint32)))
    jax.block_until_ready(red(buf))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = red(buf)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / iters
    return buf.size / dt


def run(fast: bool = False, smoke: bool = False) -> str:
    if smoke:
        t, chunks, iters = 2048, 8, 2
        s_sweep, b_sweep = [4, 8], [64, 256]
    elif fast:
        t, chunks, iters = 8192, 16, 3
        s_sweep, b_sweep = [1, 8], [64, 256]
    else:
        t, chunks, iters = 32768, 32, 5
        s_sweep, b_sweep = [1, 8, 32], [64, 256, 1024]
    store = store_dataset(make_synthetic_zipf(t, NUM_COLS, seed=0), chunks,
                          "ascii")
    on_tpu = jax.default_backend() == "tpu"
    backends = ["ref", "pallas-interpret"] + (["pallas"] if on_tpu else [])

    entries = []
    for s in s_sweep:
        for b in b_sweep:
            for be in backends:
                e = _time_round_step(store, be, s, b, iters)
                entries.append(e)
                print(f"[bench_slot_kernel] {be:16s} S={s:3d} B={b:5d}  "
                      f"{e['us_per_round']:10.1f} us/round  "
                      f"{e['tuples_per_sec']:12.0f} tuples/s")

    def _at(be, s, b):
        for e in entries:
            if (e["backend"], e["S"], e["B"]) == (be, s, b):
                return e
        return None

    s_bar = 8 if 8 in s_sweep else s_sweep[-1]
    b_bar = 256 if 256 in b_sweep else b_sweep[-1]
    ref_bar = _at("ref", s_bar, b_bar)
    pallas_bar = _at("pallas", s_bar, b_bar)
    interp_bar = _at("pallas-interpret", s_bar, b_bar)
    speedup = (round(pallas_bar["tuples_per_sec"] / ref_bar["tuples_per_sec"],
                     3) if pallas_bar else None)

    from benchmarks.common import memory_report, runner_fingerprint

    io_bps = _measure_read_bw(store)
    # calibration uses the production backend for this platform: the compiled
    # kernel on TPU, the XLA ref path elsewhere (interpret is a debug mode)
    cal_entry = pallas_bar if on_tpu and pallas_bar else ref_bar
    base_us, slot_us = _round_cost_fit(entries, cal_entry["backend"], b_bar)
    out = {
        "platform": jax.default_backend(),
        "workers": WORKERS,
        "table_tuples": t,
        "record_bytes": store.codec.record_bytes,
        "S_sweep": s_sweep,
        "B_sweep": b_sweep,
        "entries": entries,
        "speedup_pallas_vs_ref": speedup,
        "speedup_interpret_vs_ref": round(
            interp_bar["tuples_per_sec"] / ref_bar["tuples_per_sec"], 3),
        "interpret_exempt": not on_tpu,
        "memory": memory_report(),
        "fingerprint": runner_fingerprint(),
        "calibration": {
            "backend": cal_entry["backend"],
            "S": cal_entry["S"], "B": cal_entry["B"],
            "workers": WORKERS,
            "cpu_tuples_per_sec": cal_entry["tuples_per_sec"],
            "io_bytes_per_sec": round(io_bps, 1),
            # extraction cost of the calibration codec: lets select_plan
            # rescale the tuple rate when serving a different codec
            "cost_per_tuple": float(store.codec.extract_cost_per_tuple()),
            # S-sweep round-cost fit: round_us(S) = base + slot_us·S.  Feeds
            # the scheduler's measured slot capacity; 0.0 = fit unavailable
            "round_base_us": round(base_us, 1),
            "round_slot_us": round(slot_us, 2),
        },
    }
    from benchmarks.common import bench_output_paths

    for path in bench_output_paths("slot_kernel"):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
    print(f"[bench_slot_kernel] calibration: "
          f"{out['calibration']['cpu_tuples_per_sec']:.0f} tuples/s "
          f"({out['calibration']['backend']}), "
          f"read {io_bps / 1e9:.2f} GB/s")
    return json.dumps({
        "speedup_pallas_vs_ref": speedup,
        "interpret_exempt": out["interpret_exempt"],
        "ref_tuples_per_sec": ref_bar["tuples_per_sec"],
        "cal_tuples_per_sec": out["calibration"]["cpu_tuples_per_sec"],
    })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for the CI bench-smoke step")
    args = ap.parse_args()
    run(fast=args.fast, smoke=args.smoke)


if __name__ == "__main__":
    main()
