"""Shared helpers for the benchmark modules."""

from __future__ import annotations

import numpy as np

from repro.core.engine import EngineConfig, OLAEngine
from repro.core.queries import Linear, Query, Range, TRUE
from repro.data.generator import (
    make_ptf_like, make_synthetic_zipf, make_wiki_like, store_dataset,
)

SYN_COEF16 = tuple(1.0 / (k + 1) for k in range(16))
PTF_COEF = (0.0, 0.0, 0.0, 1.0, 2.0, 1.5, 0.0, 0.0)  # mag/err/flux expression


def bench_output_paths(name: str) -> tuple:
    """Result-file path(s) anchored to the repo root, not the process CWD —
    the server's ``default_rates_path`` reads from the same anchor, so the
    calibration round-trips no matter where either process was started.
    ``BENCH_<name>.json`` at the root is the single canonical artifact (the
    committed baseline the CI gate diffs against); the old
    ``results/bench_<name>.json`` mirror is gone — it was gitignored, went
    stale the moment a lane ran from another CWD, and nothing read it."""
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return (os.path.join(root, f"BENCH_{name}.json"),)


def runner_fingerprint() -> dict:
    """Identity of the machine/toolchain a benchmark ran on — written into
    every BENCH_*.json so the regression gate (``scripts/
    check_bench_regression.py``) can tell whether a committed baseline came
    from a comparable runner.  Machine-dependent checks (RSS) are skipped on
    mismatch instead of failing spuriously; the Eq. (4) modeled-clock
    metrics are machine-independent and stay gated regardless."""
    import os
    import platform

    cpu_model = None
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    cpu_model = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    if not cpu_model:
        cpu_model = platform.processor() or platform.machine()
    import jax

    return {
        "cpu_model": cpu_model,
        "cpu_count": os.cpu_count(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "platform": platform.platform(),
    }


def memory_report() -> dict:
    """Peak host RSS + resident device bytes for BENCH_*.json outputs.

    ``device_raw_bytes`` counts only uint8 arrays — the packed views / slabs
    whose footprint the streaming residency bounds; ``device_total_bytes``
    adds the f32 state pytrees."""
    from repro.data.pipeline import device_resident_bytes, peak_host_rss_bytes

    return {
        "peak_host_rss_bytes": peak_host_rss_bytes(),
        "device_raw_bytes": device_resident_bytes(np.uint8),
        "device_total_bytes": device_resident_bytes(),
    }


def latency_stats(results) -> dict:
    """Latency percentiles + SLO-hit rate for BENCH_*.json outputs.

    ``results`` are :class:`~repro.serve.ola_server.WorkloadResult`\\ s.
    ``slo_hit_rate`` averages over the queries that carried an SLO
    (``slo_met is not None``); it is ``None`` when none did.  Outcome counts
    split scan-served answers from queued/shed ones.
    """
    lat = np.asarray([r.latency for r in results], float)
    out = {
        "p50_latency_s": float(np.percentile(lat, 50)) if len(lat) else None,
        "p95_latency_s": float(np.percentile(lat, 95)) if len(lat) else None,
        "p99_latency_s": float(np.percentile(lat, 99)) if len(lat) else None,
        "mean_latency_s": float(lat.mean()) if len(lat) else None,
        "mean_queue_wait_s": float(np.mean([r.queue_wait for r in results]))
        if results else None,
        "outcomes": {
            k: sum(r.sched_outcome == k for r in results)
            for k in ("admitted", "queued", "preempted", "shed", "tier1")},
    }
    hits = [r.slo_met for r in results if r.slo_met is not None]
    out["slo_hit_rate"] = float(np.mean(hits)) if hits else None
    return out


def latency_stats_by_class(results) -> dict:
    """Per-priority-class latency percentiles + SLO-hit rate.

    Groups :class:`~repro.serve.ola_server.WorkloadResult`\\ s by their
    ``priority`` field (the SLO class) — the per-class p99-vs-offered-load
    curves in ``bench_workload``'s full lane are built from this.  Classes
    with no queries are simply absent.
    """
    by: dict = {}
    for r in results:
        by.setdefault(r.priority, []).append(r)
    return {cls: latency_stats(rs) for cls, rs in sorted(by.items())}


def trace_summary(tracer) -> dict:
    """Compact per-span-name summary of a :class:`~repro.obs.trace.
    SpanTracer` buffer for BENCH_*.json artifacts: event/drop counts,
    per-name span counts with total seconds, and any chrome-trace schema
    problems the validator found (empty list = valid)."""
    from repro.obs.trace import validate_chrome_trace

    doc = tracer.to_chrome_trace()
    spans: dict = {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X":
            continue
        d = spans.setdefault(ev["name"], {"count": 0, "total_s": 0.0})
        d["count"] += 1
        d["total_s"] += float(ev.get("dur", 0.0)) / 1e6
    return {
        "events": len(doc["traceEvents"]),
        "dropped": int(getattr(tracer, "dropped", 0)),
        "spans": spans,
        "schema_problems": validate_chrome_trace(doc),
    }


def datasets(fast: bool):
    t = 8192 if fast else 16384
    chunks = 32 if fast else 64
    out = {
        "synthetic": store_dataset(make_synthetic_zipf(t, 16, 0), chunks,
                                   "ascii"),
        "ptf-ascii": store_dataset(make_ptf_like(t, chunks, 0), chunks,
                                   "ascii"),
        "ptf-binary": store_dataset(make_ptf_like(t, chunks, 0), chunks,
                                    "binary"),
    }
    w, _ = make_wiki_like(t, 30, 0)
    out["wiki"] = store_dataset(w, max(chunks // 3, 8), "ascii")
    return out


def selectivity_query(dataset: str, selectivity: float,
                      epsilon: float = 0.05) -> Query:
    if dataset.startswith("ptf"):
        # range predicate on ra (col 0) covering x% of [0, 360)
        return Query(agg="sum", expr=Linear(PTF_COEF),
                     pred=Range(0, 0.0, 360.0 * selectivity) if selectivity < 1
                     else TRUE, epsilon=epsilon)
    if dataset == "wiki":
        # per-language count: language 0 is 'en'
        return Query(agg="count", pred=Range(0, -0.5, 0.5), epsilon=epsilon)
    return Query(agg="sum", expr=Linear(SYN_COEF16),
                 pred=Range(0, 0.0, 1e8 * selectivity) if selectivity < 1
                 else TRUE, epsilon=epsilon)


def run_curve(store, query: Query, strategy: str, workers: int,
              seed: int = 0, max_rounds: int = 20000):
    """-> (times, errs, final) with the Eq. 4 modeled clock."""
    eng = OLAEngine(store, [query],
                    EngineConfig(num_workers=workers, strategy=strategy,
                                 budget_init=64, seed=seed))
    state = eng.init_state()
    times, errs = [], []
    rep = None
    for _ in range(max_rounds):
        b = eng.budget_ladder(float(state.budget))
        state, rep = eng.round_fn(b)(state, eng.packed, eng.speeds)
        # Eq. 4: READ and EXTRACT are overlapped pipelines — wall time is
        # the max of the cumulative busy times, not a per-round barrier
        times.append(max(float(state.t_io), float(state.t_cpu)))
        errs.append(float(rep.err[0]))
        if bool(rep.all_stopped) or bool(rep.exhausted):
            break
    t = times[-1] if times else 0.0
    return np.asarray(times), np.asarray(errs), {
        "t_model": t,
        "tuples_ratio": float(int(rep.m_tuples) / eng.program.total_tuples),
        "chunks_ratio": float(np.asarray(state.raw_touched).sum()
                              / eng.program.n_chunks),
        "estimate": float(rep.estimate[0]),
        "stopped": bool(rep.all_stopped),
    }


def ext_baseline_time(store, workers: int,
                      io_bps: float = 565e6, cpu_ops: float = 2.0e9) -> float:
    """External tables: exact answer = one full sequential scan (Eq. 4)."""
    total_bytes = float(store.chunk_sizes.sum()) * store.codec.record_bytes
    total_tuples = float(store.num_tuples)
    t_io = total_bytes / io_bps
    t_cpu = total_tuples * store.codec.extract_cost_per_tuple() / cpu_ops / workers
    return max(t_io, t_cpu)
