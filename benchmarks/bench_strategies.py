"""Paper Fig. 11: holistic (H) / single-pass (S) / resource-aware (BI) /
chunk-level (C) on the synthetic dataset, 1 / 4 / 16 workers, no selectivity.

Validation targets (paper §7.2.2): in CPU-bound settings (few workers,
ASCII) S and BI reduce error fastest; with many workers (IO-bound) BI
degenerates to C/H behaviour while S is worst (stops sampling too early);
BI is always (nearly) the best strategy — the adaptive headline.
"""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import datasets, run_curve, selectivity_query


def run(fast: bool = False) -> str:
    store = datasets(fast)["synthetic"]
    q = selectivity_query("synthetic", 1.0, epsilon=0.03)
    workers_list = [1, 4] if fast else [1, 4, 16]
    table = {}
    for workers in workers_list:
        per = {}
        for strat, tag in (("holistic", "H"), ("single_pass", "S"),
                           ("resource_aware", "BI"), ("chunk_level", "C")):
            times, errs, final = run_curve(store, q, strat, workers, seed=11)
            per[tag] = {"t_model": round(final["t_model"], 6),
                        "tuples_ratio": round(final["tuples_ratio"], 4),
                        "chunks_ratio": round(final["chunks_ratio"], 4)}
        table[f"{workers}w"] = per
    with open("results/bench_strategies.json", "w") as f:
        json.dump(table, f, indent=1)

    # adaptivity check: BI within 1.3x of the best strategy at every width
    ok = all(
        per["BI"]["t_model"] <= 1.3 * min(v["t_model"] for v in per.values())
        for per in table.values())
    return json.dumps({"BI_always_near_best": ok, "table": table})
