"""Paper Fig. 12-13: sample synopsis across query sequences.

Ten queries: five accuracy levels, each run twice — increasing (Fig. 12) and
decreasing (Fig. 13) — under two synopsis budgets (small/large).  Validation
targets: repeats are answered (mostly) from the synopsis; the large budget
answers the decreasing sequence entirely in memory after the first query;
the paper's headline is >10x sequence speedup from a <1%-of-data synopsis.
"""

from __future__ import annotations

import json

from benchmarks.common import datasets
from repro.core.controller import EstimationController
from repro.core.engine import EngineConfig
from repro.core.queries import Linear, Query

from benchmarks.common import SYN_COEF16


def _sequence(store, budgets, eps_list, fast):
    out = {}
    for budget in budgets:
        ctrl = EstimationController(
            store, EngineConfig(num_workers=4, strategy="resource_aware",
                                budget_init=64, seed=5),
            synopsis_budget_tuples=budget)
        rows = []
        for eps in eps_list:
            for rep in range(2):
                q = Query(agg="sum", expr=Linear(SYN_COEF16), epsilon=eps)
                r = ctrl.run_query([q], max_rounds=30000)
                rows.append({"eps": eps, "rep": rep,
                             "t_model": round(r.t_model_total, 6),
                             "tuples_ratio": round(r.tuples_ratio, 4),
                             "chunks_raw": round(r.chunks_ratio, 4),
                             "from_synopsis": r.from_synopsis})
        out[f"budget_{budget}"] = rows
    return out


def run(fast: bool = False) -> str:
    store = datasets(fast)["synthetic"]
    total = store.num_tuples
    budgets = [total // 32, total // 2]      # small (~3%) vs large (50%):
    # the paper's small/large split — large holds everything the most
    # accurate query of the sequence ever extracts
    eps_up = [0.20, 0.10, 0.05, 0.03, 0.02]
    result = {
        "increasing": _sequence(store, budgets, eps_up, fast),
        "decreasing": _sequence(store, budgets, list(reversed(eps_up)), fast),
    }
    with open("results/bench_synopsis.json", "w") as f:
        json.dump(result, f, indent=1)

    # headline: sequence speedup of later queries vs the first (large budget,
    # decreasing accuracy — the paper's best case)
    rows = result["decreasing"][f"budget_{budgets[1]}"]
    first = rows[0]["t_model"]
    rest = sum(r["t_model"] for r in rows[1:]) / max(len(rows) - 1, 1)
    synopsis_hits = sum(r["from_synopsis"] for r in rows[1:])
    return json.dumps({
        "first_query_t": round(first, 6),
        "mean_later_t": round(rest, 6),
        "sequence_speedup": round(first / max(rest, 1e-9), 1),
        "later_from_synopsis": f"{synopsis_hits}/{len(rows) - 1}",
    })
