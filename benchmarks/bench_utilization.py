"""Paper Fig. 14: CPU and IO utilization traces, BI vs chunk-level, in a
CPU-bound setting.

The paper's point: C blocks reads while CPUs chew full chunks (IO duty cycle
swings between extremes), while BI's adaptive per-chunk sample sizes keep
reads flowing.  We reproduce the per-round utilization traces from the
engine's Eq. 4 cost monitor and compare IO-idle fractions.
"""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import datasets, selectivity_query
from repro.core.engine import EngineConfig, OLAEngine


def _trace(store, strategy, fast):
    q = selectivity_query("ptf-ascii", 1.0, epsilon=0.01)
    eng = OLAEngine(store, [q],
                    EngineConfig(num_workers=2, strategy=strategy,
                                 budget_init=64, seed=3))
    state = eng.init_state()
    t_wall = 0.0
    io_total = cpu_total = bytes_total = 0.0
    rounds = 0
    for _ in range(6000 if not fast else 2000):
        b = eng.budget_ladder(float(state.budget))
        state, rep = eng.round_fn(b)(state, eng.packed, eng.speeds)
        rounds += 1
        io_s, cpu_s = float(rep.round_io_s), float(rep.round_cpu_s)
        io_total += io_s
        cpu_total += cpu_s
        t_wall = max(io_total, cpu_total)   # Eq. 4 overlapped pipeline
        bytes_total += float(rep.bytes_round)
        if bool(rep.all_stopped) or bool(rep.exhausted):
            break
    return {
        "rounds": rounds,
        "t_wall_model": round(t_wall, 6),
        "io_duty": round(io_total / max(t_wall, 1e-12), 4),
        "cpu_duty": round(cpu_total / max(t_wall, 1e-12), 4),
        "read_MBps_effective": round(bytes_total / max(t_wall, 1e-12) / 1e6, 1),
    }


def run(fast: bool = False) -> str:
    store = datasets(fast)["ptf-ascii"]
    out = {}
    for strategy, tag in (("resource_aware", "BI"), ("chunk_level", "C")):
        out[tag] = _trace(store, strategy, fast)
    # the paper's Fig 14 point: BI keeps reads flowing (higher effective
    # read throughput / shorter drain time) in the CPU-bound regime
    out["BI_drains_faster"] = out["BI"]["t_wall_model"] <= out["C"]["t_wall_model"]
    with open("results/bench_utilization.json", "w") as f:
        json.dump(out, f, indent=1)
    return json.dumps(out)
