"""Paper Fig. 7-10: error ratio vs (modeled) time across datasets, formats,
selectivities and worker counts; EXT / chunk-level (C) / bi-level (BI).

Headline statistic: per dataset, the speedup of BI over EXT to reach ε=0.05
at selectivity 1.0 with 4 workers — the paper's headline is "as little as
10% of the EXT time in CPU-bound settings" (ptf-ascii is the CPU-bound case,
ptf-binary the IO-bound case where everything collapses to EXT speed).
"""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import (
    datasets, ext_baseline_time, run_curve, selectivity_query,
)


def run(fast: bool = False) -> str:
    stores = datasets(fast)
    workers_list = [1, 4] if fast else [1, 4, 16]
    sels = [1.0] if fast else [1.0, 0.1]
    rows = []
    for name, store in stores.items():
        for workers in workers_list:
            ext_t = ext_baseline_time(store, workers)
            for sel in (sels if name != "wiki" else [1.0]):
                q = selectivity_query(name, sel)
                for strat, tag in (("resource_aware", "BI"),
                                   ("chunk_level", "C")):
                    times, errs, final = run_curve(store, q, strat, workers,
                                                   seed=7)
                    rows.append({
                        "dataset": name, "workers": workers, "sel": sel,
                        "method": tag, "t_to_eps": final["t_model"],
                        "ext_t": ext_t,
                        "speedup_vs_ext": ext_t / max(final["t_model"], 1e-12),
                        "tuples_ratio": final["tuples_ratio"],
                        "chunks_ratio": final["chunks_ratio"],
                        "stopped_early": final["stopped"],
                    })
    with open("results/bench_convergence.json", "w") as f:
        json.dump(rows, f, indent=1)

    def headline(ds):
        r = [x for x in rows if x["dataset"] == ds and x["method"] == "BI"
             and x["workers"] == 4 and x["sel"] == 1.0]
        return round(r[0]["speedup_vs_ext"], 2) if r else None

    return json.dumps({
        "BI_speedup_vs_EXT@4w": {ds: headline(ds) for ds in stores},
        "rows": len(rows),
    })
