"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, where
``derived`` is the benchmark's headline statistic (JSON-encoded).
"""

from __future__ import annotations

import argparse
import sys
import time


BENCHES = [
    ("convergence", "benchmarks.bench_convergence"),     # Fig. 7-10
    ("strategies", "benchmarks.bench_strategies"),       # Fig. 11
    ("synopsis", "benchmarks.bench_synopsis"),           # Fig. 12-13
    ("utilization", "benchmarks.bench_utilization"),     # Fig. 14
    ("bounds_mc", "benchmarks.bench_bounds_mc"),         # Table 3
    ("kernels", "benchmarks.bench_kernels"),             # EXTRACT hot spot
    ("slot_kernel", "benchmarks.bench_slot_kernel"),     # fused round extract
    ("ola_eval", "benchmarks.bench_ola_eval"),           # beyond-paper eval
    ("workload", "benchmarks.bench_workload"),           # shared-scan serving
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced repetitions (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for name, module in BENCHES:
        if args.only and name != args.only:
            continue
        mod = __import__(module, fromlist=["run"])
        t0 = time.perf_counter()
        try:
            derived = mod.run(fast=args.fast)
        except Exception as e:  # noqa: BLE001 — keep the harness going
            failures.append((name, repr(e)))
            print(f"{name},ERROR,{e!r}", flush=True)
            continue
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{derived}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
