"""Paper Table 3: Monte-Carlo coverage of 95% bounds at increasing fractions
of processed chunks — bi-level (sound) vs unordered chunk-level (inspection-
paradox-vulnerable).

Uneven chunk sizes make completion order correlate with content, arming the
paradox exactly as parallel-completion-time correlation does in the paper.
"""

from __future__ import annotations

import json

import numpy as np

from repro.core.engine import EngineConfig, OLAEngine
from repro.core.queries import Linear, Query, Range
from repro.data.generator import make_synthetic_zipf, store_dataset

from benchmarks.common import SYN_COEF16


def _coverage_at_fractions(strategy, store, truth, fractions, runs):
    hits = {f: 0 for f in fractions}
    counts = {f: 0 for f in fractions}
    for r in range(runs):
        q = Query(agg="sum", expr=Linear(SYN_COEF16),
                  pred=Range(0, 0.0, 0.5e8), epsilon=1e-9)
        eng = OLAEngine(store, [q],
                        EngineConfig(num_workers=4, strategy=strategy,
                                     budget_init=128, seed=1000 + r))
        state = eng.init_state()
        targets = sorted(fractions)
        ti = 0
        while ti < len(targets):
            b = eng.budget_ladder(float(state.budget))
            state, rep = eng.round_fn(b)(state, eng.packed, eng.speeds)
            frac = int(rep.n_chunks) / store.num_chunks
            while ti < len(targets) and frac >= targets[ti]:
                f = targets[ti]
                lo, hi = float(rep.lo[0]), float(rep.hi[0])
                hits[f] += int(lo <= truth <= hi)
                counts[f] += 1
                ti += 1
            if bool(rep.exhausted):
                break
    return {f: round(hits[f] / max(counts[f], 1), 3) for f in fractions}


def run(fast: bool = False) -> str:
    t = 8192 if fast else 16384
    vals = make_synthetic_zipf(t, 16, 11)
    store = store_dataset(vals, 48, "ascii", uneven=True, seed=2,
                          uneven_spread=0.8)
    sel = (vals[:, 0] >= 0) & (vals[:, 0] < 0.5e8)
    truth = float((vals @ np.asarray(SYN_COEF16)) @ sel)
    fractions = [0.05, 0.1, 0.2, 0.3]
    runs = 20 if fast else 40
    table = {
        "bi_level": _coverage_at_fractions("resource_aware", store, truth,
                                           fractions, runs),
        "chunk_level_unordered": _coverage_at_fractions(
            "chunk_level_unordered", store, truth, fractions, runs),
    }
    with open("results/bench_bounds_mc.json", "w") as f:
        json.dump(table, f, indent=1)
    return json.dumps(table)
