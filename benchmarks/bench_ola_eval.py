"""Beyond-paper: OLA early-terminated evaluation vs exhaustive eval.

Derived stat: fraction of eval examples needed to pin the metric to ±2%,
and the bias of the early estimate vs the exhaustive mean.
"""

from __future__ import annotations

import json

import numpy as np

from repro.ola_ml.eval_ola import ola_eval


def run(fast: bool = False) -> str:
    rng = np.random.default_rng(0)
    n_shards = 16 if fast else 48
    shards = [rng.normal(2.5, 0.8, size=rng.integers(400, 800))
              for _ in range(n_shards)]
    truth = float(np.concatenate(shards).mean())
    res = ola_eval(lambda x: x, shards, epsilon=0.02, seed=1)
    out = {
        "examples_used_frac": round(res.examples_used / res.total_examples, 4),
        "shards_used": res.shards_used,
        "rel_bias": round(abs(res.estimate - truth) / abs(truth), 5),
        "error_ratio": round(res.error_ratio, 5),
    }
    with open("results/bench_ola_eval.json", "w") as f:
        json.dump(out, f, indent=1)
    return json.dumps(out)
