"""Workload serving benchmark: shared-scan server vs one-query-at-a-time.

A Poisson stream of aggregate queries (mixed SUM/COUNT/AVG, random
selectivities and ε targets) is served two ways:

* **server** — :class:`~repro.serve.ola_server.OLAWorkloadServer`: all
  queries multiplex onto one shared scan with mid-scan admission and
  synopsis seeding;
* **sequential** — the classic :class:`EstimationController`, one query
  batch per scan, in arrival order (reported both without and with the
  between-queries synopsis).

Headline stats: total raw tuples extracted per mode (the paper's scarce
resource) and per-query latency on the Eq. (4) modeled clock.  Results are
saved to ``BENCH_workload.json`` at the repo root (the committed baseline
the CI regression gate diffs against).

Standalone:  PYTHONPATH=src python -m benchmarks.bench_workload [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import gc
import json
import os

import numpy as np

from repro.core.controller import EstimationController
from repro.core.engine import EngineConfig, OLAEngine
from repro.core.queries import GroupBy, Linear, Query, Range, TRUE
from repro.data.generator import (make_synthetic_zipf, make_wiki_like,
                                  store_dataset)
from repro.sched import QuerySLO, SchedulerConfig, WorkloadScheduler
from repro.sched.admission import scan_tuples_per_s
from repro.serve.ola_server import (OLAWorkloadServer, ServerOptions,
                                    poisson_workload)
from repro.serve.rollup import RollupConfig


def build_queries(num_cols: int, count: int, seed: int) -> list[Query]:
    rng = np.random.default_rng(seed)
    coeffs = tuple(1.0 / (k + 1) for k in range(num_cols))
    out = []
    for i in range(count):
        kind = rng.choice(["sum", "count", "avg"], p=[0.5, 0.3, 0.2])
        sel = float(rng.uniform(0.3, 1.0))
        pred = Range(0, 0.0, 1e8 * sel) if sel < 0.999 else TRUE
        eps = float(rng.uniform(0.04, 0.10))
        expr = Linear(coeffs)
        out.append(Query(agg=str(kind), expr=expr, pred=pred, epsilon=eps,
                         name=f"q{i}-{kind}"))
    return out


def run_server(store, cfg, arrivals, max_slots, scheduler=None):
    from benchmarks.common import latency_stats, latency_stats_by_class
    from repro.data.pipeline import device_resident_bytes

    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=max_slots, scheduler=scheduler))
    for item in arrivals:
        q, at, slo = item if len(item) == 3 else (*item, None)
        srv.submit(q, arrival_t=at, slo=slo)
    peak_raw = [0]

    def _sample(_srv):
        peak_raw[0] = max(peak_raw[0], device_resident_bytes(np.uint8))

    results = srv.run(on_round=_sample)
    assert not srv.truncated, "workload did not finish; stats would be biased"
    lat = np.asarray([r.latency for r in results])
    out = {
        "tuples": srv.tuples_scanned,
        "lat_mean": float(lat.mean()),
        "lat_p95": float(np.percentile(lat, 95)),
        "makespan": srv.t_model,
        "rounds": srv.rounds,
        "topup_passes": srv.topup_passes,
        "preempted": srv.preempt_count,
        "answered_from_synopsis": sum(r.from_synopsis for r in results),
        **latency_stats(results),
        "per_class": latency_stats_by_class(results),
        # peak raw-data device footprint observed between rounds (uint8
        # only).  Packed: the resident view, every round.  Stream: usually 0
        # — the slab lives only while its round runs — so the in-flight
        # bound (2 slabs: current + double-buffer) is reported alongside.
        "device_raw_bytes": peak_raw[0],
    }
    if srv.engine.pipeline is not None:
        out["slab_bytes"] = srv.engine.pipeline.slab_bytes
        out["device_raw_in_flight_bound"] = 2 * srv.engine.pipeline.slab_bytes
        out["chunk_reads"] = srv.engine.pipeline.chunk_reads
    else:
        out["device_raw_in_flight_bound"] = max(peak_raw[0], 1)
    srv.close()
    return out


def attach_slos(queries, t_full: float, seed: int) -> list:
    """Random SLO mix for a query list: deadlines drawn relative to the
    full-scan time (some comfortably loose, some tight enough that only a
    scheduler meets them), priorities over all three classes."""
    rng = np.random.default_rng(seed)
    out = []
    for q in queries:
        pri = str(rng.choice(["batch", "normal", "interactive"],
                             p=[0.3, 0.5, 0.2]))
        dl = float(rng.uniform(0.15, 2.5)) * t_full
        out.append(QuerySLO(deadline_s=dl, priority=pri))
    return out


def run_closed_loop(store, cfg, queries, slos, max_slots, concurrency,
                    scheduler=None):
    """Closed-loop load: a fixed population of ``concurrency`` clients, each
    submitting its next query the instant the previous one completes (the
    classic interactive-exploration model — think-time zero).  Arrival times
    therefore *depend on service*, which is what makes closed-loop the
    honest complement to the open-loop Poisson lane."""
    from benchmarks.common import latency_stats

    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=max_slots, scheduler=scheduler))
    total = len(queries)
    submitted = 0

    def feed():
        nonlocal submitted
        while (submitted < total
               and submitted - len(srv.results) < concurrency):
            srv.submit(queries[submitted], arrival_t=srv.t_model,
                       slo=slos[submitted])
            submitted += 1

    feed()
    guard = 0
    while len(srv.results) < total:
        stepped = srv.step()
        feed()
        guard += 1
        if guard > 200_000 or (not stepped and not srv.queue
                               and not srv._any_active()
                               and submitted == total):
            break
    results = sorted(srv.results, key=lambda r: r.qid)
    from benchmarks.common import latency_stats_by_class

    out = {
        "tuples": srv.tuples_scanned,
        "makespan": srv.t_model,
        "rounds": srv.rounds,
        "completed": len(results),
        "shed": srv.shed_count,
        "preempted": srv.preempt_count,
        **latency_stats(results),
        "per_class": latency_stats_by_class(results),
    }
    srv.close()
    return out


def run_sched_lanes(store, cfg, queries, rate: float, max_slots: int,
                    concurrency: int, seed: int) -> dict:
    """The scheduler benchmark proper: the same SLO-tagged workload served
    with and without the scheduler, under open-loop (Poisson) and
    closed-loop load.  Headline: SLO-hit rate and tail latency."""
    t_full = float(store.num_tuples) / scan_tuples_per_s(store, cfg)
    slos = attach_slos(queries, t_full, seed=seed + 1)
    sched_cfg = SchedulerConfig(slot_capacity=max(2.0, max_slots / 2),
                                preempt=True)

    arrivals = poisson_workload(queries, rate_per_model_s=rate, seed=seed)
    open_items = [(q, at, slo) for (q, at), slo in zip(arrivals, slos)]
    out = {"t_full_scan_s": t_full, "num_queries": len(queries),
           "open_loop": {}, "closed_loop": {}}
    out["open_loop"]["unscheduled"] = run_server(
        store, cfg, open_items, max_slots)
    out["open_loop"]["scheduled"] = run_server(
        store, cfg, open_items, max_slots,
        scheduler=WorkloadScheduler(sched_cfg))
    out["closed_loop"]["unscheduled"] = run_closed_loop(
        store, cfg, queries, slos, max_slots, concurrency)
    out["closed_loop"]["scheduled"] = run_closed_loop(
        store, cfg, queries, slos, max_slots, concurrency,
        scheduler=WorkloadScheduler(sched_cfg))
    return out


def run_load_sweep(store, cfg, queries, max_slots: int, seed: int,
                   multipliers=(0.5, 2.0, 8.0)) -> list:
    """Per-class p99-vs-offered-load curves (the full lane's trend
    artifact): the same SLO-tagged workload replayed at several open-loop
    arrival rates — ``multiplier`` arrivals per full-scan time — scheduled
    vs unscheduled, with per-priority-class latency/SLO stats from
    ``latency_stats_by_class``.  Each point reuses one Poisson draw so the
    curves differ only in time compression, not in workload composition."""
    t_full = float(store.num_tuples) / scan_tuples_per_s(store, cfg)
    slos = attach_slos(queries, t_full, seed=seed + 1)
    out = []
    for mult in multipliers:
        rate = mult / t_full
        arrivals = poisson_workload(queries, rate_per_model_s=rate,
                                    seed=seed + 2)
        items = [(q, at, slo) for (q, at), slo in zip(arrivals, slos)]
        sched_cfg = SchedulerConfig(slot_capacity=max(2.0, max_slots / 2),
                                    preempt=True)
        point = {
            "offered_load_per_scan": mult,
            "rate_per_model_s": rate,
            "unscheduled": run_server(store, cfg, items, max_slots),
            "scheduled": run_server(store, cfg, items, max_slots,
                                    scheduler=WorkloadScheduler(sched_cfg)),
        }
        out.append(point)
        for kind in ("unscheduled", "scheduled"):
            pc = point[kind]["per_class"]
            per = "  ".join(
                f"{cls}: p99 {st['p99_latency_s']:.5f}s hit "
                f"{st['slo_hit_rate'] if st['slo_hit_rate'] is None else round(st['slo_hit_rate'], 3)}"
                for cls, st in pc.items())
            print(f"[bench_workload] load x{mult:<4g} {kind:<11s} {per}")
    return out


def build_hot_cold_mix(num_cols: int, n_hot: int, repeats: int,
                       n_cold: int, seed: int) -> tuple:
    """Hot/cold workload for the rollup (Tier-1 answer cache) lane.

    ``n_hot`` distinct SUM patterns are each repeated ``repeats`` times
    (fresh Query objects per repeat — the cache must match on *pattern*,
    not object identity), round-robin interleaved with ``n_cold``
    never-repeating queries from :func:`build_queries`.  Returns
    ``(queries, hot_count)``; the interleaving spreads a pattern's repeats
    out in time so later repeats arrive after the promotion threshold."""
    coeffs = tuple(1.0 / (k + 1) for k in range(num_cols))
    rounds: list[list[Query]] = [[] for _ in range(repeats)]
    for h in range(n_hot):
        sel = 0.4 + 0.5 * (h / max(n_hot - 1, 1))
        for r in range(repeats):
            rounds[r].append(Query(
                agg="sum", expr=Linear(coeffs),
                pred=Range(0, 0.0, 1e8 * sel), epsilon=0.08,
                name=f"hot{h}-r{r}"))
    cold = build_queries(num_cols, n_cold, seed=seed + 1)
    for i, q in enumerate(cold):
        rounds[i % repeats].append(q)
    queries = [q for rnd in rounds for q in rnd]
    return queries, n_hot * repeats


def run_rollup_lane(store, cfg, slots: int, smoke: bool = False) -> dict:
    """Rollup-tier benchmark: a hot/cold mix served with and without the
    Tier-1 answer cache.  Headline (and CI-gated): ``rollup_hit_rate`` —
    the fraction of queries answered from the rollup tier without touching
    the scan — and ``tier1_p95_latency_s``, the modeled p95 latency of
    those answers (pure queue-to-intake time: no scan rounds)."""
    n_hot, repeats, n_cold = (3, 6, 6) if smoke else (4, 10, 16)
    queries, hot_count = build_hot_cold_mix(
        store.codec.num_cols, n_hot, repeats, n_cold, seed=21)
    arrivals = poisson_workload(queries, rate_per_model_s=2000.0, seed=22)

    def _serve(rollup):
        srv = OLAWorkloadServer(
                  store, cfg,
                  options=ServerOptions(max_slots=slots, rollup=rollup))
        for q, at in arrivals:
            srv.submit(q, arrival_t=at)
        results = srv.run()
        assert not srv.truncated, "rollup lane did not finish"
        return srv, results

    base_srv, _ = _serve(None)
    srv, results = _serve(RollupConfig(promote_hits=2))
    tier1 = [r for r in results if r.sched_outcome == "tier1"]
    t1_lat = np.asarray([r.latency for r in tier1], float)
    out = {
        "num_queries": len(queries),
        "hot_queries": hot_count,
        "hot_patterns": n_hot,
        "tier1_answers": len(tier1),
        "rollup_hit_rate": round(len(tier1) / len(queries), 4),
        "tier1_p95_latency_s": (float(np.percentile(t1_lat, 95))
                                if len(t1_lat) else None),
        "cells": len(srv.rollup.cells),
        "promotions": srv.rollup.promotions,
        "demotions": srv.rollup.demotions,
        "tuples_scanned": srv.tuples_scanned,
        "tuples_scanned_no_rollup": base_srv.tuples_scanned,
        "tuples_saved": base_srv.tuples_scanned - srv.tuples_scanned,
        "rounds": srv.rounds,
        "rounds_no_rollup": base_srv.rounds,
        **latency_stats_rollup(results),
    }
    base_srv.close()
    srv.close()
    return out


def latency_stats_rollup(results) -> dict:
    from benchmarks.common import latency_stats

    st = latency_stats(results)
    return {"p50_latency_s": st["p50_latency_s"],
            "p95_latency_s": st["p95_latency_s"],
            "outcomes": st["outcomes"]}


def run_chaos_lane(store, cfg, slots: int, smoke: bool = False) -> dict:
    """Chaos benchmark: the SLO-tagged scheduled workload served under
    injected chunk-read faults (``repro.data.faults.FaultInjector``, fixed
    seed — deterministic run to run).

    Two fault families, matching the fault-tolerant scan plane's two
    recovery tiers:

    * **transient sweep** — every chunk read fails ``transient_fails``
      times with probability ``rate`` before healing; the retry policy
      must absorb all of them, so every lane asserts the estimates are
      *bit-exact* against the fault-free run and no result is degraded.
      ``recovery_overhead_pct`` is the retried-read overhead (retries per
      hundred chunk reads — the modeled clock is retry-invariant, so the
      extra reads are the honest cost signal);
    * **lost chunk** — one chunk is permanently unreadable: the scan
      quarantines it, every affected query completes ``degraded=True``
      over the surviving population, and the lane records the degraded
      rate and that the workload finished without stalling.

    Stream residency throughout: faults surface at the read path (packed
    residency reads raw bytes once at ingest, before any fault window).
    """
    from repro.core.engine import SlotOLAEngine
    from repro.data.faults import FaultConfig, FaultInjector, RetryPolicy

    cfg = dataclasses.replace(cfg, residency="stream")
    nq = 6 if smoke else 16
    queries = build_queries(8, nq, seed=31)
    t_full = float(store.num_tuples) / scan_tuples_per_s(store, cfg)
    slos = attach_slos(queries, t_full, seed=32)
    arrivals = poisson_workload(queries, rate_per_model_s=2000.0, seed=33)
    items = [(q, at, slo) for (q, at), slo in zip(arrivals, slos)]
    sched_cfg = SchedulerConfig(slot_capacity=max(2.0, slots / 2),
                                preempt=True)
    # seed chosen so the 10% lane injects on >= 1 chunk even in the
    # 16-chunk smoke store — a zero-retry lane would gate the recovery
    # overhead band on a degenerate 0.0 baseline
    injector_seed = 7

    def _serve(fault_cfg, max_attempts: int = 4):
        fstore = (FaultInjector(store, fault_cfg)
                  if fault_cfg is not None else store)
        engine = SlotOLAEngine(fstore, slots, cfg)
        # benchmark clock is modeled: don't wall-sleep through backoff
        engine.pipeline.retry = RetryPolicy(max_attempts=max_attempts,
                                            sleep=lambda s: None)
        srv = OLAWorkloadServer(
                  fstore, cfg,
                  options=ServerOptions(engine=engine,
                      synopsis_budget_tuples=0,
                      scheduler=WorkloadScheduler(sched_cfg)))
        for q, at, slo in items:
            srv.submit(q, arrival_t=at, slo=slo)
        results = srv.run()
        assert not srv.truncated, "chaos lane did not finish"
        pf = srv.engine.pipeline
        slo_res = [r.slo_met for r in results if r.slo_met is not None]
        out = {
            "completed": len(results),
            "degraded_rate": round(
                sum(r.degraded for r in results) / max(len(results), 1), 4),
            "chunks_quarantined": srv.chunks_quarantined,
            "read_retries": int(pf.read_retries),
            "read_failures": int(pf.read_failures),
            "chunk_reads": int(pf.chunk_reads),
            "recovery_overhead_pct": round(
                100.0 * pf.read_retries / max(pf.chunk_reads, 1), 4),
            "slo_hit_rate": (round(sum(slo_res) / len(slo_res), 4)
                             if slo_res else None),
            "injected": (dict(fstore.injected)
                         if fault_cfg is not None else {}),
        }
        ests = [r.estimate for r in results]
        srv.close()
        return out, ests

    rates = (0.0, 0.1, 0.3)
    sweep = []
    base_ests = None
    for rate in rates:
        fc = (FaultConfig(seed=injector_seed, transient_rate=rate,
                          transient_fails=2) if rate > 0 else None)
        lane, ests = _serve(fc)
        lane["transient_rate"] = rate
        if rate == 0.0:
            base_ests = ests
        else:
            exact = len(ests) == len(base_ests) and all(
                a == b or (np.isnan(a) and np.isnan(b))
                for a, b in zip(base_ests, ests))
            lane["bit_exact_vs_fault_free"] = bool(exact)
            assert exact, f"transient rate {rate}: estimates diverged"
            assert lane["degraded_rate"] == 0.0, lane
        sweep.append(lane)

    lost, _ = _serve(FaultConfig(seed=injector_seed, lost_chunks=(3,)),
                     max_attempts=2)
    assert lost["chunks_quarantined"] == 1, lost
    assert lost["completed"] == nq, lost

    at_10 = next(l for l in sweep if l["transient_rate"] == 0.1)
    return {
        "num_queries": nq,
        "injector_seed": injector_seed,
        "transient_sweep": sweep,
        "lost_chunk": lost,
        # CI-gated headline metrics (scripts/check_bench_regression.py)
        "slo_hit_rate_under_faults": at_10["slo_hit_rate"],
        "recovery_overhead_pct": at_10["recovery_overhead_pct"],
        "degraded_rate": lost["degraded_rate"],
    }


def _print_chaos(c: dict) -> None:
    for lane in c["transient_sweep"]:
        exact = lane.get("bit_exact_vs_fault_free", "-")
        print(f"  chaos/transient {lane['transient_rate']:<4g}: "
              f"slo-hit {lane['slo_hit_rate']}  retries "
              f"{lane['read_retries']}/{lane['chunk_reads']} reads "
              f"({lane['recovery_overhead_pct']:.1f}% overhead)  "
              f"degraded {lane['degraded_rate']:.0%}  bit-exact {exact}")
    l = c["lost_chunk"]
    print(f"  chaos/lost-chunk: {l['chunks_quarantined']} quarantined, "
          f"{l['completed']} completed, degraded {l['degraded_rate']:.0%}, "
          f"slo-hit {l['slo_hit_rate']}")


def _run_chaos_only(store, cfg, slots: int, smoke: bool = True) -> str:
    """CI chaos smoke lane: run only the fault-injection harness and merge
    the ``chaos`` section into an existing BENCH_workload.json."""
    chaos_out = run_chaos_lane(store, cfg, slots, smoke=smoke)
    _merge_section("chaos", chaos_out)
    print(f"[bench_workload] chaos lanes over {chaos_out['num_queries']} "
          f"queries (injector seed {chaos_out['injector_seed']})")
    _print_chaos(chaos_out)
    return json.dumps({
        "slo_hit_rate_under_faults": chaos_out["slo_hit_rate_under_faults"],
        "recovery_overhead_pct": chaos_out["recovery_overhead_pct"],
        "degraded_rate": chaos_out["degraded_rate"],
    })


def run_rescan_lane(smoke: bool = False) -> dict:
    """Repeated-scan lane for the parse-once decoded-chunk cache.

    The same hot chunk set is scanned to census repeatedly (one
    ``single_pass`` engine run per pass, the prefetcher — and therefore the
    decoded cache — shared across passes), with the cache on vs off, for
    ASCII and binary codecs.  CI-gated headlines:

    * ``decoded_hit_rate`` — fraction of per-round slab assemblies served
      from the decoded cache (deterministic counters);
    * ``extract_tuples_avoided`` — tuples whose tokenize/parse was skipped
      on a re-scan (counted once per chunk hold);
    * ``hot_rescan_speedup`` — wall tuples/s of second-and-later passes,
      cache on ÷ cache off.  The acceptance bar (≥ 2× on ASCII, ref
      backend, CPU) lives here: ASCII re-extraction is ≈ 3360 ns-units per
      tuple, so skipping it dominates the hot pass; binary parse is
      near-free, so its speedup is reported but not gated.

    Every pass asserts the estimate is bit-identical cache on/off — the
    fast path must never change an answer.
    """
    import time as _time

    import jax

    # chunk-sized budgets (budget pinned to rows-per-chunk): each round
    # extracts whole chunks, so the EXTRACT term dominates the wall clock
    # and the lane measures parse-once, not python dispatch overhead
    t, chunks, timed = (32768, 16, 3) if smoke else (131072, 32, 3)
    budget = t // chunks
    # 16-column records: the widest synthetic schema, so the per-tuple
    # ASCII tokenize/parse cost the cache skips is the dominant round term
    cols = 16
    coeffs = tuple(1.0 / (k + 1) for k in range(cols))
    census = Query(agg="sum", expr=Linear(coeffs), epsilon=1e-9,
                   name="census")

    def one_pass(eng, max_rounds=20000):
        state = eng.init_state()
        rep = None
        t0 = _time.perf_counter()
        for _ in range(max_rounds):
            b = eng.budget_ladder(float(state.budget))
            state, data = eng.round_data(state)
            mode, data = eng.data_mode(data)
            state, rep = eng.round_fn(b, mode)(state, data, eng.speeds)
            if bool(rep.all_stopped) or bool(rep.exhausted):
                break
        else:
            raise AssertionError("rescan pass did not exhaust")
        jax.block_until_ready(rep.estimate)
        return float(rep.estimate[0]), _time.perf_counter() - t0

    out = {}
    for codec in ("ascii", "binary"):
        store = store_dataset(make_synthetic_zipf(t, cols, seed=5), chunks,
                              codec)
        dec_bytes = 1 << 26

        def run_passes(decoded_cache_bytes):
            cfg = EngineConfig(num_workers=4, strategy="single_pass",
                               budget_init=budget, budget_min=budget,
                               budget_max=budget, seed=7,
                               residency="stream", extract_backend="ref",
                               decoded_cache_bytes=decoded_cache_bytes)
            eng = OLAEngine(store, [census], cfg)
            try:
                ests, hot_times = [], []
                # pass 0 cold-fills the cache, pass 1 warms the hot-path
                # jit variants; passes 2.. are the timed hot re-scans
                for p in range(2 + timed):
                    est, dt = one_pass(eng)
                    ests.append(est)
                    if p >= 2:
                        hot_times.append(dt)
                pf = eng.pipeline
                counters = {
                    "decoded_hits": pf.decoded_hits,
                    "decoded_misses": pf.decoded_misses,
                    "extract_tuples_avoided": pf.extract_tuples_avoided,
                    "decoded_fraction": pf.decoded_fraction(),
                }
                return ests, sum(hot_times), counters
            finally:
                eng.close()

        ests_on, hot_on, counters = run_passes(dec_bytes)
        ests_off, hot_off, _ = run_passes(0)
        assert ests_on == ests_off, (codec, ests_on, ests_off)
        touches = counters["decoded_hits"] + counters["decoded_misses"]
        tps_on = timed * store.num_tuples / max(hot_on, 1e-12)
        tps_off = timed * store.num_tuples / max(hot_off, 1e-12)
        out[codec] = {
            "table_tuples": t,
            "chunks": chunks,
            "passes_timed": timed,
            "decoded_cache_bytes": dec_bytes,
            "decoded_hit_rate": round(
                counters["decoded_hits"] / max(touches, 1), 4),
            "extract_tuples_avoided": int(
                counters["extract_tuples_avoided"]),
            "decoded_fraction": round(counters["decoded_fraction"], 4),
            "hot_tuples_per_s": round(tps_on, 1),
            "hot_tuples_per_s_nocache": round(tps_off, 1),
            "hot_rescan_speedup": round(tps_on / max(tps_off, 1e-12), 3),
            "bit_exact_vs_nocache": True,
        }
    return out


def _print_rescan(r: dict) -> None:
    for codec, lane in r.items():
        print(f"  rescan/{codec:<6s}: hit rate "
              f"{lane['decoded_hit_rate']:.2%}, "
              f"{lane['extract_tuples_avoided']} extract tuples avoided, "
              f"hot {lane['hot_tuples_per_s']:.0f} vs "
              f"{lane['hot_tuples_per_s_nocache']:.0f} tuples/s "
              f"({lane['hot_rescan_speedup']:.2f}x)")


def _run_rescan_only(smoke: bool = True) -> str:
    """CI decoded-cache smoke lane: run only the repeated-scan harness and
    merge the ``rescan`` section into an existing BENCH_workload.json."""
    rescan_out = run_rescan_lane(smoke=smoke)
    _merge_section("rescan", rescan_out)
    print("[bench_workload] repeated-scan lanes (parse-once decoded cache)")
    _print_rescan(rescan_out)
    return json.dumps({
        codec: {"decoded_hit_rate": lane["decoded_hit_rate"],
                "hot_rescan_speedup": lane["hot_rescan_speedup"]}
        for codec, lane in rescan_out.items()})


def run_sequential(store, cfg, arrivals, synopsis_budget):
    ctrl = EstimationController(store, cfg,
                                synopsis_budget_tuples=synopsis_budget)
    total = store.num_tuples
    clock = 0.0
    tuples = 0
    lats = []
    for q, at in arrivals:
        res = ctrl.run_query([q])
        start = max(clock, at)
        clock = start + res.t_model_total
        tuples += int(round(res.tuples_ratio * total))
        lats.append(clock - at)
    lat = np.asarray(lats)
    return {
        "tuples": tuples,
        "lat_mean": float(lat.mean()),
        "lat_p95": float(np.percentile(lat, 95)),
        "makespan": clock,
    }


def run(fast: bool = False, smoke: bool = False, sched: bool = True,
        sched_only: bool = False, rollup: bool = True,
        rollup_only: bool = False, chaos_only: bool = False,
        rescan_only: bool = False, obs_only: bool = False,
        groups_only: bool = False) -> str:
    if rescan_only:
        return _run_rescan_only(smoke=smoke)
    if groups_only:
        return _run_groups_only(smoke=smoke)
    if smoke:
        t, chunks, nq, slots = 2048, 16, 6, 4
    elif fast:
        t, chunks, nq, slots = 8192, 32, 12, 8
    else:
        t, chunks, nq, slots = 16384, 64, 24, 8
    store = store_dataset(make_synthetic_zipf(t, 8, seed=0), chunks, "ascii")
    cfg = EngineConfig(num_workers=4, seed=7)
    queries = build_queries(8, nq, seed=1)
    # arrival rate scaled so several queries overlap one scan's modeled time
    arrivals = poisson_workload(queries, rate_per_model_s=2000.0, seed=2)

    if sched_only:
        return _run_sched_only(store, cfg, queries, slots, smoke=smoke)
    if rollup_only:
        return _run_rollup_only(store, cfg, slots, smoke=smoke)
    if chaos_only:
        return _run_chaos_only(store, cfg, slots, smoke=smoke)
    if obs_only:
        return _run_obs_only(store, cfg, arrivals, slots, smoke=smoke)

    # streaming residency first (clean device-byte measurement), then packed
    server_stream = run_server(
        store, dataclasses.replace(cfg, residency="stream"), arrivals, slots)
    gc.collect()
    server = run_server(store, cfg, arrivals, slots)
    seq = run_sequential(store, cfg, arrivals, synopsis_budget=0)
    seq_syn = run_sequential(store, cfg, arrivals, synopsis_budget=4096)
    # the shared scan is residency-independent: identical raw tuple count
    assert server_stream["tuples"] == server["tuples"], (
        server_stream["tuples"], server["tuples"])

    from benchmarks.common import memory_report, runner_fingerprint

    sched_out = None
    if sched:
        sched_out = run_sched_lanes(store, cfg, queries, rate=2000.0,
                                    max_slots=slots,
                                    concurrency=max(2, slots // 2), seed=11)
        if not smoke:
            # per-class p99-vs-offered-load curves: full/fast lanes only —
            # the weekly run's bench-full artifact tracks them over time
            sched_out["load_sweep"] = run_load_sweep(
                store, cfg, queries, max_slots=slots, seed=11)

    rollup_out = None
    if rollup and not smoke:
        # the CI smoke run gets its rollup section from the dedicated
        # --rollup-only step instead (keeps the base smoke lane's timings
        # comparable with pre-rollup baselines)
        rollup_out = run_rollup_lane(store, cfg, slots, smoke=smoke)

    out = {
        "num_queries": nq,
        "table_tuples": t,
        "packed_view_bytes": int(store.num_chunks * store.max_chunk_tuples
                                 * store.codec.record_bytes),
        "server": server,
        "server_stream": server_stream,
        "sequential": seq,
        "sequential_synopsis": seq_syn,
        "sched": sched_out,
        "rollup": rollup_out,
        "tuples_saved_vs_sequential": seq["tuples"] - server["tuples"],
        "tuples_ratio_vs_sequential": round(
            server["tuples"] / max(seq["tuples"], 1), 4),
        "device_raw_ratio_stream_vs_packed": round(
            server_stream["device_raw_in_flight_bound"]
            / max(server["device_raw_bytes"], 1), 4),
        "memory": memory_report(),
        "fingerprint": runner_fingerprint(),
    }
    from benchmarks.common import bench_output_paths

    for path in bench_output_paths("workload"):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=1)

    print(f"[bench_workload] {nq} queries over {t} tuples")
    print(f"  server     : {server['tuples']:8d} tuples extracted, "
          f"mean latency {server['lat_mean']:.4f}s (modeled), "
          f"p95 {server['lat_p95']:.4f}s, {server['rounds']} rounds, "
          f"{server['answered_from_synopsis']} answered from synopsis")
    print(f"  sequential : {seq['tuples']:8d} tuples extracted, "
          f"mean latency {seq['lat_mean']:.4f}s, p95 {seq['lat_p95']:.4f}s")
    print(f"  seq+synopsis: {seq_syn['tuples']:7d} tuples extracted, "
          f"mean latency {seq_syn['lat_mean']:.4f}s")
    print(f"  shared scan extracts {out['tuples_ratio_vs_sequential']:.2%} "
          f"of the sequential baseline's tuples")
    print(f"  stream residency: same {server_stream['tuples']} tuples with "
          f"<= {server_stream['device_raw_in_flight_bound']} raw device "
          f"bytes in flight (2 slabs) vs packed "
          f"{server['device_raw_bytes']} resident")
    if sched_out is not None:
        _print_sched(sched_out)
    if rollup_out is not None:
        _print_rollup(rollup_out)
    return json.dumps({
        "tuples_ratio_vs_sequential": out["tuples_ratio_vs_sequential"],
        "server_tuples": server["tuples"],
        "sequential_tuples": seq["tuples"],
        "server_lat_mean": round(server["lat_mean"], 5),
        "sequential_lat_mean": round(seq["lat_mean"], 5),
    })


def _print_sched(sched_out: dict) -> None:
    for mode in ("open_loop", "closed_loop"):
        for kind in ("unscheduled", "scheduled"):
            r = sched_out[mode][kind]
            hit = r.get("slo_hit_rate")
            print(f"  sched/{mode:<11s} {kind:<11s}: "
                  f"p50 {r['p50_latency_s']:.5f}s  p95 {r['p95_latency_s']:.5f}s  "
                  f"p99 {r['p99_latency_s']:.5f}s  "
                  f"slo-hit {hit if hit is None else round(hit, 3)}  "
                  f"shed {r['outcomes']['shed']}")


def _merge_section(section: str, value) -> None:
    """Merge one top-level section (plus the runner fingerprint) into the
    existing BENCH_workload.json files — the pattern the focused CI lanes
    (``--sched-only`` / ``--rollup-only``) use so they can update their
    slice of the result file without re-running the whole benchmark."""
    from benchmarks.common import bench_output_paths, runner_fingerprint

    for path in bench_output_paths("workload"):
        base = {}
        try:
            with open(path) as f:
                base = json.load(f)
        except (OSError, ValueError):
            pass
        base[section] = value
        base["fingerprint"] = runner_fingerprint()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(base, f, indent=1)


def _run_sched_only(store, cfg, queries, slots: int, smoke: bool = True) -> str:
    """CI scheduler smoke lane: run only the closed-loop/open-loop SLO
    harness and merge the ``sched`` section into an existing
    BENCH_workload.json (or write a fresh file when none exists)."""
    sched_out = run_sched_lanes(store, cfg, queries, rate=2000.0,
                                max_slots=slots,
                                concurrency=max(2, slots // 2), seed=11)
    if not smoke:
        sched_out["load_sweep"] = run_load_sweep(
            store, cfg, queries, max_slots=slots, seed=11)
    _merge_section("sched", sched_out)
    print(f"[bench_workload] scheduler lanes over {len(queries)} queries")
    _print_sched(sched_out)
    cl = sched_out["closed_loop"]
    return json.dumps({
        "closed_loop_slo_hit_scheduled": cl["scheduled"]["slo_hit_rate"],
        "closed_loop_slo_hit_unscheduled": cl["unscheduled"]["slo_hit_rate"],
        "closed_loop_p99_scheduled": cl["scheduled"]["p99_latency_s"],
    })


def _print_rollup(r: dict) -> None:
    t1p95 = r["tier1_p95_latency_s"]
    print(f"  rollup: {r['tier1_answers']}/{r['num_queries']} answered "
          f"tier-1 (hit rate {r['rollup_hit_rate']:.2%}), tier-1 p95 "
          f"{t1p95 if t1p95 is None else round(t1p95, 6)}s, "
          f"{r['tuples_saved']} tuples saved "
          f"({r['tuples_scanned']} vs {r['tuples_scanned_no_rollup']} "
          f"without the cache), {r['cells']} cells "
          f"({r['promotions']} promotions)")


def _run_rollup_only(store, cfg, slots: int, smoke: bool = True) -> str:
    """CI rollup smoke lane: run only the hot/cold answer-cache harness and
    merge the ``rollup`` section into an existing BENCH_workload.json."""
    rollup_out = run_rollup_lane(store, cfg, slots, smoke=smoke)
    _merge_section("rollup", rollup_out)
    print(f"[bench_workload] rollup lane over {rollup_out['num_queries']} "
          f"queries ({rollup_out['hot_patterns']} hot patterns)")
    _print_rollup(rollup_out)
    return json.dumps({
        "rollup_hit_rate": rollup_out["rollup_hit_rate"],
        "tier1_p95_latency_s": rollup_out["tier1_p95_latency_s"],
        "tuples_saved": rollup_out["tuples_saved"],
    })


def _same_float(a, b) -> bool:
    """Bit-for-bit float equality with NaN == NaN (shed queries without a
    seed answer carry NaN estimates on both sides of the comparison)."""
    if a is None or b is None:
        return a is b
    return a == b or (a != a and b != b)


def _answer_key(results) -> list:
    """The answer-affecting fields of a result list — anything tracing
    could conceivably perturb if it ever leaked into the arithmetic."""
    return [(r.qid, repr(r.estimate), repr(r.halfwidth), repr(r.latency),
             r.sched_outcome, r.rounds_resident, r.from_synopsis)
            for r in results]


def _run_obs_only(store, cfg, arrivals, slots: int, smoke: bool = True) -> str:
    """CI observability smoke lane: run the same workload untraced and
    traced, assert the answers are bit-identical (the instrumentation is
    host-side bookkeeping, never arithmetic), validate the chrome-trace
    export against the schema checker, check every result carries an
    explain record whose final figures equal the answer, and merge the
    ``obs`` section into BENCH_workload.json.

    ``trace_overhead_pct`` is best-of-N wall time traced vs untraced
    (best-of, because the smoke workload is tiny and single runs are
    noisy).  The regression gate holds it under an absolute ceiling —
    informational until a baseline containing the section lands.
    """
    import time

    from benchmarks.common import trace_summary
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import SpanTracer

    def _one(tracer=None, metrics=None):
        srv = OLAWorkloadServer(
                  store, cfg,
                  options=ServerOptions(max_slots=slots, tracer=tracer,
                      metrics=metrics))
        for item in arrivals:
            q, at, slo = item if len(item) == 3 else (*item, None)
            srv.submit(q, arrival_t=at, slo=slo)
        t0 = time.perf_counter()
        results = srv.run()
        dt = time.perf_counter() - t0
        srv.close()
        return srv, results, dt

    reps = 3 if smoke else 5
    _, results_off, _ = _one()          # warmup: JIT compiles off the clock
    t_off = min(_one()[2] for _ in range(reps))
    best = None
    for _ in range(reps):
        run_i = _one(tracer=SpanTracer(), metrics=MetricsRegistry())
        if best is None or run_i[2] < best[2]:
            best = run_i
    srv_on, results_on, t_on = best

    # NEUTRAL-path parity: tracing must not change a single answer bit
    assert _answer_key(results_on) == _answer_key(results_off), \
        "tracing changed the workload answers"
    # every retired query carries an explain record whose final figures
    # are the answer, bit for bit
    for r in results_on:
        assert r.explain is not None, f"missing explain for {r.qid}"
        assert _same_float(r.explain.final_estimate, r.estimate), r.qid
        assert _same_float(r.explain.final_ci_halfwidth, r.halfwidth), r.qid
    summary = trace_summary(srv_on.tracer)
    assert not summary["schema_problems"], summary["schema_problems"]

    snap = srv_on.metrics_snapshot()
    retired = sum(v for k, v in snap.items()
                  if k.startswith("queries_total"))
    assert retired == len(results_on), (retired, len(results_on))
    pct_raw = (t_on - t_off) / max(t_off, 1e-9) * 100.0
    # the gated figure clamps at zero: negative "overhead" is timer noise
    # on the tiny smoke workload, and a negative committed baseline would
    # drag the gate's abs_grow ceiling below the real instrumentation budget
    pct = max(pct_raw, 0.0)
    obs_out = {
        "trace_overhead_pct": round(pct, 3),
        "trace_overhead_pct_raw": round(pct_raw, 3),
        "untraced_best_s": round(t_off, 6),
        "traced_best_s": round(t_on, 6),
        "num_results": len(results_on),
        "explain_attached": sum(r.explain is not None for r in results_on),
        "metrics_series": len(snap),
        "trace": summary,
    }
    _merge_section("obs", obs_out)
    print(f"[bench_workload] observability lane over {len(results_on)} "
          f"queries")
    print(f"  obs: trace overhead {pct_raw:+.2f}% "
          f"({t_on:.4f}s traced vs {t_off:.4f}s untraced, best of {reps}), "
          f"{summary['events']} trace events ({summary['dropped']} dropped), "
          f"schema OK, {len(snap)} metric series, "
          f"answers bit-identical with tracing on")
    return json.dumps({
        "trace_overhead_pct": obs_out["trace_overhead_pct"],
        "trace_events": summary["events"],
        "explain_attached": obs_out["explain_attached"],
    })


def _run_groups_only(smoke: bool = True) -> str:
    """CI grouped-query smoke lane: a Zipf-skewed wiki-like store (column 0
    is a heavy-tailed language id) served a batch of ``Query(group_by=...)``
    aggregates.  Measures the discovery plane's top-K recall — the tracked
    cells at retirement vs the exact per-language totals — plus the
    ``__other__`` spill coverage and modeled p95 latency, and merges the
    ``groups`` section into BENCH_workload.json."""
    if smoke:
        t, chunks, langs, nq, slots = 8192, 12, 16, 4, 4
    else:
        t, chunks, langs, nq, slots = 32768, 32, 40, 8, 4
    vals, _ = make_wiki_like(t, num_languages=langs, seed=0)
    store = store_dataset(vals, chunks, "ascii", uneven=True, seed=0)
    cfg = EngineConfig(num_workers=4, seed=7, max_groups=8)

    rng = np.random.default_rng(3)
    queries = []
    for i in range(nq):
        col = int(rng.choice([1, 2]))         # hits or bytes
        eps = float(rng.uniform(0.05, 0.10))
        coeffs = tuple(1.0 if k == col else 0.0 for k in range(4))
        queries.append(Query(agg="sum", expr=Linear(coeffs), epsilon=eps,
                             name=f"g{i}-c{col}",
                             group_by=GroupBy(col=0, max_groups=8, top_k=5)))

    srv = OLAWorkloadServer(store, cfg, options=ServerOptions(
        max_slots=slots, synopsis_budget_tuples=0))
    for i, q in enumerate(queries):
        srv.submit(q, arrival_t=1e-4 * i)
    results = srv.run()
    assert not srv.truncated, "grouped workload did not finish"
    srv.close()

    recalls, spill_seen = [], 0
    for r in results:
        q = queries[r.qid]
        agg_col = next(k for k, c in enumerate(q.expr.coeffs) if c)
        totals = {}
        for lang, x in zip(vals[:, 0], vals[:, agg_col]):
            totals[float(lang)] = totals.get(float(lang), 0.0) + float(x)
        k = q.group_by.effective_top_k
        true_top = {v for v, _ in
                    sorted(totals.items(), key=lambda kv: -kv[1])[:k]}
        tracked = {g.value for g in r.groups if not g.is_other}
        recalls.append(len(true_top & tracked) / len(true_top))
        spill_seen += any(g.is_other and g.n > 0 for g in r.groups)
    recall = float(np.mean(recalls))
    lat = np.asarray([r.latency for r in results])
    assert recall >= 0.9, (recall, recalls)

    groups_out = {
        "topk_recall": round(recall, 4),
        "p95_latency_s": round(float(np.percentile(lat, 95)), 6),
        "mean_latency_s": round(float(lat.mean()), 6),
        "num_queries": len(results),
        "spill_nonempty": int(spill_seen),
        "rounds": srv.rounds,
        "tuples": srv.tuples_scanned,
    }
    _merge_section("groups", groups_out)
    print(f"[bench_workload] grouped lane over {len(results)} grouped "
          f"queries ({t} tuples, {langs} languages)")
    print(f"  groups: top-{queries[0].group_by.effective_top_k} recall "
          f"{recall:.3f}, p95 latency {groups_out['p95_latency_s']:.4f}s "
          f"(modeled), spill nonempty {spill_seen}/{len(results)}, "
          f"{srv.rounds} rounds")
    return json.dumps({
        "topk_recall": groups_out["topk_recall"],
        "p95_latency_s": groups_out["p95_latency_s"],
        "num_queries": groups_out["num_queries"],
    })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for the CI bench-smoke step")
    ap.add_argument("--no-sched", action="store_true",
                    help="skip the scheduler (SLO) lanes")
    ap.add_argument("--sched-only", action="store_true",
                    help="run only the scheduler lanes and merge the "
                         "'sched' section into BENCH_workload.json "
                         "(CI scheduler smoke lane)")
    ap.add_argument("--no-rollup", action="store_true",
                    help="skip the rollup (Tier-1 answer cache) lane")
    ap.add_argument("--rollup-only", action="store_true",
                    help="run only the rollup hot/cold lane and merge the "
                         "'rollup' section into BENCH_workload.json "
                         "(CI rollup smoke lane)")
    ap.add_argument("--chaos", action="store_true",
                    help="run only the fault-injection chaos lanes and "
                         "merge the 'chaos' section into "
                         "BENCH_workload.json (CI chaos smoke lane)")
    ap.add_argument("--rescan", action="store_true",
                    help="run only the parse-once decoded-cache "
                         "repeated-scan lanes and merge the 'rescan' "
                         "section into BENCH_workload.json "
                         "(CI decoded-cache smoke lane)")
    ap.add_argument("--obs", action="store_true",
                    help="run only the observability lane (tracing "
                         "overhead + parity + chrome-trace schema) and "
                         "merge the 'obs' section into BENCH_workload.json "
                         "(CI observability smoke lane)")
    ap.add_argument("--groups", action="store_true",
                    help="run only the grouped-query lane (online GROUP BY "
                         "discovery recall + latency) and merge the "
                         "'groups' section into BENCH_workload.json "
                         "(CI grouped smoke lane)")
    args = ap.parse_args()
    run(fast=args.fast, smoke=args.smoke, sched=not args.no_sched,
        sched_only=args.sched_only, rollup=not args.no_rollup,
        rollup_only=args.rollup_only, chaos_only=args.chaos,
        rescan_only=args.rescan, obs_only=args.obs,
        groups_only=args.groups)


if __name__ == "__main__":
    main()
