"""End-to-end driver: train a smollm-family model with OLA-gated ingest.

    PYTHONPATH=src python examples/train_with_verification.py [--full]

Every corpus segment's raw metadata table passes the paper's verification
battery (sampled, early-terminated) before any training FLOPs are spent;
poisoned segments are rejected from their raw bytes alone.  ``--full`` uses
the real smollm-135m config (TPU-scale; the default reduced config trains a
few hundred steps on CPU).
"""

import argparse
import json

from repro.configs import get_config
from repro.data.corpus import SyntheticCorpus
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("smollm-135m", reduced=not args.full)
    tcfg = TrainerConfig(steps_per_segment=args.steps // 6 or 1, batch=4,
                         seq_len=128, max_steps=args.steps,
                         ckpt_dir=args.ckpt_dir)
    corpus = SyntheticCorpus(vocab=cfg.vocab_size, num_segments=8,
                             docs_per_segment=128, doc_len=128,
                             poison_every=3, seed=0)
    trainer = Trainer(cfg, tcfg)
    result = trainer.run(corpus)
    result.pop("state")

    print(json.dumps(result, indent=1))
    print("\ningest gate log:")
    for e in trainer.log:
        if e["event"] == "gate":
            verdict = "ADMIT" if e["admitted"] else f"REJECT({e['failed']})"
            print(f"  segment {e['segment']}: {verdict:18s} "
                  f"sampled {100 * e['tuples_ratio']:.1f}% of metadata")
    losses = [e["loss"] for e in trainer.log if e["event"] == "step"]
    if losses:
        k = max(len(losses) // 8, 1)
        print("\nloss curve:", " ".join(f"{x:.3f}" for x in losses[::k]))


if __name__ == "__main__":
    main()
