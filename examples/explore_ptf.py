"""The paper's motivating workflow (Section 1): PTF candidate-batch
verification as a sequence of HAVING queries with early-out.

    PYTHONPATH=src python examples/explore_ptf.py

A clumped "telescope night" table is verified by three aggregate checks; the
controller stops each query as soon as its confidence interval decides the
HAVING predicate, and aborts the whole sequence on the first failure —
no load, no full scan, no wasted work on an uninteresting batch.
"""

import numpy as np

from repro.core import (
    Column, EngineConfig, EstimationController, Having, Query, Range, TRUE,
)
from repro.data.generator import make_ptf_like, store_dataset


def main():
    candidates = make_ptf_like(num_tuples=32768, num_chunks_hint=64, seed=1)
    store = store_dataset(candidates, num_chunks=64, fmt="binary",
                          name="ptf_night")
    # ground truth for context
    print(f"batch: {store.num_tuples} candidates in {store.num_chunks} "
          f"binary (FITS-like) chunks")
    print(f"true mean mag {candidates[:, 3].mean():.3f}, "
          f"true mean err {candidates[:, 4].mean():.4f}\n")

    verification = [
        # mean photometric error must be small
        Query(agg="avg", expr=Column(4), pred=TRUE,
              having=Having("<", 0.05), epsilon=0.05, name="avg_mag_err<0.05"),
        # enough bright detections (mag < 17)
        Query(agg="count", pred=Range(3, 0.0, 17.0),
              having=Having(">", 500.0), epsilon=0.05, name="bright>500"),
        # mean magnitude sane
        Query(agg="avg", expr=Column(3), pred=TRUE,
              having=Having("<", 22.0), epsilon=0.05, name="avg_mag<22"),
    ]

    ctrl = EstimationController(
        store, EngineConfig(num_workers=4, strategy="resource_aware", seed=3),
        synopsis_budget_tuples=4096)
    results = ctrl.run_verification(verification)

    passed = len(results) == len(verification) and all(
        int(r.decisions[0]) != 0 for r in results)
    for q, r in zip(verification, results):
        verdict = {1: "PASS", 0: "FAIL", -1: "exact"}[int(r.decisions[0])]
        print(f"{q.name:20s} -> {verdict:5s} est={r.final_estimate[0]:12.4g} "
              f"tuples={100 * r.tuples_ratio:5.1f}% "
              f"t_model={r.t_model_total * 1e3:7.3f}ms "
              f"synopsis={r.from_synopsis}")
    print(f"\nbatch verdict: {'ADMIT -> in-depth analysis' if passed else 'REJECT'}")


if __name__ == "__main__":
    main()
