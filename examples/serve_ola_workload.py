"""Workload serving demo: a stream of OLA queries sharing one scan.

    PYTHONPATH=src python examples/serve_ola_workload.py

Generates a zipfian raw table, then fires a Poisson stream of mixed
SUM/COUNT/AVG queries (different selectivities, ε targets, and HAVING
clauses) at the :class:`OLAWorkloadServer`.  Queries join the shared scan
mid-flight (seeded from the bi-level synopsis), leave as soon as their
target is met, and the server reports per-query latency plus how many raw
tuples the whole workload cost — compare with running each query as its own
scan.
"""

import numpy as np

from repro.core.engine import EngineConfig
from repro.core.queries import Having, Linear, Query, Range
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.serve.ola_server import (OLAWorkloadServer, ServerOptions,
                                    select_plan)


def main():
    values = make_synthetic_zipf(num_tuples=16384, num_cols=8, seed=0)
    store = store_dataset(values, num_chunks=64, fmt="ascii")
    coef = tuple(1.0 / (k + 1) for k in range(8))
    x = values @ np.asarray(coef)
    exact_sum = float(x.sum())

    cfg = EngineConfig(num_workers=4, seed=7)
    server = OLAWorkloadServer(
                 store, cfg,
                 options=ServerOptions(max_slots=4,
                     synopsis_budget_tuples=4096))

    workload = [
        (Query(agg="sum", expr=Linear(coef), epsilon=0.05,
               name="sum-all"), 0.0),
        (Query(agg="count", pred=Range(0, 0.0, 4e7), epsilon=0.08,
               name="count-sel"), 0.0005),
        (Query(agg="sum", expr=Linear(coef), pred=Range(0, 0.0, 6e7),
               having=Having("<", exact_sum), epsilon=0.05,
               name="having-verify"), 0.001),
        (Query(agg="avg", expr=Linear(coef), epsilon=0.05,
               name="avg-all"), 0.0015),
        (Query(agg="sum", expr=Linear(coef), epsilon=0.03,
               name="sum-tight"), 0.002),
    ]
    for q, at in workload:
        plan = select_plan(store, cfg, q)
        print(f"submit {q.name:14s} arrival={at:.4f}s plan={plan}")
        server.submit(q, arrival_t=at)

    results = server.run()

    print(f"\n{'query':>14} {'plan':>14} {'estimate':>12} {'err%':>6} "
          f"{'dec':>3} {'latency(s)':>10} {'seeded':>6} {'seen':>6}")
    for r in results:
        print(f"{r.name:>14} {r.plan:>14} {r.estimate:12.4g} "
              f"{100 * r.err:6.2f} {r.decision:3d} {r.latency:10.5f} "
              f"{r.seeded_tuples:6d} {r.tuples_seen:6d}")
    print(f"\nshared scan extracted {server.tuples_scanned} of "
          f"{store.num_tuples} tuples for {len(results)} queries "
          f"({server.rounds} rounds, {server.topup_passes} top-up passes); "
          f"exact SUM = {exact_sum:.6g}")


if __name__ == "__main__":
    main()
