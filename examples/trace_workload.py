"""Observability demo: trace a served workload, explain every answer.

    PYTHONPATH=src python examples/trace_workload.py

Runs a small OLA workload with the span tracer and metrics registry
attached, then:

* saves the query-lifecycle trace as chrome-trace JSON
  (``ola_trace.json`` — open it at https://ui.perfetto.dev or in
  ``chrome://tracing``): one ``round`` span per server round, with
  ``claims``/``kernel``/``merge``/``estimate`` children and the
  reader-thread ``READ`` spans on their own track;
* prints each query's explain record — the admission decision with its
  Eq. (4) cost terms, the tier that answered, and the per-round
  ``(m, estimate, ci_halfwidth)`` convergence trajectory;
* dumps the metrics registry in Prometheus text exposition format.
"""

import json

import numpy as np

from repro.core.engine import EngineConfig
from repro.core.queries import Linear, Query, Range
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanTracer, validate_chrome_trace
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions

OUT = "ola_trace.json"


def main():
    values = make_synthetic_zipf(num_tuples=8192, num_cols=8, seed=0)
    store = store_dataset(values, num_chunks=32, fmt="ascii")
    coef = tuple(1.0 / (k + 1) for k in range(8))

    tracer = SpanTracer()
    metrics = MetricsRegistry()
    cfg = EngineConfig(num_workers=4, seed=7)
    server = OLAWorkloadServer(
                 store, cfg,
                 options=ServerOptions(max_slots=4,
                     synopsis_budget_tuples=2048, tracer=tracer,
                     metrics=metrics))

    workload = [
        (Query(agg="sum", expr=Linear(coef), epsilon=0.05,
               name="sum-all"), 0.0),
        (Query(agg="count", pred=Range(0, 0.0, 4e7), epsilon=0.08,
               name="count-sel"), 0.0005),
        (Query(agg="avg", expr=Linear(coef), epsilon=0.05,
               name="avg-all"), 0.001),
        (Query(agg="sum", expr=Linear(coef), pred=Range(0, 0.0, 6e7),
               epsilon=0.03, name="sum-tight"), 0.0015),
    ]
    for q, at in workload:
        server.submit(q, arrival_t=at)
    results = server.run()

    # --- chrome-trace export -------------------------------------------
    doc = tracer.to_chrome_trace()
    problems = validate_chrome_trace(doc)
    assert not problems, problems
    tracer.save(OUT)
    n_spans = sum(e["ph"] == "X" for e in doc["traceEvents"])
    print(f"wrote {OUT}: {n_spans} spans "
          f"({len(doc['traceEvents'])} events) — open at ui.perfetto.dev")

    # --- per-query explain records -------------------------------------
    for r in results:
        ex = r.explain
        print(f"\n=== {r.name} -> {r.estimate:.6g} "
              f"(±{r.halfwidth:.3g}, {r.sched_outcome})")
        print(f"  admission: {ex.admission_reason} | plan={ex.plan} | "
              f"Eq.(4) T_io={ex.cost_t_io_s:.4g}s "
              f"T_cpu={ex.cost_t_cpu_s:.4g}s")
        print(f"  tier     : {ex.tier} — {ex.tier_reason}")
        traj = ex.trajectory
        for s in traj[:3]:
            print(f"  round {s.round:3d}: m={s.m:6d} est={s.est:.6g} "
                  f"ci_halfwidth={s.ci_halfwidth:.4g} b_eff={s.b_eff}")
        if len(traj) > 3:
            s = traj[-1]
            print(f"  ... round {s.round:3d}: m={s.m:6d} est={s.est:.6g} "
                  f"ci_halfwidth={s.ci_halfwidth:.4g}")
        # the full record is JSON-able for dashboards / API responses
        json.dumps(ex.to_dict())

    # --- metrics registry ----------------------------------------------
    print("\n--- metrics (Prometheus text exposition) ---")
    print(server.metrics.to_prometheus().rstrip())
    server.close()


if __name__ == "__main__":
    main()
