"""Beyond-paper: ε-accurate model evaluation with early termination.

    PYTHONPATH=src python examples/ola_eval_demo.py

Evaluates a (reduced) LM's per-token loss over many validation shards with
the bi-level estimator: shards are chunks, examples are tuples, and the eval
stops as soon as the mean loss is pinned to ±2% — typically after a small
fraction of the eval set.
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.ola_ml.eval_ola import ola_eval


def main():
    cfg = get_config("smollm-135m", reduced=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    seq = 64
    loss_of = jax.jit(lambda tok: _per_example_loss(model, params, tok, cfg))

    rng = np.random.default_rng(0)
    shards = [rng.integers(0, cfg.vocab_size, (rng.integers(64, 128), seq + 1))
              .astype(np.int32) for _ in range(24)]

    res = ola_eval(lambda ex: np.asarray(loss_of(jnp.asarray(ex))),
                   shards, epsilon=0.02, batch=32, seed=1)
    total = sum(len(s) for s in shards)
    print(f"estimate      : {res.estimate:.4f}  [{res.lo:.4f}, {res.hi:.4f}]")
    print(f"error ratio   : {res.error_ratio:.4f} (target 0.02)")
    print(f"examples used : {res.examples_used}/{total} "
          f"({100 * res.examples_used / total:.1f}%) across "
          f"{res.shards_used} shards")

    # exhaustive reference
    full = np.concatenate([np.asarray(loss_of(jnp.asarray(s))) for s in shards])
    print(f"exhaustive    : {full.mean():.4f} "
          f"(bias {100 * abs(res.estimate - full.mean()) / full.mean():.2f}%)")


def _per_example_loss(model, params, toks, cfg):
    import repro.models.layers as L

    logits, _ = model.forward(params, toks[:, :-1])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, toks[:, 1:, None], axis=-1)[..., 0]
    return -ll.mean(axis=-1)


if __name__ == "__main__":
    main()
