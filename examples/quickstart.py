"""Quickstart: online aggregation over a raw dataset in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Generates a zipfian raw table (ASCII fixed-width — the CPU-bound EXTRACT
case), runs one SUM query with the resource-aware bi-level engine, and prints
the estimate converging against the exact answer.
"""

import numpy as np

from repro.core import EngineConfig, EstimationController, Linear, Query, Range
from repro.data.generator import make_synthetic_zipf, store_dataset


def main():
    # --- a "raw file": 32k tuples x 16 columns, 64 chunks, ASCII format ----
    values = make_synthetic_zipf(num_tuples=32768, num_cols=16, seed=0)
    store = store_dataset(values, num_chunks=64, fmt="ascii")

    # --- the query: SELECT SUM(Σ c_k·A_k) WHERE A_0 < 5e7, ε = 3% ----------
    coef = tuple(1.0 / (k + 1) for k in range(16))
    query = Query(agg="sum", expr=Linear(coef), pred=Range(0, 0.0, 5e7),
                  epsilon=0.03)
    sel = (values[:, 0] >= 0) & (values[:, 0] < 5e7)
    exact = float((values @ np.asarray(coef)) @ sel)

    # --- run with δ-interval progress reports -------------------------------
    ctrl = EstimationController(
        store, EngineConfig(num_workers=4, strategy="resource_aware", seed=7),
        delta_model_s=0.002)
    result = ctrl.run_query([query])

    print(f"{'t_model(s)':>10} {'estimate':>14} {'error%':>8} {'n':>4} {'m':>7}")
    for r in result.reports:
        print(f"{r.t_model:10.4f} {r.estimate[0]:14.4g} "
              f"{100 * r.err[0]:8.2f} {r.n_chunks:4d} {r.m_tuples:7d}")
    print(f"\nexact answer     : {exact:.6g}")
    print(f"final estimate   : {result.final_estimate[0]:.6g} "
          f"({100 * abs(result.final_estimate[0] - exact) / abs(exact):.2f}% off)")
    print(f"tuples extracted : {100 * result.tuples_ratio:.1f}% of the table")
    print(f"chunks read      : {100 * result.chunks_ratio:.1f}% of the file")


if __name__ == "__main__":
    main()
