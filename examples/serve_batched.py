"""Batched serving demo: continuous batching over any decode-capable arch.

    PYTHONPATH=src python examples/serve_batched.py [--arch zamba2-1.2b]

Runs reduced-config batched decode with slot refill — exercises the KV-cache
ring buffers (SWA), SSM states (hybrid) and matrix memories (xLSTM) through
the same engine.
"""

import argparse
import json
import time

import numpy as np

from repro.configs import get_config
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    eng = ServeEngine(cfg, batch_slots=3, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8)
                    .astype(np.int32), max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    steps = eng.run()
    dt = time.perf_counter() - t0
    print(json.dumps({
        "arch": args.arch, "family": cfg.family,
        "requests": len(reqs), "decode_steps": steps,
        "all_done": all(r.done for r in reqs),
        "tok_per_s": round(sum(len(r.out_tokens) for r in reqs) / dt, 1),
    }, indent=1))
    for r in reqs[:3]:
        print(f"req {r.rid}: {list(r.prompt[:4])}... -> {r.out_tokens}")


if __name__ == "__main__":
    main()
