"""Activation sharding constraints for model code (MaxText-style).

Without explicit constraints GSPMD may resolve FSDP-sharded weights against
batch-sharded activations by *replicating the batch* (all-gathering
activations instead of weights) — compute then scales with the model axis
only and the data axis does redundant work (measured 16x matmul-FLOP
inflation on the 16x16 mesh; see EXPERIMENTS.md §Perf iteration 0).

Models call :func:`constrain` at residual-stream boundaries; outside a
:func:`sharding_scope` it is the identity, so single-device smoke tests and
the engine are unaffected.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


@contextlib.contextmanager
def sharding_scope(mesh: Mesh, batch_axes: tuple = ("pod", "data"),
                   model_axis: str = "model"):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = {"mesh": mesh, "batch_axes": batch_axes, "model_axis": model_axis}
    try:
        yield
    finally:
        _TLS.ctx = prev


def _ctx() -> Optional[dict]:
    return getattr(_TLS, "ctx", None)


def _batch_tuple(mesh: Mesh, batch_axes: tuple, batch: int):
    chosen = []
    size = 1
    for a in batch_axes:
        if a in mesh.shape and batch % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    return tuple(chosen) if chosen else None


def data_group_count(tokens: int) -> int:
    """Number of dispatch groups for grouped (data-axis-local) MoE routing.

    Inside a sharding scope this is the data-axis size (each shard routes its
    own tokens — dispatch and combine become collective-free); outside, 1.
    """
    ctx = _ctx()
    if ctx is None:
        return 1
    g = 1
    for a in ctx["batch_axes"]:
        if a != ctx["model_axis"] and a in ctx["mesh"].shape:
            g *= ctx["mesh"].shape[a]
    while g > 1 and tokens % g != 0:
        g //= 2
    return max(g, 1)


def constrain(x, kind: str):
    """Apply a named constraint if inside a sharding scope.

    kinds:
      "btd"    — (B, S, D) residual stream: batch over data(/pod)
      "btv"    — (B, S, V) logits: batch over data, vocab over model
      "bd"     — (B, D): batch over data
      "ecd"    — (E, C, D) MoE expert buffer: experts over model if divisible
    """
    ctx = _ctx()
    if ctx is None:
        return x
    mesh = ctx["mesh"]
    b_ax = _batch_tuple(mesh, ctx["batch_axes"], x.shape[0])
    m_ax = ctx["model_axis"]
    msize = mesh.shape.get(m_ax, 1)
    if kind == "btd":
        spec = P(b_ax)
    elif kind == "btv":
        v_ok = x.shape[-1] % msize == 0
        spec = P(b_ax, None, m_ax if v_ok else None)
    elif kind == "bd":
        spec = P(b_ax)
    elif kind == "ecd":
        e_ok = x.shape[0] % msize == 0
        spec = P(m_ax if e_ok else None)
    elif kind == "gecd":
        # grouped MoE buffer (G, E, C, d): groups over data, experts over
        # model when the count divides
        e_ok = x.shape[1] % msize == 0 and x.shape[1] >= msize
        spec = P(b_ax, m_ax if e_ok else None)
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
