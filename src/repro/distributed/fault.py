"""Fault tolerance: failure simulation, elastic re-mesh, straggler policy.

On a real fleet the runtime signals are heartbeat timeouts and ICI link
errors; here the same control flow is driven by a :class:`FailureInjector`
so every path is testable on CPU:

* **checkpoint/restart** — trainer saves atomically every N steps; on
  (injected) failure the driver rebuilds a mesh from the surviving device
  count and restores — `checkpoint.restore` reshards onto the new mesh.
* **elastic re-mesh** — :func:`best_mesh_shape` picks the largest valid
  (data, model) grid for the surviving chips, keeping the model axis intact
  first (TP size is fixed by weight shapes), then shrinking data parallelism.
  Global batch is preserved by raising gradient-accumulation steps.
* **straggler mitigation** — the OLA engine's global chunk queue is already
  straggler-proof (slow workers claim fewer chunks; DESIGN.md §3); for
  training, :func:`rebalance_accum` adjusts per-host microbatch counts from
  observed step times (simulated in tests).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples."""

    fail_at_steps: tuple = ()
    kill_devices: int = 0
    _tripped: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> Optional[int]:
        """Returns surviving device delta if a failure fires at this step."""
        if step in self.fail_at_steps and step not in self._tripped:
            self._tripped.add(step)
            return self.kill_devices
        return None


def best_mesh_shape(n_devices: int, model_axis: int,
                    pod_axis: int = 1) -> tuple:
    """Largest (pod, data, model) grid for the surviving chip count.

    The model axis is load-bearing (weight shard shapes) so it is preserved;
    data parallelism absorbs the loss.  Raises if fewer than one model group
    survives.
    """
    per_pod = n_devices // max(pod_axis, 1)
    data = per_pod // model_axis
    if data < 1:
        # not enough chips for one model replica in each pod: collapse pods
        pod_axis = 1
        data = n_devices // model_axis
    if data < 1:
        raise RuntimeError(
            f"cannot fit model axis {model_axis} on {n_devices} devices")
    if pod_axis > 1:
        return (pod_axis, data, model_axis)
    return (data, model_axis)


def preserved_global_batch(global_batch: int, old_data: int,
                           new_data: int) -> tuple[int, int]:
    """(per_step_batch, accum_steps) preserving the optimizer-visible batch
    after data-parallel shrink."""
    if global_batch % new_data != 0:
        # round batch down to a shardable size (documented drift)
        global_batch = (global_batch // new_data) * new_data
    accum = max(int(np.ceil(old_data / new_data)), 1)
    return global_batch, accum


def rebalance_accum(step_times_per_host: np.ndarray,
                    base_accum: int) -> np.ndarray:
    """Straggler-aware microbatch counts: hosts slower than the median get
    proportionally fewer microbatches (work stays globally constant)."""
    t = np.asarray(step_times_per_host, np.float64)
    speed = np.median(t) / np.maximum(t, 1e-9)
    raw = base_accum * speed
    out = np.maximum(np.round(raw / raw.mean() * base_accum), 1).astype(int)
    return out
