"""Logical-axis → mesh-axis sharding rules (MaxText-style).

The model zoo annotates every parameter with logical axis names
(models/layers.py).  This module maps them onto the production mesh:

* ``model`` axis — tensor parallelism: "vocab", "q_heads", "mlp",
  "heads_ssm", and "experts" (pure EP when the expert count divides the
  axis; otherwise experts stay unsharded and their FFN shards on "mlp").
* ``data`` axis — FSDP: the "embed" (d_model) dimension of weight matrices
  shards over data, so parameters AND optimizer state scale down with the
  full chip count (granite-34b + f32 Adam does not fit per-chip HBM under
  pure TP).  XLA/GSPMD inserts the weight all-gathers; overlapping them is
  a §Perf item.
* ``pod`` axis — outer data parallelism only (batch); params are replicated
  across pods and gradients all-reduce hierarchically.

Families can override: xLSTM replicates everything (heads=4, d_model=768 —
TP would pad 4x; batch shards over both axes instead, DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict
    batch_axes: tuple = ("pod", "data")   # activation batch sharding
    replicate_params: bool = False

    def axis_for(self, logical: str) -> Optional[str]:
        return None if self.replicate_params else self.rules.get(logical)


DEFAULT_RULES = {
    "vocab": "model",
    "q_heads": "model",
    "mlp": "model",
    "mlp2": "model",
    "experts": "model",
    "experts_unsharded": None,
    "router_experts": None,
    "kv_heads": None,       # replicated under TP (exact GQA)
    "head": None,
    "embed": "data",        # FSDP: weight matrices shard d_model over data
    "embed2": "data",
    "heads_ssm": "model",
    "state": None,
    "conv": None,
    "layers": None,
    "sites": None,
    "pos": None,
}

# Dimensions that may stay unsharded when not divisible (fall back gracefully
# instead of erroring): everything — divisibility is checked per-array below.


def rules_for(family: str) -> ShardingRules:
    if family == "xlstm":
        return ShardingRules(rules={}, replicate_params=True,
                             batch_axes=("pod", "data", "model"))
    return ShardingRules(rules=DEFAULT_RULES)


def _spec_for_array(shape, axes, rules: ShardingRules, mesh: Mesh) -> P:
    parts = []
    used = set()
    for dim, logical in zip(shape, axes):
        mesh_axis = rules.axis_for(logical)
        if (mesh_axis is not None and mesh_axis in mesh.shape
                and mesh_axis not in used
                and dim % mesh.shape[mesh_axis] == 0):
            parts.append(mesh_axis)
            used.add(mesh_axis)
        else:
            parts.append(None)
    # drop trailing Nones for tidiness
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def logical_to_sharding(shape, axes, rules: ShardingRules,
                        mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, _spec_for_array(shape, axes, rules, mesh))


def param_shardings(params, specs, rules: ShardingRules, mesh: Mesh):
    """Pytree of NamedShardings matching ``params`` (specs carries the
    logical-axes tuples; leaves of specs are tuples of str)."""

    def one(ax, p):
        return logical_to_sharding(p.shape, ax, rules, mesh)

    # map over specs first: its leaves (axis tuples) are pytree nodes, so the
    # is_leaf predicate must run against the specs tree, not params
    return jax.tree.map(one, specs, params,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, str) for a in x))


def activation_sharding(mesh: Mesh, rules: ShardingRules, batch: int,
                        *trailing) -> NamedSharding:
    """Batch-sharded activation spec: batch over the configured axes (those
    present in the mesh and dividing the batch), trailing dims unsharded."""
    axes = [a for a in rules.batch_axes if a in mesh.shape]
    size = 1
    chosen = []
    for a in axes:
        if batch % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    spec = P(tuple(chosen) if chosen else None, *trailing)
    return NamedSharding(mesh, spec)


def cache_sharding(mesh: Mesh, cache_leaf_shape, batch_dim: int,
                   seq_dim: Optional[int], heads_dim: Optional[int],
                   batch: int) -> NamedSharding:
    """Serve-cache sharding: batch→data when divisible; heads→model when the
    (padded) head count divides, else seq→model (distributed attention over
    the cache — GSPMD inserts the partial-softmax collectives)."""
    ndim = len(cache_leaf_shape)
    parts: list = [None] * ndim
    if batch % mesh.shape.get("data", 1) == 0 and batch > 1:
        parts[batch_dim] = "data"
    msize = mesh.shape.get("model", 1)
    if (heads_dim is not None and cache_leaf_shape[heads_dim] % msize == 0
            and cache_leaf_shape[heads_dim] >= msize):
        parts[heads_dim] = "model"
    elif seq_dim is not None and cache_leaf_shape[seq_dim] % msize == 0:
        parts[seq_dim] = "model"
    while parts and parts[-1] is None:
        parts.pop()
    return NamedSharding(mesh, P(*parts))
