"""Gradient compression hooks with error feedback.

For cross-pod (DCI) bandwidth-bound training: compress gradients before the
optimizer sees them; the quantization error is fed back into the next step
(error feedback keeps SGD-style convergence guarantees — Karimireddy et al.
2019).  Two codecs:

* :func:`int8_compressor` — per-tensor symmetric int8 quantization (8x
  bandwidth reduction on the pod-axis all-reduce; the dequantized gradient
  is what the all-reduce effectively transports).
* :func:`topk_compressor` — magnitude top-k sparsification (k as a fraction),
  the rest accumulates in the error buffer.

Both are pure functions usable inside jit; they compose with
``make_train_step(compressor=...)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _quant_dequant_int8(g: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def int8_compressor(grads, err):
    """Error-feedback int8: transmit quant(g + e), keep the residual."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        dq = _quant_dequant_int8(g32)
        return dq.astype(g.dtype), g32 - dq

    grads_out = jax.tree.map(lambda g, e: one(g, e)[0], grads, err)
    err_out = jax.tree.map(lambda g, e: one(g, e)[1], grads, err)
    return grads_out, err_out


def topk_compressor(grads, err, frac: float = 0.01):
    """Error-feedback magnitude top-k (per tensor)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        flat = g32.reshape(-1)
        k = max(int(frac * flat.size), 1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(g32) >= thresh, g32, 0.0)
        return kept.astype(g.dtype), g32 - kept

    out_g = jax.tree.map(lambda g, e: one(g, e)[0], grads, err)
    out_e = jax.tree.map(lambda g, e: one(g, e)[1], grads, err)
    return out_g, out_e


def get_compressor(name: str):
    return {"none": None, "int8": int8_compressor,
            "topk": functools.partial(topk_compressor, frac=0.01)}[name]
