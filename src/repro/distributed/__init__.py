"""Distribution plane: sharding rules, compression, fault tolerance."""

from repro.distributed.sharding import (
    ShardingRules,
    activation_sharding,
    logical_to_sharding,
    param_shardings,
    rules_for,
)

__all__ = [
    "ShardingRules",
    "activation_sharding",
    "logical_to_sharding",
    "param_shardings",
    "rules_for",
]
