"""Sharded, atomic, restart-safe checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
    manifest.msgpack     — tree structure, shapes, dtypes, mesh info, step,
                           data-pipeline state (chunk queue head, rng)
    arrays.npz           — flat leaf arrays (addressable shards gathered;
                           single-process host → full arrays)
    COMMIT               — written last; a checkpoint without COMMIT is
                           ignored on restore (atomic-commit protocol)

Fault-tolerance contract: restore() maps saved arrays onto *whatever mesh
the new process brings up* — an elastic restart after losing a pod reshards
automatically because shardings are reconstructed from the new mesh, not
from the manifest.
"""

from __future__ import annotations

import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}

    def part(p):
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                return str(getattr(p, attr))
        return str(p)

    for path, leaf in flat:
        out["/".join(part(p) for p in path)] = leaf
    return out


def save(directory: str, step: int, state, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    """Write an atomic checkpoint; prune old ones to ``keep``."""
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves.items()
              if hasattr(v, "shape")}
    scalars = {k: v for k, v in leaves.items() if not hasattr(v, "shape")}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": int(step),
        "scalars": {k: (v if isinstance(v, (int, float, str, bool)) else None)
                    for k, v in scalars.items()},
        "extra": extra or {},
        "keys": sorted(arrays.keys()),
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # prune
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
    return final


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMIT")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree template).

    ``shardings`` (same structure or prefix) places arrays on the *current*
    mesh — this is where elastic resharding happens.
    """
    path = os.path.join(directory, f"step_{step}")
    assert os.path.exists(os.path.join(path, "COMMIT")), \
        f"checkpoint {path} not committed"
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}

    leaves_like = _flatten_with_paths(like)
    flat, treedef = jax.tree_util.tree_flatten(like)
    keys_in_order = list(leaves_like.keys())
    assert len(keys_in_order) == len(flat)

    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    new_leaves = []
    for key, template, shard in zip(keys_in_order, flat, shard_flat):
        if key in arrays:
            arr = arrays[key]
            if shard is not None:
                arr = jax.device_put(jnp.asarray(arr), shard)
            new_leaves.append(arr)
        else:
            new_leaves.append(template)   # e.g. newly-added state fields
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_extra(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read(), strict_map_key=False)["extra"]
