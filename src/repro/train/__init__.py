"""Training plane: optimizer, train step, checkpointing, trainer loop."""

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.train_step import TrainState, make_train_step

__all__ = ["AdamWConfig", "TrainState", "adamw_init", "adamw_update",
           "make_train_step"]
