"""The jitted training step: loss → grads → (optional compression) → AdamW.

Gradient all-reduce across ``data``/``pod`` axes is implicit in GSPMD (the
batch is sharded, parameters are not replicated along those axes except
across pods); the optional error-feedback compression hook quantizes
gradients before the update for bandwidth-bound regimes (DESIGN.md §7).

Microbatching: ``accum_steps > 1`` splits the per-step batch and accumulates
grads in f32 via ``lax.scan`` — activation memory scales with the microbatch
while the optimizer sees the full global batch.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, OptState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: dict
    opt: OptState
    step: jnp.ndarray
    compress_error: Optional[dict] = None   # error-feedback residual


def init_train_state(params, compress: bool = False) -> TrainState:
    err = jax.tree.map(jnp.zeros_like, params) if compress else None
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32), compress_error=err)


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    accum_steps: int = 1,
                    compressor=None) -> Callable:
    """loss_fn(params, batch) -> scalar.  Returns step(state, batch)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: TrainState, batch):
        if accum_steps > 1:
            def micro(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(state.params, mb)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                    grad_acc, grads)
                return (loss_acc + loss / accum_steps, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zeros), mbs)
        else:
            loss, grads = grads_of(state.params, batch)

        err = state.compress_error
        if compressor is not None:
            grads, err = compressor(grads, err)

        params, opt, metrics = adamw_update(opt_cfg, state.params, grads,
                                            state.opt)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1,
                               compress_error=err)
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return step
