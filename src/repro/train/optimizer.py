"""AdamW with warmup-cosine schedule and global-norm clipping.

Hand-rolled (no optax dependency): moments live in the same sharding as the
parameters, so FSDP sharding of "embed" dims scales optimizer memory with
the full chip count.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    mu: dict
    nu: dict
    step: jnp.ndarray


def adamw_init(params) -> OptState:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return OptState(mu=zeros(), nu=zeros(), step=jnp.zeros((), jnp.int32))


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, params, grads, opt: OptState):
    """-> (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    # three passes so leaf tuples in model pytrees can't confuse un-zipping;
    # XLA CSEs the duplicated arithmetic under jit.
    new_params = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[0],
                              params, grads, opt.mu, opt.nu)
    new_mu = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[1],
                          params, grads, opt.mu, opt.nu)
    new_nu = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[2],
                          params, grads, opt.mu, opt.nu)
    return (new_params, OptState(mu=new_mu, nu=new_nu, step=step),
            {"grad_norm": gnorm, "lr": lr})
