"""Trainer: segment-gated training loop with checkpoint/restart and failure
injection.

Flow per segment (the production ingest pattern, DESIGN.md §2):

    1. OLA ingest gate verifies the segment's raw metadata table (PTF-style
       HAVING sequence, ε-accurate, early-terminated).  Rejected segments
       are skipped *before* any tokenization or training FLOPs.
    2. Admitted segments stream batches through the jitted train step.
    3. Atomic checkpoints every ``ckpt_every`` steps; the failure injector
       can kill "devices" at a step boundary, triggering the elastic-restart
       path (rebuild mesh via best_mesh_shape → restore → continue).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.corpus import SyntheticCorpus, standard_ingest_queries
from repro.distributed.fault import FailureInjector, best_mesh_shape
from repro.models import build_model
from repro.ola_ml.verify import IngestGate
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps_per_segment: int = 20
    batch: int = 8
    seq_len: int = 128
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    max_steps: int = 10_000
    seed: int = 0
    gate_epsilon: float = 0.05


class Trainer:
    def __init__(self, model_cfg: ModelConfig, tcfg: TrainerConfig,
                 opt_cfg: AdamWConfig = AdamWConfig(),
                 injector: Optional[FailureInjector] = None):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg
        self.injector = injector
        self.model = build_model(model_cfg)
        self.gate = IngestGate(standard_ingest_queries(tcfg.gate_epsilon))
        self.step_fn = jax.jit(
            make_train_step(self.model.loss, opt_cfg), donate_argnums=(0,))
        self.restarts = 0
        self.log: list[dict] = []

    def init_state(self):
        params, _ = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        return init_train_state(params)

    def run(self, corpus: SyntheticCorpus, state=None) -> dict:
        tcfg = self.tcfg
        state = state or self.init_state()
        step = int(state.step)
        admitted = rejected = 0
        t0 = time.perf_counter()

        for seg in corpus.segments:
            if step >= tcfg.max_steps:
                break
            decision = self.gate.check(seg.meta_store)
            self.log.append({"event": "gate", "segment": seg.index,
                             "admitted": decision.admitted,
                             "tuples_ratio": decision.tuples_ratio,
                             "failed": decision.failed_query})
            if not decision.admitted:
                rejected += 1
                continue
            admitted += 1
            for batch in corpus.batches(seg, tcfg.batch, tcfg.seq_len,
                                        tcfg.steps_per_segment,
                                        seed=tcfg.seed):
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                state, metrics = self.step_fn(state, batch)
                step += 1
                self.log.append({"event": "step", "step": step,
                                 "loss": float(metrics["loss"]),
                                 "grad_norm": float(metrics["grad_norm"])})
                if tcfg.ckpt_dir and step % tcfg.ckpt_every == 0:
                    ckpt.save(tcfg.ckpt_dir, step, state,
                              extra={"segment": seg.index})
                if self.injector is not None:
                    delta = self.injector.check(step)
                    if delta is not None:
                        state = self._recover(state, delta)
                        self.restarts += 1
                if step >= tcfg.max_steps:
                    break

        losses = [e["loss"] for e in self.log if e["event"] == "step"]
        return {
            "steps": step,
            "admitted": admitted,
            "rejected": rejected,
            "restarts": self.restarts,
            "first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "wall_s": time.perf_counter() - t0,
            "state": state,
        }

    # ---------------------------------------------------------- recovery --
    def _recover(self, state, killed_devices: int):
        """Simulated failure: rebuild a smaller mesh (single-host: recompute
        the would-be mesh shape for the surviving count), restore the last
        committed checkpoint — or reuse live state when no ckpt_dir is set."""
        n_dev = max(len(jax.devices()) - killed_devices, 1)
        shape = best_mesh_shape(n_dev, model_axis=1)
        self.log.append({"event": "failure", "survivors": n_dev,
                         "new_mesh": shape})
        if self.tcfg.ckpt_dir:
            last = ckpt.latest_step(self.tcfg.ckpt_dir)
            if last is not None:
                template = jax.tree.map(np.asarray, state)
                return ckpt.restore(self.tcfg.ckpt_dir, last, template)
        return state
