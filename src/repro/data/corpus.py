"""Training corpus with a raw metadata plane for OLA verification.

A :class:`SyntheticCorpus` is organized in *segments* (the ingest unit); each
segment carries

* a token payload — (num_docs, doc_len) int32 synthetic token sequences, and
* a **raw metadata table** — one row per document in fixed-width ASCII
  (columns: doc_len, quality, lang_id, dup_score, tok_entropy, src_id), i.e.
  exactly the kind of per-record raw file the paper's engine samples.

The trainer's ingest gate (ola_ml/verify.py) runs the PTF-style verification
sequence over the metadata ChunkStore of each segment before any training
step touches its tokens.  Quality statistics vary by segment so some segments
genuinely fail verification (segments with ``poison=True``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.chunkstore import ChunkStore
from repro.data.generator import store_dataset


@dataclasses.dataclass
class Segment:
    index: int
    tokens: np.ndarray         # (docs, doc_len) int32
    meta_store: ChunkStore     # raw metadata table
    poison: bool


class SyntheticCorpus:
    def __init__(self, vocab: int, num_segments: int = 8,
                 docs_per_segment: int = 512, doc_len: int = 256,
                 meta_chunks: int = 16, poison_every: int = 3,
                 seed: int = 0):
        self.vocab = vocab
        self.doc_len = doc_len
        self.segments: list[Segment] = []
        rng = np.random.default_rng(seed)
        for si in range(num_segments):
            poison = poison_every > 0 and (si % poison_every == poison_every - 1)
            toks = self._sample_tokens(rng, docs_per_segment, doc_len, vocab)
            meta = self._sample_meta(rng, docs_per_segment, poison)
            store = store_dataset(meta, meta_chunks, "ascii",
                                  name=f"seg{si}", seed=seed + si)
            self.segments.append(Segment(si, toks, store, poison))

    @staticmethod
    def _sample_tokens(rng, docs, doc_len, vocab):
        # cheap order-0 zipfian token stream — enough for loss curves
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = ranks ** -1.1
        p /= p.sum()
        return rng.choice(vocab, size=(docs, doc_len), p=p).astype(np.int32)

    @staticmethod
    def _sample_meta(rng, docs, poison):
        doc_len = rng.integers(16, 2048, docs).astype(np.float64)
        quality = rng.beta(8, 2 if not poison else 6, docs) * 100.0
        lang_id = rng.integers(0, 30, docs).astype(np.float64)
        dup = rng.beta(1, 20 if not poison else 3, docs) * 100.0
        ent = rng.normal(7.0 if not poison else 4.5, 0.8, docs)
        src = rng.integers(0, 12, docs).astype(np.float64)
        return np.stack([doc_len, quality, lang_id, dup, ent, src], axis=1)

    def batches(self, segment: Segment, batch: int, seq_len: int, steps: int,
                seed: int = 0):
        """Yield {tokens, labels} batches from a verified segment."""
        rng = np.random.default_rng(seed + segment.index)
        docs, dl = segment.tokens.shape
        reps = max(1, int(np.ceil(seq_len + 1) / dl))
        for _ in range(steps):
            rows = rng.integers(0, docs, size=(batch, reps + 1))
            flat = segment.tokens[rows].reshape(batch, -1)
            out = flat[:, : seq_len + 1]
            yield {"tokens": out[:, :-1].astype(np.int32),
                   "labels": out[:, 1:].astype(np.int32)}


# Verification battery (the PTF analogy, Section 1): each query must pass for
# the segment to be admitted.  Columns: 0 len, 1 quality, 2 lang, 3 dup,
# 4 entropy, 5 src.
def standard_ingest_queries(epsilon: float = 0.05):
    from repro.core.queries import Column, Having, Query, Range, TRUE

    return [
        # mean quality high enough
        Query(agg="avg", expr=Column(1), pred=TRUE,
              having=Having(">", 75.0), epsilon=epsilon, name="avg_quality"),
        # near-duplicate mass below threshold
        Query(agg="avg", expr=Column(3), pred=TRUE,
              having=Having("<", 10.0), epsilon=epsilon, name="avg_dup"),
        # token entropy sane
        Query(agg="avg", expr=Column(4), pred=TRUE,
              having=Having(">", 6.0), epsilon=epsilon, name="avg_entropy"),
    ]
