"""Streaming slab pipeline: bounded-memory chunk delivery store → engine.

The paper's READ stage streams random chunks off disk while EXTRACT/EVALUATE
keep the CPU busy (§4; PF-OLA's overlapped parallel aggregation makes the
same bet).  :class:`SlabPrefetcher` is that stage for the jitted engines:
instead of materializing the whole store as one padded ``(N, M_max, rec)``
device tensor (``ChunkStore.packed_device_view`` — the
``EngineConfig.residency="packed"`` path, fine for small stores), each round
receives a bounded ``(W, rows_max, rec)`` uint8 *slab* holding exactly the
chunks the round's workers will extract from.

Round protocol (``residency="stream"``):

1. the host predicts the round's CLAIM outcome with
   :meth:`~repro.core.engine.EngineProgram.plan_claims` — the claim rule is a
   pure function of ``(cur, head, schedule)``, so the prediction is exact and
   the jitted round's own CLAIM lands on the same chunks;
2. :meth:`SlabPrefetcher.assemble` builds the slab from its host chunk cache
   (disk-backed chunks are read on the fly and *evicted from the store*, so
   host residency is O(slab), never O(dataset)) and ``device_put``\\ s it;
3. the engine hints the next schedule positions via :meth:`prefetch`; a
   background reader thread pulls those chunks from disk while the device is
   busy with the current round — the READ/compute overlap of the paper's
   pipeline.

Memory bounds: device residency is the in-flight slab plus (transiently) the
previous round's — ``2 × slab_bytes`` of raw data instead of the packed
view's ``N × M_max × rec``; host residency is the LRU chunk cache
(``max_cached_chunks``, default ``2·W + lookahead`` chunks).
"""

from __future__ import annotations

import math
import queue
import threading
import time
import weakref
from collections import OrderedDict
from typing import Callable, Iterable, Optional

import numpy as np

from repro.data.faults import RetryPolicy
from repro.obs.trace import NULL_TRACER


def device_resident_bytes(dtype=None) -> int:
    """Total bytes of live JAX device arrays (optionally one dtype only).

    ``dtype=np.uint8`` isolates the raw-data buffers (packed views / slabs)
    from the f32 state pytrees — the number the streaming-residency tests and
    benchmarks report.
    """
    import jax

    total = 0
    want = None if dtype is None else np.dtype(dtype)
    for a in jax.live_arrays():
        if want is not None and a.dtype != want:
            continue
        total += int(np.prod(a.shape)) * a.dtype.itemsize
    return total


def peak_host_rss_bytes() -> int:
    """Peak resident-set size of this process (Linux/macOS)."""
    import resource
    import sys

    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # ru_maxrss is KiB on Linux, bytes on macOS
    return int(ru) if sys.platform == "darwin" else int(ru) * 1024


class DecodedChunkCache:
    """Budgeted parse-once cache of decoded ``(rows, C)`` float32 blocks.

    The first time a chunk is extracted its decoded block is retained here
    (up to ``budget_bytes``); later rounds feed the decoded-input slot-eval
    kernel and skip tokenize/parse entirely.  Eviction is **cost-aware**:
    victims minimize ``extract_cost_per_tuple × touch-frequency / recency
    age``, so an ASCII chunk (≈3360 ns/tuple to re-extract) is worth ~25×
    more residency than a binary one (≈32 ns/tuple) at equal touch history.

    The cache pins the store's ``content_version`` (the same invalidation
    contract the rollup tier uses): :meth:`check_version` clears everything
    on a bump, so out-of-band re-ingests can never serve stale decodes.
    """

    def __init__(self, budget_bytes: int, cost_per_tuple: float = 1.0):
        self.budget_bytes = int(budget_bytes)
        self.cost_per_tuple = float(cost_per_tuple)
        self._blocks: dict[int, np.ndarray] = {}
        self._cost: dict[int, float] = {}
        self._hits: dict[int, int] = {}
        self._last: dict[int, int] = {}
        self._clock = 0
        self._version: Optional[int] = None
        self.bytes_cached = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._blocks)

    def __contains__(self, j: int) -> bool:
        return j in self._blocks

    @property
    def tuples_cached(self) -> int:
        return sum(b.shape[0] for b in self._blocks.values())

    def check_version(self, version: int) -> None:
        """Pin/verify the store content version; clear on mismatch."""
        if self._version is None:
            self._version = version
        elif version != self._version:
            self.clear()
            self._version = version

    def get(self, j: int) -> Optional[np.ndarray]:
        blk = self._blocks.get(j)
        if blk is not None:
            self._clock += 1
            self._hits[j] += 1
            self._last[j] = self._clock
        return blk

    def _score(self, j: int) -> float:
        age = self._clock - self._last[j] + 1
        return self._cost[j] * self._hits[j] / age

    def put(self, j: int, block: np.ndarray,
            cost_per_tuple: Optional[float] = None) -> bool:
        """Admit a decoded block, evicting lowest-score victims to fit."""
        nb = int(block.nbytes)
        if j in self._blocks or nb > self.budget_bytes:
            return False
        self._clock += 1
        while self.bytes_cached + nb > self.budget_bytes and self._blocks:
            victim = min(self._blocks, key=self._score)
            self.drop(victim)
            self.evictions += 1
        self._blocks[j] = block
        self._cost[j] = (self.cost_per_tuple if cost_per_tuple is None
                         else float(cost_per_tuple))
        self._hits[j] = 1
        self._last[j] = self._clock
        self.bytes_cached += nb
        return True

    def drop(self, j: int) -> bool:
        """Remove one chunk (quarantine / invalidation hook)."""
        blk = self._blocks.pop(j, None)
        if blk is None:
            return False
        self.bytes_cached -= int(blk.nbytes)
        self._cost.pop(j, None)
        self._hits.pop(j, None)
        self._last.pop(j, None)
        return True

    def clear(self) -> None:
        self._blocks.clear()
        self._cost.clear()
        self._hits.clear()
        self._last.clear()
        self.bytes_cached = 0


class SlabPrefetcher:
    """Assembles bounded per-round slabs from a :class:`ChunkStore`.

    One instance serves one engine: ``num_workers`` fixes the slab's leading
    dim, ``row_multiple`` pads ``rows_max`` up to the streaming kernel's row
    tile so block shapes stay stable.  ``device_put`` lets the SPMD engines
    place the slab sharded over the mesh's worker axis.

    With ``decoded_cache_bytes > 0`` the prefetcher additionally maintains a
    :class:`DecodedChunkCache` and :meth:`assemble` returns a *mixed
    raw/decoded* slab triple ``(raw (W,R,rec) u8, dec (W,R,C) f32,
    is_decoded (W,) bool)``: cached workers get their decoded rows (no disk
    read, no parse), the rest get raw bytes as before.

    Counter lifecycle (``COUNTER_FIELDS``): the monitoring counters are
    cumulative over the prefetcher's *lifetime* — they survive ``close()``
    and reader-thread exit, and are zeroed only by an explicit
    :meth:`reset_counters` call.  :meth:`bind_metrics` exposes them on a
    :class:`~repro.obs.metrics.MetricsRegistry` as pull gauges (values read
    at snapshot time, zero hot-path writes).
    """

    #: Monotone counter attributes — the single source of truth for the
    #: counter block's lifecycle contract (see class docstring).
    COUNTER_FIELDS = (
        "chunk_reads", "cache_hits", "bytes_read", "slabs_built",
        "decoded_hits", "decoded_misses", "decoded_fills",
        "extract_tuples_avoided", "read_retries", "read_failures",
    )

    def __init__(self, store, num_workers: int, row_multiple: int = 1,
                 lookahead: int = 8, max_cached_chunks: Optional[int] = None,
                 device_put: Optional[Callable] = None,
                 adaptive: bool = False,
                 max_lookahead: Optional[int] = None,
                 retry: Optional[RetryPolicy] = None,
                 decoded_cache_bytes: int = 0):
        self.store = store
        self.retry = retry if retry is not None else RetryPolicy()
        self.num_workers = int(num_workers)
        rb = int(store.codec.record_bytes)
        rows = int(store.max_chunk_tuples)
        rm = max(int(row_multiple), 1)
        self.rows_max = int(math.ceil(rows / rm) * rm)
        self.slab_shape = (self.num_workers, self.rows_max, rb)
        self.slab_bytes = int(np.prod(self.slab_shape))
        self.lookahead = int(lookahead)
        # adaptive lookahead (measured READ/CPU ratio): ``lookahead`` floats
        # between the configured base and ``max_lookahead`` based on how
        # many rounds one chunk READ spans — a slow disk raises it so the
        # reader thread stays ahead of the scan, a fast one keeps the host
        # cache small.  The cache capacity is provisioned for the ceiling.
        self.adaptive = bool(adaptive)
        self.base_lookahead = self.lookahead
        self.max_lookahead = int(max_lookahead
                                 or max(4 * self.lookahead,
                                        2 * self.num_workers))
        cap_lookahead = self.max_lookahead if self.adaptive else self.lookahead
        self.capacity = int(max_cached_chunks
                            or (2 * self.num_workers + cap_lookahead))
        # READ/CPU rate probes (wall clock): cumulative seconds spent in
        # chunk reads, and an EMA of the inter-assemble gap (≈ one round's
        # compute+step time) and of the chunks consumed per round
        self.read_seconds = 0.0
        self._round_s: Optional[float] = None
        self._claims_per_round = 1.0
        self._last_assemble_t: Optional[float] = None
        if device_put is None:
            import jax

            device_put = jax.device_put
        self._device_put = device_put
        self._cache: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()
        self._inflight: dict[int, threading.Event] = {}
        self._hints: "queue.SimpleQueue[Optional[int]]" = queue.SimpleQueue()
        self._closed = False
        # ring of pre-allocated slab buffers (zero-copy assembly): disk
        # bytes readinto() the target slab slice directly, and the two-deep
        # ring preserves the double-buffer slack — the previous round's
        # async device_put source is never touched by the current round
        self._ring = [np.zeros(self.slab_shape, np.uint8) for _ in range(2)]
        self._ring_i = 0
        # the zero-copy readinto path must honor store *wrappers* (fault
        # injection, pacing proxies) that intercept chunk_bytes via
        # __getattr__ delegation — so it is taken only when the store's own
        # class implements read_chunk_into
        self._direct_readinto = any(
            "read_chunk_into" in k.__dict__ for k in type(store).__mro__)
        # parse-once decoded-chunk cache (budget 0 = off, the parity default)
        self._num_cols = int(store.codec.num_cols)
        if int(decoded_cache_bytes) > 0:
            self.decoded: Optional[DecodedChunkCache] = DecodedChunkCache(
                int(decoded_cache_bytes),
                cost_per_tuple=float(store.codec.extract_cost_per_tuple()))
            self._dec_ring = [
                np.zeros((self.num_workers, self.rows_max, self._num_cols),
                         np.float32) for _ in range(2)]
        else:
            self.decoded = None
            self._dec_ring = None
        self._empty_slab_dev = None  # lazy (W, 0, rec) raw leaf, all-dec rounds
        self._last_assembled: dict[int, int] = {}
        # span tracer (host-side; NULL_TRACER = one method call when off)
        self.tracer = NULL_TRACER
        # counters (monitoring / tests) — cumulative for the prefetcher's
        # lifetime; see COUNTER_FIELDS for the lifecycle contract.  The
        # fault slice covers retried reads, reads that exhausted their
        # retries, and the per-chunk error slot the reader thread stashes
        # into (re-raised — after one more synchronous retried attempt —
        # at assemble() time instead of being silently swallowed)
        for _f in self.COUNTER_FIELDS:
            setattr(self, _f, 0)
        self.read_errors: dict[int, Exception] = {}
        # the reader holds only a weakref: an engine dropped without close()
        # lets the prefetcher be GC'd, upon which the thread exits on its
        # next poll instead of pinning the cache for the process lifetime
        self._reader = threading.Thread(target=_reader_main,
                                        args=(weakref.ref(self), self._hints),
                                        daemon=True, name="slab-prefetcher")
        self._reader.start()

    # ------------------------------------------------------------- reads ----
    def _read_chunk(self, j: int) -> np.ndarray:
        """READ one chunk; hits the host cache, else disk (+ store eviction
        so a disk-backed store never accumulates resident raw chunks)."""
        while True:
            with self._lock:
                raw = self._cache.get(j)
                if raw is not None:
                    self._cache.move_to_end(j)
                    self.cache_hits += 1
                    return raw
                ev = self._inflight.get(j)
                if ev is None:
                    ev = self._inflight[j] = threading.Event()
                    mine = True
                else:
                    mine = False
            if not mine:
                ev.wait()
                continue  # re-check the cache (entry may have been trimmed)
            try:
                t0 = time.perf_counter()

                def _verified_read():
                    raw = self.store.chunk_bytes(j)
                    # end-to-end integrity: verify against the manifest CRC
                    # even when the bytes came through a wrapper (the store
                    # itself only checks its own disk boundary)
                    verify = getattr(self.store, "verify_chunk", None)
                    if verify is not None:
                        verify(j, raw)
                    return raw

                with self.tracer.span("READ", chunk=j):
                    raw, retries = self.retry.call(_verified_read, j)
                self.store.evict(j)  # host residency stays O(slab)
                dt = time.perf_counter() - t0
                with self._lock:
                    self.chunk_reads += 1
                    self.read_retries += retries
                    self.read_errors.pop(j, None)
                    self.bytes_read += raw.nbytes
                    self.read_seconds += dt
                    self._cache[j] = raw
                    self._cache.move_to_end(j)
                    while len(self._cache) > self.capacity:
                        self._cache.popitem(last=False)
                return raw
            except Exception as e:
                with self._lock:
                    self.read_retries += int(getattr(e, "retries", 0))
                raise
            finally:
                with self._lock:
                    self._inflight.pop(j, None)
                ev.set()

    # ------------------------------------------------------------ public ----
    def prefetch(self, chunk_ids: Iterable[int]) -> None:
        """Hint upcoming chunks: the reader thread pulls them off disk while
        the device computes the current round (READ/compute overlap)."""
        if self._closed:
            return
        n = 0
        for j in chunk_ids:
            self._hints.put(int(j))
            n += 1
        if n and self.tracer.enabled:
            self.tracer.event("prefetch_hint", n=n)

    def _fill_raw(self, j: int, out_rows: np.ndarray) -> np.ndarray:
        """Fill ``out_rows[:rows]`` with chunk ``j``'s bytes in place.

        Host-cache (or in-flight) chunks copy out of the cache; cold
        disk-backed chunks ``readinto()`` the file directly into the slab
        slice — the zero-copy path (retry + end-to-end CRC included, the
        read happens inside :meth:`ChunkStore.read_chunk_into`).
        """
        with self._lock:
            raw = self._cache.get(j)
            if raw is not None:
                self._cache.move_to_end(j)
                self.cache_hits += 1
            inflight = j in self._inflight
        if raw is None and not inflight and self._direct_readinto:
            t0 = time.perf_counter()
            with self.tracer.span("READ", chunk=j, zero_copy=1):
                view, retries = self.retry.call(
                    lambda: self.store.read_chunk_into(j, out_rows), j)
            dt = time.perf_counter() - t0
            with self._lock:
                self.chunk_reads += 1
                self.read_retries += retries
                self.read_errors.pop(j, None)
                self.bytes_read += view.nbytes
                self.read_seconds += dt
            return view
        if raw is None:
            raw = self._read_chunk(j)
        out_rows[: raw.shape[0]] = raw
        return out_rows[: raw.shape[0]]

    def _maybe_fill_decoded(self, j: int, raw: np.ndarray) -> None:
        """Parse-once: retain chunk ``j``'s decoded block on first extract."""
        if self.decoded is None or j in self.decoded or raw.shape[0] == 0:
            return
        if raw.shape[0] * self._num_cols * 4 > self.decoded.budget_bytes:
            return
        import jax.numpy as jnp

        blk = np.asarray(self.store.codec.decode_ref(
            jnp.asarray(np.ascontiguousarray(raw))), np.float32)
        if self.decoded.put(j, blk):
            self.decoded_fills += 1

    def decoded_fraction(self) -> float:
        """Fraction of the store's tuples whose decoded blocks are cached —
        the ``decoded_fraction`` term :func:`repro.sched.admission.
        eq4_cost_terms` discounts the Eq. (4) CPU cost by."""
        if self.decoded is None:
            return 0.0
        total = int(self.store.num_tuples)
        return min(1.0, self.decoded.tuples_cached / max(total, 1))

    def drop_decoded(self, chunk_ids: Iterable[int]) -> int:
        """Drop chunks from the decoded cache (quarantine hook); returns the
        number actually dropped."""
        if self.decoded is None:
            return 0
        return sum(self.decoded.drop(int(j)) for j in chunk_ids)

    def assemble(self, chunk_ids: np.ndarray, active: np.ndarray):
        """Build the round's slab(s) on device.

        ``chunk_ids[w]`` is worker w's chunk (from ``plan_claims``); inactive
        workers get zero rows (the round masks them by ``b_eff == 0``).
        Buffers come from a two-deep pre-allocated ring: the previous slab's
        async ``device_put`` source is never touched by the current round
        (the double-buffer slack in the memory bound), and disk bytes
        ``readinto()`` the target slab slice with no staging copy.

        Returns the device slab (decoded cache off), or a
        ``(raw, dec, is_decoded, all_decoded)`` 4-tuple (decoded cache on):
        the first three are device arrays — cached workers get zero raw rows
        + their decoded block, feeding the decoded-input kernel — and
        ``all_decoded`` is a host bool (every *active* worker decoded) the
        engine uses to pick the all-decoded round variant, which skips
        tokenize/parse entirely.  All-decoded rounds never touch the raw
        ring: the raw leaf is a cached zero-row ``(W, 0, rec)`` slab (the
        ``"all"`` round variant never reads it), so a hot re-scan pays
        neither the slab zero-fill nor the host→device raw transfer.
        """
        if self.adaptive:
            self._observe_round(int(np.sum(np.asarray(active, bool))))
        i = self._ring_i
        self._ring_i = (i + 1) % len(self._ring)
        buf = self._ring[i]
        if self.decoded is None:
            buf.fill(0)
            for w in range(self.num_workers):
                if bool(active[w]):
                    self._fill_raw(int(chunk_ids[w]), buf[w])
            self.slabs_built += 1
            if self.adaptive:
                # stamp *after* the synchronous reads: the next round's gap
                # then measures compute/step time only, not READ time
                self._last_assemble_t = time.perf_counter()
            return self._device_put(buf)
        self.decoded.check_version(self.store.content_version)
        dbuf = self._dec_ring[i]
        is_dec = np.zeros(self.num_workers, bool)
        # probe before filling: an all-decoded round skips the raw ring
        # entirely (no zero-fill, no transfer)
        all_dec = all(int(chunk_ids[w]) in self.decoded
                      for w in range(self.num_workers) if bool(active[w]))
        if not all_dec:
            buf.fill(0)
        for w in range(self.num_workers):
            if not bool(active[w]):
                dbuf[w].fill(0)
                continue
            j = int(chunk_ids[w])
            blk = self.decoded.get(j)
            if blk is not None:
                dbuf[w, : blk.shape[0]] = blk
                dbuf[w, blk.shape[0]:].fill(0)
                is_dec[w] = True
                self.decoded_hits += 1
                if self._last_assembled.get(w) != j:
                    # full-chunk granularity: a freshly claimed cached
                    # chunk's rows never hit the tokenizer again
                    self.extract_tuples_avoided += int(blk.shape[0])
                self._last_assembled[w] = j
                continue
            self.decoded_misses += 1
            dbuf[w].fill(0)
            raw = self._fill_raw(j, buf[w])
            self._maybe_fill_decoded(j, raw)
            self._last_assembled[w] = j
        self.slabs_built += 1
        if self.adaptive:
            # stamp *after* the synchronous reads: the next round's gap then
            # measures compute/step time only, not READ time
            self._last_assemble_t = time.perf_counter()
        if all_dec:
            if self._empty_slab_dev is None:
                self._empty_slab_dev = self._device_put(
                    np.zeros((self.num_workers, 0, self.slab_shape[2]),
                             np.uint8))
            raw_dev = self._empty_slab_dev
        else:
            raw_dev = self._device_put(buf)
        return (raw_dev, self._device_put(dbuf),
                self._device_put(is_dec), all_dec)

    def _observe_round(self, n_claims: int) -> None:
        """Adaptive lookahead from the measured READ/CPU rate ratio.

        One chunk READ takes ``read_seconds / chunk_reads`` wall seconds;
        one round (the gap between ``assemble`` calls ≈ device compute +
        host step) takes ``_round_s``.  The reader must run
        ``ceil(t_read / t_round)`` rounds ahead — times the chunks the scan
        consumes per round — for READ to stay hidden under compute.  A slow
        store therefore *raises* the lookahead (up to ``max_lookahead``,
        which the cache is provisioned for); a fast one relaxes it back to
        the configured base.
        """
        now = time.perf_counter()
        if self._last_assemble_t is not None:
            # gap since the previous assemble *finished* (see the end-of-
            # assemble stamp): device compute + host step, READ excluded
            dt = now - self._last_assemble_t
            self._round_s = (dt if self._round_s is None
                             else 0.7 * self._round_s + 0.3 * dt)
            self._claims_per_round = (0.7 * self._claims_per_round
                                      + 0.3 * max(n_claims, 0))
        if self._round_s is None or self.chunk_reads == 0:
            return
        t_read = self.read_seconds / self.chunk_reads
        rounds_spanned = t_read / max(self._round_s, 1e-9)
        need = math.ceil(rounds_spanned * max(self._claims_per_round, 1.0))
        self.lookahead = int(np.clip(need, self.base_lookahead,
                                     self.max_lookahead))

    # ---------------------------------------------------------- counters ----
    def counters(self) -> dict:
        """Point-in-time snapshot of the monotone counters (decoded-cache
        totals included when that tier is on)."""
        with self._lock:
            out = {f: int(getattr(self, f)) for f in self.COUNTER_FIELDS}
            out["read_errors_pending"] = len(self.read_errors)
        if self.decoded is not None:
            out["decoded_evictions"] = int(self.decoded.evictions)
            out["decoded_bytes_cached"] = int(self.decoded.bytes_cached)
            out["decoded_tuples_cached"] = int(self.decoded.tuples_cached)
        return out

    def reset_counters(self) -> None:
        """Zero every ``COUNTER_FIELDS`` counter, the READ-time probe, and
        the per-chunk error slots.  This is the *only* reset path: neither
        ``close()`` nor reader-thread exit touches the counters, so totals
        stay cumulative over the prefetcher's lifetime unless the owner
        explicitly asks for a fresh window."""
        with self._lock:
            for f in self.COUNTER_FIELDS:
                setattr(self, f, 0)
            self.read_errors.clear()
            self.read_seconds = 0.0

    def bind_metrics(self, registry, prefix: str = "prefetch") -> None:
        """Expose the counter block on a
        :class:`~repro.obs.metrics.MetricsRegistry` as pull gauges — read
        at snapshot time, zero writes on any hot path.  Idempotent; safe to
        call again after :meth:`reset_counters` (gauges re-read the live
        attributes)."""
        for f in self.COUNTER_FIELDS:
            registry.gauge(f"{prefix}_{f}",
                           help=f"SlabPrefetcher.{f} (cumulative)",
                           fn=(lambda f=f: getattr(self, f)))
        registry.gauge(f"{prefix}_read_seconds",
                       help="cumulative wall seconds spent in chunk READs",
                       fn=lambda: self.read_seconds)
        if self.decoded is not None:
            dec = self.decoded
            registry.gauge(f"{prefix}_decoded_evictions",
                           help="DecodedChunkCache evictions",
                           fn=lambda: dec.evictions)
            registry.gauge(f"{prefix}_decoded_bytes_cached",
                           help="DecodedChunkCache resident bytes",
                           fn=lambda: dec.bytes_cached)

    def close(self) -> None:
        # counters deliberately NOT reset here — see reset_counters()
        self._closed = True
        self._hints.put(None)
        # join the reader so interpreter shutdown can't race a half-read
        # chunk (daemon threads die mid-read otherwise); bounded so a stuck
        # disk cannot hang close()
        reader = getattr(self, "_reader", None)
        if (reader is not None and reader.is_alive()
                and reader is not threading.current_thread()):
            reader.join(timeout=5.0)


def _reader_main(ref: "weakref.ref[SlabPrefetcher]",
                 hints: "queue.SimpleQueue") -> None:
    """Background READ loop.  Module-level on purpose: the thread must not
    keep the prefetcher alive, so it polls a weakref and exits once the
    owner is closed or collected."""
    while True:
        try:
            j = hints.get(timeout=1.0)
        except queue.Empty:
            if ref() is None:
                return
            continue
        pf = ref()
        if pf is None or j is None or pf._closed:
            return
        try:
            with pf._lock:
                hit = j in pf._cache
            if not hit:
                pf._read_chunk(int(j))
        except Exception as e:
            # the reader must never die — but a failure must not vanish
            # either: count it and stash the exception per chunk id so
            # assemble() can retry synchronously and re-raise if the chunk
            # really is gone (the old bare ``pass`` silently under-delivered
            # the round)
            with pf._lock:
                pf.read_failures += 1
                pf.read_errors[int(j)] = e
        del pf  # drop the strong ref before blocking on the next hint
