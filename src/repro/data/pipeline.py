"""Streaming slab pipeline: bounded-memory chunk delivery store → engine.

The paper's READ stage streams random chunks off disk while EXTRACT/EVALUATE
keep the CPU busy (§4; PF-OLA's overlapped parallel aggregation makes the
same bet).  :class:`SlabPrefetcher` is that stage for the jitted engines:
instead of materializing the whole store as one padded ``(N, M_max, rec)``
device tensor (``ChunkStore.packed_device_view`` — the
``EngineConfig.residency="packed"`` path, fine for small stores), each round
receives a bounded ``(W, rows_max, rec)`` uint8 *slab* holding exactly the
chunks the round's workers will extract from.

Round protocol (``residency="stream"``):

1. the host predicts the round's CLAIM outcome with
   :meth:`~repro.core.engine.EngineProgram.plan_claims` — the claim rule is a
   pure function of ``(cur, head, schedule)``, so the prediction is exact and
   the jitted round's own CLAIM lands on the same chunks;
2. :meth:`SlabPrefetcher.assemble` builds the slab from its host chunk cache
   (disk-backed chunks are read on the fly and *evicted from the store*, so
   host residency is O(slab), never O(dataset)) and ``device_put``\\ s it;
3. the engine hints the next schedule positions via :meth:`prefetch`; a
   background reader thread pulls those chunks from disk while the device is
   busy with the current round — the READ/compute overlap of the paper's
   pipeline.

Memory bounds: device residency is the in-flight slab plus (transiently) the
previous round's — ``2 × slab_bytes`` of raw data instead of the packed
view's ``N × M_max × rec``; host residency is the LRU chunk cache
(``max_cached_chunks``, default ``2·W + lookahead`` chunks).
"""

from __future__ import annotations

import math
import queue
import threading
import time
import weakref
from collections import OrderedDict
from typing import Callable, Iterable, Optional

import numpy as np

from repro.data.faults import RetryPolicy


def device_resident_bytes(dtype=None) -> int:
    """Total bytes of live JAX device arrays (optionally one dtype only).

    ``dtype=np.uint8`` isolates the raw-data buffers (packed views / slabs)
    from the f32 state pytrees — the number the streaming-residency tests and
    benchmarks report.
    """
    import jax

    total = 0
    want = None if dtype is None else np.dtype(dtype)
    for a in jax.live_arrays():
        if want is not None and a.dtype != want:
            continue
        total += int(np.prod(a.shape)) * a.dtype.itemsize
    return total


def peak_host_rss_bytes() -> int:
    """Peak resident-set size of this process (Linux/macOS)."""
    import resource
    import sys

    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # ru_maxrss is KiB on Linux, bytes on macOS
    return int(ru) if sys.platform == "darwin" else int(ru) * 1024


class SlabPrefetcher:
    """Assembles bounded per-round slabs from a :class:`ChunkStore`.

    One instance serves one engine: ``num_workers`` fixes the slab's leading
    dim, ``row_multiple`` pads ``rows_max`` up to the streaming kernel's row
    tile so block shapes stay stable.  ``device_put`` lets the SPMD engines
    place the slab sharded over the mesh's worker axis.
    """

    def __init__(self, store, num_workers: int, row_multiple: int = 1,
                 lookahead: int = 8, max_cached_chunks: Optional[int] = None,
                 device_put: Optional[Callable] = None,
                 adaptive: bool = False,
                 max_lookahead: Optional[int] = None,
                 retry: Optional[RetryPolicy] = None):
        self.store = store
        self.retry = retry if retry is not None else RetryPolicy()
        self.num_workers = int(num_workers)
        rb = int(store.codec.record_bytes)
        rows = int(store.max_chunk_tuples)
        rm = max(int(row_multiple), 1)
        self.rows_max = int(math.ceil(rows / rm) * rm)
        self.slab_shape = (self.num_workers, self.rows_max, rb)
        self.slab_bytes = int(np.prod(self.slab_shape))
        self.lookahead = int(lookahead)
        # adaptive lookahead (measured READ/CPU ratio): ``lookahead`` floats
        # between the configured base and ``max_lookahead`` based on how
        # many rounds one chunk READ spans — a slow disk raises it so the
        # reader thread stays ahead of the scan, a fast one keeps the host
        # cache small.  The cache capacity is provisioned for the ceiling.
        self.adaptive = bool(adaptive)
        self.base_lookahead = self.lookahead
        self.max_lookahead = int(max_lookahead
                                 or max(4 * self.lookahead,
                                        2 * self.num_workers))
        cap_lookahead = self.max_lookahead if self.adaptive else self.lookahead
        self.capacity = int(max_cached_chunks
                            or (2 * self.num_workers + cap_lookahead))
        # READ/CPU rate probes (wall clock): cumulative seconds spent in
        # chunk reads, and an EMA of the inter-assemble gap (≈ one round's
        # compute+step time) and of the chunks consumed per round
        self.read_seconds = 0.0
        self._round_s: Optional[float] = None
        self._claims_per_round = 1.0
        self._last_assemble_t: Optional[float] = None
        if device_put is None:
            import jax

            device_put = jax.device_put
        self._device_put = device_put
        self._cache: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._lock = threading.Lock()
        self._inflight: dict[int, threading.Event] = {}
        self._hints: "queue.SimpleQueue[Optional[int]]" = queue.SimpleQueue()
        self._closed = False
        # counters (monitoring / tests)
        self.chunk_reads = 0
        self.cache_hits = 0
        self.bytes_read = 0
        self.slabs_built = 0
        # fault accounting: retried reads, reads that exhausted their
        # retries, and the per-chunk error slot the reader thread stashes
        # into (re-raised — after one more synchronous retried attempt —
        # at assemble() time instead of being silently swallowed)
        self.read_retries = 0
        self.read_failures = 0
        self.read_errors: dict[int, Exception] = {}
        # the reader holds only a weakref: an engine dropped without close()
        # lets the prefetcher be GC'd, upon which the thread exits on its
        # next poll instead of pinning the cache for the process lifetime
        self._reader = threading.Thread(target=_reader_main,
                                        args=(weakref.ref(self), self._hints),
                                        daemon=True, name="slab-prefetcher")
        self._reader.start()

    # ------------------------------------------------------------- reads ----
    def _read_chunk(self, j: int) -> np.ndarray:
        """READ one chunk; hits the host cache, else disk (+ store eviction
        so a disk-backed store never accumulates resident raw chunks)."""
        while True:
            with self._lock:
                raw = self._cache.get(j)
                if raw is not None:
                    self._cache.move_to_end(j)
                    self.cache_hits += 1
                    return raw
                ev = self._inflight.get(j)
                if ev is None:
                    ev = self._inflight[j] = threading.Event()
                    mine = True
                else:
                    mine = False
            if not mine:
                ev.wait()
                continue  # re-check the cache (entry may have been trimmed)
            try:
                t0 = time.perf_counter()

                def _verified_read():
                    raw = self.store.chunk_bytes(j)
                    # end-to-end integrity: verify against the manifest CRC
                    # even when the bytes came through a wrapper (the store
                    # itself only checks its own disk boundary)
                    verify = getattr(self.store, "verify_chunk", None)
                    if verify is not None:
                        verify(j, raw)
                    return raw

                raw, retries = self.retry.call(_verified_read, j)
                self.store.evict(j)  # host residency stays O(slab)
                dt = time.perf_counter() - t0
                with self._lock:
                    self.chunk_reads += 1
                    self.read_retries += retries
                    self.read_errors.pop(j, None)
                    self.bytes_read += raw.nbytes
                    self.read_seconds += dt
                    self._cache[j] = raw
                    self._cache.move_to_end(j)
                    while len(self._cache) > self.capacity:
                        self._cache.popitem(last=False)
                return raw
            except Exception as e:
                with self._lock:
                    self.read_retries += int(getattr(e, "retries", 0))
                raise
            finally:
                with self._lock:
                    self._inflight.pop(j, None)
                ev.set()

    # ------------------------------------------------------------ public ----
    def prefetch(self, chunk_ids: Iterable[int]) -> None:
        """Hint upcoming chunks: the reader thread pulls them off disk while
        the device computes the current round (READ/compute overlap)."""
        if self._closed:
            return
        for j in chunk_ids:
            self._hints.put(int(j))

    def assemble(self, chunk_ids: np.ndarray, active: np.ndarray):
        """Build the round's ``(W, rows_max, rec)`` uint8 slab on device.

        ``chunk_ids[w]`` is worker w's chunk (from ``plan_claims``); inactive
        workers get zero rows (the round masks them by ``b_eff == 0``).  A
        fresh host buffer per call keeps the previous slab's async
        ``device_put`` untouched — the double-buffer slack in the memory
        bound.
        """
        if self.adaptive:
            self._observe_round(int(np.sum(np.asarray(active, bool))))
        slab = np.zeros(self.slab_shape, np.uint8)
        for w in range(self.num_workers):
            if bool(active[w]):
                raw = self._read_chunk(int(chunk_ids[w]))
                slab[w, : raw.shape[0]] = raw
        self.slabs_built += 1
        if self.adaptive:
            # stamp *after* the synchronous reads: the next round's gap then
            # measures compute/step time only, not READ time
            self._last_assemble_t = time.perf_counter()
        return self._device_put(slab)

    def _observe_round(self, n_claims: int) -> None:
        """Adaptive lookahead from the measured READ/CPU rate ratio.

        One chunk READ takes ``read_seconds / chunk_reads`` wall seconds;
        one round (the gap between ``assemble`` calls ≈ device compute +
        host step) takes ``_round_s``.  The reader must run
        ``ceil(t_read / t_round)`` rounds ahead — times the chunks the scan
        consumes per round — for READ to stay hidden under compute.  A slow
        store therefore *raises* the lookahead (up to ``max_lookahead``,
        which the cache is provisioned for); a fast one relaxes it back to
        the configured base.
        """
        now = time.perf_counter()
        if self._last_assemble_t is not None:
            # gap since the previous assemble *finished* (see the end-of-
            # assemble stamp): device compute + host step, READ excluded
            dt = now - self._last_assemble_t
            self._round_s = (dt if self._round_s is None
                             else 0.7 * self._round_s + 0.3 * dt)
            self._claims_per_round = (0.7 * self._claims_per_round
                                      + 0.3 * max(n_claims, 0))
        if self._round_s is None or self.chunk_reads == 0:
            return
        t_read = self.read_seconds / self.chunk_reads
        rounds_spanned = t_read / max(self._round_s, 1e-9)
        need = math.ceil(rounds_spanned * max(self._claims_per_round, 1.0))
        self.lookahead = int(np.clip(need, self.base_lookahead,
                                     self.max_lookahead))

    def close(self) -> None:
        self._closed = True
        self._hints.put(None)
        # join the reader so interpreter shutdown can't race a half-read
        # chunk (daemon threads die mid-read otherwise); bounded so a stuck
        # disk cannot hang close()
        reader = getattr(self, "_reader", None)
        if (reader is not None and reader.is_alive()
                and reader is not threading.current_thread()):
            reader.join(timeout=5.0)


def _reader_main(ref: "weakref.ref[SlabPrefetcher]",
                 hints: "queue.SimpleQueue") -> None:
    """Background READ loop.  Module-level on purpose: the thread must not
    keep the prefetcher alive, so it polls a weakref and exits once the
    owner is closed or collected."""
    while True:
        try:
            j = hints.get(timeout=1.0)
        except queue.Empty:
            if ref() is None:
                return
            continue
        pf = ref()
        if pf is None or j is None or pf._closed:
            return
        try:
            with pf._lock:
                hit = j in pf._cache
            if not hit:
                pf._read_chunk(int(j))
        except Exception as e:
            # the reader must never die — but a failure must not vanish
            # either: count it and stash the exception per chunk id so
            # assemble() can retry synchronously and re-raise if the chunk
            # really is gone (the old bare ``pass`` silently under-delivered
            # the round)
            with pf._lock:
                pf.read_failures += 1
                pf.read_errors[int(j)] = e
        del pf  # drop the strong ref before blocking on the next hint
