"""Synthetic dataset generators mirroring the paper's Section 7.1 datasets.

* :func:`make_synthetic_zipf` — the paper's "synthetic": 16 integer columns,
  column k zipfian with parameter ``0.25·k`` (uniform → extremely skewed),
  values < 1e9, homogeneous chunks (tuples assigned at random).
* :func:`make_ptf_like` — the PTF shape: detections sorted by time, clumped
  in position/time so chunks are *internally homogeneous but very different
  from each other* — the regime where bi-level sampling shines (Figure 8's
  explanation).  8 columns, 6 "real numbers with 10 decimal digits".
* :func:`make_wiki_like` — sparse GROUP BY: a language-id column with a
  zipfian group distribution; per-group COUNT has tiny per-chunk support,
  reproducing Figure 10's slow-variance-decay behaviour.

Generators return ``(values (T, C) float64, group_names?)`` and are encoded
into a :class:`~repro.data.chunkstore.ChunkStore` by ``store_dataset``.
"""

from __future__ import annotations

import numpy as np

from repro.data.chunkstore import ChunkStore
from repro.data.formats import AsciiFixedFormat, BinaryBigEndianFormat


def bounded_zipf(rng: np.random.Generator, s: float, size: int,
                 support: int = 100_000, vmax: float = 1e8 - 1) -> np.ndarray:
    """Zipf(s) over a finite support, scaled to [0, vmax].

    ``np.random.zipf`` requires s > 1; the paper sweeps s ∈ [0, 4) so we use
    inverse-CDF sampling over a finite rank space, valid for any s >= 0
    (s = 0 degenerates to uniform, matching the paper's A_1).
    """
    ranks = np.arange(1, support + 1, dtype=np.float64)
    w = ranks ** -s
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = rng.random(size)
    idx = np.searchsorted(cdf, u)  # rank-1 sampled most often for s > 0
    # spread ranks over the value domain; rank 0 -> 0, rank support-1 -> vmax
    return idx.astype(np.float64) * (vmax / support)


def make_synthetic_zipf(num_tuples: int = 131_072, num_cols: int = 16,
                        seed: int = 0) -> np.ndarray:
    """The paper's synthetic dataset at configurable scale."""
    rng = np.random.default_rng(seed)
    cols = [bounded_zipf(rng, 0.25 * k, num_tuples) for k in range(num_cols)]
    return np.stack(cols, axis=1)


def make_ptf_like(num_tuples: int = 131_072, num_chunks_hint: int = 128,
                  seed: int = 0) -> np.ndarray:
    """PTF-shaped data: time-sorted, position-clumped transient detections.

    Columns: [0] ra, [1] dec, [2] time, [3] mag, [4] mag_err, [5] flux,
    [6] field_id, [7] ccd_id.  Tuples are sorted by time; each "night"
    produces a handful of clumps near the telescope's pointing — so
    consecutive tuples (= chunks) are homogeneous while nights differ a lot.
    """
    rng = np.random.default_rng(seed)
    # nights span several chunks; detections are emitted clump-by-clump in
    # contiguous runs, so a chunk-sized window is (mostly) a single clump:
    # internally homogeneous, very different between chunks — Figure 8's
    # regime for the real PTF catalog (clumps of ~1M detections vs 68MB
    # chunks).
    chunk_tuples = max(num_tuples // num_chunks_hint, 1)
    rows = []
    t0 = 0.0
    made = 0
    night = 0
    while made < num_tuples:
        n_clumps = int(rng.integers(2, 6))
        centers_ra = rng.normal(180.0 + 40.0 * np.sin(night / 6.0), 15.0,
                                n_clumps) % 360
        centers_dec = rng.normal(33.0, 8.0, n_clumps)
        base_mag = rng.uniform(14, 21, n_clumps)
        for c in range(n_clumps):
            if made >= num_tuples:
                break
            n = min(int(chunk_tuples * rng.uniform(1.0, 2.5)),
                    num_tuples - made)
            ra = (centers_ra[c] + rng.normal(0, 0.4, n)) % 360
            dec = np.clip(centers_dec[c] + rng.normal(0, 0.4, n), -90, 90)
            time = t0 + np.sort(rng.random(n)) * 0.4
            mag = np.clip(base_mag[c] + rng.normal(0, 0.3, n), 10, 25)
            mag_err = np.abs(rng.normal(0.02, 0.01, n)) + 1e-3
            flux = 10 ** (-0.4 * (mag - 25.0))
            field_id = np.full(n, float(night % 97))
            ccd_id = rng.integers(0, 12, n).astype(np.float64)
            rows.append(np.stack([ra, dec, time, mag, mag_err, flux,
                                  field_id, ccd_id], 1))
            made += n
            t0 += 0.4
        night += 1
    return np.concatenate(rows, axis=0)[:num_tuples]


def make_wiki_like(num_tuples: int = 262_144, num_languages: int = 40,
                   seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Wiki-traffic-shaped data: [0] language_id, [1] hits, [2] bytes, [3] hour.

    Language frequencies are zipfian (en dominates); hits are heavy-tailed.
    Returns ``(values, language_ids)``.
    """
    rng = np.random.default_rng(seed)
    lang_w = (np.arange(1, num_languages + 1, dtype=np.float64)) ** -1.1
    lang_w /= lang_w.sum()
    lang = rng.choice(num_languages, size=num_tuples, p=lang_w)
    hits = np.floor(np.exp(rng.normal(2.0, 1.5, num_tuples)))
    nbytes = hits * np.abs(rng.normal(8_000, 3_000, num_tuples))
    nbytes = np.minimum(nbytes, 1e8 - 1)
    hour = rng.integers(0, 24 * 31, num_tuples).astype(np.float64)
    vals = np.stack([lang.astype(np.float64), hits, nbytes, hour], 1)
    return vals, np.arange(num_languages)


def store_dataset(values: np.ndarray, num_chunks: int, fmt: str = "ascii",
                  name: str = "dataset", directory: str | None = None,
                  uneven: bool = False, seed: int = 0,
                  uneven_spread: float = 0.25) -> ChunkStore:
    """Encode ``values`` into a chunked raw store.

    ``uneven=True`` draws chunk sizes from a ±``uneven_spread`` jitter around
    the mean — the paper's estimators support unequal M_j and the tests
    exercise it (larger spreads arm the inspection paradox harder).
    """
    t, c = values.shape
    num_chunks = max(min(num_chunks, t // 2), 1)  # no empty chunks
    codec = (AsciiFixedFormat(c) if fmt == "ascii" else BinaryBigEndianFormat(c))
    if uneven:
        rng = np.random.default_rng(seed + 1)
        w = rng.uniform(1.0 - uneven_spread, 1.0 + uneven_spread, num_chunks)
        sizes = np.maximum((w / w.sum() * t).astype(np.int64), 2)
        # fix rounding drift
        while sizes.sum() > t:
            sizes[np.argmax(sizes)] -= 1
        while sizes.sum() < t:
            sizes[np.argmin(sizes)] += 1
    else:
        base = t // num_chunks
        sizes = np.full(num_chunks, base, np.int64)
        sizes[: t - base * num_chunks] += 1
    store = ChunkStore.create(name=name, codec=codec, directory=directory)
    off = 0
    for j in range(num_chunks):
        m = int(sizes[j])
        store.append_chunk(codec.encode(values[off:off + m]), num_tuples=m)
        off += m
    store.finalize()
    return store
