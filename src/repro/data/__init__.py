"""Raw-data substrate: formats, synthetic datasets, chunk store, pipeline.

This is the layer the OLA engine samples *from* — the analogue of the paper's
CSV/FITS files on disk.  Records live in their raw byte representation until
EXTRACT touches them; extraction cost is the whole point of the paper.
"""

from repro.data.formats import AsciiFixedFormat, BinaryBigEndianFormat, FORMATS
from repro.data.chunkstore import ChunkStore, ChunkMeta
from repro.data.pipeline import SlabPrefetcher
from repro.data.generator import (
    make_ptf_like,
    make_synthetic_zipf,
    make_wiki_like,
)

__all__ = [
    "AsciiFixedFormat",
    "BinaryBigEndianFormat",
    "FORMATS",
    "ChunkStore",
    "ChunkMeta",
    "SlabPrefetcher",
    "make_ptf_like",
    "make_synthetic_zipf",
    "make_wiki_like",
]
