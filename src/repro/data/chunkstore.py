"""Chunked raw-byte store — the paper's "raw file" abstraction.

A :class:`ChunkStore` is a sequence of raw chunks (each holding many records
in their on-disk byte format) plus the per-chunk metadata the estimators need
(``M_j`` — Section 4.3 notes textual formats get it from ``wc -l``-style
preprocessing and binary formats from file headers; here it is recorded at
ingest).

Two residency modes:

* in-memory (default): chunks are numpy uint8 arrays — the NoDB-style cache.
* disk-backed (``directory=...``): chunks are spilled to ``<name>.chunkNNN.bin``
  files and read back on demand, giving the benchmarks a real READ stage with
  measurable I/O time (and letting tests exercise restart-from-metadata).

Two device-facing residency modes (selected by ``EngineConfig.residency``):

* ``"packed"`` — :meth:`packed_device_view`: a padded
  ``(N, max_record_count, record_bytes)`` uint8 tensor for the jitted
  engine.  O(dataset) device memory; right for stores that fit.
* ``"stream"`` — the engine pulls bounded per-round ``(W, rows_max, rec)``
  slabs through :class:`repro.data.pipeline.SlabPrefetcher`: chunks are read
  (and, when disk-backed, evicted) on the fly by a background reader thread,
  so host and device residency are O(slab), not O(dataset).
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Optional

import numpy as np

from repro.data.faults import CorruptChunkError


@dataclasses.dataclass
class ChunkMeta:
    num_tuples: int
    num_bytes: int
    path: Optional[str] = None  # set iff disk-backed
    # CRC32 of the chunk's raw bytes, recorded at ingest and checked on
    # every disk re-read; None for stores ingested before checksums
    # existed (legacy manifests open fine, they just skip verification)
    crc32: Optional[int] = None


class ChunkStore:
    def __init__(self, name: str, codec, directory: Optional[str] = None):
        self.name = name
        self.codec = codec
        self.directory = directory
        self.meta: list[ChunkMeta] = []
        self._chunks: list[Optional[np.ndarray]] = []
        self._finalized = False
        self._content_version = 0

    # ------------------------------------------------------------- create --
    @classmethod
    def create(cls, name: str, codec, directory: Optional[str] = None) -> "ChunkStore":
        if directory is not None:
            os.makedirs(directory, exist_ok=True)
        return cls(name=name, codec=codec, directory=directory)

    def append_chunk(self, raw: np.ndarray, num_tuples: int) -> None:
        assert not self._finalized
        raw = np.ascontiguousarray(raw, dtype=np.uint8).reshape(num_tuples, -1)
        assert raw.shape[1] == self.codec.record_bytes, (
            raw.shape, self.codec.record_bytes)
        j = len(self.meta)
        crc = zlib.crc32(raw.tobytes()) & 0xFFFFFFFF
        if self.directory is not None:
            path = os.path.join(self.directory, f"{self.name}.chunk{j:05d}.bin")
            raw.tofile(path)
            self.meta.append(ChunkMeta(num_tuples, raw.nbytes, path, crc))
            self._chunks.append(None)  # not resident
        else:
            self.meta.append(ChunkMeta(num_tuples, raw.nbytes, None, crc))
            self._chunks.append(raw)
        self._content_version += 1

    def finalize(self) -> None:
        self._finalized = True
        if self.directory is not None:
            manifest = {
                "name": self.name,
                "codec": type(self.codec).__name__,
                "num_cols": self.codec.num_cols,
                "chunks": [dataclasses.asdict(m) for m in self.meta],
            }
            with open(os.path.join(self.directory, f"{self.name}.manifest.json"), "w") as f:
                json.dump(manifest, f)

    @classmethod
    def open(cls, directory: str, name: str) -> "ChunkStore":
        """Re-open a disk-backed store from its manifest (restart path)."""
        from repro.data.formats import AsciiFixedFormat, BinaryBigEndianFormat

        with open(os.path.join(directory, f"{name}.manifest.json")) as f:
            manifest = json.load(f)
        codec_cls = {"AsciiFixedFormat": AsciiFixedFormat,
                     "BinaryBigEndianFormat": BinaryBigEndianFormat}[manifest["codec"]]
        store = cls(name=name, codec=codec_cls(manifest["num_cols"]), directory=directory)
        for m in manifest["chunks"]:
            store.meta.append(ChunkMeta(**m))
            store._chunks.append(None)
        store._finalized = True
        return store

    # -------------------------------------------------------------- access --
    @property
    def content_version(self) -> int:
        """Monotone counter over the store's raw content: bumped per
        ingested chunk and by :meth:`mark_content_changed`.  Derived
        artifacts that cache *answers* over the bytes (the rollup tier's
        cells, see ``repro.serve.rollup``) pin the version they were built
        over and invalidate on mismatch."""
        return self._content_version

    def mark_content_changed(self) -> None:
        """Signal an out-of-band mutation of the raw bytes (a re-ingest,
        an external writer touching the backing files): bumps
        :attr:`content_version` so version-pinned caches drop their
        state.  The store itself holds no derived aggregates — this is a
        pure version bump."""
        self._content_version += 1

    @property
    def num_chunks(self) -> int:
        return len(self.meta)

    @property
    def num_tuples(self) -> int:
        return sum(m.num_tuples for m in self.meta)

    @property
    def chunk_sizes(self) -> np.ndarray:
        """The M_j vector (Table 1)."""
        return np.asarray([m.num_tuples for m in self.meta], np.int32)

    @property
    def max_chunk_tuples(self) -> int:
        return int(self.chunk_sizes.max())

    def chunk_bytes(self, j: int) -> np.ndarray:
        """READ stage for one chunk: resident copy or a disk read.

        Disk re-reads are CRC-verified against the manifest; a mismatch
        raises :class:`CorruptChunkError` (which feeds the retry/quarantine
        path) instead of handing corrupt bytes to the extractor.
        """
        raw = self._chunks[j]
        if raw is None:
            m = self.meta[j]
            data = np.fromfile(m.path, dtype=np.uint8)
            if data.size != m.num_tuples * self.codec.record_bytes:
                raise CorruptChunkError(
                    f"chunk {j}: short read ({data.size} bytes, expected "
                    f"{m.num_tuples * self.codec.record_bytes})", chunk_id=j)
            raw = data.reshape(m.num_tuples, self.codec.record_bytes)
            self.verify_chunk(j, raw)
        return raw

    def read_chunk_into(self, j: int, out: np.ndarray) -> np.ndarray:
        """READ one chunk directly into a caller-provided buffer.

        ``out`` is a C-contiguous uint8 array of at least
        ``(num_tuples, record_bytes)``; the chunk's rows land at
        ``out[:num_tuples]`` and the filled view is returned.  Disk-backed
        chunks ``readinto()`` the file — the zero-copy slab-assembly path:
        file bytes go straight into the target slab slice with no
        intermediate numpy staging buffer.  Short reads and CRC mismatches
        raise :class:`CorruptChunkError` exactly like :meth:`chunk_bytes`.

        Note for wrappers: :class:`~repro.data.faults.FaultInjector` and
        other store proxies intercept :meth:`chunk_bytes` only, so callers
        that must honor injection (the :class:`SlabPrefetcher`) take this
        fast path only when the store's *own class* provides it.
        """
        m = self.meta[j]
        view = out[: m.num_tuples]
        raw = self._chunks[j]
        if raw is not None:
            np.copyto(view, raw)
            return view
        nbytes = m.num_tuples * self.codec.record_bytes
        with open(m.path, "rb") as f:
            got = f.readinto(memoryview(view.reshape(-1)[:nbytes]))
        if got != nbytes:
            raise CorruptChunkError(
                f"chunk {j}: short read ({got} bytes, expected {nbytes})",
                chunk_id=j)
        self.verify_chunk(j, view)
        return view

    def verify_chunk(self, j: int, raw: np.ndarray) -> None:
        """Check ``raw`` against chunk ``j``'s manifest CRC32.

        No-op for legacy manifests without checksums.  Consumers that
        receive chunk bytes through an intermediary (the
        :class:`~repro.data.pipeline.SlabPrefetcher`, possibly via a
        :class:`~repro.data.faults.FaultInjector`) call this to verify
        end-to-end, not just at the disk boundary.
        """
        crc = self.meta[j].crc32
        if crc is None:
            return
        got = zlib.crc32(np.ascontiguousarray(raw).tobytes()) & 0xFFFFFFFF
        if got != crc:
            raise CorruptChunkError(
                f"chunk {j}: CRC32 mismatch (manifest {crc:#010x}, "
                f"read {got:#010x})", chunk_id=j)

    def evict(self, j: int) -> None:
        """Drop a resident chunk (only meaningful for disk-backed stores)."""
        if self.directory is not None:
            self._chunks[j] = None

    def cache(self, j: int) -> None:
        if self._chunks[j] is None:
            self._chunks[j] = self.chunk_bytes(j)

    def packed_device_view(self) -> tuple[np.ndarray, np.ndarray]:
        """Padded ``(N, M_max, record_bytes)`` uint8 + ``(N,)`` sizes.

        Padding rows are zero; the engine masks by ``M_j`` so they are never
        included in estimation.
        """
        n, mx, rb = self.num_chunks, self.max_chunk_tuples, self.codec.record_bytes
        out = np.zeros((n, mx, rb), np.uint8)
        for j in range(n):
            raw = self.chunk_bytes(j)
            out[j, : raw.shape[0]] = raw
            # a disk-backed store must not end up resident twice (raw chunks
            # cached by an earlier pass + this packed copy)
            self.evict(j)
        return out, self.chunk_sizes

    def decode_all(self) -> np.ndarray:
        """Ground-truth full EXTRACT (tests/benchmarks only): (T, C) float32."""
        import jax.numpy as jnp

        parts = [np.asarray(self.codec.decode_ref(jnp.asarray(self.chunk_bytes(j))))
                 for j in range(self.num_chunks)]
        return np.concatenate(parts, axis=0)
