"""Fault taxonomy, retry policy, and deterministic fault injection.

OLA-RAW queries raw files in place, so the scan plane sits on storage that
returns transient errors, truncated reads, and corrupt bytes.  This module
gives every layer a shared, *typed* vocabulary for those failures plus the
two tools the rest of the stack builds on:

* :class:`RetryPolicy` — bounded exponential backoff with deterministic
  jitter and a per-chunk read deadline.  Wired into
  :meth:`SlabPrefetcher._read_chunk` (and thereby the background reader
  thread): a read that keeps failing is converted into a
  :class:`ChunkLostError` carrying the chunk id, which the engine's
  residency layer turns into a quarantine instead of a stall.
* :class:`FaultInjector` — a :class:`~repro.data.chunkstore.ChunkStore`
  wrapper that injects failures *deterministically* from a seed, so every
  failure path is reproducible in tests and the chaos bench lane.  Modes:
  per-chunk transient-fail-k-times (heals after ``transient_fails``
  attempts — the retry path recovers bit-exactly), permanent loss
  (always raises :class:`ChunkLostError` — the quarantine path), bit-flip
  corruption (caught by the store's CRC via ``verify_chunk``), and latency
  spikes.

The taxonomy maps onto answer semantics: a *retried* transient fault leaves
the estimate bit-exact and ``degraded=False``; an *exhausted* retry or a
checksum mismatch quarantines the chunk, shrinking the sampled population
(the bi-level estimator's chunk count ``K`` and tuple total ``M`` drop, CIs
widen) and flagging every subsequent answer ``degraded=True``.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Callable, Optional

import numpy as np


class FaultError(Exception):
    """Base class for scan-plane faults; carries the offending chunk id."""

    def __init__(self, msg: str, chunk_id: Optional[int] = None):
        super().__init__(msg)
        self.chunk_id = chunk_id


class TransientReadError(FaultError):
    """A read failed but retrying may succeed (EIO, flaky NFS, ...)."""


class CorruptChunkError(FaultError):
    """Chunk bytes fail their manifest CRC32 — content cannot be trusted."""


class ChunkLostError(FaultError):
    """The chunk is gone for good: retries exhausted, deadline passed, or
    persistent corruption.  The residency layer quarantines it."""


def _unit_hash(*parts) -> float:
    """Deterministic hash of arbitrary parts -> [0, 1).  CRC32-based so it
    is stable across processes and python versions (unlike ``hash``)."""
    h = 0
    for p in parts:
        h = zlib.crc32(repr(p).encode(), h)
    return (h & 0xFFFFFFFF) / 2.0**32


@dataclasses.dataclass
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter and a
    per-chunk wall-clock deadline.

    ``call(fn, chunk_id)`` retries ``fn`` on :class:`TransientReadError`,
    :class:`CorruptChunkError` (a re-read may heal a transient bad read),
    and ``OSError``; any other exception — notably :class:`ChunkLostError`
    from a store that knows the chunk is gone — propagates immediately.
    When attempts or the deadline exhaust, raises :class:`ChunkLostError`
    chained to the last failure.  ``sleep`` is injectable for tests.
    """

    max_attempts: int = 4
    base_delay_s: float = 0.002
    max_delay_s: float = 0.1
    jitter: float = 0.5
    deadline_s: float = 5.0
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep

    def delay_s(self, chunk_id: int, attempt: int) -> float:
        backoff = min(self.base_delay_s * (2.0 ** attempt), self.max_delay_s)
        u = _unit_hash(self.seed, int(chunk_id), int(attempt))
        return backoff * (1.0 - self.jitter * u)

    def call(self, fn: Callable[[], "np.ndarray"], chunk_id: int):
        """-> (result, retries) — ``retries`` counts failed attempts."""
        t0 = time.monotonic()
        retries = 0
        last: Optional[BaseException] = None
        for attempt in range(max(int(self.max_attempts), 1)):
            try:
                return fn(), retries
            except (TransientReadError, CorruptChunkError, OSError) as e:
                last = e
                retries += 1
                if attempt + 1 >= self.max_attempts:
                    break
                d = self.delay_s(chunk_id, attempt)
                if time.monotonic() - t0 + d > self.deadline_s:
                    break
                self.sleep(d)
        err = ChunkLostError(
            f"chunk {chunk_id}: read failed after {retries} attempt(s) "
            f"({type(last).__name__}: {last})", chunk_id=int(chunk_id),
        )
        err.retries = retries
        raise err from last


@dataclasses.dataclass
class FaultConfig:
    """Which chunks fail, and how.  All decisions are pure functions of
    ``(seed, mode, chunk_id)`` so a given config is bit-reproducible."""

    seed: int = 0
    # transient: affected chunks fail their first ``transient_fails`` reads
    # with TransientReadError, then heal (the retry path recovers them)
    transient_rate: float = 0.0
    transient_fails: int = 2
    # permanent loss: ChunkLostError on every read
    loss_rate: float = 0.0
    lost_chunks: tuple = ()
    # bit-flip corruption of the returned bytes (caught by CRC downstream);
    # ``corrupt_once`` corrupts only the first read (heals under retry)
    corrupt_rate: float = 0.0
    corrupt_chunks: tuple = ()
    corrupt_once: bool = False
    # latency spike on the first read of affected chunks
    latency_rate: float = 0.0
    latency_s: float = 0.0


class FaultInjector:
    """Deterministic fault-injecting :class:`ChunkStore` wrapper.

    Delegates everything to the wrapped store (``__getattr__``), overriding
    only :meth:`chunk_bytes`.  With an all-zero :class:`FaultConfig` the
    wrapper is a transparent pass-through — bit-exact vs the plain store
    (gated in ``tests/test_faults.py``), so it can stay on in CI.
    """

    def __init__(self, store, config: Optional[FaultConfig] = None, **kw):
        self._store = store
        self.config = config if config is not None else FaultConfig(**kw)
        self._flock = threading.Lock()
        self._attempts: dict[int, int] = {}
        self.injected = {"transient": 0, "lost": 0, "corrupt": 0,
                         "latency": 0}

    def __getattr__(self, name):
        return getattr(self._store, name)

    # ------------------------------------------------------ fault rolls ----
    def chunk_is_lost(self, j: int) -> bool:
        cfg = self.config
        return (j in cfg.lost_chunks
                or _unit_hash(cfg.seed, "lost", j) < cfg.loss_rate)

    def chunk_is_transient(self, j: int) -> bool:
        cfg = self.config
        return _unit_hash(cfg.seed, "transient", j) < cfg.transient_rate

    def chunk_is_corrupt(self, j: int) -> bool:
        cfg = self.config
        return (j in cfg.corrupt_chunks
                or _unit_hash(cfg.seed, "corrupt", j) < cfg.corrupt_rate)

    def chunk_has_latency(self, j: int) -> bool:
        cfg = self.config
        return _unit_hash(cfg.seed, "latency", j) < cfg.latency_rate

    # ------------------------------------------------------------ READ ----
    def chunk_bytes(self, j: int) -> np.ndarray:
        j = int(j)
        cfg = self.config
        if self.chunk_is_lost(j):
            with self._flock:
                self.injected["lost"] += 1
            raise ChunkLostError(f"chunk {j}: injected permanent loss",
                                 chunk_id=j)
        with self._flock:
            attempt = self._attempts.get(j, 0)
            self._attempts[j] = attempt + 1
        if attempt == 0 and cfg.latency_s > 0 and self.chunk_has_latency(j):
            with self._flock:
                self.injected["latency"] += 1
            time.sleep(cfg.latency_s)
        if attempt < cfg.transient_fails and self.chunk_is_transient(j):
            with self._flock:
                self.injected["transient"] += 1
            raise TransientReadError(
                f"chunk {j}: injected transient failure "
                f"(attempt {attempt + 1}/{cfg.transient_fails})", chunk_id=j)
        raw = self._store.chunk_bytes(j)
        if self.chunk_is_corrupt(j) and not (cfg.corrupt_once
                                             and attempt > 0):
            raw = np.array(raw, copy=True)
            flat = raw.reshape(-1)
            pos = int(_unit_hash(cfg.seed, "pos", j) * flat.size) % flat.size
            bit = int(_unit_hash(cfg.seed, "bit", j) * 8) % 8
            flat[pos] ^= np.uint8(1 << bit)
            with self._flock:
                self.injected["corrupt"] += 1
        return raw
