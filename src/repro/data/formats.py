"""Raw record formats and their codecs.

Two families, mirroring the paper's ptf-csv (text) and ptf-fits (binary):

* :class:`AsciiFixedFormat` — fixed-width ASCII decimal.  Each field is 16
  bytes: ``sign, 8 integer digits, '.', 6 fraction digits``; a record is the
  concatenation of its fields.  This is the *TPU adaptation* of CSV (see
  DESIGN.md §3): variable-width tokenization is inherently sequential, so the
  layout is regularised while keeping EXTRACT genuinely expensive (dozens of
  VPU ops per field — digit gathers, multiplies, adds — exactly the
  CPU-bound EXTRACT profile of the paper's text experiments).
* :class:`BinaryBigEndianFormat` — FITS stores big-endian IEEE floats; EXTRACT
  is a byte-swap + bitcast, i.e. nearly free.  This reproduces the paper's
  finding that ptf-fits processing is IO-bound while ptf-csv is CPU-bound.

Each format implements ``encode`` (host numpy, used by the generators),
``decode_ref`` (pure-jnp oracle, consumed by XLA on CPU and by kernel tests)
and exposes geometry used by the Pallas kernels' BlockSpecs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

INT_DIGITS = 8
FRAC_DIGITS = 6
FIELD_BYTES = 1 + INT_DIGITS + 1 + FRAC_DIGITS  # sign + digits + '.' + digits
_MAX_ABS = 10.0 ** INT_DIGITS


@dataclasses.dataclass(frozen=True)
class AsciiFixedFormat:
    """Fixed-width ASCII decimal records (text family)."""

    num_cols: int
    name: str = "ascii"

    @property
    def record_bytes(self) -> int:
        return self.num_cols * FIELD_BYTES

    # -- host-side encode ---------------------------------------------------
    def encode(self, values: np.ndarray) -> np.ndarray:
        """(T, C) float -> (T, record_bytes) uint8."""
        t, c = values.shape
        assert c == self.num_cols, (c, self.num_cols)
        v = np.asarray(values, np.float64)
        if np.any(np.abs(v) >= _MAX_ABS):
            raise ValueError(f"values must be < 1e{INT_DIGITS} in magnitude")
        sign = np.where(v < 0, ord("-"), ord("+")).astype(np.uint8)
        av = np.abs(v)
        ip = np.floor(av)
        fp = np.rint((av - ip) * 10 ** FRAC_DIGITS).astype(np.int64)
        # carry from rounding .999999x up
        carry = fp >= 10 ** FRAC_DIGITS
        ip = ip.astype(np.int64) + carry
        fp = np.where(carry, 0, fp)
        out = np.empty((t, c, FIELD_BYTES), np.uint8)
        out[..., 0] = sign
        rem = ip
        for d in range(INT_DIGITS):  # most-significant first
            div = 10 ** (INT_DIGITS - 1 - d)
            out[..., 1 + d] = (rem // div % 10 + ord("0")).astype(np.uint8)
        out[..., 1 + INT_DIGITS] = ord(".")
        rem = fp
        for d in range(FRAC_DIGITS):
            div = 10 ** (FRAC_DIGITS - 1 - d)
            out[..., 2 + INT_DIGITS + d] = (rem // div % 10 + ord("0")).astype(np.uint8)
        return out.reshape(t, self.record_bytes)

    # -- device-side decode (oracle; the Pallas kernel mirrors this) --------
    def decode_ref(self, raw: jnp.ndarray) -> jnp.ndarray:
        """(T, record_bytes) uint8 -> (T, C) float32.  Pure jnp."""
        t = raw.shape[0]
        f = raw.reshape(t, self.num_cols, FIELD_BYTES).astype(jnp.int32)
        zero = jnp.int32(ord("0"))
        ipow = jnp.asarray([10 ** (INT_DIGITS - 1 - d) for d in range(INT_DIGITS)],
                           jnp.float32)
        fpow = jnp.asarray([10.0 ** -(d + 1) for d in range(FRAC_DIGITS)], jnp.float32)
        ival = jnp.einsum("tcd,d->tc", (f[..., 1:1 + INT_DIGITS] - zero).astype(jnp.float32), ipow)
        fval = jnp.einsum("tcd,d->tc", (f[..., 2 + INT_DIGITS:] - zero).astype(jnp.float32), fpow)
        sign = jnp.where(f[..., 0] == ord("-"), -1.0, 1.0).astype(jnp.float32)
        return sign * (ival + fval)

    def extract_cost_per_tuple(self) -> float:
        """Modeled op count per tuple — feeds the resource monitor's cost
        model (Section 5.4's CPU term).  Calibrated so ASCII extraction is
        CPU-bound against the default 565 MB/s read rate, matching the
        paper's ptf-csv characterization (tokenize+branch+convert dominate
        real text parsing, not the 3-op/digit arithmetic floor)."""
        return float(self.num_cols * (INT_DIGITS + FRAC_DIGITS) * 30)


@dataclasses.dataclass(frozen=True)
class BinaryBigEndianFormat:
    """Big-endian float32 records (FITS-like binary family)."""

    num_cols: int
    name: str = "binary"

    @property
    def record_bytes(self) -> int:
        return self.num_cols * 4

    def encode(self, values: np.ndarray) -> np.ndarray:
        v = np.asarray(values, ">f4")  # big-endian on purpose (FITS convention)
        return v.view(np.uint8).reshape(values.shape[0], self.record_bytes)

    def decode_ref(self, raw: jnp.ndarray) -> jnp.ndarray:
        t = raw.shape[0]
        b = raw.reshape(t, self.num_cols, 4).astype(jnp.uint32)
        word = (b[..., 0] << 24) | (b[..., 1] << 16) | (b[..., 2] << 8) | b[..., 3]
        return jax.lax.bitcast_convert_type(word, jnp.float32)

    def extract_cost_per_tuple(self) -> float:
        return float(self.num_cols * 4)  # byte shuffles only: near-free


FORMATS = {"ascii": AsciiFixedFormat, "binary": BinaryBigEndianFormat}
