"""qwen2.5-14b — dense GQA decoder [hf:Qwen/Qwen2.5-0.5B family; hf].

48L, d_model 5120, 40 Q heads / 8 KV heads (head_dim 128), SwiGLU d_ff 13824,
vocab 152064, QKV bias, rope theta 1e6.  TP16 pads Q heads 40->48.
long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13824, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6,
)
