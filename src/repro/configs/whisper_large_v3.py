"""whisper-large-v3 — enc-dec audio backbone [arXiv:2212.04356; unverified].

32 encoder + 32 decoder layers (whisper-large has both stacks; the
assignment's "32L"), d_model 1280, 20 MHA heads, GELU MLP d_ff 5120,
vocab 51866.  Conv frontend stubbed: input_specs supplies frame embeddings.
long_500k: SKIPPED — full (enc-dec) attention, no sub-quadratic path.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, head_dim=64,
    norm="ln", mlp="gelu", use_rope=False, tie_embeddings=True,
    notes="audio; conv frontend stubbed (frame embeddings supplied)",
)
