"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

32L, d_model 4096, 32 Q / 8 KV heads (head_dim 128), 8 experts top-2 with
d_ff 14336, vocab 32000, SWA window 4096.  8 experts don't divide the 16-way
model axis -> experts stay TP-sharded on d_ff (DESIGN.md §7).
long_500k: RUNS — SWA is sub-quadratic and the decode cache is the window.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    num_experts=8, top_k=2, window=4096, rope_theta=1e6,
)
