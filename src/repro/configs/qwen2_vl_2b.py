"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191; hf].

28L, d_model 1536, 12 Q / 2 KV heads (head_dim 128), SwiGLU d_ff 8960,
vocab 151936, QKV bias, M-RoPE sections (16, 24, 24).  Vision frontend
stubbed: input_specs supplies patch embeddings + 3-stream position ids.
long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128,
    qkv_bias=True, mrope_sections=(16, 24, 24), tie_embeddings=True,
)
