"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L, d_model 4096, 32 Q / 8 KV heads (head_dim 128), 16 experts top-2 with
d_ff 6400, vocab 32064.  16 experts / 16-way model axis = pure expert
parallelism (1 expert per shard).  SparseMixer router approximated by
normalized top-2 softmax (DESIGN.md).  long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=6400, vocab_size=32064, head_dim=128,
    num_experts=16, top_k=2,
)
