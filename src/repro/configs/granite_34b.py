"""granite-34b — llama-arch code model, MQA [arXiv:2405.04324; hf].

88L, d_model 6144, 48 Q heads / 1 KV head (MQA, head_dim 128), SwiGLU
d_ff 24576, vocab 49152.  The deepest assigned arch — the scan-over-layers
compile-time case.  long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
)
