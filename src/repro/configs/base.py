"""Unified architecture config consumed by the model zoo, launcher and dry-run.

One :class:`ModelConfig` describes any of the 10 assigned architectures; the
``family`` field selects the assembly (``repro.models.model_zoo.build_model``).
``tp`` is the mesh model-axis size the padding is computed against (16 for the
production mesh; smoke tests use 1).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | encdec | vlm | hybrid | xlstm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # None -> d_model // num_heads
    # ---- attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding-window attention (mixtral)
    norm: str = "rms"                # rms | ln
    mlp: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    use_rope: bool = True
    mrope_sections: Optional[Tuple[int, ...]] = None   # qwen2-vl
    # ---- MoE
    num_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # ---- SSM / hybrid
    ssm_state: int = 64
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    shared_attn_every: int = 6       # zamba2: shared block cadence
    # ---- xLSTM
    slstm_at: Tuple[int, ...] = ()
    # ---- distribution / numerics
    tp: int = 1                      # model-axis size padding target
    remat: bool = True
    compute_dtype: str = "bfloat16"
    # lax.scan unroll for layer stacks: 1 = rolled (fast compile, production),
    # True = fully unrolled (dry-run: XLA cost_analysis counts while-loop
    # bodies once, so honest FLOP/byte/collective accounting needs unrolling)
    scan_unroll: object = 1
    # ---- serving
    long_window: Optional[int] = None  # SWA override for long-context serve
    # ---- bookkeeping
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def with_tp(self, tp: int) -> "ModelConfig":
        return dataclasses.replace(self, tp=tp)

    def reduced(self) -> "ModelConfig":
        """Smoke-test configuration of the same family (CPU-sized)."""
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=max(self.num_heads // 4, 2) if self.num_heads >= 8 else self.num_heads,
            num_kv_heads=min(self.num_kv_heads, max(self.num_heads // 8, 1)),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            shared_attn_every=2,
            slstm_at=(1,) if self.slstm_at else (),
            mrope_sections=(4, 6, 6) if self.mrope_sections else None,
            ssm_headdim=32,
            ssm_chunk=32,
            tp=1,
            remat=False,
        )


def param_count(cfg: ModelConfig) -> int:
    """Approximate *real* (unpadded) parameter count — the N of 6·N·D."""
    d, f, l, v = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.vocab_size
    hd = cfg.head_dim_
    attn = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    if cfg.family == "xlstm":
        per = 0
        for i in range(l):
            if i in cfg.slstm_at:
                dh = d
                per += 4 * (d * dh + (dh // cfg.num_heads) * dh) \
                    + dh * int(8.0 / 3.0 * d) + int(4.0 / 3.0 * d) * d
            else:
                din = 2 * d
                per += 2 * d * din + 3 * din * (din // cfg.num_heads) * cfg.num_heads // cfg.num_heads \
                    + din * d
        return per + 2 * v * d if not cfg.tie_embeddings else per + v * d
    if cfg.family == "hybrid":
        din = 2 * d
        n = cfg.ssm_state
        mamba = (2 * d * din + 2 * d * n + d * (din // cfg.ssm_headdim)
                 + din * d)
        shared = attn + 3 * d * f
        sites = l // cfg.shared_attn_every
        return l * mamba + shared + 2 * d * d * sites + v * d
    if cfg.num_experts:
        mlp = 3 * d * f * cfg.num_experts + d * cfg.num_experts
    elif cfg.mlp == "swiglu":
        mlp = 3 * d * f
    else:
        mlp = 2 * d * f
    per_layer = attn + mlp
    layers = l * (2 if cfg.family == "encdec" else 1)
    if cfg.family == "encdec":
        per_layer_dec = attn * 2 + mlp  # self + cross attention
        total = l * (attn + mlp) + l * per_layer_dec
    else:
        total = layers * per_layer
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return total + emb


def active_param_count(cfg: ModelConfig) -> int:
    """N_active for MoE rooflines (6·N_active·D)."""
    if not cfg.num_experts:
        return param_count(cfg)
    d, f, l = cfg.d_model, cfg.d_ff, cfg.num_layers
    hd = cfg.head_dim_
    attn = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    mlp_active = 3 * d * f * cfg.top_k
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return l * (attn + mlp_active) + emb
