"""Registry of the 10 assigned architectures (+ shape sets).

``--arch <id>`` everywhere resolves through :func:`get_config`.
Shapes follow the assignment:

    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (serve prefill)
    decode_32k   seq 32768,  global_batch 128   (serve decode: 1 new token,
                                                 KV/recurrent state of 32k)
    long_500k    seq 524288, global_batch 1     (long-context decode; only
                                                 sub-quadratic families)
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "smollm-135m": "repro.configs.smollm_135m",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "granite-34b": "repro.configs.granite_34b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k runs only for sub-quadratic families (per-arch notes in configs/)
LONG_OK = {"zamba2-1.2b", "xlstm-125m", "mixtral-8x7b"}


def get_config(arch: str, tp: int = 1, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    cfg: ModelConfig = mod.CONFIG
    if reduced:
        cfg = cfg.reduced()
    return cfg.with_tp(tp)


def list_archs() -> tuple:
    return ARCHS


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped long_500k cells flagged."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and arch not in LONG_OK
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name, skipped))
    return out
