"""Architecture configs: one module per assigned architecture + registry."""

from repro.configs.base import ModelConfig, active_param_count, param_count
from repro.configs.registry import ARCHS, get_config, list_archs

__all__ = ["ARCHS", "ModelConfig", "active_param_count", "get_config",
           "list_archs", "param_count"]
