"""zamba2-1.2b — hybrid Mamba2 + shared attention [arXiv:2411.15242; hf].

38 Mamba2 layers (d_state 64, headdim 64, expand 2), one weight-shared
attention+MLP block applied every 6 layers (32 heads, d_ff 8192),
d_model 2048, vocab 32000.  long_500k: RUNS — SSD is O(S); the shared
attention uses a 4096 sliding window in long-context serve (long_window).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_headdim=64, shared_attn_every=6,
    long_window=4096, tie_embeddings=True,
)
