"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12 blocks, d_model 768, 4 heads, vocab 50304; sLSTM at positions (5, 11)
(~the paper's mLSTM:sLSTM ratio), no separate FFN (d_ff = 0; block-internal
projections).  Runs replicated-TP / batch-over-both-axes (DESIGN.md §6).
long_500k: RUNS — O(1) recurrent state.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="xlstm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, slstm_at=(5, 11), tie_embeddings=True,
    ssm_chunk=256,  # mLSTM chunkwise-parallel block length
)
