"""smollm-135m — llama-arch small dense [hf:HuggingFaceTB/SmolLM-135M; hf].

30L, d_model 576, 9 Q / 3 KV heads (head_dim 64), SwiGLU d_ff 1536,
vocab 49152, tied embeddings.  TP16 pads heads 9->16 (KV 3->4).
This is the ~135M end-to-end training example arch.
long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    d_ff=1536, vocab_size=49152, head_dim=64, tie_embeddings=True,
)
