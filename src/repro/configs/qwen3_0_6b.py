"""qwen3-0.6b — dense GQA with qk_norm [hf:Qwen/Qwen3-8B family; hf].

28L, d_model 1024, 16 Q / 8 KV heads with head_dim 128 (qwen3 decouples
head_dim from d_model), SwiGLU d_ff 3072, vocab 151936, qk-norm, tied.
long_500k: SKIPPED — full attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=3072, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
)
