"""SLO-aware workload scheduling (admission, fairness, claim ordering).

This package sits between incoming queries and the engine (see
``repro.serve.ola_server``): :class:`QuerySLO` describes what a query needs,
:class:`AdmissionController` triages admit/queue/shed against the Eq. (4)
cost model, :class:`FairnessPolicy` divides each round's evaluation budget
across resident slots by weighted max-min, and
:func:`variance_claim_order` reorders the scan's unclaimed chunk tail so
high-uncertainty work is claimed first.  :class:`WorkloadScheduler` bundles
the policies; a :data:`NEUTRAL` configuration reproduces the unscheduled
server bit-for-bit.
"""

from repro.sched.admission import (
    ADMIT,
    QUEUE,
    SHED,
    AdmissionController,
    AdmissionDecision,
    ServerLoad,
    scan_tuples_per_s,
)
from repro.sched.claims import slot_chunk_variances, variance_claim_order
from repro.sched.fairness import FairnessPolicy, max_min_weights
from repro.sched.scheduler import NEUTRAL, SchedulerConfig, WorkloadScheduler
from repro.sched.slo import NO_SLO, PRIORITY_WEIGHTS, QuerySLO

__all__ = [
    "ADMIT", "QUEUE", "SHED",
    "AdmissionController", "AdmissionDecision", "ServerLoad",
    "scan_tuples_per_s", "slot_chunk_variances", "variance_claim_order",
    "FairnessPolicy", "max_min_weights",
    "NEUTRAL", "SchedulerConfig", "WorkloadScheduler",
    "NO_SLO", "PRIORITY_WEIGHTS", "QuerySLO",
]
