"""SLO-aware workload scheduling (admission, fairness, preemption, claims).

This package sits between incoming queries and the engine (see
``repro.serve.ola_server``): :class:`QuerySLO` describes what a query needs,
:class:`AdmissionController` triages admit/queue/shed — the candidate priced
by the Eq. (4) cost model, the queue wait by the learned per-class
service-time quantile (:class:`ServiceTimeModel`) — :class:`FairnessPolicy`
divides each round's evaluation budget across resident slots by weighted
max-min (capacity hand-set or derived from the benchmark calibration via
:func:`measured_slot_capacity`), :func:`select_victim` picks the slot to
evict when a feasible deadline would otherwise die in the queue, and
:func:`variance_claim_order` reorders the scan's unclaimed chunk tail so
chunks that most reduce the far-from-target slots' uncertainty are claimed
first.  :class:`WorkloadScheduler` bundles the policies; a :data:`NEUTRAL`
configuration reproduces the unscheduled server bit-for-bit.
"""

from repro.sched.admission import (
    ADMIT,
    QUEUE,
    SHED,
    TIER1,
    AdmissionController,
    AdmissionDecision,
    ServerLoad,
    scan_tuples_per_s,
)
from repro.sched.claims import slot_chunk_variances, variance_claim_order
from repro.sched.fairness import (
    FairnessPolicy,
    max_min_weights,
    measured_slot_capacity,
)
from repro.sched.preempt import select_victim
from repro.sched.scheduler import NEUTRAL, SchedulerConfig, WorkloadScheduler
from repro.sched.service_model import P2Quantile, ServiceTimeModel
from repro.sched.slo import NO_SLO, PRIORITY_WEIGHTS, QuerySLO

__all__ = [
    "ADMIT", "QUEUE", "SHED", "TIER1",
    "AdmissionController", "AdmissionDecision", "ServerLoad",
    "scan_tuples_per_s", "slot_chunk_variances", "variance_claim_order",
    "FairnessPolicy", "max_min_weights", "measured_slot_capacity",
    "select_victim",
    "P2Quantile", "ServiceTimeModel",
    "NEUTRAL", "SchedulerConfig", "WorkloadScheduler",
    "NO_SLO", "PRIORITY_WEIGHTS", "QuerySLO",
]
