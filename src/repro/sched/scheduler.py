"""The workload scheduler: SLO admission + fairness + claim ordering.

:class:`WorkloadScheduler` is the policy bundle the
:class:`~repro.serve.ola_server.OLAWorkloadServer` consults; it owns no
engine state.  Division of labor per decision point:

* **intake** (``queue_key``): ready queries are considered in priority
  order (weight desc, then arrival, then qid) instead of pure FIFO;
* **admission** (``admission.decide``): admit / queue / shed against the
  query's :class:`~repro.sched.slo.QuerySLO`, using the Eq. (4) cost model;
* **per round** (``round_weights``): weighted max-min fairness shares over
  the resident slots, written into the slot table's ``weight`` column —
  under ``slot_capacity`` contention, high-priority slots keep more of each
  round's evaluation budget;
* **per round** (``claim_order``): variance-guided permutation of the
  schedule's unclaimed tail (see ``repro.sched.claims``).

The **neutral** configuration — infinite capacity, ``claim_policy=
"schedule"``, FIFO queue, no SLOs — reproduces the unscheduled server
round-for-round, bit-exactly; ``tests/test_sched.py`` gates that.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.sched.admission import AdmissionController
from repro.sched.claims import variance_claim_order
from repro.sched.fairness import FairnessPolicy
from repro.sched.slo import NO_SLO, PRIORITY_WEIGHTS, QuerySLO


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    # per-round slot-budget units across resident slots (inf = uncontended;
    # e.g. 2.0 = the deployment can afford two full slot evaluations per
    # round and the fairness policy divides them)
    slot_capacity: float = math.inf
    claim_policy: str = "variance"      # "schedule" (committed order) | "variance"
    queue_policy: str = "priority"      # "fifo" | "priority"
    shed_enabled: bool = True
    # returns the best available estimate at the deadline instead of letting
    # an admitted query overstay its slot
    deadline_enforcement: bool = True
    admission_pessimism: float = 1.0

    def __post_init__(self):
        assert self.claim_policy in ("schedule", "variance"), self.claim_policy
        assert self.queue_policy in ("fifo", "priority"), self.queue_policy


#: Neutral configuration for parity testing: scheduling machinery engaged,
#: every policy pinned to the unscheduled server's behavior.
NEUTRAL = SchedulerConfig(slot_capacity=math.inf, claim_policy="schedule",
                          queue_policy="fifo", shed_enabled=False,
                          deadline_enforcement=False)


class WorkloadScheduler:
    def __init__(self, config: SchedulerConfig = SchedulerConfig()):
        self.config = config
        self.fairness = FairnessPolicy(config.slot_capacity)
        self.admission = AdmissionController(
            shed_enabled=config.shed_enabled,
            pessimism=config.admission_pessimism)

    # ------------------------------------------------------------- intake ----
    def queue_key(self, wq) -> tuple:
        """Sort key for the ready queue (ascending)."""
        if self.config.queue_policy == "fifo":
            return (wq.arrival_t, wq.qid)
        slo = wq.slo or NO_SLO
        return (-PRIORITY_WEIGHTS[slo.priority], wq.arrival_t, wq.qid)

    # ---------------------------------------------------------- per round ----
    def round_weights(self, slot_slos: list, active: np.ndarray) -> np.ndarray:
        """Fairness shares (S,) f32 for the slot table's weight column.
        ``slot_slos[s]`` is the resident query's SLO (or None)."""
        prio = np.asarray([
            PRIORITY_WEIGHTS[(slo or NO_SLO).priority] for slo in slot_slos],
            np.float64)
        return self.fairness.weights(prio, active).astype(np.float32)

    def claim_order(self, state, chunk_sizes: np.ndarray,
                    active: Optional[np.ndarray] = None,
                    ) -> Optional[np.ndarray]:
        if self.config.claim_policy != "variance":
            return None
        return variance_claim_order(state, chunk_sizes, active)

    # ---------------------------------------------------------------- SLO ----
    @staticmethod
    def effective_epsilon(query, slo: Optional[QuerySLO],
                          seed_estimate: Optional[float]) -> float:
        """Translate an absolute half-width target into the engine's relative
        ε stop condition using the synopsis magnitude estimate; without one the
        query's own ε stands (the absolute target is then checked only at
        completion, via :meth:`QuerySLO.met`)."""
        eps = float(query.epsilon)
        if slo is None or not math.isfinite(slo.target_halfwidth):
            return eps
        if seed_estimate is None or not math.isfinite(seed_estimate) \
                or abs(seed_estimate) < 1e-12:
            return eps
        # err ratio = (hi-lo)/(2|est|) = halfwidth/|est|
        return float(min(eps, slo.target_halfwidth / abs(seed_estimate)))
