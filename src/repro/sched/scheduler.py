"""The workload scheduler: SLO admission + fairness + claim ordering.

:class:`WorkloadScheduler` is the policy bundle the
:class:`~repro.serve.ola_server.OLAWorkloadServer` consults; it owns no
engine state.  Division of labor per decision point:

* **intake** (``queue_key``): ready queries are considered in priority
  order (weight desc, then arrival, then qid) instead of pure FIFO;
* **admission** (``admission.decide``): admit / queue / shed against the
  query's :class:`~repro.sched.slo.QuerySLO`, pricing the candidate's
  service with the Eq. (4) cost model and the queue wait with the learned
  per-class service-time quantile (``repro.sched.service_model``, fed by
  :meth:`WorkloadScheduler.observe_service` at every retirement);
* **admission** (``config.preempt``): when a feasible deadline would die
  waiting, evict a strictly-lower-priority slot (``repro.sched.preempt``);
* **per round** (``round_weights``): weighted max-min fairness shares over
  the resident slots, written into the slot table's ``weight`` column —
  under ``slot_capacity`` contention (hand-set, or derived from the
  benchmark calibration via ``slot_capacity="measured"`` +
  :meth:`WorkloadScheduler.calibrate`), high-priority slots keep more of
  each round's evaluation budget;
* **per round** (``claim_order``): variance-guided permutation of the
  schedule's unclaimed tail, each slot's variance weighted by its remaining
  distance to its ε target (see ``repro.sched.claims``).

The **neutral** configuration — infinite capacity, ``claim_policy=
"schedule"``, FIFO queue, no SLOs, no preemption — reproduces the
unscheduled server round-for-round, bit-exactly; ``tests/test_sched.py``
gates that.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import numpy as np

from repro.sched.admission import AdmissionController
from repro.sched.claims import variance_claim_order
from repro.sched.fairness import FairnessPolicy, measured_slot_capacity
from repro.sched.service_model import ServiceTimeModel
from repro.sched.slo import NO_SLO, PRIORITY_WEIGHTS, QuerySLO


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    # per-round slot-budget units across resident slots (inf = uncontended;
    # e.g. 2.0 = the deployment can afford two full slot evaluations per
    # round and the fairness policy divides them).  The string "measured"
    # derives the capacity from the bench_slot_kernel calibration's
    # round-cost fit (see repro.sched.fairness.measured_slot_capacity) when
    # the server calls WorkloadScheduler.calibrate with its loaded rates;
    # without a usable calibration it degrades to inf (uncontended).
    slot_capacity: Union[float, str] = math.inf
    claim_policy: str = "variance"      # "schedule" (committed order) | "variance"
    queue_policy: str = "priority"      # "fifo" | "priority"
    shed_enabled: bool = True
    # returns the best available estimate at the deadline instead of letting
    # an admitted query overstay its slot
    deadline_enforcement: bool = True
    admission_pessimism: float = 1.0
    # evict a strictly-lower-priority slot when a deadline is feasible only
    # with preemption (repro.sched.preempt); the victim is re-queued with
    # its statistics snapshot, never dropped
    preempt: bool = False
    # queue waits are priced at this quantile of each class's observed
    # service times (repro.sched.service_model); the CLT cost model remains
    # the cold-start prior until min_samples completions per class
    wait_quantile: float = 0.9
    service_min_samples: int = 8
    # slot_capacity="measured": fraction of the scan-side round cost the
    # deployment lets slot evaluation add (capacity = headroom·base/slot_us)
    measured_headroom: float = 0.5

    def __post_init__(self):
        assert self.claim_policy in ("schedule", "variance"), self.claim_policy
        assert self.queue_policy in ("fifo", "priority"), self.queue_policy
        if isinstance(self.slot_capacity, str):
            assert self.slot_capacity == "measured", self.slot_capacity
        assert 0.0 < self.wait_quantile < 1.0, self.wait_quantile


#: Neutral configuration for parity testing: scheduling machinery engaged,
#: every policy pinned to the unscheduled server's behavior.
NEUTRAL = SchedulerConfig(slot_capacity=math.inf, claim_policy="schedule",
                          queue_policy="fifo", shed_enabled=False,
                          deadline_enforcement=False, preempt=False)


class WorkloadScheduler:
    def __init__(self, config: SchedulerConfig = SchedulerConfig()):
        self.config = config
        cap = (math.inf if config.slot_capacity == "measured"
               else config.slot_capacity)
        self.fairness = FairnessPolicy(cap)
        self.service_model = ServiceTimeModel(
            quantile=config.wait_quantile,
            min_samples=config.service_min_samples)
        self.admission = AdmissionController(
            shed_enabled=config.shed_enabled,
            pessimism=config.admission_pessimism,
            service_model=self.service_model)

    # -------------------------------------------------------- calibration ----
    def calibrate(self, rates) -> None:
        """Bind a :class:`~repro.serve.ola_server.MeasuredRates` calibration.

        With ``slot_capacity="measured"`` this derives the fairness
        capacity from the measured round-cost fit; rates without the fit
        fields (or ``None``) leave the capacity uncontended.  Hand-set
        numeric capacities are never overridden.  Called by the server at
        construction; idempotent, host-side only.
        """
        if self.config.slot_capacity != "measured":
            return
        cap = measured_slot_capacity(rates, self.config.measured_headroom)
        self.fairness.slot_capacity = math.inf if cap is None else cap

    def bind_metrics(self, registry) -> None:
        """Expose the scheduler's observable state on a
        :class:`~repro.obs.metrics.MetricsRegistry`: per-action admission
        decision tallies (pull gauges) and the fairness slot capacity."""
        self.admission.bind_metrics(registry)
        registry.gauge("sched_slot_capacity",
                       help="fairness slot capacity (per-round budget units)",
                       fn=lambda: self.fairness.slot_capacity)

    # ------------------------------------------------------------ feedback ----
    def observe_service(self, slo: Optional[QuerySLO],
                        service_s: float) -> None:
        """Feed one completed query's scan service time (slot grant →
        retirement, modeled seconds) into the per-class quantile sketch."""
        self.service_model.observe((slo or NO_SLO).priority, float(service_s))

    # ------------------------------------------------------------- intake ----
    def queue_key(self, wq) -> tuple:
        """Sort key for the ready queue (ascending)."""
        if self.config.queue_policy == "fifo":
            return (wq.arrival_t, wq.qid)
        slo = wq.slo or NO_SLO
        return (-PRIORITY_WEIGHTS[slo.priority], wq.arrival_t, wq.qid)

    # ---------------------------------------------------------- per round ----
    def round_weights(self, slot_slos: list, active: np.ndarray) -> np.ndarray:
        """Fairness shares (S,) f32 for the slot table's weight column.
        ``slot_slos[s]`` is the resident query's SLO (or None)."""
        prio = np.asarray([
            PRIORITY_WEIGHTS[(slo or NO_SLO).priority] for slo in slot_slos],
            np.float64)
        return self.fairness.weights(prio, active).astype(np.float32)

    def claim_order(self, state, chunk_sizes: np.ndarray,
                    active: Optional[np.ndarray] = None,
                    slot_need: Optional[np.ndarray] = None,
                    ) -> Optional[np.ndarray]:
        """Variance-guided claim permutation; ``slot_need`` (the server's
        per-slot ε-distance weights from the last round report) switches the
        chunk key to the need-weighted aggregate."""
        if self.config.claim_policy != "variance":
            return None
        return variance_claim_order(state, chunk_sizes, active,
                                    slot_need=slot_need)

    # ---------------------------------------------------------------- SLO ----
    @staticmethod
    def effective_epsilon(query, slo: Optional[QuerySLO],
                          seed_estimate: Optional[float]) -> float:
        """Translate an absolute half-width target into the engine's relative
        ε stop condition using the synopsis magnitude estimate; without one the
        query's own ε stands (the absolute target is then checked only at
        completion, via :meth:`QuerySLO.met`)."""
        eps = float(query.epsilon)
        if slo is None or not math.isfinite(slo.target_halfwidth):
            return eps
        if seed_estimate is None or not math.isfinite(seed_estimate) \
                or abs(seed_estimate) < 1e-12:
            return eps
        # err ratio = (hi-lo)/(2|est|) = halfwidth/|est|
        return float(min(eps, slo.target_halfwidth / abs(seed_estimate)))
