"""Variance-guided chunk claim ordering.

The engine claims chunks in a committed random schedule.  For correctness
only the *first-touch* order matters: the inspection-paradox guarantee (§4.2)
needs the set of started chunks to always be a prefix of the committed random
order, so sample inclusion never depends on content.  The order in which
already-started chunks are *revisited* (top-up passes re-opening early-closed
chunks, schedules rewound behind re-opened work) is statistically free — and
that freedom is worth using: claiming the chunks with the highest within-chunk
variance across the live slots first shrinks the dominant CI terms soonest,
so high-uncertainty queries converge and release their slots earlier (Neyman
allocation, applied to claim order).

:func:`variance_claim_order` therefore permutes only the unclaimed tail of
``state.schedule`` (positions ≥ head), in three bands:

1. never-started chunks, in their original committed order (unknown variance
   — the paper's ``plan_schedule`` treats them as infinite);
2. started-and-open chunks, by measured aggregate variance, descending;
3. closed/exhausted chunks last (claiming them burns a round for nothing).

The result is written back into the engine state by the server *before* the
round's claim prediction runs, so the streaming prefetcher and the in-jit
CLAIM follow the same order (host-predictability is preserved by
construction — the ordering is itself a host-side computation).
"""

from __future__ import annotations

from typing import Optional

import numpy as np


def slot_chunk_variances(state, active: Optional[np.ndarray] = None,
                         slot_need: Optional[np.ndarray] = None,
                         ) -> np.ndarray:
    """Aggregate per-chunk within-variance across slots — ``(N,)``.

    Same s²/m proxy as ``BiLevelSynopsis.within_variances``, but masked to
    the live slots: the claim order should chase uncertainty that some
    *resident* query still cares about.  Chunks a slot has fewer than two
    tuples from contribute zero (no variance estimate yet).

    ``slot_need`` (optional, ``(S,)`` non-negative) weights each slot's
    variance plane by its remaining **distance to its ε target** before
    aggregating — ``need_s = max(err_s/ε_s − 1, 0)`` as computed by the
    server from the last round report.  A slot at 3× its target then pulls
    claim order twice as hard as one at 2×, and a slot that already met ε
    (need 0) stops steering entirely; the aggregate becomes the
    need-weighted **sum** over slots (total outstanding uncertainty — the
    Neyman-allocation reading) instead of the unweighted max PR 4 used,
    which let one nearly-converged slot's noisy chunk outrank a chunk every
    far-from-target slot needs.  Without ``slot_need`` the PR-4 max key is
    kept (the policy-unit tests pin both forms).
    """
    m = np.asarray(state.stats.m, np.float64)          # (S, N)
    ys = np.asarray(state.stats.ysum, np.float64)
    yq = np.asarray(state.stats.ysq, np.float64)
    if m.ndim == 1:
        # frozen plane: the (N,) sample size is shared by every query row —
        # broadcast it so the max below aggregates over ALL queries, not
        # just the first
        m = np.broadcast_to(m[None], ys.shape)
    ss = yq - np.where(m > 0, ys * ys / np.maximum(m, 1.0), 0.0)
    v = np.where(m >= 2, np.maximum(ss / np.maximum(m - 1.0, 1.0), 0.0), 0.0)
    if active is not None:
        active = np.asarray(active, bool)
        if active.shape[0] != v.shape[0]:
            raise ValueError(
                f"active mask length {active.shape[0]} does not match the "
                f"stats plane's leading dim {v.shape[0]}")
        v = v * active[:, None]
    if slot_need is not None:
        need = np.asarray(slot_need, np.float64)
        if need.shape[0] != v.shape[0]:
            raise ValueError(
                f"slot_need length {need.shape[0]} does not match the "
                f"stats plane's leading dim {v.shape[0]}")
        return (v * need[:, None]).sum(axis=0)
    return v.max(axis=0)


def variance_claim_order(state, chunk_sizes: np.ndarray,
                         active: Optional[np.ndarray] = None,
                         slot_need: Optional[np.ndarray] = None,
                         ) -> Optional[np.ndarray]:
    """New ``(N,)`` schedule with the unclaimed tail variance-ordered, or
    ``None`` when the order is already optimal / there is nothing to
    reorder.  Positions ``< state.head`` (claimed or done — every worker's
    held position is below the head) are never moved.  ``slot_need``
    switches the per-chunk key to the ε-distance-weighted aggregate (see
    :func:`slot_chunk_variances`)."""
    schedule = np.asarray(state.schedule)
    n = len(schedule)
    head = int(state.head)
    if head >= n - 1:
        return None
    tail = schedule[head:]
    scan_m = np.asarray(state.scan_m)
    closed = np.asarray(state.closed)
    sizes = np.asarray(chunk_sizes)
    v = slot_chunk_variances(state, active, slot_need)
    dead = closed[tail] | (scan_m[tail] >= sizes[tail])
    started = scan_m[tail] > 0
    band = np.where(dead, 2, np.where(started, 1, 0))
    if not (band == 1).any():
        # nothing measured in the tail: variance ordering is the committed
        # order (never-started chunks must keep it), modulo dead chunks
        if not dead.any() or (band == 2).all():
            return None
    # lexsort: most-significant key last; stability keeps band-0 chunks in
    # committed order and makes band-1 variance ties deterministic
    order = np.lexsort((np.arange(len(tail)), -v[tail], band))
    new_tail = tail[order]
    if np.array_equal(new_tail, tail):
        return None
    out = schedule.copy()
    out[head:] = new_tail
    return out.astype(np.int32)
