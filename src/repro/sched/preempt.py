"""Deadline-feasibility preemption: evict a batch slot for a hot deadline.

Admission (``repro.sched.admission``) triages a deadline query against the
server's *current* slot occupancy — PR 4 stopped there, which meant a
feasible interactive deadline could still die waiting behind a batch slot:
the scheduler knew the deadline was reachable *if only* the query held a
slot now, and shed it anyway.  That violates the priority contract the
fairness weights already encode (an interactive query outranks a batch one
4:1): if the batch slot's budget share is worth taking per round, the slot
itself is worth taking when the alternative is missing a feasible deadline.

:func:`select_victim` is the policy half: given the candidate's SLO and the
resident slots' SLOs, pick the slot to evict — or ``None`` when preemption
cannot help (no strictly-lower-priority resident).  The mechanism half
lives in the server (``OLAWorkloadServer._evict``): the victim's per-slot
sufficient-statistics row is snapshotted host-side
(:func:`repro.core.engine.slot_stats_snapshot`), the slot is released, and
the victim re-enters the queue flagged ``preempted`` — on re-admission the
snapshot seeds its slot row (it is a richer seed than the synopsis: every
tuple the query already counted), so no sample is lost and the query is
**never silently dropped**.  The caller only preempts when *both* hold:

* waiting is infeasible — the admission decision's predicted finish (queue
  wait priced by the service model) lands past the deadline;
* preempting is sufficient — admitted *now*, the candidate's predicted
  service fits inside the deadline.

Guarded by ``SchedulerConfig.preempt`` (default off; the NEUTRAL parity
configuration never preempts).
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.sched.slo import NO_SLO, PRIORITY_WEIGHTS


def select_victim(candidate_slo, slot_slos: Sequence,
                  slot_admit_t: Sequence[float],
                  evictable: Sequence[bool]) -> Optional[int]:
    """Pick the slot to evict for ``candidate_slo``, or ``None``.

    ``slot_slos[s]`` is the resident query's SLO (``None`` for empty or
    no-SLO slots — treated as :data:`~repro.sched.slo.NO_SLO`),
    ``evictable[s]`` gates slots that may be taken at all (occupied and not
    already stopped).  Only slots of **strictly lower** priority weight than
    the candidate qualify — equal-priority work is never preempted (that
    would just trade one miss for another and invite eviction cycles).
    Among qualifying slots the victim is the lowest-weight one, tie-broken
    by the *latest* admission time: the newest batch slot has the least
    sunk scan work, so evicting it wastes the least (its sample is
    snapshotted and restored on re-admission either way).
    """
    cand_w = (candidate_slo or NO_SLO).weight
    best: Optional[int] = None
    best_key = None
    for s, (slo, ok) in enumerate(zip(slot_slos, evictable)):
        if not ok:
            continue
        w = PRIORITY_WEIGHTS[(slo or NO_SLO).priority]
        if w >= cand_w:
            continue
        key = (w, -float(slot_admit_t[s]))
        if best_key is None or key < best_key:
            best, best_key = s, key
    return best
