"""Service-level objectives for workload queries.

A :class:`QuerySLO` rides along with a submitted query and tells the
scheduler (``repro.sched.scheduler``) what "good service" means for it:

* ``deadline_s`` — modeled seconds *from arrival* by which the answer must
  be returned.  The scheduler admission-checks feasibility against it, the
  fairness policy prioritizes against it, and (when enforcement is on) the
  server returns the best estimate available at the deadline instead of
  letting the query overstay — the paper's core premise that OLA can stop
  early and trade accuracy for time, applied per query.
* ``target_halfwidth`` — absolute confidence-interval half-width target.
  The engine's native stop condition is the *relative* error ratio ε; when a
  synopsis seed provides a magnitude estimate, the scheduler translates the
  absolute target into an effective ε for the slot row.
* ``priority`` — class label mapped to a weight by :data:`PRIORITY_WEIGHTS`;
  drives queue ordering and the weighted max-min fairness split.
"""

from __future__ import annotations

import dataclasses
import math

# Priority class → fairness weight.  Ratios, not absolutes: an interactive
# slot gets 4× a batch slot's share when the round budget is contended.
PRIORITY_WEIGHTS = {
    "batch": 1.0,
    "normal": 2.0,
    "interactive": 4.0,
}


@dataclasses.dataclass(frozen=True)
class QuerySLO:
    """Per-query service-level objective (all fields optional).

    The default instance — infinite deadline, no half-width target, normal
    priority — is the no-SLO query: the scheduler treats it exactly like
    the pre-scheduler server did (admit or FIFO-queue, never shed).
    """

    deadline_s: float = math.inf        # modeled seconds from arrival
    target_halfwidth: float = math.inf  # absolute CI half-width target
    priority: str = "normal"

    def __post_init__(self):
        if self.priority not in PRIORITY_WEIGHTS:
            raise ValueError(
                f"unknown priority {self.priority!r}; expected one of "
                f"{sorted(PRIORITY_WEIGHTS)}")
        if not self.deadline_s > 0:
            raise ValueError(f"deadline_s must be positive, got {self.deadline_s}")
        if not self.target_halfwidth > 0:
            raise ValueError(
                f"target_halfwidth must be positive, got {self.target_halfwidth}")

    @property
    def weight(self) -> float:
        return PRIORITY_WEIGHTS[self.priority]

    @property
    def has_deadline(self) -> bool:
        return math.isfinite(self.deadline_s)

    def met(self, latency_s: float, halfwidth: float) -> bool:
        """Did a completed query hit this SLO?  A NaN half-width (an
        unserved query — no answer was produced at all) never counts as a
        hit, even for a deadline-only SLO: meeting a deadline with no
        estimate is not service."""
        if math.isnan(halfwidth):
            return False
        if latency_s > self.deadline_s:
            return False
        if math.isfinite(self.target_halfwidth):
            return bool(halfwidth <= self.target_halfwidth)
        return True


NO_SLO = QuerySLO()
