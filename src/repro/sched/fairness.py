"""Weighted max-min fairness over per-slot round budgets.

Each engine round extracts up to ``b_eff`` tuples per worker, and every
active slot may *count* (evaluate into its statistics) up to the full window
— one "budget unit" per slot.  When the deployment caps the per-round
evaluation work (``slot_capacity`` units — the CPU/VPU can only afford so
many slot·tuple evaluations per round), the round budget must be divided.

:func:`max_min_weights` is the classic weighted water-filling: every active
slot demands 1.0 unit; shares grow proportionally to the slots' priority
weights until a slot's demand is satisfied (share capped at 1.0), and the
freed capacity is redistributed over the rest.  Properties (unit-tested):

* no contention (``capacity >= active``) → every share is exactly 1.0, so
  the engine round is bit-identical to the unscheduled server;
* equal weights under contention → equal shares ``capacity / active``;
* a slot never gets more than 1.0 or (under contention) less than its
  weight-proportional floor.
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np


def max_min_weights(priority: np.ndarray, active: np.ndarray,
                    capacity: float) -> np.ndarray:
    """Per-slot fairness shares in ``(0, 1]``.

    ``priority (S,)`` positive weights, ``active (S,)`` bool, ``capacity``
    total units across active slots (``inf`` = uncontended).  Inactive slots
    get share 1.0 (they are gated out of the round by ``SlotTable.active``
    anyway; 1.0 keeps the table write a no-op when nothing is resident).
    """
    priority = np.asarray(priority, np.float64)
    active = np.asarray(active, bool)
    s = priority.shape[0]
    out = np.ones(s, np.float64)
    idx = np.flatnonzero(active)
    n_act = len(idx)
    if n_act == 0 or capacity >= n_act:
        return out
    if not np.all(priority[idx] > 0):
        raise ValueError("priority weights must be positive")
    cap = max(float(capacity), 1e-9)
    w = priority[idx].copy()
    share = np.zeros(n_act, np.float64)
    remaining = np.ones(n_act, bool)
    # water-fill: raise the level λ until Σ min(1, λ·w_i) == capacity.
    # Each pass either saturates at least one slot (≤ S passes) or solves
    # the linear remainder exactly.
    while cap > 1e-12 and remaining.any():
        w_rem = w[remaining]
        lam = cap / w_rem.sum()
        grant = lam * w_rem
        if np.all(grant <= 1.0 + 1e-12):
            share[remaining] += np.minimum(grant, 1.0)
            break
        # saturate the slots that would overflow, recurse on the rest
        sat = np.zeros(n_act, bool)
        sat[np.flatnonzero(remaining)[grant > 1.0]] = True
        share[sat] = 1.0
        cap -= float(sat.sum())
        remaining &= ~sat
    out[idx] = np.clip(share, 1e-6, 1.0)  # every active slot makes progress
    return out


def measured_slot_capacity(rates, headroom: float = 0.5) -> Optional[float]:
    """Per-round slot-budget units derived from the *measured* round-step
    costs, replacing the hand-set ``slot_capacity`` knob.

    ``benchmarks/bench_slot_kernel.py`` fits its S sweep to the linear model
    ``round_us(S) = base + slot_us · S`` and records the coefficients in the
    calibration block (``MeasuredRates.round_base_us`` — the scan-side cost
    of one round: claim, gather, parse, merge — and ``round_slot_us`` — the
    marginal cost of one fully-counted slot evaluation).  The capacity the
    hardware affords is then how much slot evaluation fits inside a
    ``headroom`` fraction of the scan-side round cost::

        capacity = headroom · base / slot_us

    i.e. at ``headroom=0.5`` the deployment tolerates slot evaluation
    inflating the round by at most 50% over its scan-side floor.  Floored at
    1.0 — a lone resident slot always gets the full window (the scan must
    make progress) — which also keeps the uncontended single-query case
    bit-identical to the unscheduled server.  Returns ``None`` (caller keeps
    its static knob) when the calibration predates the fit fields or the
    fit is degenerate (non-positive slope: adding slots measured as free).
    """
    if rates is None:
        return None
    base = float(getattr(rates, "round_base_us", 0.0) or 0.0)
    slot = float(getattr(rates, "round_slot_us", 0.0) or 0.0)
    if not (math.isfinite(base) and math.isfinite(slot)
            and base > 0.0 and slot > 0.0):
        return None
    if not headroom > 0:
        raise ValueError(f"headroom must be positive: {headroom}")
    return max(1.0, headroom * base / slot)


class FairnessPolicy:
    """Bundles the capacity knob with the water-filling rule.

    ``slot_capacity`` may be retargeted after construction (the scheduler's
    :meth:`~repro.sched.scheduler.WorkloadScheduler.calibrate` swaps in the
    measured capacity when the server hands it a calibration) — the weights
    are computed fresh from the current value every round.
    """

    def __init__(self, slot_capacity: float = math.inf):
        if not slot_capacity > 0:
            raise ValueError(f"slot_capacity must be positive: {slot_capacity}")
        self.slot_capacity = float(slot_capacity)

    def weights(self, priority: np.ndarray, active: np.ndarray) -> np.ndarray:
        return max_min_weights(priority, active, self.slot_capacity)
