"""SLO-feasibility admission control for the workload server.

PF-OLA frames parallel online aggregation as resource arbitration: a new
query should only hold a scan slot if the resources it will consume can
plausibly deliver its target.  The :class:`AdmissionController` makes that
call per submitted query, from the same Eq. (4) cost terms ``select_plan``
uses (measured IO/CPU rates when a calibration exists, modeled constants
otherwise):

* **tier-1** — a promoted rollup cell (``repro.serve.rollup``) already
  answers the query within its accuracy target: served from the cache
  before the triage even prices a scan — zero scan seconds beats every
  plan below;
* **admit** — a slot is free and the predicted finish lands inside the
  deadline;
* **queue** — no slot right now (or higher-priority work is ahead) but the
  deadline is still reachable once one frees;
* **shed** — the deadline is provably hopeless even under the optimistic
  prediction; the server answers immediately from the synopsis (flagged
  best-effort) instead of wasting scan rounds on it.

The *candidate's own* service prediction is deliberately coarse — a CLT
extrapolation ``err ∝ 1/√m`` from the synopsis seed when one exists, a
full-pass bound when not — because its job is triage, not simulation.  The
**wait** prediction is where the learning lives: each job ahead of the
candidate (slot occupants and queued work) is priced at its priority
class's observed service-time quantile (default p90, via
:class:`~repro.sched.service_model.ServiceTimeModel`), with the CLT
full-pass bound as the cold-start prior.  Pricing the queue at a high
quantile instead of the mean makes the shed call "will the deadline
survive a plausibly *bad* wait" — the right default when service times are
heavy-tailed.  Queries without a deadline are never shed: the controller
degrades to admit-or-queue, which is what the scheduler parity gate pins
down.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

ADMIT, QUEUE, SHED = "admitted", "queued", "shed"
#: Tier-1 short-circuit: answered from the rollup cache, no slot, no scan
#: rounds (see repro.serve.rollup).  Decided *before* the admit/queue/shed
#: triage — under the Eq. (4) cost model a rollup answer that already meets
#: the query's accuracy target costs zero scan seconds, which beats any
#: feasible scan plan (and any wait) unconditionally.
TIER1 = "tier1"


def eq4_cost_terms(store, config, rates=None, *, total_bytes=None,
                   total_tuples=None, decoded_fraction: float = 0.0) -> tuple:
    """The two Eq. (4) cost terms for one full pass over ``store`` —
    ``(T_io, T_cpu)`` modeled seconds — on measured rates when available
    (worker-count and codec-cost rescaled), modeled constants otherwise.
    Single source of truth shared by ``select_plan`` (plan choice) and the
    admission controller (feasibility): both must price the scan on the
    same model, or a query could be admitted under one cost regime and
    planned under another.

    ``total_bytes``/``total_tuples`` override the store totals — the
    workload server prices a *surviving* population after chunk quarantine
    (a lost chunk is neither read nor extracted on any future pass).

    ``decoded_fraction`` is the share of the store's tuples held in the
    parse-once decoded-chunk cache (``EngineConfig.decoded_cache_bytes``):
    those tuples skip tokenize/parse on every re-scan, so the *CPU* term is
    discounted by ``1 - fraction``.  The IO term is untouched — a decoded
    hit also skips the read, but READ is already priced per first touch
    (raw_touched), and admission prices full re-passes conservatively."""
    if total_bytes is None:
        total_bytes = float(store.chunk_sizes.sum()) * store.codec.record_bytes
    if total_tuples is None:
        total_tuples = float(store.num_tuples)
    total_bytes, total_tuples = float(total_bytes), float(total_tuples)
    if rates is not None:
        t_io = total_bytes / rates.io_bytes_per_sec
        # the measured tuple rate is aggregate over the calibration run's
        # worker count; extraction scales with workers, reads do not
        cpu_rate = rates.cpu_tuples_per_sec * config.num_workers / rates.workers
        # tuples/s is codec-relative (ASCII parse vs near-free binary): when
        # the calibration recorded its extraction cost, rescale for the
        # serving store's codec instead of misclassifying it
        if rates.cost_per_tuple > 0:
            cpu_rate *= (rates.cost_per_tuple
                         / max(store.codec.extract_cost_per_tuple(), 1e-12))
        t_cpu = total_tuples / cpu_rate
    else:
        t_io = total_bytes / config.io_bytes_per_sec
        t_cpu = (total_tuples * store.codec.extract_cost_per_tuple()
                 / config.cpu_tuple_ops_per_sec / config.num_workers)
    t_cpu *= 1.0 - min(max(float(decoded_fraction), 0.0), 1.0)
    return t_io, t_cpu


def scan_tuples_per_s(store, config, rates=None, *, total_bytes=None,
                      total_tuples=None, decoded_fraction: float = 0.0
                      ) -> float:
    """Aggregate scan throughput (tuples/modeled-second) for a full pass —
    the Eq. (4) overlapped-pipeline rate ``total / max(T_io, T_cpu)``.  A
    slot riding the shared scan accumulates sample at (up to) this rate;
    under fairness contention its share scales by its weight.  The
    population overrides mirror :func:`eq4_cost_terms` (post-quarantine
    repricing over surviving chunks), as does ``decoded_fraction`` (the
    parse-once cache's CPU discount)."""
    t_io, t_cpu = eq4_cost_terms(store, config, rates,
                                 total_bytes=total_bytes,
                                 total_tuples=total_tuples,
                                 decoded_fraction=decoded_fraction)
    n = float(store.num_tuples) if total_tuples is None else float(total_tuples)
    return n / max(t_io, t_cpu, 1e-12)


@dataclasses.dataclass(frozen=True)
class ServerLoad:
    """Snapshot of the server at one admission attempt.

    ``slot_drain_s`` / ``queue_ahead_service_s`` are the service-model-
    priced wait components (predicted seconds until a slot frees, and the
    summed predicted service of queued work ahead of the candidate); when
    the caller cannot price them (no scheduler, no model) they stay
    ``None`` and :meth:`AdmissionController.decide` falls back to a
    per-job estimate — the observed mean when history exists, the full-pass
    bound when not.
    """

    now: float                      # modeled server clock
    free_slots: int
    queue_ahead: int                # higher-priority/earlier queries waiting
    scan_rate: float                # tuples/modeled-second (see above)
    total_tuples: int
    mean_service_s: Optional[float] = None   # completed-query history
    slot_drain_s: Optional[float] = None     # model-priced occupant drain
    queue_ahead_service_s: Optional[float] = None  # model-priced queue work


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    action: str                     # ADMIT | QUEUE | SHED | TIER1
    predicted_service_s: float
    predicted_finish_t: float       # modeled-clock completion estimate
    reason: str

    def as_dict(self) -> dict:
        """JSON-able form (explain records, metrics snapshots)."""
        return dataclasses.asdict(self)


class AdmissionController:
    """Feasibility triage (see module docstring).

    ``pessimism`` scales the service prediction (>1 sheds earlier, <1
    later); ``shed_enabled=False`` turns every would-be shed into a queue —
    useful when callers prefer late answers over best-effort ones.
    ``service_model`` (a :class:`~repro.sched.service_model
    .ServiceTimeModel`) prices per-job waits at the candidate class's
    observed quantile; without one the controller uses the observed mean,
    and with no history at all the full-pass bound.
    """

    def __init__(self, shed_enabled: bool = True, pessimism: float = 1.0,
                 service_model=None):
        self.shed_enabled = bool(shed_enabled)
        self.pessimism = float(pessimism)
        self.service_model = service_model
        # per-action decision tallies (observability; see bind_metrics)
        self.decisions: dict[str, int] = {
            ADMIT: 0, QUEUE: 0, SHED: 0, TIER1: 0}

    def _done(self, d: AdmissionDecision) -> AdmissionDecision:
        self.decisions[d.action] = self.decisions.get(d.action, 0) + 1
        return d

    def bind_metrics(self, registry, prefix: str = "admission") -> None:
        """Expose the per-action decision tallies on a
        :class:`~repro.obs.metrics.MetricsRegistry` as pull gauges."""
        for action in (ADMIT, QUEUE, SHED, TIER1):
            registry.gauge(f"{prefix}_decisions",
                           help="admission decisions by action",
                           labels={"action": action},
                           fn=(lambda a=action: self.decisions.get(a, 0)))

    @staticmethod
    def required_tuples(seed_m: int, seed_err: float, epsilon: float,
                        total_tuples: int) -> float:
        """Additional sample the query still needs, by CLT extrapolation:
        the error ratio shrinks ~1/√m, so hitting ε from (m₀, err₀) takes
        ``m₀·(err₀/ε)²`` total tuples.  With no seed (or a degenerate one)
        the bound is a full pass — the honest worst case."""
        if (seed_m > 0 and math.isfinite(seed_err) and seed_err > 0
                and epsilon > 0):
            if seed_err <= epsilon:
                return 0.0
            m_target = seed_m * (seed_err / epsilon) ** 2
            return float(min(total_tuples, m_target) - seed_m)
        return float(total_tuples)

    def decide(self, *, arrival_t: float, slo, epsilon: float,
               load: ServerLoad, seed_m: int = 0,
               seed_err: float = math.inf,
               rollup_err: float = math.inf,
               group_count: int = 0) -> AdmissionDecision:
        """One admission call.  ``seed_m``/``seed_err`` describe the best
        synopsis-seeded answer currently available for the query (0/inf when
        the synopsis cannot serve it).

        ``group_count > 0`` marks a grouped query (``Query(group_by=...)``)
        whose stop condition requires that many group cells to converge
        independently: each cell sees only its own share of the predicate
        mass, so the CLT tuple need multiplies by the cell count — capped at
        one full pass, since a census answers every cell exactly.  Grouped
        callers also pass no seed (cells cannot be seeded), so the bound
        degrades gracefully to the full-pass worst case.

        ``rollup_err`` is the error ratio of the Tier-1 rollup answer for
        the query's pattern (``inf`` when no promoted cell serves it; the
        caller passes 0.0 when a HAVING verdict is already decided).  The
        Tier-1 short-circuit runs *before* the admit/queue/shed triage:
        when the rollup answer meets ε, Eq. (4) routing is trivial — its
        scan cost is zero, so no admit/queue plan can beat it.  When it
        does not meet ε the query still benefits: the caller feeds the
        cell as ``seed_m``/``seed_err`` and the CLT extrapolation prices
        the *remaining* scan, not a cold start.
        """
        if rollup_err <= epsilon:
            return self._done(AdmissionDecision(
                TIER1, 0.0, max(load.now, arrival_t),
                f"rollup answer meets target (err {rollup_err:.3g} <= "
                f"eps {epsilon:.3g}) at zero scan cost"))
        free = load.free_slots > 0 and load.queue_ahead == 0
        need = self.required_tuples(seed_m, seed_err, epsilon,
                                    load.total_tuples)
        if group_count > 0:
            need = min(float(load.total_tuples), need * group_count)
        service = self.pessimism * need / max(load.scan_rate, 1e-12)
        if free:
            wait = 0.0
        else:
            # Queue model: the candidate waits for a slot to drain, then for
            # every queued job ahead of it.  Each component is priced by the
            # service model's per-class quantile when the caller provides it
            # (ServerLoad.slot_drain_s / queue_ahead_service_s); the
            # fallback per-job price is the observed mean, and with no
            # history at all the full-pass bound — NOT the candidate's own
            # seed-discounted prediction, which would let a well-seeded
            # query predict a near-zero wait behind a queue of full-pass
            # work (the PR-4 full-pass-fallback bug).
            full_pass = load.total_tuples / max(load.scan_rate, 1e-12)
            per = load.mean_service_s if load.mean_service_s else full_pass
            if self.service_model is not None:
                per = self.service_model.predict(slo.priority, per)
            drain = load.slot_drain_s if load.slot_drain_s is not None else per
            ahead = (load.queue_ahead_service_s
                     if load.queue_ahead_service_s is not None
                     else load.queue_ahead * per)
            wait = drain + ahead
        finish = max(load.now, arrival_t) + wait + service

        if not slo.has_deadline:
            action = ADMIT if free else QUEUE
            return self._done(
                AdmissionDecision(action, service, finish, "no deadline"))
        deadline_t = arrival_t + slo.deadline_s
        if finish > deadline_t and self.shed_enabled:
            return self._done(AdmissionDecision(
                SHED, service, finish,
                f"predicted finish {finish:.3g}s past deadline "
                f"{deadline_t:.3g}s"))
        action = ADMIT if free else QUEUE
        return self._done(
            AdmissionDecision(action, service, finish, "deadline feasible"))
