"""Online per-priority-class service-time distributions.

PR 4's admission controller priced queue waits with a single scalar — the
mean of every completed query's service time, regardless of class.  Means
are the wrong statistic for admission: service times under OLA are heavy
-tailed (a loose-ε interactive probe retires in one round, a tight-ε batch
sum rides the scan to near-census), and a deadline decision made against
the mean is optimistic exactly when the queue is full of the slow kind.

:class:`ServiceTimeModel` replaces that scalar with one **running quantile
sketch per priority class**, fitted online from completed
:class:`~repro.serve.ola_server.WorkloadResult`\\ s (the server feeds it a
``(priority, service_seconds)`` pair at every retirement).  The admission
controller then prices each queued/occupying job at the class's p-quantile
(default p90 — configurable via ``SchedulerConfig.wait_quantile``), so the
shed/queue call is "will the deadline survive a *plausibly bad* wait", not
"an average one".

The sketch is Jain & Chlamtac's P² algorithm: five markers per class,
O(1) memory and O(1) update, no sample buffer — the right shape for a
server that retires millions of queries.  Cold start is explicit: below
``min_samples`` observations the prediction *blends* the sketch with the
caller's prior (the Eq. (4) CLT full-pass bound), sliding from model-free
to measured as evidence accumulates.
"""

from __future__ import annotations

import math
from typing import Optional


class P2Quantile:
    """Jain & Chlamtac's P² streaming quantile estimator (one quantile).

    Constant memory: five marker heights + positions.  Until five
    observations arrive the estimate is the exact empirical quantile of the
    buffered prefix.  Accuracy is property-tested against ``np.percentile``
    in ``tests/test_sched.py``.
    """

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {p}")
        self.p = float(p)
        self.n_obs = 0
        self._q: list[float] = []        # marker heights
        self._n: list[float] = []        # marker positions (1-indexed)
        self._dn = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]

    def observe(self, x: float) -> None:
        x = float(x)
        if not math.isfinite(x):
            return                       # a NaN/inf service time is a bug
        self.n_obs += 1
        if self.n_obs <= 5:
            self._q.append(x)
            self._q.sort()
            if self.n_obs == 5:
                self._n = [1.0, 2.0, 3.0, 4.0, 5.0]
            return
        q, n, p = self._q, self._n, self.p
        # locate the cell and clamp the extremes
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and not (q[k] <= x < q[k + 1]):
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1.0
        # desired positions drift by the quantile increments
        nd = [1.0 + (self.n_obs - 1) * d for d in self._dn]
        for i in (1, 2, 3):
            d = nd[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1.0) or (
                    d <= -1.0 and n[i - 1] - n[i] < -1.0):
                d = math.copysign(1.0, d)
                # parabolic (P²) adjustment, linear fallback when it would
                # push the marker out of order
                qp = q[i] + d / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + d) * (q[i + 1] - q[i])
                    / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1])
                    / (n[i] - n[i - 1]))
                if not (q[i - 1] < qp < q[i + 1]):
                    j = i + int(d)
                    qp = q[i] + d * (q[j] - q[i]) / (n[j] - n[i])
                q[i] = qp
                n[i] += d

    def value(self) -> Optional[float]:
        """Current quantile estimate; ``None`` before any observation."""
        if self.n_obs == 0:
            return None
        if self.n_obs <= 5:
            # exact small-sample quantile: at five or fewer observations
            # _q is still the raw sorted sample (markers have not moved
            # yet), so interpolate rather than return the median marker —
            # a p90 sketch over [1,1,1,1,100] must answer ~70, not 1
            k = self.p * (len(self._q) - 1)
            lo = int(math.floor(k))
            hi = min(lo + 1, len(self._q) - 1)
            return self._q[lo] + (k - lo) * (self._q[hi] - self._q[lo])
        return self._q[2]


class ServiceTimeModel:
    """Per-priority-class service-time quantiles, fitted online.

    ``observe(priority, service_s)`` feeds one completed query;
    ``predict(priority, prior_s)`` returns the class's ``quantile`` estimate
    once ``min_samples`` observations exist, a linear blend of sketch and
    ``prior_s`` below that, and ``prior_s`` itself with no evidence at all.
    Unknown classes (no :data:`~repro.sched.slo.PRIORITY_WEIGHTS` entry ever
    observed) simply stay on the prior — the model never invents data.
    """

    def __init__(self, quantile: float = 0.9, min_samples: int = 8):
        if not min_samples >= 1:
            raise ValueError(f"min_samples must be >= 1, got {min_samples}")
        self.quantile = float(quantile)
        self.min_samples = int(min_samples)
        self._sketch: dict[str, P2Quantile] = {}

    def observe(self, priority: str, service_s: float) -> None:
        if not (math.isfinite(service_s) and service_s >= 0.0):
            return
        sk = self._sketch.get(priority)
        if sk is None:
            sk = self._sketch[priority] = P2Quantile(self.quantile)
        sk.observe(service_s)

    def n_obs(self, priority: str) -> int:
        sk = self._sketch.get(priority)
        return 0 if sk is None else sk.n_obs

    def predict(self, priority: str, prior_s: float) -> float:
        """Quantile of the class's observed service times, cold-started from
        ``prior_s`` (the CLT cost-model bound): with ``n`` observations the
        result is ``(n·sketch + (min_samples - n)·prior) / min_samples``
        until ``n >= min_samples``, then the sketch alone."""
        sk = self._sketch.get(priority)
        est = None if sk is None else sk.value()
        if est is None:
            return float(prior_s)
        n = sk.n_obs
        if n >= self.min_samples:
            return float(est)
        w = n / float(self.min_samples)
        return float(w * est + (1.0 - w) * prior_s)
