"""Roofline accounting from dry-run artifacts (no hardware required)."""

from repro.roofline.analysis import analyze_lowered, collective_bytes
from repro.roofline.hw import TPU_V5E

__all__ = ["TPU_V5E", "analyze_lowered", "collective_bytes"]
