"""Target-hardware constants (TPU v5e), per the assignment brief."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class HWSpec:
    name: str
    peak_flops_bf16: float     # per chip
    hbm_bw: float              # bytes/s per chip
    ici_link_bw: float         # bytes/s per link
    ici_links: int             # usable links per chip (2D torus, bidirectional)
    hbm_bytes: float


TPU_V5E = HWSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    ici_links=2,       # conservative: one bidirectional ring axis in flight
    hbm_bytes=16e9,
)
