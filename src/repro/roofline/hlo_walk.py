"""While-loop-aware HLO cost extraction.

XLA's ``cost_analysis()`` counts a ``while`` body once regardless of trip
count, and fully unrolling 88-layer stacks for the dry-run costs tens of
compile-minutes per cell.  This walker keeps scans *rolled* (fast compiles,
faithful per-layer collective schedules) and recovers exact totals itself:

1. split the compiled HLO text into computations;
2. per computation, tally dot FLOPs (2·|out|·K from the operand shape and
   ``lhs_contracting_dims``) and collective transport bytes (ring-algorithm
   conventions, replica-group sizes parsed per op);
3. build the call graph (``body=/condition=`` for whiles, ``calls=`` for
   fusions, ``branch_computations`` for conditionals), parse each loop's trip
   count from its condition computation (``compare(gte, constant(N))``);
4. propagate multipliers from ENTRY (trip count on while-body edges) and sum.

Validated against fully-unrolled compiles of the same cells
(tests/test_sharding_roofline.py + EXPERIMENTS.md §Dry-run methodology).
Unresolvable trip counts fall back to 1 and are reported in ``unresolved``.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{?\s*$")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALL_ATTR_RE = re.compile(
    r"(?:condition|body|calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_RE = re.compile(r"\bwhile\(")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_DOT_OPS_RE = re.compile(r"\bdot\(\s*%?([\w.\-]+)\s*,\s*%?([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(\s*%?([\w.\-]+)\s*,\s*%?([\w.\-]+)\)")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COLL_KIND_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_OPERAND_REF_RE = re.compile(r"%([\w.\-]+)")
# ops that move no HBM bytes of their own
_FREE_OPS = ("parameter(", "tuple(", "get-tuple-element(", "constant(",
             "bitcast(", "after-all(", "partition-id(", "replica-id(")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, 0
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return dims, n * _DTYPE_BYTES.get(m.group(1), 4)


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


@dataclasses.dataclass
class CompCost:
    dot_flops: float = 0.0
    dot_count: int = 0
    coll: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_count: int = 0
    hbm_bytes: float = 0.0    # operand+result bytes at fusion boundaries
    children: list = dataclasses.field(default_factory=list)  # (name, kind)
    while_pairs: list = dataclasses.field(default_factory=list)  # (cond, body)
    constants: dict = dataclasses.field(default_factory=dict)
    compare_ops: list = dataclasses.field(default_factory=list)


def split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and line.endswith("{") and ("(" in line):
            head = line.split("(")[0].strip()
            name = head.replace("ENTRY", "").strip().lstrip("%")
            cur = name
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps


def entry_name(hlo_text: str) -> str:
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            return line.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
    # fallback: last computation
    return list(split_computations(hlo_text))[-1]


def slice_fusion_names(comps: dict[str, list[str]]) -> set:
    """Names of fused computations that contain a slice-like op: a fusion
    calling one of these touches only slice-sized HBM regions per call."""
    out = set()
    for name, lines in comps.items():
        for line in lines:
            if ("dynamic-slice(" in line or "dynamic-update-slice(" in line
                    or " gather(" in line):
                out.add(name)
                break
    return out


def analyze_computation(lines: list[str], default_group: int,
                        slice_fusions: set = frozenset()) -> CompCost:
    cost = CompCost()
    shapes: dict[str, list[int]] = {}
    out_bytes: dict[str, int] = {}
    for line in lines:
        dm = _DEF_RE.match(line)
        name = None
        if dm:
            name = dm.group(1)
            rhs = dm.group(2)
            dims, nbytes = _first_shape(rhs)
            if dims is not None:
                shapes[name] = dims
                out_bytes[name] = nbytes
            # HBM traffic model: every non-free instruction at this level
            # reads its operands and writes its result once (fusion bodies
            # are excluded by the caller via bytes_mult=0).  Slice-like ops
            # only touch slice-sized regions: a dynamic-slice of a
            # loop-invariant sequence reads one step per trip (charging the
            # full operand ×trip_count overstated xlstm prefill by 60x), and
            # dynamic-update-slice writes in place.
            if not any(op in rhs for op in _FREE_OPS):
                paren = rhs.split("(", 1)
                operand_sizes = []
                if len(paren) > 1:
                    args = paren[1].split(")")[0]
                    for ref in _OPERAND_REF_RE.findall(args):
                        operand_sizes.append(out_bytes.get(ref, 0))
                is_dus = ("dynamic-update-slice" in line
                          or "dynamic_update_slice" in line)
                callee = _CALL_ATTR_RE.search(line)
                fused_slice = (("fusion(" in rhs) and callee is not None
                               and callee.group(1) in slice_fusions)
                is_slice = ("dynamic-slice" in line or "dynamic_slice" in line
                            or " gather(" in rhs or "/gather" in line
                            or fused_slice)
                if is_dus:
                    # in-place update: read+write the slice region (smallest
                    # non-trivial operand approximates the update)
                    small = min((o for o in operand_sizes if o > 0),
                                default=nbytes)
                    small = min(small, nbytes)
                    cost.hbm_bytes += 2 * small
                elif is_slice:
                    cost.hbm_bytes += 2 * nbytes   # read slice + write out
                else:
                    cost.hbm_bytes += nbytes + sum(operand_sizes)
        # constants (for trip counts)
        cm = _CONST_RE.search(line)
        if dm and cm and "s32[]" in line or (dm and cm and "s64[]" in line):
            cost.constants[name] = int(cm.group(1))
        if "compare(" in line:
            pm = _COMPARE_RE.search(line)
            if pm:
                cost.compare_ops.append((pm.group(1), pm.group(2)))
        # call edges
        if _WHILE_RE.search(line):
            wb = _COND_BODY_RE.search(line)
            if wb:
                cost.while_pairs.append((wb.group(1), wb.group(2)))
            else:  # attribute order variant
                cm_ = re.search(r"condition=%?([\w.\-]+)", line)
                bm_ = re.search(r"body=%?([\w.\-]+)", line)
                if cm_ and bm_:
                    cost.while_pairs.append((cm_.group(1), bm_.group(1)))
        else:
            # fusion/reduce bodies: flops counted, internal bytes are not
            # HBM traffic (that's the point of fusion)
            for callee in _CALL_ATTR_RE.findall(line):
                cost.children.append((callee, "fused"))
        bm = _BRANCH_RE.search(line)
        if bm:
            for c in bm.group(1).split(","):
                cost.children.append((c.strip().lstrip("%"), "call"))
        # dots
        if " dot(" in line or line.startswith("dot("):
            ops = _DOT_OPS_RE.search(line)
            lc = _LHS_C_RE.search(line)
            out_dims = shapes.get(name or "", [])
            if ops and lc is not None:
                lhs = shapes.get(ops.group(1))
                k = 1
                if lhs:
                    for d in (int(x) for x in lc.group(1).split(",") if x):
                        if d < len(lhs):
                            k *= lhs[d]
                out_n = 1
                for d in out_dims:
                    out_n *= d
                cost.dot_flops += 2.0 * out_n * k
                cost.dot_count += 1
        # collectives
        km = _COLL_KIND_RE.search(line)
        if km and dm:
            kind = km.group(1)
            if "-done(" in line:
                continue  # volume charged on the -start op
            res_bytes = _all_shapes_bytes(line.split("=", 1)[0])
            if res_bytes == 0:
                # fall back: first shape on the rhs
                _, res_bytes = _first_shape(dm.group(2))
            g = default_group
            m1 = _GROUPS_EXPLICIT_RE.search(line)
            m2 = _GROUPS_IOTA_RE.search(line)
            if m1:
                g = len(m1.group(1).split(","))
            elif m2:
                g = int(m2.group(2))
            frac = (g - 1) / max(g, 1)
            if kind == "all-reduce":
                vol = 2.0 * res_bytes * frac
            elif kind == "all-gather":
                vol = res_bytes * frac
            elif kind == "reduce-scatter":
                vol = res_bytes * (g - 1)
            elif kind == "all-to-all":
                vol = res_bytes * frac
            else:
                vol = float(res_bytes)
            cost.coll[kind] += vol
            cost.coll_count += 1
    return cost


def _trip_count(cond_cost: CompCost) -> int | None:
    """Loop bound from the condition computation: compare(gte, constant(N))."""
    for a, b in cond_cost.compare_ops:
        for side in (a, b):
            if side in cond_cost.constants:
                return cond_cost.constants[side]
    # single s32 constant in the computation: take it
    if len(cond_cost.constants) == 1:
        return next(iter(cond_cost.constants.values()))
    return None


def walk(hlo_text: str, default_group: int = 2) -> dict:
    comps = split_computations(hlo_text)
    sfuse = slice_fusion_names(comps)
    costs = {n: analyze_computation(ls, default_group, sfuse)
             for n, ls in comps.items()}
    entry = entry_name(hlo_text)

    total_flops = 0.0
    total_coll = {k: 0.0 for k in COLLECTIVE_KINDS}
    total_bytes = 0.0
    dot_count = 0
    coll_count = 0
    unresolved = 0
    visited_stack = []

    def visit(name: str, mult: float, bytes_mult: float):
        nonlocal total_flops, total_bytes, dot_count, coll_count, unresolved
        c = costs.get(name)
        if c is None or name in visited_stack:
            return
        visited_stack.append(name)
        total_flops += c.dot_flops * mult
        total_bytes += c.hbm_bytes * bytes_mult
        dot_count += c.dot_count
        coll_count += c.coll_count
        for k in COLLECTIVE_KINDS:
            total_coll[k] += c.coll[k] * mult
        for cond, body in c.while_pairs:
            trip = _trip_count(costs.get(cond, CompCost()))
            if trip is None:
                trip = 1
                unresolved += 1
            visit(cond, mult, 0.0)
            visit(body, mult * trip, bytes_mult * trip)
        for child, _kind in c.children:
            visit(child, mult, 0.0)
        visited_stack.pop()

    visit(entry, 1.0, 1.0)
    total_coll["total"] = sum(total_coll.values())
    return {
        "matmul_flops": total_flops,
        "dot_count": dot_count,
        "collective": total_coll,
        "collective_count": coll_count,
        "hbm_bytes": total_bytes,
        "unresolved_trip_counts": unresolved,
        "num_computations": len(comps),
    }
