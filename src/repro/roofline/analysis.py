"""Three-term roofline from the compiled dry-run artifact.

    compute_s    = HLO_FLOPs_per_chip / peak_FLOPs
    memory_s     = HLO_bytes_per_chip / HBM_bw
    collective_s = transported_ICI_bytes_per_chip / (link_bw · links)

``compiled.cost_analysis()`` supplies FLOPs/bytes of the *per-device* SPMD
program (GSPMD emits one partitioned module).  Collective bytes are NOT in
cost_analysis: :func:`collective_bytes` parses the compiled HLO text and sums
transported volume per op with ring-algorithm conventions:

    all-reduce      2 · size · (g-1)/g        (reduce-scatter + all-gather)
    all-gather      size_out · (g-1)/g
    reduce-scatter  size_in  · (g-1)/g
    all-to-all      size · (g-1)/g
    collective-permute  size

where ``g`` is the replica-group size parsed from the op's
``replica_groups`` attribute (both explicit ``{{0,1,..}}`` and iota
``[n,g]<=[N]`` forms).

MODEL_FLOPS uses 6·N·D for training and 2·N·D for serving (N = real —
unpadded — parameter count, N_active for MoE), so the ``useful_flops_ratio``
column charges head/vocab padding, remat recompute and dispatch overhead
honestly.
"""

from __future__ import annotations

import re
from typing import Optional

from repro.roofline.hw import TPU_V5E, HWSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9\[\],{}\s]*?)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")

_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def collective_bytes(hlo_text: str, default_group: int = 2) -> dict:
    """Transported ICI bytes per chip, by collective kind (see module doc)."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        line_s = line.strip()
        m = _COLL_RE.search(line_s)
        if not m or line_s.startswith("ROOT tuple"):
            continue
        kind = m.group(2).lower()
        # result shape(s): text before the op name on the lhs
        lhs = line_s.split("=", 1)
        res_bytes = _shape_bytes(lhs[0]) if len(lhs) > 1 else 0
        if res_bytes == 0:
            res_bytes = _shape_bytes(m.group(1))
        g = _group_size(line_s, default_group)
        frac = (g - 1) / max(g, 1)
        if kind == "all-reduce":
            vol = 2.0 * res_bytes * frac
        elif kind == "all-gather":
            vol = res_bytes * frac
        elif kind == "reduce-scatter":
            vol = res_bytes * (g - 1)      # input = g × output
        elif kind == "all-to-all":
            vol = res_bytes * frac
        else:  # collective-permute
            vol = float(res_bytes)
        out[kind] += vol
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("count", "total"))
    return out


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+\[[0-9,]*\])")
_DOT_OPERANDS_RE = re.compile(r"dot\(\s*%?([\w.\-]+)\s*,\s*%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_ONE_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _dims_of(shape_text: str) -> list[int]:
    m = _ONE_SHAPE_RE.search(shape_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def matmul_flops_from_hlo(hlo_text: str) -> dict:
    """Exact per-device matmul FLOPs: Σ over `dot` ops of 2·|out|·K.

    XLA:CPU's ``cost_analysis()['flops']`` charges fused elementwise /
    broadcast / reduce traffic at rates that have nothing to do with the TPU
    MXU, so the roofline compute term uses the dots parsed from the
    partitioned HLO instead (contracting sizes come from each dot's
    ``lhs_contracting_dims`` against its operand's shape).  Ops inside
    rolled `while` bodies are counted once — the dry-run unrolls layer scans
    precisely so this is exact (remaining rolled loops: sLSTM time scan,
    noted in EXPERIMENTS.md).
    """
    shapes: dict[str, list[int]] = {}
    total = 0.0
    count = 0
    unresolved = 0
    for raw in hlo_text.splitlines():
        line = raw.strip()
        dm = _DEF_RE.match(line)
        if dm:
            shapes[dm.group(1)] = _dims_of(dm.group(2))
        if " dot(" not in line and not line.startswith("dot("):
            continue
        if dm is None:
            continue
        out_dims = shapes.get(dm.group(1), [])
        ops = _DOT_OPERANDS_RE.search(line)
        cm = _LHS_CONTRACT_RE.search(line)
        if not ops or cm is None:
            unresolved += 1
            continue
        lhs = shapes.get(ops.group(1))
        if lhs is None:
            unresolved += 1
            continue
        k = 1
        for d in (int(x) for x in cm.group(1).split(",") if x):
            if d < len(lhs):
                k *= lhs[d]
        out_n = 1
        for d in out_dims:
            out_n *= d
        total += 2.0 * out_n * k
        count += 1
    return {"matmul_flops": total, "dot_count": count,
            "dot_unresolved": unresolved}


def model_flops(arch: str, shape: str, n_chips: int) -> Optional[float]:
    """6·N·D (train) / 2·N·D (serve) with the *real* parameter count."""
    from repro.configs.registry import SHAPES, get_config
    from repro.configs.base import active_param_count

    cfg = get_config(arch)
    spec = SHAPES[shape]
    n = active_param_count(cfg)
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    factor = 6.0 if spec.kind == "train" else 2.0
    return factor * n * tokens


def analyze_lowered(lowered, compiled, arch: str, shape: str, n_chips: int,
                    hw: HWSpec = TPU_V5E) -> dict:
    from repro.roofline.hlo_walk import walk

    cost = compiled.cost_analysis() or {}
    raw_flops = float(cost.get("flops", 0.0))
    # fusion-boundary HBM traffic from the walker (XLA:CPU's "bytes accessed"
    # counts fusion internals and misses loop trip counts)
    text = compiled.as_text()
    w = walk(text)
    bytes_accessed = float(w["hbm_bytes"])
    coll = dict(w["collective"], count=w["collective_count"])
    mm = {"matmul_flops": w["matmul_flops"], "dot_count": w["dot_count"],
          "dot_unresolved": w["unresolved_trip_counts"]}
    flops = mm["matmul_flops"]

    compute_s = flops / hw.peak_flops_bf16
    memory_s = bytes_accessed / hw.hbm_bw
    collective_s = coll["total"] / (hw.ici_link_bw * hw.ici_links)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(arch, shape, n_chips)
    useful = (mf / (flops * n_chips)) if (mf and flops) else None
    bound_s = max(terms.values())
    return {
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "bound_s": bound_s,
            "collective_detail": {k: float(v) for k, v in coll.items()},
            "model_flops": mf,
            "hlo_flops_per_chip": flops,          # exact matmul flops (dots)
            "hlo_flops_raw_per_chip": raw_flops,  # XLA:CPU cost model, fyi
            "dot_count": mm["dot_count"],
            "dot_unresolved": mm["dot_unresolved"],
            "hlo_bytes_per_chip": bytes_accessed,
            "useful_flops_ratio": useful,
            # fraction of the step the dominant resource is actually needed
            # by the useful model FLOPs — the score we hillclimb:
            "roofline_fraction": (
                (mf / n_chips / hw.peak_flops_bf16) / bound_s
                if (mf and bound_s > 0) else None),
        }
    }
