"""Reservoir-style chunk admission order for synopsis construction (Section 6.1).

The synopsis admits chunks "in the random order they are extracted for
estimation" — i.e. the committed chunk schedule itself.  When the memory
budget is exhausted the variance-driven reallocation (synopsis.py) decides how
much of each chunk survives; classic reservoir *eviction* is replaced by
variance-proportional shrinking, which is the paper's novelty.  This module
only provides the admission order and a plain Vitter reservoir used by tests
as a behavioural baseline.
"""

from __future__ import annotations

import numpy as np


def reservoir_insertion_order(schedule: np.ndarray, extracted_rounds: np.ndarray) -> np.ndarray:
    """Order in which chunks become candidates for synopsis insertion.

    ``schedule`` is the committed random chunk order; ``extracted_rounds[j]``
    is the round at which chunk ``schedule[j]`` produced its first sample.
    Ties (same round, the common case with lockstep workers) break by schedule
    position, preserving the prefix property.
    """
    order = np.lexsort((np.arange(len(schedule)), extracted_rounds))
    return schedule[order]


def vitter_reservoir(stream: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """Vitter's Algorithm R — baseline oracle for synopsis tests."""
    rng = np.random.default_rng(seed)
    res = list(stream[:k])
    for i in range(k, len(stream)):
        j = rng.integers(0, i + 1)
        if j < k:
            res[j] = stream[i]
    return np.asarray(res)
