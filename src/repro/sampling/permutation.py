"""Keyed bijective permutations over ``[0, M)`` via a balanced Feistel network.

Why Feistel and not ``jax.random.permutation``:

* OLA-RAW needs an *incremental* random order per chunk (Section 4.1): tuples
  are extracted a few at a time, the synopsis keeps a *circular window* into
  the order (Section 6.2), and subsequent queries continue from ``start+count``.
  A bijection evaluated on demand gives O(1) state per chunk instead of an
  O(M_j) materialised permutation for every one of thousands of chunks.
* The permutation must be recomputable bit-for-bit after a checkpoint restore
  and on any worker — a pure keyed function is trivially so.

Construction: 4-round balanced Feistel over ``2 * half_bits`` bits with a
multiply-xor round function, cycle-walking down to the true domain ``M``.
Balanced Feistel networks with >= 3 rounds are permutations of the full
power-of-two domain for *any* round function; cycle-walking restricts the
permutation to ``[0, M)`` while preserving bijectivity.  The domain is at most
``4 * M`` so the expected walk length is < 4 steps.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

_NUM_ROUNDS = 4
# SplitMix32 / Murmur3 finalizer constants.
_C1 = np.uint32(0x9E3779B9)
_C2 = np.uint32(0x85EBCA6B)
_C3 = np.uint32(0xC2B2AE35)


def _u32(x) -> jnp.ndarray:
    return jnp.asarray(x).astype(jnp.uint32)


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """SplitMix32 finalizer: a cheap, well-distributed 32-bit mixer."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * _C1
    x = (x ^ (x >> 13)) * _C2
    x = x ^ (x >> 16)
    return x


def chunk_seed(master_seed, chunk_id) -> jnp.ndarray:
    """Derive an independent per-chunk permutation key (Section 4.1 requires
    independent orders across chunks)."""
    return _mix32(_u32(master_seed) ^ (_mix32(_u32(chunk_id)) + _C3))


def _round_keys(seed: jnp.ndarray) -> jnp.ndarray:
    """(NUM_ROUNDS,) uint32 round keys derived from one seed."""
    r = jnp.arange(_NUM_ROUNDS, dtype=jnp.uint32)
    return _mix32(_u32(seed) + (r + jnp.uint32(1)) * _C2)


def _half_bits(domain_m: int) -> int:
    """Half-width (in bits) of the smallest even-width Feistel domain >= M."""
    m = max(int(domain_m), 2)
    total = max(2, int(np.ceil(np.log2(m))))
    total += total % 2  # balanced network needs an even bit count
    return total // 2


def _feistel_round_trip(x: jnp.ndarray, keys: jnp.ndarray, hb: int) -> jnp.ndarray:
    """One full 4-round Feistel pass over the 2*hb-bit domain."""
    mask = jnp.uint32((1 << hb) - 1)
    left = (x >> hb) & mask
    right = x & mask
    for r in range(_NUM_ROUNDS):
        f = _mix32(right ^ keys[r]) & mask
        left, right = right, left ^ f
    return ((left << hb) | right).astype(jnp.uint32)


def feistel_permute(seed, index, domain_m: int) -> jnp.ndarray:
    """``perm_seed(index)`` for ``index in [0, M)`` — a bijection on ``[0, M)``.

    ``index`` may be any integer array; the result has the same shape with
    dtype int32.  ``domain_m`` must be a static Python int (it fixes the
    Feistel width), which is always the case for chunk tuple counts coming
    from file metadata.
    """
    domain_m = int(domain_m)
    if domain_m <= 1:
        return jnp.zeros_like(jnp.asarray(index), dtype=jnp.int32)
    hb = _half_bits(domain_m)
    keys = _round_keys(seed)
    m = jnp.uint32(domain_m)

    def walk(x):
        # Cycle-walk: re-encrypt until the value lands inside [0, M).
        def cond(v):
            return v >= m

        def body(v):
            return _feistel_round_trip(v, keys, hb)

        first = _feistel_round_trip(x, keys, hb)
        return jax.lax.while_loop(cond, body, first)

    idx = _u32(index)
    out = jax.vmap(walk)(idx.reshape(-1)).reshape(idx.shape)
    return out.astype(jnp.int32)


def feistel_permute_dyn(seed, index, m_dynamic, width_m: int) -> jnp.ndarray:
    """Like :func:`feistel_permute` but with a *traced* target domain.

    The Feistel width is fixed by the static ``width_m`` (>= any runtime
    ``m_dynamic``); cycle-walking then restricts to ``[0, m_dynamic)``.  Used
    inside the jitted engine where per-chunk tuple counts ``M_j`` are traced
    values.  Walk length is geometric with mean ``width_domain / m_dynamic`` —
    fine when chunk sizes are within a small factor of the maximum (chunk
    sizing follows the paper's "tens-of-MB, near-uniform" guidance), and the
    loop is bounded regardless because the walk visits a permutation cycle.
    """
    width_m = int(width_m)
    hb = _half_bits(max(width_m, 2))
    keys = _round_keys(seed)
    m = jnp.maximum(_u32(m_dynamic), jnp.uint32(1))

    def walk(x, mj):
        def cond(v):
            return v >= mj

        def body(v):
            return _feistel_round_trip(v, keys, hb)

        first = _feistel_round_trip(x, keys, hb)
        return jax.lax.while_loop(cond, body, first)

    idx = _u32(index)
    flat = jax.vmap(walk, in_axes=(0, None))(idx.reshape(-1), m)
    return flat.reshape(idx.shape).astype(jnp.int32)


def permutation_window_dyn(seed, start, count: int, m_dynamic, width_m: int) -> jnp.ndarray:
    """Dynamic-domain circular window: ``perm[start : start+count] mod M_j``."""
    offs = (jnp.asarray(start, jnp.int32) + jnp.arange(count, dtype=jnp.int32))
    offs = offs % jnp.maximum(jnp.asarray(m_dynamic, jnp.int32), 1)
    return feistel_permute_dyn(seed, offs, m_dynamic, width_m)


def permutation_window(seed, start, count: int, domain_m: int) -> jnp.ndarray:
    """Positions ``perm[start : start+count]`` of the chunk's random order,
    wrapping circularly (the Section 6.2 "circular random scan").

    ``count`` is static; ``start`` may be traced.  Returns ``(count,)`` int32
    tuple indices.
    """
    domain_m = int(domain_m)
    offs = (jnp.asarray(start, dtype=jnp.int32) + jnp.arange(count, dtype=jnp.int32))
    offs = offs % jnp.int32(max(domain_m, 1))
    return feistel_permute(seed, offs, domain_m)


def random_chunk_order(master_seed: int, num_chunks: int) -> np.ndarray:
    """The predetermined random chunk processing order (Section 3).

    Committed *before* execution starts — this is what makes the started-set a
    content-independent prefix and is the anchor of the no-inspection-paradox
    argument.  Host-side numpy on purpose: the schedule is part of the query
    plan, not of the jitted computation, and must be cheap to checkpoint.
    """
    rng = np.random.default_rng(np.uint32(master_seed))
    return rng.permutation(num_chunks).astype(np.int32)
