"""Sampling substrate: keyed bijective permutations + reservoir helpers.

The paper shuffles the tuples of every chunk in memory (Section 4.1) and keeps
independent orders across chunks.  Materialising a permutation array per chunk
is hostile to the TPU memory hierarchy, so we use a keyed Feistel bijection
evaluated on the fly: the synopsis (Section 6) then only has to remember
``(key_j, start_j, count_j)`` to describe its circular sample window.
"""

from repro.sampling.permutation import (
    chunk_seed,
    feistel_permute,
    permutation_window,
    random_chunk_order,
)
from repro.sampling.reservoir import reservoir_insertion_order

__all__ = [
    "chunk_seed",
    "feistel_permute",
    "permutation_window",
    "random_chunk_order",
    "reservoir_insertion_order",
]
