"""Host-side online group discovery for the grouped slot plane.

The engine's round report carries, per slot, a small salted tally table
``(3, H)`` — ``[count, Σv, Σv²]`` of the slot's group-column values bucketed
by a per-round hash (:func:`repro.kernels.ref.tally_hash`).  The host folds
those tallies into a bounded SpaceSaving sketch (Metwally et al., the
standard O(k)-space heavy-hitter summary) and promotes the heaviest values
into the slot's tracked group cells.  Two properties make the fold sound:

* **Purity.**  A hash bucket is trusted only when its moments prove a single
  occupant value: ``Σv² · count == (Σv)²`` (f64, relative tolerance), i.e.
  the in-bucket variance is zero.  Mixed buckets are simply skipped.
* **Transience.**  The hash salt is the round number, so two values that
  collide this round almost surely separate next round — a heavy value is
  only ever *delayed*, never permanently masked.

Everything here is plain numpy on tiny arrays; the sketch never touches the
device.
"""

from __future__ import annotations

import numpy as np

# relative tolerance for the single-occupant moment test; tally moments are
# f32 sums, so pure buckets land ~1e-7·count away from exact equality
PURITY_RTOL = 1e-4


def pure_buckets(tal: np.ndarray, rtol: float = PURITY_RTOL,
                 ) -> list[tuple[float, float]]:
    """Extract provably-single-value buckets from one ``(3, H)`` tally row.

    Returns ``[(value, count), ...]`` for every bucket whose moments pass
    the zero-variance test; mixed buckets (transient hash collisions) are
    dropped.
    """
    cnt = np.asarray(tal[0], np.float64)
    vsum = np.asarray(tal[1], np.float64)
    vsq = np.asarray(tal[2], np.float64)
    lhs = vsq * cnt
    rhs = vsum * vsum
    scale = np.maximum(np.maximum(np.abs(lhs), np.abs(rhs)), 1.0)
    pure = (cnt > 0) & (np.abs(lhs - rhs) <= rtol * scale)
    out = []
    for b in np.nonzero(pure)[0]:
        # mean of n copies of one f32 value recovers that value; snap to f32
        # so sketch keys match the engine's cell-equality test bit-for-bit
        out.append((float(np.float32(vsum[b] / cnt[b])), float(cnt[b])))
    return out


class GroupSketch:
    """Bounded SpaceSaving heavy-hitter sketch over one slot's group column.

    ``offer(value, count)`` is the weighted SpaceSaving update: tracked
    values accumulate, new values take over the minimum-count entry when the
    sketch is full (inheriting its count as the overestimation error bound).
    ``top(k)`` returns the k heaviest ``(value, count)`` pairs.
    """

    def __init__(self, capacity: int):
        assert capacity >= 1
        self.capacity = int(capacity)
        self.counts: dict[float, float] = {}
        self.errors: dict[float, float] = {}
        # total pure-bucket mass absorbed — promotion policies gate on it
        # (a sketch that has seen too little is ranked by noise)
        self.mass = 0.0

    def __len__(self) -> int:
        return len(self.counts)

    def offer(self, value: float, count: float) -> None:
        if count <= 0:
            return
        self.mass += count
        if value in self.counts:
            self.counts[value] += count
        elif len(self.counts) < self.capacity:
            self.counts[value] = count
            self.errors[value] = 0.0
        else:
            victim = min(self.counts, key=self.counts.get)
            floor = self.counts.pop(victim)
            self.errors.pop(victim, None)
            self.counts[value] = floor + count
            self.errors[value] = floor

    def fold(self, tal: np.ndarray, rtol: float = PURITY_RTOL) -> None:
        """Fold one round's ``(3, H)`` tally row into the sketch."""
        for value, count in pure_buckets(tal, rtol):
            self.offer(value, count)

    def top(self, k: int) -> list[tuple[float, float]]:
        order = sorted(self.counts.items(), key=lambda kv: -kv[1])
        return order[:k]

    def guaranteed(self, value: float) -> float:
        """Lower bound on the value's true tallied count (count − error)."""
        return self.counts.get(value, 0.0) - self.errors.get(value, 0.0)


def promote_values(sketch: GroupSketch, tracked: list[float],
                   max_groups: int) -> list[float]:
    """Pick sketch values to promote into free tracked cells (grow-only).

    Returns the heavy-hitter values not yet tracked, heaviest first, at most
    the number of free cells.  Promotion never evicts a tracked cell — a
    cell's stats window restarts only for the ``__other__`` spill (which
    must drop the promoted value's mass), so swapping tracked cells would
    throw away converged CIs for marginal sketch churn.
    """
    free = max_groups - len(tracked)
    if free <= 0:
        return []
    seen = set(tracked)
    out = []
    for value, _ in sketch.top(max_groups):
        if value not in seen:
            out.append(value)
            if len(out) == free:
                break
    return out
