"""Query plane: the aggregate-query AST and its compiled tile evaluator.

Queries follow the paper's Section 2.2 form::

    SELECT AGGREGATE(expression) FROM T WHERE predicate [HAVING agg <op> thr]

with AGGREGATE in {SUM, COUNT, AVERAGE}, ``expression`` a numeric expression
over columns, and ``predicate`` a conjunction of range/comparison terms.
GROUP BY is handled exactly as the paper prescribes: each group becomes a
separate query with a group-membership predicate, and all the queries run
simultaneously over the same scan (the engine's stats arrays carry a leading
query dimension).

``compile_queries`` lowers a list of queries to a single jitted *tile
evaluator*  ``cols (t, C) -> (x (Q, t), p (Q, t))``  where ``x_i`` is the
expression value predicate-masked per Table 1 (``x_i = 0`` if the tuple fails
the predicate) and ``p_i`` is the 0/1 predicate indicator.  Both the pure-JAX
engine and the Pallas ``chunk_agg`` / ``sampled_stats`` kernels consume this
evaluator's coefficient form.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Linear:
    """``Σ_k coeffs[k] · col_k`` — the paper's evaluation expression
    (``SUM(Σ_i c_i · A_i)`` in Section 7)."""

    coeffs: tuple[float, ...]

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        c = jnp.asarray(self.coeffs, dtype=cols.dtype)
        return cols[..., : len(self.coeffs)] @ c


@dataclasses.dataclass(frozen=True)
class Column:
    """A single column reference, e.g. ``T.a``."""

    index: int

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        return cols[..., self.index]


@dataclasses.dataclass(frozen=True)
class SquaredDiff:
    """``(T.a - T.b)^2`` — the paper's example of a non-linear expression."""

    a: int
    b: int

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        d = cols[..., self.a] - cols[..., self.b]
        return d * d


@dataclasses.dataclass(frozen=True)
class Custom:
    """Arbitrary jnp-traceable expression ``f(cols (..., C)) -> (...)``."""

    fn: Callable[[jnp.ndarray], jnp.ndarray]

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        return self.fn(cols)


ONE = Custom(fn=lambda cols: jnp.ones(cols.shape[:-1], cols.dtype))
"""Expression ``1`` — COUNT is SUM with expression = 1 (Section 4.3)."""


# ---------------------------------------------------------------------------
# Predicates (conjunctive normal form over simple terms)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Range:
    """``lo <= col < hi`` — the paper's selectivity-controlling predicate."""

    col: int
    lo: float = -np.inf
    hi: float = np.inf

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        c = cols[..., self.col]
        return (c >= self.lo) & (c < self.hi)


@dataclasses.dataclass(frozen=True)
class Cmp:
    col: int
    op: str  # one of < <= > >= == !=
    value: float

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        c = cols[..., self.col]
        v = jnp.asarray(self.value, cols.dtype)
        return {
            "<": c < v, "<=": c <= v, ">": c > v, ">=": c >= v,
            "==": c == v, "!=": c != v,
        }[self.op]


@dataclasses.dataclass(frozen=True)
class GroupEq:
    """Group-membership predicate used by the GROUP BY expansion."""

    col: int
    value: float

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        return cols[..., self.col] == jnp.asarray(self.value, cols.dtype)


@dataclasses.dataclass(frozen=True)
class And:
    terms: tuple

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        out = jnp.ones(cols.shape[:-1], dtype=bool)
        for t in self.terms:
            out = out & t(cols)
        return out


TRUE = And(terms=())


# ---------------------------------------------------------------------------
# Queries
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Having:
    op: str  # < <= > >=
    threshold: float


@dataclasses.dataclass(frozen=True)
class Query:
    """One OLA query.  ``epsilon`` is the target error ratio (stop condition),
    ``confidence`` the CI level, both per Section 2.2's user parameters."""

    agg: str  # 'sum' | 'count' | 'avg'
    expr: object = ONE
    pred: object = TRUE
    having: Optional[Having] = None
    epsilon: float = 0.05
    confidence: float = 0.95
    name: str = "q"

    def __post_init__(self):
        if self.agg not in ("sum", "count", "avg"):
            raise ValueError(f"unsupported aggregate: {self.agg}")

    @property
    def columns_used(self) -> frozenset[int]:
        """Columns the query touches — drives synopsis reuse (Section 6)."""
        cols: set[int] = set()

        def walk(node):
            if isinstance(node, Linear):
                cols.update(range(len(node.coeffs)))
            elif isinstance(node, (Column,)):
                cols.add(node.index)
            elif isinstance(node, SquaredDiff):
                cols.update((node.a, node.b))
            elif isinstance(node, Custom):
                cols.add(-1)  # unknown support: requires all columns
            elif isinstance(node, (Range, Cmp, GroupEq)):
                cols.add(node.col)
            elif isinstance(node, And):
                for t in node.terms:
                    walk(t)

        walk(self.expr)
        walk(self.pred)
        return frozenset(cols)


def expand_group_by(base: Query, group_col: int, group_values: Sequence[float],
                    ) -> list[Query]:
    """GROUP BY handling per Section 2.2: one query per group, identical
    except for an extra group-membership conjunct, all run simultaneously."""
    out = []
    for v in group_values:
        pred = And(terms=(base.pred, GroupEq(group_col, float(v))))
        out.append(dataclasses.replace(base, pred=pred, name=f"{base.name}[g={v}]"))
    return out


# ---------------------------------------------------------------------------
# Compilation to a tile evaluator
# ---------------------------------------------------------------------------


def compile_queries(queries: Sequence[Query]) -> Callable[[jnp.ndarray], tuple]:
    """Lower queries to ``cols (t, C) -> (x (Q, t), p (Q, t))`` (see module doc).

    The returned function is pure jnp (trace-safe) and is consumed by the
    engine inside jit; the kernels use :func:`linear_plan` instead when every
    query is linear+range (the common fast path).
    """
    qs = tuple(queries)

    def evaluate(cols: jnp.ndarray):
        xs, ps = [], []
        for q in qs:
            p = q.pred(cols)
            e = jnp.ones(cols.shape[:-1], cols.dtype) if q.agg == "count" else q.expr(cols)
            pf = p.astype(cols.dtype)
            xs.append(jnp.asarray(e, cols.dtype) * pf)
            ps.append(pf)
        return jnp.stack(xs, axis=0), jnp.stack(ps, axis=0)

    return evaluate


@dataclasses.dataclass(frozen=True)
class LinearPlan:
    """Coefficient form for the Pallas kernels: every query is a linear
    expression with conjunctive range predicates.

    ``coeffs (Q, C)``; predicate as per-column bounds ``lo/hi (Q, C)`` with
    ±inf for unconstrained columns.
    """

    coeffs: np.ndarray
    lo: np.ndarray
    hi: np.ndarray

    @property
    def num_queries(self) -> int:
        return self.coeffs.shape[0]


def linear_plan(queries: Sequence[Query], num_cols: int) -> LinearPlan:
    """Extract the coefficient form, or raise if a query is not linear+range."""
    q_n = len(queries)
    coeffs = np.zeros((q_n, num_cols), np.float32)
    lo = np.full((q_n, num_cols), -np.inf, np.float32)
    hi = np.full((q_n, num_cols), np.inf, np.float32)
    for qi, q in enumerate(queries):
        if q.agg == "count":
            pass  # coeffs stay zero; kernels compute count from the predicate
        elif isinstance(q.expr, Linear):
            coeffs[qi, : len(q.expr.coeffs)] = q.expr.coeffs
        elif isinstance(q.expr, Column):
            coeffs[qi, q.expr.index] = 1.0
        else:
            raise ValueError(f"query {q.name}: expression not linear, "
                             "use the pure-JAX evaluator path")

        def add_pred(node):
            if isinstance(node, And):
                for t in node.terms:
                    add_pred(t)
            elif isinstance(node, Range):
                lo[qi, node.col] = max(lo[qi, node.col], node.lo)
                hi[qi, node.col] = min(hi[qi, node.col], node.hi)
            elif isinstance(node, Cmp) and node.op in ("<", "<=", ">", ">="):
                if node.op in ("<", "<="):
                    hi[qi, node.col] = min(hi[qi, node.col], node.value)
                else:
                    lo[qi, node.col] = max(lo[qi, node.col], node.value)
            elif isinstance(node, (GroupEq, Cmp)):
                # equality: encode as a degenerate [v, v] closed range via eps
                v = node.value
                lo[qi, node.col] = max(lo[qi, node.col], v)
                hi[qi, node.col] = min(hi[qi, node.col], np.nextafter(np.float32(v), np.float32(np.inf)))
            else:
                raise ValueError(f"query {q.name}: predicate not range-conjunctive")

        add_pred(q.pred)
    return LinearPlan(coeffs=coeffs, lo=lo, hi=hi)
