"""Query plane: the aggregate-query AST and its compiled tile evaluator.

Queries follow the paper's Section 2.2 form::

    SELECT AGGREGATE(expression) FROM T WHERE predicate [HAVING agg <op> thr]

with AGGREGATE in {SUM, COUNT, AVERAGE}, ``expression`` a numeric expression
over columns, and ``predicate`` a conjunction of range/comparison terms.
GROUP BY is expressed as ``Query(group_by=GroupBy(col, max_groups, top_k))``:
one slot owns a bounded vector of per-group cells whose values are discovered
online during the scan (a SpaceSaving-style heavy-hitter sketch promotes hot
values into cells; rare values spill into an ``__other__`` cell so memory
stays fixed).  The paper's original prescription — each group a separate
query with a group-membership predicate — survives as :func:`group_fanout`
and is the bit-exactness oracle for the grouped plane.

``compile_queries`` lowers a list of queries to a single jitted *tile
evaluator*  ``cols (t, C) -> (x (Q, t), p (Q, t))``  where ``x_i`` is the
expression value predicate-masked per Table 1 (``x_i = 0`` if the tuple fails
the predicate) and ``p_i`` is the 0/1 predicate indicator.  Both the pure-JAX
engine and the Pallas ``chunk_agg`` / ``sampled_stats`` kernels consume this
evaluator's coefficient form.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np
from jax.scipy.special import ndtri

# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Linear:
    """``Σ_k coeffs[k] · col_k`` — the paper's evaluation expression
    (``SUM(Σ_i c_i · A_i)`` in Section 7)."""

    coeffs: tuple[float, ...]

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        c = jnp.asarray(self.coeffs, dtype=cols.dtype)
        return cols[..., : len(self.coeffs)] @ c


@dataclasses.dataclass(frozen=True)
class Column:
    """A single column reference, e.g. ``T.a``."""

    index: int

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        return cols[..., self.index]


@dataclasses.dataclass(frozen=True)
class SquaredDiff:
    """``(T.a - T.b)^2`` — the paper's example of a non-linear expression."""

    a: int
    b: int

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        d = cols[..., self.a] - cols[..., self.b]
        return d * d


@dataclasses.dataclass(frozen=True)
class Custom:
    """Arbitrary jnp-traceable expression ``f(cols (..., C)) -> (...)``."""

    fn: Callable[[jnp.ndarray], jnp.ndarray]

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        return self.fn(cols)


ONE = Custom(fn=lambda cols: jnp.ones(cols.shape[:-1], cols.dtype))
"""Expression ``1`` — COUNT is SUM with expression = 1 (Section 4.3)."""


# ---------------------------------------------------------------------------
# Predicates (conjunctive normal form over simple terms)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Range:
    """``lo <= col < hi`` — the paper's selectivity-controlling predicate."""

    col: int
    lo: float = -np.inf
    hi: float = np.inf

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        c = cols[..., self.col]
        return (c >= self.lo) & (c < self.hi)


@dataclasses.dataclass(frozen=True)
class Cmp:
    col: int
    op: str  # one of < <= > >= == !=
    value: float

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        c = cols[..., self.col]
        v = jnp.asarray(self.value, cols.dtype)
        return {
            "<": c < v, "<=": c <= v, ">": c > v, ">=": c >= v,
            "==": c == v, "!=": c != v,
        }[self.op]


@dataclasses.dataclass(frozen=True)
class GroupEq:
    """Group-membership predicate used by the GROUP BY expansion."""

    col: int
    value: float

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        return cols[..., self.col] == jnp.asarray(self.value, cols.dtype)


@dataclasses.dataclass(frozen=True)
class And:
    terms: tuple

    def __call__(self, cols: jnp.ndarray) -> jnp.ndarray:
        out = jnp.ones(cols.shape[:-1], dtype=bool)
        for t in self.terms:
            out = out & t(cols)
        return out


TRUE = And(terms=())


# ---------------------------------------------------------------------------
# Queries
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Having:
    op: str  # < <= > >=
    threshold: float


@dataclasses.dataclass(frozen=True)
class GroupBy:
    """Online GROUP BY over one column, bounded at ``max_groups`` cells.

    Up to ``max_groups`` distinct values get dedicated group cells with their
    own sufficient stats and CIs; values are discovered online by a bounded
    heavy-hitter sketch fed from per-round group tallies, and everything not
    tracked spills into an ``__other__`` cell so memory stays fixed.  The
    query retires when its ``top_k`` largest cells (by |estimate|) meet the
    query's epsilon.  ``values`` pins known group values into cells at
    admission — pinned cells accumulate from round 0 and are bit-exact
    against the :func:`group_fanout` expansion on the ref backend.
    """

    col: int
    max_groups: int = 8
    top_k: int = 0  # 0 -> all max_groups cells must converge
    values: Optional[tuple[float, ...]] = None

    def __post_init__(self):
        if self.max_groups < 1:
            raise ValueError("GroupBy.max_groups must be >= 1")
        if not (0 <= self.top_k <= self.max_groups):
            raise ValueError("GroupBy.top_k must be in [0, max_groups]")
        if self.values is not None:
            vals = tuple(float(v) for v in self.values)
            if len(vals) > self.max_groups:
                raise ValueError(
                    f"GroupBy: {len(vals)} pinned values exceed "
                    f"max_groups={self.max_groups}")
            if len(set(vals)) != len(vals):
                raise ValueError("GroupBy: pinned values must be distinct")
            object.__setattr__(self, "values", vals)

    @property
    def effective_top_k(self) -> int:
        return self.top_k if self.top_k > 0 else self.max_groups


@dataclasses.dataclass(frozen=True)
class GroupResult:
    """One cell of a grouped answer (``WorkloadResult.groups``).

    ``value`` is the group's column value (``nan`` for the ``__other__``
    spill cell, flagged by ``is_other``); ``n`` is the number of tuples
    sampled while the cell was live; ``decision`` is the HAVING decision
    code for the cell (1 pass / 0 fail / -1 undecided or no clause).
    """

    value: float
    estimate: float
    lo: float
    hi: float
    err: float
    n: int
    decision: int = -1
    is_other: bool = False


@dataclasses.dataclass(frozen=True)
class Query:
    """One OLA query.  ``epsilon`` is the target error ratio (stop condition),
    ``confidence`` the CI level, both per Section 2.2's user parameters.
    ``group_by`` turns the scalar aggregate into an online GROUP BY (the
    scalar ``estimate/lo/hi`` then describe the *base predicate* population
    and the per-group answers arrive as ``WorkloadResult.groups``)."""

    agg: str  # 'sum' | 'count' | 'avg'
    expr: object = ONE
    pred: object = TRUE
    having: Optional[Having] = None
    epsilon: float = 0.05
    confidence: float = 0.95
    name: str = "q"
    group_by: Optional[GroupBy] = None

    def __post_init__(self):
        if self.agg not in ("sum", "count", "avg"):
            raise ValueError(f"unsupported aggregate: {self.agg}")
        if self.group_by is not None and not isinstance(self.group_by, GroupBy):
            raise TypeError("Query.group_by must be a GroupBy (or None)")

    @property
    def columns_used(self) -> frozenset[int]:
        """Columns the query touches — drives synopsis reuse (Section 6)."""
        cols: set[int] = set()

        def walk(node):
            if isinstance(node, Linear):
                cols.update(range(len(node.coeffs)))
            elif isinstance(node, (Column,)):
                cols.add(node.index)
            elif isinstance(node, SquaredDiff):
                cols.update((node.a, node.b))
            elif isinstance(node, Custom):
                cols.add(-1)  # unknown support: requires all columns
            elif isinstance(node, (Range, Cmp, GroupEq)):
                cols.add(node.col)
            elif isinstance(node, And):
                for t in node.terms:
                    walk(t)

        walk(self.expr)
        walk(self.pred)
        if self.group_by is not None:
            cols.add(self.group_by.col)
        return frozenset(cols)


def group_fanout(base: Query, group_col: int, group_values: Sequence[float],
                 ) -> list[Query]:
    """GROUP BY per Section 2.2's original prescription: one scalar query per
    *pre-known* group value, identical except for an extra group-membership
    conjunct, all run simultaneously.  This expansion is the correctness
    oracle for the grouped slot plane — a ``Query(group_by=...)`` over the
    same known values must be bit-exact against it on the ref backend."""
    out = []
    for v in group_values:
        pred = And(terms=(base.pred, GroupEq(group_col, float(v))))
        out.append(dataclasses.replace(base, pred=pred, group_by=None,
                                       name=f"{base.name}[g={v}]"))
    return out


def expand_group_by(base: Query, group_col: int, group_values: Sequence[float],
                    ) -> list[Query]:
    """Deprecated: express GROUP BY as
    ``Query(group_by=GroupBy(col, max_groups, top_k))`` and read the answer
    from ``WorkloadResult.groups``.

    This wrapper is the pre-grouped-plane workaround — one slot per
    *pre-known* group value, no online discovery, no ``__other__`` spill.
    Behavior is unchanged (it delegates to :func:`group_fanout`); it emits a
    ``DeprecationWarning`` and will be removed once no caller needs the
    explicit fan-out."""
    warnings.warn(
        "expand_group_by is deprecated; use "
        "Query(group_by=GroupBy(col, max_groups, top_k)) and read "
        "WorkloadResult.groups",
        DeprecationWarning, stacklevel=2)
    return group_fanout(base, group_col, group_values)


# ---------------------------------------------------------------------------
# Compilation to a tile evaluator
# ---------------------------------------------------------------------------


def compile_queries(queries: Sequence[Query]) -> Callable[[jnp.ndarray], tuple]:
    """Lower queries to ``cols (t, C) -> (x (Q, t), p (Q, t))`` (see module doc).

    The returned function is pure jnp (trace-safe) and is consumed by the
    engine inside jit; the kernels use :func:`linear_plan` instead when every
    query is linear+range (the common fast path).
    """
    qs = tuple(queries)

    def evaluate(cols: jnp.ndarray):
        xs, ps = [], []
        for q in qs:
            p = q.pred(cols)
            e = jnp.ones(cols.shape[:-1], cols.dtype) if q.agg == "count" else q.expr(cols)
            pf = p.astype(cols.dtype)
            xs.append(jnp.asarray(e, cols.dtype) * pf)
            ps.append(pf)
        return jnp.stack(xs, axis=0), jnp.stack(ps, axis=0)

    return evaluate


@dataclasses.dataclass(frozen=True)
class LinearPlan:
    """Coefficient form for the Pallas kernels: every query is a linear
    expression with conjunctive range predicates.

    ``coeffs (Q, C)``; predicate as per-column bounds ``lo/hi (Q, C)`` with
    ±inf for unconstrained columns.
    """

    coeffs: np.ndarray
    lo: np.ndarray
    hi: np.ndarray

    @property
    def num_queries(self) -> int:
        return self.coeffs.shape[0]


# ---------------------------------------------------------------------------
# Dynamic query slot table (workload serving)
# ---------------------------------------------------------------------------
#
# The slot table is the *data-driven* counterpart of ``compile_queries``: a
# fixed-size (max_slots) array pytree describing up to S concurrently-running
# linear+range queries.  Because the table is a plain pytree of arrays, the
# engine round step can take it as a dynamic argument — admitting or retiring
# a query is a host-side row write, with no recompilation.  Only queries whose
# expression/predicate fit :class:`LinearPlan` coefficient form are encodable
# (the same restriction as the Pallas kernels); arbitrary ``Custom`` queries
# still go through the frozen ``compile_queries`` path.

AGG_SUM, AGG_COUNT, AGG_AVG = 0, 1, 2
_AGG_CODES = {"sum": AGG_SUM, "count": AGG_COUNT, "avg": AGG_AVG}

PLAN_CHUNK_LEVEL, PLAN_HOLISTIC, PLAN_SINGLE_PASS, PLAN_RESOURCE_AWARE = 0, 1, 2, 3
PLAN_CODES = {"chunk_level": PLAN_CHUNK_LEVEL, "holistic": PLAN_HOLISTIC,
              "single_pass": PLAN_SINGLE_PASS,
              "resource_aware": PLAN_RESOURCE_AWARE}

# op codes live with the decision rule (single source of truth)
from repro.core.estimators import HAVING_NONE, HAVING_OP_CODES as _HAVING_CODES


class SlotTable(NamedTuple):
    """Dynamic per-slot query descriptors, all arrays of leading dim S.

    ``coeffs/lo/hi`` are the :class:`LinearPlan` coefficient form; ``agg``
    and ``plan`` are code columns (``AGG_*`` / ``PLAN_*``); ``having_op`` is
    ``HAVING_NONE`` for slots without a HAVING clause.  ``active`` gates a
    slot's participation in extraction, chunk-close voting, and stopping.
    """

    coeffs: jnp.ndarray      # (S, C) f32
    lo: jnp.ndarray          # (S, C) f32
    hi: jnp.ndarray          # (S, C) f32
    agg: jnp.ndarray         # (S,) int32  AGG_* code
    plan: jnp.ndarray        # (S,) int32  PLAN_* code
    eps: jnp.ndarray         # (S,) f32 target error ratio
    z: jnp.ndarray           # (S,) f32 z-score of the slot's confidence level
    having_op: jnp.ndarray   # (S,) int32  _HAVING_CODES or HAVING_NONE
    having_thr: jnp.ndarray  # (S,) f32
    active: jnp.ndarray      # (S,) bool
    weight: jnp.ndarray      # (S,) f32 fairness share in (0, 1]: the slot
                             # counts only the first ceil(weight·b_eff)
                             # tuples of each worker window per round
                             # (repro.sched.fairness; 1 = unweighted round)
    gcol: jnp.ndarray        # (S,) int32 group-by column; -1 = ungrouped
    gval: jnp.ndarray        # (S, G) f32 tracked group values
    gact: jnp.ndarray        # (S, G) f32 0/1 cell-live flags; cell G-1 is
                             # the __other__ spill cell.  G = max_groups+1
                             # (0 when the engine has no grouped support —
                             # the grouped code then compiles away entirely)
    gtopk: jnp.ndarray       # (S,) int32 cells that must meet eps to stop

    @property
    def max_slots(self) -> int:
        return int(self.agg.shape[0])

    @property
    def group_cells(self) -> int:
        """G — per-slot group cells incl. ``__other__`` (0 = ungrouped table)."""
        return int(self.gval.shape[1])


def empty_slot_table(max_slots: int, num_cols: int,
                     max_groups: int = 0) -> SlotTable:
    """All-inactive table; inactive slots have an always-false predicate.

    ``max_groups > 0`` sizes every slot for grouped queries: ``max_groups``
    tracked-value cells plus one ``__other__`` spill cell.  The default 0
    keeps the group arrays zero-width so ungrouped engines are statically
    unchanged."""
    s, c = int(max_slots), int(num_cols)
    g = int(max_groups) + 1 if int(max_groups) > 0 else 0
    return SlotTable(
        coeffs=jnp.zeros((s, c), jnp.float32),
        lo=jnp.full((s, c), jnp.inf, jnp.float32),   # empty range: pred False
        hi=jnp.full((s, c), -jnp.inf, jnp.float32),
        agg=jnp.zeros((s,), jnp.int32),
        plan=jnp.full((s,), PLAN_RESOURCE_AWARE, jnp.int32),
        eps=jnp.ones((s,), jnp.float32),
        z=jnp.full((s,), 1.959964, jnp.float32),   # 95% placeholder
        having_op=jnp.full((s,), HAVING_NONE, jnp.int32),
        having_thr=jnp.zeros((s,), jnp.float32),
        active=jnp.zeros((s,), bool),
        weight=jnp.ones((s,), jnp.float32),
        gcol=jnp.full((s,), -1, jnp.int32),
        gval=jnp.zeros((s, g), jnp.float32),
        gact=jnp.zeros((s, g), jnp.float32),
        gtopk=jnp.zeros((s,), jnp.int32),
    )


def encode_slot(query: Query, num_cols: int, plan: str = "resource_aware",
                max_groups: int = 0) -> dict:
    """Encode one linear+range query as a slot-table row (numpy scalars/rows).

    ``max_groups`` is the *table's* group capacity (``empty_slot_table``'s
    parameter); a grouped query raises if it asks for more cells than the
    table carries.  Pinned ``GroupBy.values`` go live in cells ``0..k-1``
    at admission; the ``__other__`` cell (last) is always live for grouped
    slots so undiscovered groups accumulate from round 0.

    Raises ``ValueError`` (via :func:`linear_plan`) for queries outside the
    coefficient form.
    """
    lp = linear_plan([query], num_cols)
    hop = HAVING_NONE if query.having is None else _HAVING_CODES[query.having.op]
    thr = 0.0 if query.having is None else float(query.having.threshold)
    g = int(max_groups) + 1 if int(max_groups) > 0 else 0
    gcol, gtopk = -1, 0
    gval = np.zeros((g,), np.float32)
    gact = np.zeros((g,), np.float32)
    gb = query.group_by
    if gb is not None:
        if gb.max_groups > int(max_groups):
            raise ValueError(
                f"query {query.name}: group_by.max_groups={gb.max_groups} "
                f"exceeds the slot table's max_groups={int(max_groups)}")
        if not (0 <= gb.col < num_cols):
            raise ValueError(
                f"query {query.name}: group_by column {gb.col} out of range")
        gcol, gtopk = gb.col, gb.effective_top_k
        gact[g - 1] = 1.0  # __other__ live from admission
        for i, v in enumerate(gb.values or ()):
            gval[i] = np.float32(v)
            gact[i] = 1.0
    return dict(
        coeffs=lp.coeffs[0], lo=lp.lo[0], hi=lp.hi[0],
        agg=np.int32(_AGG_CODES[query.agg]),
        plan=np.int32(PLAN_CODES[plan]),
        eps=np.float32(query.epsilon),
        z=np.float32(ndtri((1.0 + query.confidence) / 2.0)),
        having_op=np.int32(hop), having_thr=np.float32(thr),
        active=True, weight=np.float32(1.0),
        gcol=np.int32(gcol), gval=gval, gact=gact, gtopk=np.int32(gtopk),
    )


def slot_table_set(table: SlotTable, s: int, row: dict) -> SlotTable:
    """Functional row write (host-side, between rounds).

    Group columns default to the ungrouped row (``gcol=-1``, all cells dead)
    when absent or sized for a different table capacity, so rows encoded
    without ``max_groups`` slot into a grouped table cleanly."""
    g = int(table.gval.shape[1])
    gval_row = np.asarray(row.get("gval", ()), np.float32).reshape(-1)
    gact_row = np.asarray(row.get("gact", ()), np.float32).reshape(-1)
    if gval_row.shape != (g,) or gact_row.shape != (g,):
        gval_row = np.zeros((g,), np.float32)
        gact_row = np.zeros((g,), np.float32)
    return SlotTable(
        coeffs=table.coeffs.at[s].set(jnp.asarray(row["coeffs"], jnp.float32)),
        lo=table.lo.at[s].set(jnp.asarray(row["lo"], jnp.float32)),
        hi=table.hi.at[s].set(jnp.asarray(row["hi"], jnp.float32)),
        agg=table.agg.at[s].set(jnp.int32(row["agg"])),
        plan=table.plan.at[s].set(jnp.int32(row["plan"])),
        eps=table.eps.at[s].set(jnp.float32(row["eps"])),
        z=table.z.at[s].set(jnp.float32(row["z"])),
        having_op=table.having_op.at[s].set(jnp.int32(row["having_op"])),
        having_thr=table.having_thr.at[s].set(jnp.float32(row["having_thr"])),
        active=table.active.at[s].set(bool(row["active"])),
        weight=table.weight.at[s].set(jnp.float32(row.get("weight", 1.0))),
        gcol=table.gcol.at[s].set(jnp.int32(row.get("gcol", -1))),
        gval=table.gval.at[s].set(jnp.asarray(gval_row, jnp.float32)),
        gact=table.gact.at[s].set(jnp.asarray(gact_row, jnp.float32)),
        gtopk=table.gtopk.at[s].set(jnp.int32(row.get("gtopk", 0))),
    )


def slot_table_set_groups(table: SlotTable, s: int, gval_row, gact_row,
                          ) -> SlotTable:
    """Host-side group-cell write for slot ``s`` — online discovery promotes
    sketch heavy hitters into free cells between rounds.  Only ``gval`` and
    ``gact`` change; the rest of the row is untouched."""
    return table._replace(
        gval=table.gval.at[s].set(jnp.asarray(gval_row, jnp.float32)),
        gact=table.gact.at[s].set(jnp.asarray(gact_row, jnp.float32)),
    )


def slot_table_clear(table: SlotTable, s: int) -> SlotTable:
    """Deactivate a slot (query retired, deadline-enforced, or preempted);
    descriptors are left in place so the final round's report for the slot
    stays readable.  The fairness weight alone is reset to 1.0 — inactive
    slots must stay neutral (the invariant ``repro.sched.fairness``
    documents), so a weight from a contended residence never leaks into the
    row's next occupant between the clear and the scheduler's next
    round-weight write."""
    return table._replace(active=table.active.at[s].set(False),
                          weight=table.weight.at[s].set(jnp.float32(1.0)))


def slot_evaluate(table: SlotTable, cols: jnp.ndarray,
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Data-driven tile evaluator: ``cols (..., C) -> (x, p) (S, ...)``.

    Mirrors ``compile_queries`` semantics: ``p`` is the 0/1 conjunctive-range
    predicate indicator, ``x`` the predicate-masked expression value (1 for
    COUNT slots).  Inactive slots produce all-zero rows (their range is
    empty), so they never contaminate merged statistics.
    """
    dtype = cols.dtype
    c = cols[..., None, :]                                      # (..., 1, C)
    # unconstrained columns carry lo=-inf / hi=+inf, which satisfy both
    # comparisons for any finite value — no special-casing needed
    inb = (c >= table.lo.astype(dtype)) & (c < table.hi.astype(dtype))
    p = jnp.all(inb, axis=-1)                                   # (..., S)
    lin = jnp.einsum("...c,sc->...s", cols, table.coeffs.astype(dtype))
    is_count = table.agg == AGG_COUNT
    expr = jnp.where(is_count, jnp.ones_like(lin), lin)
    pf = p.astype(dtype)
    x = expr * pf
    # move the slot axis to the front: (..., S) -> (S, ...)
    return jnp.moveaxis(x, -1, 0), jnp.moveaxis(pf, -1, 0)


def linear_plan(queries: Sequence[Query], num_cols: int) -> LinearPlan:
    """Extract the coefficient form, or raise if a query is not linear+range."""
    q_n = len(queries)
    coeffs = np.zeros((q_n, num_cols), np.float32)
    lo = np.full((q_n, num_cols), -np.inf, np.float32)
    hi = np.full((q_n, num_cols), np.inf, np.float32)
    for qi, q in enumerate(queries):
        if q.agg == "count":
            pass  # coeffs stay zero; kernels compute count from the predicate
        elif isinstance(q.expr, Linear):
            coeffs[qi, : len(q.expr.coeffs)] = q.expr.coeffs
        elif isinstance(q.expr, Column):
            coeffs[qi, q.expr.index] = 1.0
        else:
            raise ValueError(f"query {q.name}: expression not linear, "
                             "use the pure-JAX evaluator path")

        def add_pred(node):
            # Lowering must be *exact* in f32 (the engine compares decoded
            # f32 values against these bounds with `lo <= c < hi`): closed
            # upper bounds and strict lower bounds shift by one f32 ulp via
            # nextafter, equality becomes the degenerate range [v, v⁺), and
            # '!=' has no conjunctive-range form — it must raise, never be
            # silently approximated (the ref evaluator computes it exactly,
            # so a lossy encoding would make the backends disagree).
            if isinstance(node, And):
                for t in node.terms:
                    add_pred(t)
            elif isinstance(node, Range):
                lo[qi, node.col] = max(lo[qi, node.col], node.lo)
                hi[qi, node.col] = min(hi[qi, node.col], node.hi)
            elif isinstance(node, (GroupEq, Cmp)):
                op = "==" if isinstance(node, GroupEq) else node.op
                v = np.float32(node.value)
                up = np.nextafter(v, np.float32(np.inf))
                if up != 0 and abs(up) < np.finfo(np.float32).tiny:
                    # XLA flushes denormals to zero, so a denormal bound
                    # (only reachable near v == 0) would compare as 0 and
                    # make the range empty; the smallest *normal* float is
                    # the nearest bound that survives FTZ, and it is exact
                    # for decoded data (nonzero magnitudes are >= 1e-6)
                    up = np.float32(np.copysign(np.finfo(np.float32).tiny, up))
                if op == "<":
                    hi[qi, node.col] = min(hi[qi, node.col], v)
                elif op == "<=":    # c <= v  ≡  c < nextafter(v)
                    hi[qi, node.col] = min(hi[qi, node.col], up)
                elif op == ">":     # c > v   ≡  c >= nextafter(v)
                    lo[qi, node.col] = max(lo[qi, node.col], up)
                elif op == ">=":
                    lo[qi, node.col] = max(lo[qi, node.col], v)
                elif op == "==":
                    lo[qi, node.col] = max(lo[qi, node.col], v)
                    hi[qi, node.col] = min(hi[qi, node.col], up)
                else:
                    raise ValueError(
                        f"query {q.name}: {op!r} is not range-encodable, "
                        "use the pure-JAX evaluator path")
            else:
                raise ValueError(f"query {q.name}: predicate not range-conjunctive")

        add_pred(q.pred)
    return LinearPlan(coeffs=coeffs, lo=lo, hi=hi)
