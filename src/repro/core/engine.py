"""The OLA-RAW engine: parallel bi-level sampling over raw chunks.

This is the paper's Sections 3–5 as one lockstep-SPMD state machine.  The
hardware adaptation (DESIGN.md §3) replaces EXTRACT threads with *workers*
(vmap lanes on one device, or mesh-`data`-axis shards under shard_map — same
round semantics, property-tested equal) and the ``t_eval`` timer with a
per-round tuple *budget*:

  round r:
    1. CLAIM   — idle workers take the next positions of the committed random
                 chunk schedule from a global queue head.  The head advances
                 by an exclusive prefix-sum over (all-gathered) idle flags, so
                 the *started set is always a prefix of the schedule*: a
                 chunk's inclusion in the sample can never depend on its
                 content.  This is the engine's inspection-paradox guarantee
                 (paper §3/§4.2).
    2. EXTRACT — each active worker extracts the next ``b`` tuples of its
                 chunk in the chunk's keyed Feistel order (paper §4.1's
                 in-memory shuffle), decodes them from raw bytes, evaluates
                 all queries (x_i = expr·pred per Table 1).
    3. MERGE   — per-chunk sufficient statistics (m_j, y'_j, y''_j, p_j) are
                 scatter-added; across devices the deltas are psum'd.
    4. DECIDE  — per-chunk local accuracy ε_j = ε (Theorem 3) closes chunks
                 under the single-pass rule; the resource monitor (modeled
                 T_io vs T_cpu, Eq. 4's two cost terms) switches the
                 resource-aware policy between holistic-like (IO-bound) and
                 single-pass-like (CPU-bound) behaviour and drives the
                 exponential-decay budget rule of §5.4.
    5. ESTIMATE— Eq. (1)/(3) over all started chunks; HAVING early-out.

Strategies (paper Fig. 5): ``chunk_level`` (C), ``holistic`` (H),
``single_pass`` (S), ``resource_aware`` (BI).  ``chunk_level`` additionally
restricts estimation to fully-extracted chunks in schedule order (the
reordering barrier of §3); a deliberately broken ``chunk_level_unordered``
mode reproduces the inspection paradox for the Table 3 experiment.

Worker state (``cur``) is the only sharded piece; chunk-slot arrays are
replicated and advanced by identical (psum-merged) updates on every device,
so the SPMD engine is deterministic and checkpointable as a plain pytree.

Two query planes share this round machinery:

* **frozen** (classic): the query list is compiled into the round program
  (``compile_queries``); stats carry a leading (Q,) dim and ``stats.m`` is
  the shared ``(N,)`` per-chunk sample size.
* **slot table** (workload serving): ``round_body`` takes a dynamic
  :class:`~repro.core.queries.SlotTable` argument describing up to S
  concurrent linear+range queries.  Queries can be admitted or retired
  between rounds by host-side row writes — no recompilation.  Because a
  query admitted mid-scan has not seen earlier tuples, ``stats.m`` becomes
  per-slot ``(S, N)`` while the *scan-level* extraction count lives in
  ``state.scan_m (N,)`` (cursor bounds, READ accounting, calibration).
  :class:`SlotOLAEngine` is the host-facing wrapper; the workload server
  (``repro.serve.ola_server``) drives admission, early leave, and top-up
  passes on top of it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimators as est
from repro.core.estimators import BiLevelStats
from repro.data.faults import FaultError
from repro.obs.trace import NULL_TRACER
from repro.core.queries import (
    AGG_COUNT,
    AGG_SUM,
    HAVING_NONE,
    PLAN_CHUNK_LEVEL,
    PLAN_RESOURCE_AWARE,
    PLAN_SINGLE_PASS,
    Query,
    SlotTable,
    compile_queries,
    linear_plan,
    slot_evaluate,
)
from repro.kernels import ops as kernel_ops
from repro.kernels.ref import TALLY_BUCKETS, tally_hash
from repro.sampling.permutation import (
    chunk_seed,
    permutation_window_dyn,
    random_chunk_order,
)

# Chunk-claim sentinels for the per-worker `cur` slot (schedule positions).
IDLE = -1       # worker finished its chunk; will claim at next round start
EXHAUSTED = -2  # schedule empty; worker permanently idle

STRATEGIES = ("chunk_level", "holistic", "single_pass", "resource_aware",
              "chunk_level_unordered")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_workers: int = 4
    strategy: str = "resource_aware"
    budget_init: int = 64        # t_eval analog: tuples per worker per round
    budget_min: int = 8          # paper's t_eval lower bound
    budget_max: int = 4096       # upper bound (δ analog; also capped by chunk size)
    seed: int = 0
    # resource model (DESIGN.md §3): chunk fetch vs extract cost.  Defaults
    # approximate the paper's testbed ratio (565 MB/s buffered reads vs
    # CPU-bound ASCII extraction).
    io_bytes_per_sec: float = 565e6
    cpu_tuple_ops_per_sec: float = 2.0e9  # VPU-op throughput for the cost model
    # worker speed factors for straggler simulation (len == num_workers)
    worker_speed: Optional[tuple] = None
    stats_dtype: str = "float32"
    cache_cap: int = 0           # per-chunk extracted-tuple cache rows (synopsis)
    # round EXTRACT implementation: "ref" keeps the decode_ref + evaluator
    # composition (supports arbitrary Custom queries); "pallas" routes the
    # gather+parse+eval+reduce through the fused kernels/slot_extract.py
    # kernel (linear+range plans only; interpret-mode fallback off-TPU);
    # "pallas-interpret" forces the Pallas interpreter even on TPU (the
    # benchmark's correctness-mode lane); "auto" picks pallas on TPU when the
    # plan supports it and ref elsewhere.
    extract_backend: str = "ref"
    # raw-data residency: "packed" keeps the whole store on device as one
    # (N, M_max, rec) tensor (fine for small stores); "stream" feeds each
    # round a bounded (W, rows_max, rec) slab through
    # data/pipeline.SlabPrefetcher — device residency O(slab), host residency
    # O(cache), READ overlapped with compute.  Round-for-round estimates are
    # identical (bit-exact on the ref backend).
    residency: str = "packed"
    slab_row_tile: int = 256     # streaming kernel's row-tile (VMEM bound)
    prefetch_lookahead: int = 8  # schedule chunks the reader thread runs ahead
    # adapt the lookahead at runtime from the measured READ/CPU rate ratio
    # (a slow store raises it toward the prefetcher's ceiling so reads stay
    # hidden under compute; purely a perf knob — estimates are unaffected)
    prefetch_adaptive: bool = False
    # parse-once decoded-chunk cache byte budget (streaming residency only):
    # the prefetcher retains each chunk's decoded (rows, C) f32 block on
    # first extraction, and later rounds feed the decoded-input kernel —
    # skipping tokenize/parse.  Estimates and the modeled resource clock are
    # bit-identical with the cache on or off; only wall time changes.
    decoded_cache_bytes: int = 0
    # grouped query plane (slot-table mode only): a slot may own up to
    # max_groups tracked group cells plus one __other__ spill cell, each with
    # its own (S, G, N) sufficient-stat rows.  0 keeps the group arrays
    # zero-width — the grouped code then compiles away and ungrouped engines
    # are statically unchanged (round-for-round bit-exact vs older builds).
    max_groups: int = 0

    def __post_init__(self):
        assert self.strategy in STRATEGIES, self.strategy
        assert self.extract_backend in ("ref", "pallas", "pallas-interpret",
                                        "auto"), self.extract_backend
        assert self.residency in ("packed", "stream"), self.residency
        assert self.decoded_cache_bytes >= 0
        assert self.decoded_cache_bytes == 0 or self.residency == "stream", (
            "decoded_cache_bytes requires residency='stream' (the cache "
            "lives in the slab prefetcher)")
        assert self.max_groups >= 0


class EngineState(NamedTuple):
    stats: BiLevelStats          # ysum/ysq/psum: (Q, N) — replicated.
                                 # stats.m is (N,) in frozen-query mode and
                                 # per-slot (S, N) in slot-table mode.
    scan_m: jnp.ndarray          # (N,) tuples the *scan* extracted per chunk
                                 # (== stats.m in frozen mode)
    offset: jnp.ndarray          # (N,) tuples extracted so far per chunk
    closed: jnp.ndarray          # (N,) bool — chunk closed for sampling
    acc_met: jnp.ndarray         # (N,) bool — local accuracy ε_j reached
    head: jnp.ndarray            # () int32 — queue head over schedule
    cur: jnp.ndarray             # (P,) int32 — schedule position per worker (sharded under SPMD)
    budget: jnp.ndarray          # () f32 — current t_eval-analog budget
    decay: jnp.ndarray           # () f32 — §5.4 exponential-decay factor
    calib_sum: jnp.ndarray       # () f32 — Σ tuples-at-accuracy (calibration)
    calib_cnt: jnp.ndarray       # () f32
    first_est: jnp.ndarray       # () bool — first chunk estimate produced
    stopped: jnp.ndarray         # (Q,) bool — per-query global stop
    round: jnp.ndarray           # () int32
    t_io: jnp.ndarray            # () f32 — cumulative modeled read seconds
    t_cpu: jnp.ndarray           # () f32 — cumulative modeled extract seconds
    cpu_bound: jnp.ndarray       # () bool — monitor verdict from last round
    cached_m: jnp.ndarray        # (N,) int32 — tuples supplied by the synopsis
    raw_touched: jnp.ndarray     # (N,) bool — chunk has caused a raw READ
    cache: jnp.ndarray           # (N, cap, C) f32 — extracted-tuple cache for
                                 # synopsis construction (cap may be 0)
    schedule: jnp.ndarray        # (N,) int32 — claim order over chunk ids.
                                 # Initialized from the program's committed
                                 # random order; the workload scheduler may
                                 # permute the *unclaimed tail* (positions
                                 # >= head) between rounds — variance-guided
                                 # claiming.  Chunks never yet started stay
                                 # in their original relative order, so the
                                 # first-touch set remains a prefix of the
                                 # committed random order (the inspection-
                                 # paradox guarantee is ordering-invariant).
    quarantined: jnp.ndarray     # (N,) bool — chunk dropped from the
                                 # population (read retries exhausted / CRC
                                 # mismatch).  A host-side write (like the
                                 # scheduler's claim reorder): the round
                                 # treats it as closed with a zero budget,
                                 # and estimation rescales to the surviving
                                 # chunk count and tuple total (CIs widen;
                                 # answers are flagged degraded upstream).
    # grouped query plane (G = max_groups+1 incl. the __other__ spill cell;
    # all four are (S, 0, N) when EngineConfig.max_groups == 0).  A cell's
    # gm counts every tuple the slot sampled while the cell was live —
    # *not* group-filtered — exactly the per-chunk sample size a dedicated
    # fan-out slot would carry, so cells live since admission are bit-exact
    # against the expand_group_by oracle.
    gm: jnp.ndarray              # (S, G, N) int32 per-cell sample sizes
    gys: jnp.ndarray             # (S, G, N) per-cell Σ x (group-masked)
    gyq: jnp.ndarray             # (S, G, N) per-cell Σ x²
    gps: jnp.ndarray             # (S, G, N) per-cell Σ p (base pred ∧ group)


class RoundReport(NamedTuple):
    estimate: jnp.ndarray        # (Q,)
    lo: jnp.ndarray              # (Q,)
    hi: jnp.ndarray              # (Q,)
    err: jnp.ndarray             # (Q,) error ratio (paper's metric)
    decided: jnp.ndarray         # (Q,) int8 HAVING verdict (-1/0/1)
    n_chunks: jnp.ndarray        # () chunks in sample
    m_tuples: jnp.ndarray        # () tuples in sample
    round_io_s: jnp.ndarray      # () modeled read seconds this round
    round_cpu_s: jnp.ndarray     # () modeled extract seconds this round
    tuples_round: jnp.ndarray    # ()
    bytes_round: jnp.ndarray     # ()
    all_stopped: jnp.ndarray     # () bool
    exhausted: jnp.ndarray       # () bool — every chunk closed
    # grouped plane (zero-width when the engine has max_groups == 0)
    g_est: jnp.ndarray           # (S, G) per-cell estimates
    g_lo: jnp.ndarray            # (S, G)
    g_hi: jnp.ndarray            # (S, G)
    g_err: jnp.ndarray           # (S, G) per-cell error ratio
    g_n: jnp.ndarray             # (S, G) int32 tuples in each cell's sample
    g_tal: jnp.ndarray           # (S, 3, H) per-round group-value tallies
                                 # [count, Σ value, Σ value²] per salted-hash
                                 # bucket of the slot's group column (base-
                                 # predicate-masked rows only) — the host
                                 # folds these into the SpaceSaving sketch
                                 # that discovers heavy-hitter groups online


class _Collectives:
    """Adapter between single-device and shard_map execution.

    ``gather_workers`` exposes every worker's flag in global worker order;
    ``merge`` sums contributions across devices; ``my_base`` is this device's
    first global worker id.  The single-device instance is the identity, so
    both modes run the *same* round body.
    """

    def __init__(self, axis_name: Optional[str] = None,
                 workers_per_device: Optional[int] = None):
        self.axis_name = axis_name
        self.wpd = workers_per_device

    def gather_workers(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.axis_name is None:
            return x
        g = jax.lax.all_gather(x, self.axis_name, axis=0)  # (D, W)
        return g.reshape((-1,) + x.shape[1:])

    def merge(self, tree):
        if self.axis_name is None:
            return tree
        return jax.lax.psum(tree, self.axis_name)

    def my_base(self) -> jnp.ndarray:
        if self.axis_name is None:
            return jnp.asarray(0, jnp.int32)
        return (jax.lax.axis_index(self.axis_name) * self.wpd).astype(jnp.int32)


class EngineProgram:
    """The jit-able round program, independent of host-side orchestration.

    Everything static lives here (schedule, seeds, query evaluator, cost
    model); per-round dynamic state is the :class:`EngineState` pytree.
    """

    def __init__(self, *, codec, queries: Sequence[Query] = (),
                 config: EngineConfig, n_chunks: int, m_max: int,
                 chunk_sizes: np.ndarray,
                 schedule: Optional[np.ndarray] = None,
                 max_slots: Optional[int] = None, confidence: float = 0.95):
        self.codec = codec
        self.queries = list(queries)
        self.config = config
        self.n_chunks = int(n_chunks)
        self.m_max = int(m_max)
        self.max_slots = None if max_slots is None else int(max_slots)
        if schedule is None:
            schedule = random_chunk_order(config.seed, self.n_chunks)
        self.schedule_np = np.asarray(schedule, np.int32)
        self.schedule = jnp.asarray(schedule, jnp.int32)
        self.seeds = chunk_seed(jnp.uint32(config.seed),
                                jnp.arange(self.n_chunks, dtype=jnp.uint32))
        self.chunk_sizes_np = np.asarray(chunk_sizes, np.int32)
        self.chunk_bytes = jnp.asarray(
            np.asarray(chunk_sizes, np.float32) * codec.record_bytes)
        if self.max_slots is None:
            assert self.queries, "frozen mode needs a non-empty query list"
            self.evaluate = compile_queries(self.queries)
            self.eps = jnp.asarray([q.epsilon for q in self.queries],
                                   jnp.float32)
            self.conf = float(self.queries[0].confidence)
        else:
            # slot-table mode: the query plane is a dynamic round argument;
            # confidence is per-slot (the table carries each slot's z), and
            # ``confidence`` here is only the default for reporting helpers.
            assert not self.queries, "slot mode takes queries via the table"
            self.evaluate = None
            self.eps = jnp.zeros((self.max_slots,), jnp.float32)
            self.conf = float(confidence)
        self.z = float(jax.scipy.special.ndtri((1.0 + self.conf) / 2.0))
        self.cost_per_tuple = float(codec.extract_cost_per_tuple())
        self.total_tuples = int(np.sum(chunk_sizes))
        self.num_cols = int(codec.num_cols)
        # grouped-plane sizing (static): G cells per slot incl. __other__,
        # H tally buckets for the online group-discovery sketch feed
        self.group_cells = (config.max_groups + 1) if config.max_groups > 0 else 0
        self.tally_buckets = TALLY_BUCKETS if self.group_cells else 0
        if self.group_cells and self.max_slots is None:
            raise ValueError(
                "max_groups > 0 requires slot-table mode (grouped queries "
                "run through the workload slot plane)")
        # EXTRACT backend resolution (static — baked into the jitted round).
        # The fused kernel parses fixed-width ASCII, needs linear+range
        # plans, and accumulates in float32: an explicit
        # "pallas"/"pallas-interpret" outside that raises here (not
        # mid-scan), while "auto" quietly keeps the ref path — binary decode
        # is near-free anyway (those stores are IO-bound, not EXTRACT-bound),
        # Custom frozen queries have no coefficient form, and a non-f32
        # stats dtype must not be silently degraded to f32 sums.  Explicit
        # "pallas" off-TPU runs the kernel in interpret mode;
        # "pallas-interpret" forces the interpreter even on TPU.
        kernel_ok = (getattr(codec, "name", "") == "ascii"
                     and jnp.dtype(config.stats_dtype) == jnp.float32)
        backend = config.extract_backend
        lp = None
        if backend == "auto":
            backend = ("pallas" if jax.default_backend() == "tpu" and kernel_ok
                       else "ref")
            if backend == "pallas" and self.max_slots is None:
                try:
                    lp = linear_plan(self.queries, self.num_cols)
                except ValueError:
                    backend = "ref"
        elif backend != "ref" and not kernel_ok:
            raise ValueError(
                f"extract_backend={backend!r} requires the fixed-width ASCII "
                "codec and float32 stats (the fused kernel parses ASCII "
                "records and accumulates its sums in f32)")
        self._ops_backend = None if backend == "ref" else backend
        self.extract_pallas = self._ops_backend is not None
        if (self.group_cells and self.extract_pallas
                and config.residency == "stream"):
            raise ValueError(
                "grouped queries (max_groups > 0) support the fused Pallas "
                "kernel only under residency='packed'; use extract_backend="
                "'ref' for streaming/decoded rounds")
        if self.extract_pallas:
            if self.max_slots is None:
                # frozen plane: lower the query list to coefficient form once;
                # raises for queries outside linear+range (use 'ref' there)
                lp = lp or linear_plan(self.queries, self.num_cols)
                self._plan_coeffs = jnp.asarray(lp.coeffs)
                self._plan_lo = jnp.asarray(lp.lo)
                self._plan_hi = jnp.asarray(lp.hi)
                self._plan_is_count = jnp.asarray(
                    [1.0 if qq.agg == "count" else 0.0 for qq in self.queries],
                    jnp.float32)

    @property
    def q_dim(self) -> int:
        """Leading stats dimension: query count or slot count."""
        return self.max_slots if self.max_slots is not None else len(self.queries)

    # ------------------------------------------------------------ state ----
    def init_state(self, synopsis_seed: Optional[dict] = None) -> EngineState:
        cfg = self.config
        q = self.q_dim
        dtype = jnp.dtype(cfg.stats_dtype)
        sizes = jnp.asarray(self.chunk_sizes_np)
        stats = est.init_stats(sizes, query_shape=(q,), dtype=dtype,
                               m_total=self.total_tuples)
        if self.max_slots is not None:
            # per-slot sample sizes: each slot joined the scan at its own time
            assert synopsis_seed is None, (
                "slot mode seeds per-slot via the workload server")
            stats = stats._replace(
                m=jnp.zeros((q, self.n_chunks), jnp.int32))
        state = EngineState(
            stats=stats,
            scan_m=jnp.zeros((self.n_chunks,), jnp.int32),
            offset=jnp.zeros((self.n_chunks,), jnp.int32),
            closed=jnp.zeros((self.n_chunks,), bool),
            acc_met=jnp.zeros((self.n_chunks,), bool),
            head=jnp.asarray(0, jnp.int32),
            cur=jnp.full((cfg.num_workers,), IDLE, jnp.int32),
            budget=jnp.asarray(float(cfg.budget_init), jnp.float32),
            decay=jnp.asarray(1.0, jnp.float32),
            calib_sum=jnp.asarray(0.0, jnp.float32),
            calib_cnt=jnp.asarray(0.0, jnp.float32),
            first_est=jnp.asarray(False),
            stopped=jnp.zeros((q,), bool),
            round=jnp.asarray(0, jnp.int32),
            t_io=jnp.asarray(0.0, jnp.float32),
            t_cpu=jnp.asarray(0.0, jnp.float32),
            cpu_bound=jnp.asarray(False),
            cached_m=jnp.zeros((self.n_chunks,), jnp.int32),
            raw_touched=jnp.zeros((self.n_chunks,), bool),
            cache=jnp.zeros((self.n_chunks, cfg.cache_cap, self.num_cols),
                            jnp.float32),
            schedule=jnp.asarray(self.schedule_np),
            quarantined=jnp.zeros((self.n_chunks,), bool),
            gm=jnp.zeros((q, self.group_cells, self.n_chunks), jnp.int32),
            gys=jnp.zeros((q, self.group_cells, self.n_chunks), dtype),
            gyq=jnp.zeros((q, self.group_cells, self.n_chunks), dtype),
            gps=jnp.zeros((q, self.group_cells, self.n_chunks), dtype),
        )
        if synopsis_seed is not None:
            stats = state.stats._replace(
                m=jnp.asarray(synopsis_seed["m"], jnp.int32),
                ysum=jnp.asarray(synopsis_seed["ysum"], dtype),
                ysq=jnp.asarray(synopsis_seed["ysq"], dtype),
                psum=jnp.asarray(synopsis_seed["psum"], dtype),
            )
            state = state._replace(
                stats=stats,
                scan_m=jnp.asarray(synopsis_seed["m"], jnp.int32),
                offset=jnp.asarray(synopsis_seed["offset"], jnp.int32),
                closed=jnp.asarray(synopsis_seed.get(
                    "closed", np.zeros(self.n_chunks, bool))),
                cached_m=jnp.asarray(synopsis_seed["m"], jnp.int32),
            )
            if "cache" in synopsis_seed and cfg.cache_cap > 0:
                pre = jnp.asarray(synopsis_seed["cache"], jnp.float32)
                state = state._replace(
                    cache=state.cache.at[:, : pre.shape[1]].set(pre))
        return state

    def plan_claims(self, state: EngineState
                    ) -> tuple[np.ndarray, np.ndarray, int]:
        """Host-side replica of the round's CLAIM step (streaming residency).

        The claim rule is a pure function of ``(cur, head, state.schedule)``
        — no chunk content — so the slab pipeline can predict *exactly* which
        chunk each worker will hold this round and assemble the slab before
        the jitted step runs.  The schedule is read from *state* (not the
        program) so a scheduler-permuted claim order (see
        :class:`repro.sched.WorkloadScheduler`) is followed identically by
        the prediction and the in-jit CLAIM.  Returns ``(chunk_ids (P,),
        active (P,), new_head)`` in global worker order (``state.cur`` is
        host-gathered, so this works unchanged for the SPMD engines).
        """
        cur = np.asarray(state.cur).astype(np.int64)
        head = int(state.head)
        n = self.n_chunks
        schedule = np.asarray(state.schedule)
        idle = cur == IDLE
        ranks = np.cumsum(idle) - idle
        want = head + ranks
        got = idle & (want < n)
        cur_next = np.where(got, want, np.where(idle, EXHAUSTED, cur))
        j = schedule[np.clip(cur_next, 0, n - 1)]
        active = cur_next >= 0
        new_head = head + int(np.sum(idle & (want < n)))
        return j, active, new_head

    def _closed_prefix_mask(self, closed: jnp.ndarray,
                            schedule: jnp.ndarray) -> jnp.ndarray:
        """Reordering barrier (§3): chunk-level estimation may only use the
        *closed prefix* of the schedule — the chunks up to the first not-yet
        -closed schedule position.  Returns the (N,) chunk mask."""
        n = self.n_chunks
        done_sched = closed[schedule]
        prefix_len = jnp.where(jnp.all(done_sched), n, jnp.argmax(~done_sched))
        return jnp.zeros((n,), bool).at[schedule].set(
            jnp.arange(n) < prefix_len)

    def _round_tallies(self, colv: jnp.ndarray, pr: jnp.ndarray,
                       live: jnp.ndarray, rnd: jnp.ndarray,
                       dtype) -> jnp.ndarray:
        """Per-slot ``(S, 3, H)`` group-value tallies ``[count, Σv, Σv²]``,
        bucketed by a per-round salted hash of the group column.  ``pr`` is
        the fully-masked predicate indicator, so only counted base-predicate
        rows tally; ``live`` (S,) gates tallies to slots still discovering
        groups (the ``__other__`` cell's active flag — ungrouped slots would
        otherwise tally their clipped column).  The salt (round number)
        re-buckets every round: hash collisions are transient, and the
        host-side SpaceSaving fold only trusts buckets whose moments prove a
        single value (Σv²·n == (Σv)²).
        """
        s, w, b = colv.shape
        hbk = self.tally_buckets
        h = tally_hash(colv, rnd.astype(jnp.uint32), hbk)        # (S, W, B)
        flat = (jnp.arange(s, dtype=jnp.int32)[:, None, None] * hbk
                + h).reshape(-1)
        prf = (pr * live[:, None, None].astype(pr.dtype)
               ).reshape(-1).astype(dtype)
        cv = colv.reshape(-1).astype(dtype)
        cnt = jnp.zeros((s * hbk,), dtype).at[flat].add(prf)
        vsum = jnp.zeros((s * hbk,), dtype).at[flat].add(prf * cv)
        vsq = jnp.zeros((s * hbk,), dtype).at[flat].add(prf * cv * cv)
        return jnp.stack([cnt.reshape(s, hbk), vsum.reshape(s, hbk),
                          vsq.reshape(s, hbk)], axis=1)

    # ------------------------------------------------------------ round ----
    def round_body(self, state: EngineState, data: jnp.ndarray,
                   speeds: jnp.ndarray, b_static: int,
                   coll: _Collectives, slots: Optional[SlotTable] = None,
                   decoded_mode: str = "none",
                   ) -> tuple[EngineState, RoundReport]:
        """One engine round.  ``state.cur``/``speeds`` are *local* worker
        slices (the full arrays in single-device mode); everything else is
        replicated.  ``data`` is the raw byte source: the whole packed store
        ``(N, M_max, rec)`` under ``residency="packed"``, or this round's
        per-worker slab ``(W_local, rows_max, rec)`` under
        ``residency="stream"`` (worker w's chunk rows at ``data[w]``,
        assembled by the host from :meth:`plan_claims` — the in-jit CLAIM
        below recomputes the same assignment, so slab row w always holds the
        chunk worker w claims).

        ``decoded_mode`` (static; streaming + decoded-chunk cache only)
        selects the round variant: ``"none"`` is the classic raw-slab round,
        otherwise ``data`` is the ``(raw_slab, decoded_slab, is_decoded)``
        triple from the prefetcher — ``"all"`` skips tokenize/parse entirely
        (every active worker's chunk is decoded-cached), ``"mixed"`` splits
        the budget between the raw-EXTRACT and decoded-input kernels per the
        mask.  Every variant produces bit-identical statistics and modeled
        resource clock (decoded workers keep their as-if-raw cost), so scan
        decisions never diverge with the cache on or off.

        With ``slots`` (slot-table mode) the query plane is data-driven:
        evaluation, ε targets, plan policies, and HAVING verdicts all come
        from the table, and per-query arrays are sized ``max_slots``."""
        cfg = self.config
        streaming = cfg.residency == "stream"
        assert decoded_mode in ("none", "mixed", "all"), decoded_mode
        if decoded_mode != "none":
            assert streaming, "decoded rounds exist only under streaming"
            data, dec, is_dec = data
        n = self.n_chunks
        slot_mode = slots is not None
        grouped = slot_mode and self.group_cells > 0
        if slot_mode:
            assert slots.gval.shape[1] == self.group_cells, (
                "slot table group capacity != engine max_groups")
        q = self.q_dim
        dtype = state.stats.ysum.dtype
        sizes = state.stats.M

        # ---- 1. CLAIM: prefix-sum queue-head allocation -------------------
        idle_local = state.cur == IDLE
        idle_all = coll.gather_workers(idle_local)               # (P,) global order
        ranks_all = jnp.cumsum(idle_all.astype(jnp.int32)) - idle_all.astype(jnp.int32)
        w_local = state.cur.shape[0]
        my_ids = coll.my_base() + jnp.arange(w_local, dtype=jnp.int32)
        ranks = ranks_all[my_ids]
        want_pos = state.head + ranks
        got = idle_local & (want_pos < n)
        cur = jnp.where(got, want_pos, jnp.where(idle_local, EXHAUSTED, state.cur))
        head = state.head + jnp.sum(idle_all & (state.head + ranks_all < n))

        active = cur >= 0
        j = state.schedule[jnp.clip(cur, 0, n - 1)]              # (W,) chunk ids
        mj = sizes[j]
        off = state.offset[j]                                    # permutation cursor
        m_before = state.scan_m[j]                               # scan tuples so far

        # ---- 2. EXTRACT ----------------------------------------------------
        # remaining unsampled tuples bounds the budget (cursor may wrap when a
        # synopsis window started mid-permutation — Section 6.2 circular scan)
        b_eff = jnp.minimum(jnp.floor(b_static * speeds).astype(jnp.int32),
                            jnp.maximum(mj - m_before, 0))
        b_eff = jnp.where(active, b_eff, 0)
        # a quarantined chunk yields nothing: a worker that (still) holds one
        # extracts zero tuples this round and releases it below (quarantine
        # implies closed), so claims drain without a stall
        b_eff = jnp.where(state.quarantined[j], 0, b_eff)
        k = jnp.arange(b_static, dtype=jnp.int32)
        valid = k[None, :] < b_eff[:, None]                      # (W, B)
        if slot_mode:
            # fairness weights (scheduler, repro.sched.fairness): slot s may
            # *count* only the first ceil(weight_s · b_eff) tuples of each
            # worker window this round.  The scan still extracts the full
            # b_eff (cursors/READ accounting are scan-level); a weighted slot
            # samples a shorter prefix of the same permutation window, which
            # is still a uniform without-replacement subsample.  weight = 1
            # reproduces the unweighted round bit-for-bit.
            b_slot = jnp.minimum(
                jnp.ceil(slots.weight[:, None]
                         * b_eff[None, :].astype(jnp.float32)).astype(jnp.int32),
                b_eff[None, :])                                  # (S, W)

        def window(seed_j, off_j, mj_j):
            return permutation_window_dyn(seed_j, off_j, b_static, mj_j, self.m_max)

        idx = jax.vmap(window)(self.seeds[j], off, mj)           # (W, B)
        cap = cfg.cache_cap
        if self.extract_pallas:
            # Fused kernel: gather + parse + slot eval + per-(worker, slot)
            # partial stats in one pass — no (S, W, B) eval tensor and no
            # decoded (W, B, C) copy (the decoded slab is emitted only when
            # the synopsis extraction cache needs it).
            if slot_mode:
                coeffs, p_lo, p_hi = slots.coeffs, slots.lo, slots.hi
                isc = (slots.agg == AGG_COUNT).astype(jnp.float32)
                gate_v = slots.active.astype(jnp.float32)
                wts = slots.weight
            else:
                coeffs, p_lo, p_hi = (self._plan_coeffs, self._plan_lo,
                                      self._plan_hi)
                isc = self._plan_is_count
                gate_v = jnp.ones((q,), jnp.float32)
                wts = jnp.ones((q,), jnp.float32)
            cols = None
            cache_rows = None
            if streaming:
                # slab-streaming kernels: row tiles of the worker's slab, so
                # chunks larger than VMEM stream tile-by-tile.  cache_cap > 0
                # makes the kernel itself emit the synopsis-cache delta rows
                # (W, cap, C) — only O(cap·C) per worker reaches HBM, never
                # the whole decoded window.
                def _stream_raw(budgets):
                    return kernel_ops.slot_extract_stream(
                        data, idx, budgets, coeffs, p_lo, p_hi, isc, gate_v,
                        weights=wts, row_tile=cfg.slab_row_tile,
                        backend=self._ops_backend, cache_cap=cap,
                        m_before=m_before)

                def _stream_dec(budgets):
                    return kernel_ops.slot_eval_decoded(
                        dec, idx, budgets, coeffs, p_lo, p_hi, isc, gate_v,
                        weights=wts, row_tile=cfg.slab_row_tile,
                        backend=self._ops_backend, cache_cap=cap,
                        m_before=m_before)

                if decoded_mode == "all":
                    res = _stream_dec(b_eff)
                elif decoded_mode == "mixed":
                    # complementary budgets: a zero-budget worker contributes
                    # exact float zeros, so the two kernel outputs sum to the
                    # single-kernel result bit-for-bit
                    b_raw = jnp.where(is_dec, 0, b_eff)
                    r_raw = _stream_raw(b_raw)
                    r_dec = _stream_dec(b_eff - b_raw)
                    res = jax.tree.map(lambda a, b: a + b, r_raw, r_dec)
                else:
                    res = _stream_raw(b_eff)
                if cap > 0:
                    stats4, cache_rows = res
                else:
                    stats4 = res
            elif grouped:
                stats4, cols, gstats4, tal_w = kernel_ops.slot_extract(
                    data, j, idx, b_eff, coeffs, p_lo, p_hi, isc, gate_v,
                    weights=wts,
                    return_cols=cap > 0, backend=self._ops_backend,
                    gcol=slots.gcol, gval=slots.gval, gact=slots.gact,
                    salt=state.round.astype(jnp.uint32),
                    tally_buckets=self.tally_buckets)
                # (W, S, G, 4) partials -> (S, G, W) sums; worker tallies
                # sum locally here (psum merges across devices below)
                g_sum_x = jnp.moveaxis(gstats4[..., 1].astype(dtype), 0, -1)
                g_sum_xx = jnp.moveaxis(gstats4[..., 2].astype(dtype), 0, -1)
                g_sum_p = jnp.moveaxis(gstats4[..., 3].astype(dtype), 0, -1)
                tal = jnp.sum(tal_w.astype(dtype), axis=0)       # (S, 3, H)
            else:
                stats4, cols = kernel_ops.slot_extract(
                    data, j, idx, b_eff, coeffs, p_lo, p_hi, isc, gate_v,
                    weights=wts,
                    return_cols=cap > 0, backend=self._ops_backend)
            sum_x = stats4[..., 1].astype(dtype).T               # (Q|S, W)
            sum_xx = stats4[..., 2].astype(dtype).T
            sum_p = stats4[..., 3].astype(dtype).T
        else:
            cache_rows = None
            w_ids = jnp.arange(idx.shape[0], dtype=jnp.int32)[:, None]
            if decoded_mode == "all":
                # parse-once fast path: the whole window gathers from the
                # decoded slab — no tokenize/parse at all
                cols = dec[w_ids, idx]                           # (W, B, C)
            else:
                if streaming:
                    raw = jax.vmap(lambda sw, ii: sw[ii])(data, idx)   # (W, B, rec)
                else:
                    raw = jax.vmap(lambda jj, ii: data[jj][ii])(j, idx)  # (W, B, rec)
                cols = jax.vmap(self.codec.decode_ref)(raw)      # (W, B, C)
                if decoded_mode == "mixed":
                    # decode_ref is row-elementwise, so decoded-slab gathers
                    # equal gather-then-decode bit-for-bit
                    cols = jnp.where(is_dec[:, None, None], dec[w_ids, idx],
                                     cols)
            if slot_mode:
                x, pr = slot_evaluate(slots, cols)               # (S, W, B)
                gate = slots.active.astype(dtype)[:, None, None]
                # per-slot window prefix (fairness): k < b_slot[s, w]
                vf = (k[None, None, :] < b_slot[:, :, None]).astype(dtype)
            else:
                x, pr = jax.vmap(self.evaluate, in_axes=0, out_axes=1)(cols)  # (Q, W, B)
                gate = jnp.ones((), dtype)
                vf = valid.astype(dtype)[None]
            x = x.astype(dtype) * vf * gate
            pr = pr.astype(dtype) * vf * gate
            sum_x = jnp.sum(x, -1)                               # (Q|S, W)
            sum_xx = jnp.sum(x * x, -1)
            sum_p = jnp.sum(pr, -1)
            if grouped:
                # per-cell accumulation from the materialized columns.  All
                # mask factors are exact 0/1 floats, so multiplying them in
                # any order is IEEE-exact — a tracked cell's products equal
                # the expand_group_by fan-out slot's (expr · p · valid ·
                # gate) bit-for-bit, which is the oracle the grouped plane
                # is gated on.  A row matches at most one tracked value, so
                # the __other__ spill indicator is the complement of the
                # tracked-cell sum.
                gcol_c = jnp.clip(slots.gcol, 0, self.num_cols - 1)
                colv = jnp.moveaxis(cols, -1, 0)[gcol_c]         # (S, W, B)
                gvals = slots.gval.astype(dtype)
                gactf = slots.gact.astype(dtype)
                eq = (colv[:, None] == gvals[:, :, None, None]).astype(dtype)
                trk = eq * gactf[:, :, None, None]               # (S, G, W, B)
                other = ((1.0 - jnp.sum(trk[:, :-1], axis=1))
                         * gactf[:, -1][:, None, None])          # (S, W, B)
                ind = jnp.concatenate([trk[:, :-1], other[:, None]], axis=1)
                gx = ind * x[:, None]                            # (S, G, W, B)
                gp = ind * pr[:, None]
                g_sum_x = jnp.sum(gx, -1)                        # (S, G, W)
                g_sum_xx = jnp.sum(gx * gx, -1)
                g_sum_p = jnp.sum(gp, -1)
                tal = self._round_tallies(colv, pr, gactf[:, -1],
                                          state.round, dtype)

        # ---- 3. MERGE -------------------------------------------------------
        af = active.astype(jnp.int32)
        deltas = dict(
            dm=jnp.zeros((n,), jnp.int32).at[j].add(b_eff * af),
            dys=jnp.zeros((q, n), dtype).at[:, j].add(sum_x * af),
            dyq=jnp.zeros((q, n), dtype).at[:, j].add(sum_xx * af),
            dps=jnp.zeros((q, n), dtype).at[:, j].add(sum_p * af),
        )
        if slot_mode:
            # per-slot sample-size deltas honor the fairness budgets (== dm
            # broadcast when every weight is 1)
            deltas["dmq"] = jnp.zeros((q, n), jnp.int32).at[:, j].add(
                b_slot * af[None, :])
        if grouped:
            gcells = self.group_cells
            deltas["dgys"] = jnp.zeros((q, gcells, n), dtype).at[:, :, j].add(
                g_sum_x * af)
            deltas["dgyq"] = jnp.zeros((q, gcells, n), dtype).at[:, :, j].add(
                g_sum_xx * af)
            deltas["dgps"] = jnp.zeros((q, gcells, n), dtype).at[:, :, j].add(
                g_sum_p * af)
            deltas["gtal"] = tal
        deltas = coll.merge(deltas)
        if slot_mode:
            # a slot only counts tuples extracted while it is active
            dm_q = slots.active.astype(jnp.int32)[:, None] * deltas["dmq"]
        else:
            dm_q = deltas["dm"]
        stats = state.stats._replace(
            m=state.stats.m + dm_q,
            ysum=state.stats.ysum + deltas["dys"],
            ysq=state.stats.ysq + deltas["dyq"],
            psum=state.stats.psum + deltas["dps"])
        if grouped:
            # a cell's m counts every tuple the slot sampled while the cell
            # was live — not group-filtered — matching the per-chunk sample
            # size a dedicated fan-out slot would carry (predicate-
            # independent), so cells live since admission are bit-exact
            # against the fan-out oracle.  Cells activated mid-scan
            # accumulate from activation: any contiguous window of a chunk's
            # committed random permutation is still a uniform without-
            # replacement sample.
            gact_i = slots.gact.astype(jnp.int32)
            gm_new = state.gm + dm_q[:, None, :] * gact_i[:, :, None]
            gys_new = state.gys + deltas["dgys"]
            gyq_new = state.gyq + deltas["dgyq"]
            gps_new = state.gps + deltas["dgps"]
            g_tal = deltas["gtal"]
        else:
            gm_new, gys_new = state.gm, state.gys
            gyq_new, gps_new = state.gyq, state.gps
            g_tal = jnp.zeros((q, 3, self.tally_buckets), dtype)
        scan_m = state.scan_m + deltas["dm"]
        offset = state.offset + deltas["dm"]

        # READ accounting: a chunk costs its full raw bytes the first time it
        # is extracted *beyond* what the synopsis supplied (Section 6.3 —
        # in-memory chunks only trigger a read when topped up from raw).
        needs_raw = active & (b_eff > 0) & (m_before >= state.cached_m[j])
        newly_raw = needs_raw & ~state.raw_touched[j]
        raw_touched = state.raw_touched | (coll.merge(
            jnp.zeros((n,), jnp.int32).at[j].add(newly_raw.astype(jnp.int32))) > 0)
        bytes_round = coll.merge(
            jnp.sum(jnp.where(newly_raw, self.chunk_bytes[j], 0.0)))

        # extracted-tuple cache for synopsis construction: row r of chunk j
        # holds the r-th tuple of its permutation window (append-only; the
        # maintenance pass shrinks windows host-side).  OOB rows are dropped.
        if cap > 0:
            if cache_rows is not None:
                # streaming kernels already emitted the (W, cap, C) delta
                # rows (zeros off-window, so inactive workers are no-ops)
                cache_delta = jnp.zeros_like(state.cache).at[j].add(cache_rows)
            else:
                kk = jnp.arange(b_static, dtype=jnp.int32)
                rows = m_before[:, None] + kk[None, :]           # (W, B) ordinals
                writable = (kk[None, :] < b_eff[:, None]) & active[:, None]
                rows = jnp.where(writable, rows, cap)            # cap == OOB -> drop
                cache_delta = jnp.zeros_like(state.cache).at[
                    j[:, None], rows].add(cols * writable[..., None], mode="drop")
            cache = state.cache + coll.merge(cache_delta)
        else:
            cache = state.cache

        # ---- 4. DECIDE -------------------------------------------------------
        # per-slot sample sizes: (W,) in frozen mode, (S, W) in slot mode
        mj_new = jnp.take(stats.m, j, axis=-1).astype(dtype)
        scan_mj = scan_m[j].astype(dtype)                        # (W,) scan-level
        big_m = sizes[j].astype(dtype)
        scale = big_m / jnp.maximum(mj_new, 1.0)
        ys_j = stats.ysum[:, j]                                  # (Q|S, W)
        yq_j = stats.ysq[:, j]
        ss = yq_j - ys_j * ys_j / jnp.maximum(mj_new, 1.0)
        fpc = (big_m - mj_new) / jnp.maximum(mj_new - 1.0, 1.0)
        v_local = scale * fpc * jnp.maximum(ss, 0.0)             # Eq. (5) LHS
        yhat_local = scale * ys_j
        tiny = jnp.asarray(1e-12, dtype)
        eps_vec = slots.eps.astype(dtype) if slot_mode else self.eps.astype(dtype)
        # per-slot confidence: each slot carries its own z (frozen mode bakes
        # in the query list's shared confidence level)
        z_q = slots.z.astype(dtype)[:, None] if slot_mode else self.z
        # slots that are retired/not-yet-admitted never hold a chunk open
        stopped_mask = (state.stopped | ~slots.active) if slot_mode else state.stopped
        # ε_j = ε rule (Theorem 3), in error-ratio form: 2 z √v_j <= ε |ŷ_j|
        local_ok_q = 2.0 * z_q * jnp.sqrt(jnp.maximum(v_local, 0.0)) <= (
            eps_vec[:, None] * jnp.maximum(jnp.abs(yhat_local), tiny))
        if slot_mode:
            # per-slot m: each live slot needs >= 2 of its own tuples
            local_ok = jnp.all((local_ok_q & (mj_new >= 2.0))
                               | stopped_mask[:, None], axis=0)
        else:
            local_ok = jnp.all(local_ok_q | stopped_mask[:, None], axis=0)
            local_ok = local_ok & (mj_new >= 2.0)
        # a quarantined chunk counts as exhausted: whoever holds it closes it
        # immediately (it contributed b_eff == 0 above)
        exhausted_w = (scan_m[j] >= sizes[j]) | state.quarantined[j]
        newly_acc = active & local_ok & ~state.acc_met[j]

        if slot_mode:
            # a chunk may close before exhaustion only if every live slot's
            # plan permits early close (single-pass semantics, or
            # resource-aware while the monitor says CPU-bound)
            allow_early = (slots.plan == PLAN_SINGLE_PASS) | (
                (slots.plan == PLAN_RESOURCE_AWARE) & state.cpu_bound)
            early_ok = jnp.all(allow_early | stopped_mask)
            close_w = exhausted_w | (local_ok & early_ok)
        else:
            strategy = cfg.strategy
            if strategy in ("chunk_level", "chunk_level_unordered", "holistic"):
                close_w = exhausted_w
            elif strategy == "single_pass":
                close_w = exhausted_w | local_ok
            else:  # resource_aware
                close_w = exhausted_w | (local_ok & state.cpu_bound)
        close_w = close_w & active

        flag_deltas = coll.merge(dict(
            acc=jnp.zeros((n,), jnp.int32).at[j].add((local_ok & active).astype(jnp.int32)),
            cls=jnp.zeros((n,), jnp.int32).at[j].add(close_w.astype(jnp.int32)),
            calib_sum=jnp.sum(jnp.where(newly_acc, scan_mj, 0.0)),
            calib_cnt=jnp.sum(newly_acc.astype(dtype)),
            b_eff_total=jnp.sum(b_eff),
        ))
        acc_met = state.acc_met | (flag_deltas["acc"] > 0)
        closed = state.closed | (flag_deltas["cls"] > 0)
        cur = jnp.where(close_w, IDLE, cur)
        calib_sum = state.calib_sum + flag_deltas["calib_sum"].astype(jnp.float32)
        calib_cnt = state.calib_cnt + flag_deltas["calib_cnt"].astype(jnp.float32)

        # resource monitor: Eq. (4)'s two cost terms for this round
        p_total = cfg.num_workers
        round_cpu = (flag_deltas["b_eff_total"].astype(jnp.float32)
                     * self.cost_per_tuple / cfg.cpu_tuple_ops_per_sec / p_total)
        round_io = bytes_round.astype(jnp.float32) / cfg.io_bytes_per_sec
        cpu_bound = round_cpu > round_io

        # budget (t_eval) update — §5.4 rules
        any_acc = flag_deltas["calib_cnt"] > 0
        halve = jnp.where(cpu_bound, state.first_est, any_acc)
        decay = jnp.where(halve, state.decay * 0.5,
                          jnp.minimum(state.decay * 2.0, 1.0))
        base = jnp.where(calib_cnt > 0, calib_sum / jnp.maximum(calib_cnt, 1.0),
                         jnp.asarray(float(cfg.budget_init), jnp.float32))
        budget = jnp.clip(base * decay, float(cfg.budget_min), float(cfg.budget_max))
        if slot_mode:
            # adapt t_eval iff some live slot runs the resource-aware plan
            use_adapt = jnp.any(slots.active & ~state.stopped
                                & (slots.plan == PLAN_RESOURCE_AWARE))
            budget = jnp.where(use_adapt, budget, state.budget)
            decay = jnp.where(use_adapt, decay, state.decay)
        elif cfg.strategy != "resource_aware":
            budget = state.budget      # fixed t_eval for the simpler strategies
            decay = state.decay

        # ---- 5. ESTIMATE -----------------------------------------------------
        if slot_mode:
            # per-slot estimation mask (S, N): chunk-level slots see only the
            # closed schedule prefix (reordering barrier); everything else
            # sees all chunks the slot has sampled
            base_mask = stats.m > 0                              # (S, N)
            est_mask = jnp.where(
                (slots.plan == PLAN_CHUNK_LEVEL)[:, None],
                base_mask & self._closed_prefix_mask(
                    closed, state.schedule)[None], base_mask)
        else:
            strategy = cfg.strategy
            if strategy == "chunk_level":
                est_mask = self._closed_prefix_mask(closed, state.schedule)
            elif strategy == "chunk_level_unordered":
                est_mask = closed                  # inspection-paradox-vulnerable
            else:
                est_mask = stats.m > 0
        # coverage-adjusted population: quarantined chunks leave the sample
        # *and* the universe — the bi-level estimator's chunk count |U| and
        # tuple total M shrink to the survivors, so the N/n scale-up and the
        # FPC price exactly the population an answer can still speak for
        # (CIs widen; masked stats over N slots equal a compact scan over
        # the survivors bit-for-bit, since the dropped columns are zero).
        alive = ~state.quarantined
        est_mask = est_mask & alive
        n_eff = (jnp.asarray(stats.n_total, jnp.int32)
                 - jnp.sum(state.quarantined.astype(jnp.int32)))
        m_eff = (jnp.asarray(stats.m_total, jnp.int32)
                 - jnp.sum(jnp.where(state.quarantined, sizes, 0)))
        # (N,) masks broadcast over the leading query dim; (S, N) are per-slot
        stats_est = stats._replace(
            m=jnp.where(est_mask, stats.m, 0),
            ysum=jnp.where(est_mask, stats.ysum, 0),
            ysq=jnp.where(est_mask, stats.ysq, 0),
            psum=jnp.where(est_mask, stats.psum, 0),
            n_total=n_eff, m_total=m_eff)

        sum_t = est.tau_hat(stats_est)
        sum_v, _ = est.var_hat(stats_est)
        cnt_t = est.count_tau_hat(stats_est)
        cnt_v, _ = est.count_var_hat(stats_est)
        need_avg = slot_mode or any(qq.agg == "avg" for qq in self.queries)
        if need_avg:
            avg_t, avg_v, _ = est.avg_estimate(stats_est)

        if slot_mode:
            agg = slots.agg
            estimate = jnp.where(agg == AGG_SUM, sum_t,
                                 jnp.where(agg == AGG_COUNT, cnt_t, avg_t))
            variance = jnp.where(agg == AGG_SUM, sum_v,
                                 jnp.where(agg == AGG_COUNT, cnt_v, avg_v))
            # per-slot confidence bounds: estimate ± z_s √var
            half = slots.z.astype(dtype) * jnp.sqrt(jnp.maximum(variance, 0.0))
            lo, hi = estimate - half, estimate + half
            err = est.error_ratio(estimate, lo, hi)

            # vectorized HAVING verdicts over the per-slot code columns
            op = slots.having_op
            decided = est.having_decision_coded(
                lo, hi, op, slots.having_thr.astype(dtype))
            stop_now = (err <= eps_vec) | (
                (op != HAVING_NONE) & (decided != -1))
            if grouped:
                # per-cell estimates over the (S, G, N) stat rows — the
                # bi-level estimators broadcast over arbitrary leading dims,
                # and a cell with gm == 0 on a chunk simply isn't in that
                # cell's sample (self-masking), so the slot-level chunk
                # eligibility mask is the only extra gating needed
                gmask = est_mask[:, None, :]
                gstats_est = BiLevelStats(
                    M=stats.M, m=jnp.where(gmask, gm_new, 0),
                    ysum=jnp.where(gmask, gys_new, 0),
                    ysq=jnp.where(gmask, gyq_new, 0),
                    psum=jnp.where(gmask, gps_new, 0),
                    n_total=n_eff, m_total=m_eff)
                g_sum_t = est.tau_hat(gstats_est)
                g_sum_v, _ = est.var_hat(gstats_est)
                g_cnt_t = est.count_tau_hat(gstats_est)
                g_cnt_v, _ = est.count_var_hat(gstats_est)
                g_avg_t, g_avg_v, _ = est.avg_estimate(gstats_est)
                agg_b = agg[:, None]
                g_est = jnp.where(agg_b == AGG_SUM, g_sum_t,
                                  jnp.where(agg_b == AGG_COUNT, g_cnt_t,
                                            g_avg_t))
                g_var = jnp.where(agg_b == AGG_SUM, g_sum_v,
                                  jnp.where(agg_b == AGG_COUNT, g_cnt_v,
                                            g_avg_v))
                g_half = (slots.z.astype(dtype)[:, None]
                          * jnp.sqrt(jnp.maximum(g_var, 0.0)))
                g_lo, g_hi = g_est - g_half, g_est + g_half
                g_err = est.error_ratio(g_est, g_lo, g_hi)
                g_n = jnp.sum(jnp.where(gmask, gm_new, 0), axis=-1)
                # grouped stop: the slot's top-K live cells (by |estimate|)
                # must all meet its eps.  lax.top_k needs a static k, so
                # rank by double argsort and compare against per-slot gtopk.
                cell_ok = (slots.gact > 0) & (g_n > 0)
                scores = jnp.where(cell_ok, jnp.abs(g_est), -jnp.inf)
                ranks = jnp.argsort(jnp.argsort(-scores, axis=-1), axis=-1)
                need_cell = cell_ok & (ranks < slots.gtopk[:, None])
                # discovery guard: with fewer than top_k live cells the
                # top-K rule would be vacuously satisfied (a fresh slot has
                # only __other__ live, which converges long before online
                # discovery has promoted anything) — such a slot keeps
                # scanning; stores with fewer true groups than top_k run to
                # exhaustion and retire on the census
                n_live = jnp.sum(cell_ok.astype(jnp.int32), axis=-1)
                grouped_ok = (jnp.all(~need_cell | (g_err <= eps_vec[:, None]),
                                      axis=-1)
                              & (n_live >= slots.gtopk))
                # grouped slots retire on the grouped rule alone (the scalar
                # err describes the base-predicate population; per-cell
                # HAVING verdicts are assembled host-side at retire)
                stop_now = jnp.where(slots.gcol >= 0, grouped_ok, stop_now)
            stopped = state.stopped | stop_now
            all_stopped = jnp.all(stopped | ~slots.active)
            n_chunks_rep = jnp.sum((scan_m > 0).astype(jnp.int32))
            m_tuples_rep = jnp.sum(scan_m)
        else:
            estimate = jnp.zeros((q,), dtype)
            variance = jnp.zeros((q,), dtype)
            for qi, qq in enumerate(self.queries):
                t_, v_ = {"sum": (sum_t, sum_v), "count": (cnt_t, cnt_v),
                          "avg": (avg_t, avg_v) if need_avg else (sum_t, sum_v)}[qq.agg]
                estimate = estimate.at[qi].set(t_[qi])
                variance = variance.at[qi].set(v_[qi])
            lo, hi = est.confidence_bounds(estimate, variance, self.conf)
            err = est.error_ratio(estimate, lo, hi)

            decided = jnp.full((q,), -1, jnp.int8)
            stop_now = err <= self.eps.astype(dtype)
            for qi, qq in enumerate(self.queries):
                if qq.having is not None:
                    d = est.having_decision(lo[qi], hi[qi], qq.having.op,
                                            qq.having.threshold)
                    decided = decided.at[qi].set(d)
                    stop_now = stop_now.at[qi].set(stop_now[qi] | (d != -1))
            stopped = state.stopped | stop_now
            all_stopped = jnp.all(stopped)
            n_chunks_rep = stats_est.n
            m_tuples_rep = jnp.sum(stats_est.m)

        if not grouped:
            g_est = g_lo = g_hi = g_err = jnp.zeros(
                (q, self.group_cells), dtype)
            g_n = jnp.zeros((q, self.group_cells), jnp.int32)

        all_closed = jnp.all(closed) & (head >= n)
        new_state = EngineState(
            stats=stats, scan_m=scan_m, offset=offset, closed=closed,
            acc_met=acc_met, head=head, cur=cur, budget=budget, decay=decay,
            calib_sum=calib_sum, calib_cnt=calib_cnt,
            first_est=jnp.asarray(True), stopped=stopped,
            round=state.round + 1, t_io=state.t_io + round_io,
            t_cpu=state.t_cpu + round_cpu, cpu_bound=cpu_bound,
            cached_m=state.cached_m, raw_touched=raw_touched, cache=cache,
            schedule=state.schedule, quarantined=state.quarantined,
            gm=gm_new, gys=gys_new, gyq=gyq_new, gps=gps_new)
        report = RoundReport(
            estimate=estimate, lo=lo, hi=hi, err=err, decided=decided,
            n_chunks=n_chunks_rep, m_tuples=m_tuples_rep,
            round_io_s=round_io, round_cpu_s=round_cpu,
            tuples_round=flag_deltas["b_eff_total"], bytes_round=bytes_round,
            all_stopped=all_stopped, exhausted=all_closed,
            g_est=g_est, g_lo=g_lo, g_hi=g_hi, g_err=g_err, g_n=g_n,
            g_tal=g_tal)
        return new_state, report


def budget_ladder(config: EngineConfig, m_max: int, b: float) -> int:
    """Snap a fractional t_eval budget to the power-of-two compile ladder."""
    b = float(np.clip(b, config.budget_min, min(config.budget_max, m_max)))
    return int(2 ** int(np.ceil(np.log2(max(b, 1.0)))))


# ---------------------------------------------------------------------------
# Slot retire / re-admit helpers (workload serving; preemption support)
# ---------------------------------------------------------------------------

def slot_stats_snapshot(state: EngineState, s: int) -> dict:
    """Host-side copy of slot ``s``'s sufficient-statistics row.

    The dict has the same ``{m, ysum, ysq, psum}`` shape contract as
    :meth:`~repro.core.synopsis.BiLevelSynopsis.seed_slot`, so a preempted
    query's snapshot slots straight back into the admission seeding path
    (:func:`slot_stats_write`) when it is re-admitted.  It is a *richer*
    seed than the synopsis — every tuple the slot already counted, at full
    per-chunk resolution — and it remains statistically valid because each
    chunk's tuples were drawn as a prefix of that chunk's committed random
    permutation, a property re-admission preserves (the scan's cursors
    never rewind).
    """
    stats = state.stats
    return dict(
        m=np.asarray(stats.m[s]),
        ysum=np.asarray(stats.ysum[s]),
        ysq=np.asarray(stats.ysq[s]),
        psum=np.asarray(stats.psum[s]),
    )


def slot_stats_fold(state: EngineState, slot_ids) -> dict:
    """Batched host-side fold-out of several slots' sufficient-statistics
    rows: ``{s: {m, ysum, ysq, psum}}`` with the same row contract as
    :func:`slot_stats_snapshot`.

    This is the rollup tier's per-round maintenance hook (see
    ``repro.serve.rollup``): after each engine round the server folds the
    resident slots whose query pattern is promoted into their rollup
    cells.  One device→host transfer per statistics array covers *all*
    requested rows (vs one transfer per slot through repeated
    :func:`slot_stats_snapshot` calls), and the empty-``slot_ids`` case —
    the common one, when no promoted pattern is resident — returns without
    touching the device at all.
    """
    slot_ids = list(slot_ids)
    if not slot_ids:
        return {}
    stats = state.stats
    m = np.asarray(stats.m)
    ysum = np.asarray(stats.ysum)
    ysq = np.asarray(stats.ysq)
    psum = np.asarray(stats.psum)
    return {s: dict(m=m[s], ysum=ysum[s], ysq=ysq[s], psum=psum[s])
            for s in slot_ids}


def slot_stats_write(stats: BiLevelStats, s: int, seed: Optional[dict],
                     n_chunks: int) -> tuple[BiLevelStats, int]:
    """Functional write of slot ``s``'s statistics row from a seed dict
    (synopsis seed or preemption snapshot) — zeros when ``seed`` is None.
    Returns ``(new_stats, seeded_tuple_count)``.  Host-side, between
    rounds; the engine round step never mutates rows of retired slots, so
    the write is race-free by construction."""
    dtype = stats.ysum.dtype
    if seed is None:
        m_row = jnp.zeros((n_chunks,), jnp.int32)
        zs = jnp.zeros((n_chunks,), dtype)
        ys_row, yq_row, ps_row = zs, zs, zs
        seeded = 0
    else:
        m_row = jnp.asarray(seed["m"], jnp.int32)
        ys_row = jnp.asarray(seed["ysum"], dtype)
        yq_row = jnp.asarray(seed["ysq"], dtype)
        ps_row = jnp.asarray(seed["psum"], dtype)
        seeded = int(np.asarray(seed["m"]).sum())
    return stats._replace(
        m=stats.m.at[s].set(m_row),
        ysum=stats.ysum.at[s].set(ys_row),
        ysq=stats.ysq.at[s].set(yq_row),
        psum=stats.psum.at[s].set(ps_row)), seeded


def zero_group_cells(state: EngineState, s: int,
                     cells=None) -> EngineState:
    """Zero slot ``s``'s per-group sufficient-stat rows (all cells, or the
    given cell indices).  Host-side, between rounds — a no-op on ungrouped
    engines.

    Used at admission (a fresh occupant must not inherit the previous
    query's cells) and by online discovery: promoting a value out of
    ``__other__`` changes what the spill cell means, so its stats restart.
    A restarted cell's sample is the post-restart window of each chunk's
    committed permutation — a contiguous window of a uniform random
    permutation, hence still a uniform without-replacement sample.
    """
    if state.gm.shape[1] == 0:
        return state
    sel = slice(None) if cells is None else np.asarray(list(cells), np.int64)
    gm = np.asarray(state.gm).copy()
    gys = np.asarray(state.gys).copy()
    gyq = np.asarray(state.gyq).copy()
    gps = np.asarray(state.gps).copy()
    gm[s, sel] = 0
    gys[s, sel] = 0
    gyq[s, sel] = 0
    gps[s, sel] = 0
    return state._replace(gm=jnp.asarray(gm), gys=jnp.asarray(gys),
                          gyq=jnp.asarray(gyq), gps=jnp.asarray(gps))


def slot_group_rows(state: EngineState, s: int) -> dict:
    """Host-side copy of slot ``s``'s per-cell stat rows
    ``{gm, gys, gyq, gps}`` (each ``(G, N)``).  Per-cell counterpart of
    :func:`slot_stats_snapshot`: each cell's row has the same
    ``{m, ysum, ysq, psum}`` contract, so the rollup tier folds tracked
    cells through the exact same cell-fold path as scalar slots."""
    return dict(
        gm=np.asarray(state.gm[s]),
        gys=np.asarray(state.gys[s]),
        gyq=np.asarray(state.gyq[s]),
        gps=np.asarray(state.gps[s]),
    )


def quarantine_chunks(state: EngineState, chunk_ids) -> EngineState:
    """Host-side quarantine write (between rounds, like the scheduler's
    claim reorder): mark chunks quarantined + closed and zero their
    statistics columns.

    With the columns zeroed and the round's ESTIMATE stage substituting the
    surviving chunk count / tuple total, the masked N-slot estimator sums
    are *bit-for-bit* what a fresh scan over only the surviving chunks
    would compute (adding float zeros is IEEE-exact) — the oracle property
    gated in ``tests/test_faults.py``.  A worker currently holding a
    quarantined chunk extracts zero tuples next round and releases it
    (quarantine implies exhausted), so the scan never stalls.
    """
    ids = np.asarray(sorted({int(c) for c in chunk_ids}), np.int64)
    if ids.size == 0:
        return state
    q = np.asarray(state.quarantined).copy()
    ids = ids[~q[ids]]
    if ids.size == 0:
        return state
    q[ids] = True
    closed = np.asarray(state.closed).copy()
    closed[ids] = True
    stats = state.stats
    m = np.asarray(stats.m).copy()
    ysum = np.asarray(stats.ysum).copy()
    ysq = np.asarray(stats.ysq).copy()
    psum = np.asarray(stats.psum).copy()
    m[..., ids] = 0
    ysum[..., ids] = 0
    ysq[..., ids] = 0
    psum[..., ids] = 0
    cached_m = np.asarray(state.cached_m).copy()
    cached_m[ids] = 0
    state = state._replace(
        quarantined=jnp.asarray(q),
        closed=jnp.asarray(closed),
        cached_m=jnp.asarray(cached_m),
        stats=stats._replace(
            m=jnp.asarray(m), ysum=jnp.asarray(ysum),
            ysq=jnp.asarray(ysq), psum=jnp.asarray(psum)))
    if state.gm.shape[1] > 0:
        gm = np.asarray(state.gm).copy()
        gys = np.asarray(state.gys).copy()
        gyq = np.asarray(state.gyq).copy()
        gps = np.asarray(state.gps).copy()
        gm[..., ids] = 0
        gys[..., ids] = 0
        gyq[..., ids] = 0
        gps[..., ids] = 0
        state = state._replace(gm=jnp.asarray(gm), gys=jnp.asarray(gys),
                               gyq=jnp.asarray(gyq), gps=jnp.asarray(gps))
    return state


class _ResidencyMixin:
    """Host-side raw-data feed shared by every engine.

    ``round_data(state)`` is what drivers pass as the round step's ``data``
    argument: the resident packed view under ``residency="packed"``, or a
    freshly assembled bounded slab under ``residency="stream"`` (claim
    prediction → prefetcher assemble → read-ahead hint for the next schedule
    positions, overlapping disk READ with this round's device compute).  It
    returns ``(state, data)``: streaming assembly is where permanent read
    failures surface, and each one quarantines the lost chunk in the
    returned state instead of raising into the driver loop.
    """

    pipeline = None
    #: Span tracer for the host-side round feed (claims prediction + slab
    #: assembly).  Default is the shared no-op; :meth:`set_tracer` swaps in
    #: a live one and propagates it to the prefetcher so READ spans land in
    #: the same trace under the reader thread's tid.
    tracer = NULL_TRACER

    def set_tracer(self, tracer) -> None:
        self.tracer = tracer
        if self.pipeline is not None:
            self.pipeline.tracer = tracer

    def _init_residency(self, store, config: EngineConfig, slab_put=None,
                        packed_put=None) -> np.ndarray:
        """Set up ``self.packed``/``self.pipeline`` per the configured
        residency; returns the chunk-size vector.  ``slab_put``/``packed_put``
        let the SPMD engines place buffers with mesh shardings."""
        self.quarantine_log: list[int] = []
        if config.residency == "stream":
            from repro.data.pipeline import SlabPrefetcher

            self.packed = None
            self.pipeline = SlabPrefetcher(
                store, num_workers=config.num_workers,
                row_multiple=config.slab_row_tile,
                lookahead=config.prefetch_lookahead, device_put=slab_put,
                adaptive=config.prefetch_adaptive,
                decoded_cache_bytes=config.decoded_cache_bytes)
            return store.chunk_sizes
        packed, sizes = store.packed_device_view()
        self.packed = (jnp.asarray(packed) if packed_put is None
                       else packed_put(packed))
        return sizes

    def round_data(self, state: EngineState) -> tuple[EngineState, object]:
        if self.pipeline is None:
            return state, self.packed
        with self.tracer.span("assemble"):
            while True:
                j, active, new_head = self.program.plan_claims(state)
                qn = np.asarray(state.quarantined)
                # never read a quarantined chunk: its worker still claims it
                # in-jit but extracts b_eff == 0 from a zero slab row
                active = np.asarray(active) & ~qn[np.asarray(j)]
                try:
                    slab = self.pipeline.assemble(j, active)
                except FaultError as e:
                    if e.chunk_id is None:
                        raise
                    # retries exhausted / CRC mismatch / permanent loss: drop
                    # the chunk from the population and re-plan.  Progress is
                    # monotone (each pass quarantines one more chunk), so this
                    # loop is bounded by the chunk count.  The decoded-chunk
                    # cache drops the chunk too: a block decoded from bytes
                    # the scan no longer trusts must not keep serving hits.
                    state = quarantine_chunks(state, [e.chunk_id])
                    self.drop_decoded_chunks([e.chunk_id])
                    self.quarantine_log.append(int(e.chunk_id))
                    continue
                # read-ahead follows the *state* schedule, so a scheduler-
                # permuted claim order (repro.sched) is what the reader
                # thread warms up; quarantined chunks are skipped
                nxt = np.asarray(state.schedule)[new_head:new_head
                                                 + self.pipeline.lookahead]
                self.pipeline.prefetch(int(p) for p in nxt if not qn[p])
                return state, slab

    def drop_decoded_chunks(self, chunk_ids) -> int:
        """Evict chunks from the prefetcher's decoded cache (quarantine /
        invalidation hook); returns the number actually dropped."""
        if self.pipeline is None or self.pipeline.decoded is None:
            return 0
        return self.pipeline.drop_decoded(chunk_ids)

    def decoded_fraction(self) -> float:
        """Fraction of the store's tuples with decoded blocks cached (the
        Eq. (4) CPU-cost discount input); 0.0 without a decoded cache."""
        if self.pipeline is None:
            return 0.0
        return self.pipeline.decoded_fraction()

    @staticmethod
    def data_mode(data) -> tuple[str, object]:
        """Split :meth:`round_data`'s result into the static round variant
        and the jit-able data argument: the prefetcher's decoded 4-tuple
        carries a host-side all-decoded flag that picks ``"all"`` vs
        ``"mixed"``; anything else is the classic ``"none"`` round."""
        if isinstance(data, tuple) and len(data) == 4:
            raw, dec_slab, mask, all_dec = data
            return ("all" if all_dec else "mixed"), (raw, dec_slab, mask)
        return "none", data

    def close(self) -> None:
        if self.pipeline is not None:
            self.pipeline.close()


class OLAEngine(_ResidencyMixin):
    """Host-facing single-process engine: owns device buffers + jitted rounds."""

    def __init__(self, store, queries: Sequence[Query], config: EngineConfig,
                 schedule: Optional[np.ndarray] = None):
        self.store = store
        self.config = config
        sizes = self._init_residency(store, config)
        self.program = EngineProgram(
            codec=store.codec, queries=queries, config=config,
            n_chunks=store.num_chunks, m_max=store.max_chunk_tuples,
            chunk_sizes=sizes, schedule=schedule)
        speeds = config.worker_speed or (1.0,) * config.num_workers
        assert len(speeds) == config.num_workers
        self.speeds = jnp.asarray(speeds, jnp.float32)
        self._round_fns: dict[tuple, callable] = {}
        self.m_max = int(store.max_chunk_tuples)

    @property
    def queries(self):
        return self.program.queries

    def init_state(self, synopsis_seed: Optional[dict] = None) -> EngineState:
        return self.program.init_state(synopsis_seed)

    def round_fn(self, b_static: int, decoded_mode: str = "none"):
        key = (b_static, decoded_mode)
        if key not in self._round_fns:
            coll = _Collectives()

            def step(state, packed, speeds):
                return self.program.round_body(state, packed, speeds, b_static,
                                               coll, decoded_mode=decoded_mode)

            self._round_fns[key] = jax.jit(step, donate_argnums=(0,))
        return self._round_fns[key]

    def budget_ladder(self, b: float) -> int:
        return budget_ladder(self.config, self.m_max, b)

    def run(self, max_rounds: int = 100_000, wall_timeout_s: float = 300.0,
            synopsis_seed: Optional[dict] = None, collect_history: bool = True):
        """Bare driver loop (the δ-interval reporting controller wraps this)."""
        state = self.init_state(synopsis_seed)
        history = []
        t0 = time.perf_counter()
        for _ in range(max_rounds):
            b = self.budget_ladder(float(state.budget))
            state, data = self.round_data(state)
            mode, data = self.data_mode(data)
            state, rep = self.round_fn(b, mode)(state, data, self.speeds)
            if collect_history:
                history.append(jax.tree.map(np.asarray, rep))
            if bool(rep.all_stopped) or bool(rep.exhausted):
                break
            if time.perf_counter() - t0 > wall_timeout_s:
                break
        return state, history


class SlotOLAEngine(_ResidencyMixin):
    """Host-facing engine whose query plane is a dynamic slot table.

    Mirrors :class:`OLAEngine` but the jitted round takes a
    :class:`~repro.core.queries.SlotTable` as a *data* argument: admitting a
    query mid-scan, retiring one early, or changing a slot's ε/plan is a
    host-side row write between rounds, with no recompilation and no
    disturbance to the other slots' statistics.  The workload server
    (``repro.serve.ola_server.OLAWorkloadServer``) owns admission policy,
    synopsis seeding, and top-up passes; this class owns device buffers and
    the jitted step.
    """

    def __init__(self, store, max_slots: int, config: EngineConfig,
                 schedule: Optional[np.ndarray] = None,
                 confidence: float = 0.95):
        self.store = store
        self.config = config
        sizes = self._init_residency(store, config)
        self.program = EngineProgram(
            codec=store.codec, config=config, n_chunks=store.num_chunks,
            m_max=store.max_chunk_tuples, chunk_sizes=sizes,
            schedule=schedule, max_slots=max_slots, confidence=confidence)
        speeds = config.worker_speed or (1.0,) * config.num_workers
        assert len(speeds) == config.num_workers
        self.speeds = jnp.asarray(speeds, jnp.float32)
        self._round_fns: dict[tuple, callable] = {}
        self.m_max = int(store.max_chunk_tuples)

    @property
    def max_slots(self) -> int:
        return self.program.max_slots

    def init_state(self) -> EngineState:
        return self.program.init_state()

    def round_fn(self, b_static: int, decoded_mode: str = "none"):
        key = (b_static, decoded_mode)
        if key not in self._round_fns:
            coll = _Collectives()

            def step(state, table, packed, speeds):
                return self.program.round_body(state, packed, speeds,
                                               b_static, coll, slots=table,
                                               decoded_mode=decoded_mode)

            self._round_fns[key] = jax.jit(step, donate_argnums=(0,))
        return self._round_fns[key]

    def budget_ladder(self, b: float) -> int:
        return budget_ladder(self.config, self.m_max, b)
