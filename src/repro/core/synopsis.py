"""Memory-resident bi-level sample synopsis — paper Section 6.

The synopsis caches, under a tuple budget ``B``, a *circular window* into each
chunk's keyed permutation together with the extracted column values, so that
subsequent queries can be estimated without touching raw data.  Because the
window is a contiguous run of the chunk's random order, whatever survives
shrinking is still a uniform without-replacement sample — the synopsis is a
valid bi-level sample *at every instant* (Section 6.1), and degenerates to a
stratified sample once every chunk is represented.

Construction/maintenance follow the paper's variance-driven strategy:

* chunks are admitted in extraction order (reservoir-style: everything fits
  until budget pressure appears);
* on pressure, the budget is split across chunks **proportionally to their
  within-chunk variance for the current query**; shrinking drops tuples from
  the *front* of the window (``start += excess``) so the survivor set remains
  a permutation window;
* on resampling, new tuples extend the window at the *end* (the engine's
  cursor continues from ``start+count``, wrapping circularly — Section 6.2),
  and the merged window is re-fit to the chunk's allocation with the same
  keep-the-tail rule.

Maintenance is a between-queries host-side pass (numpy) over the engine's
device-built extraction cache; estimation seeding evaluates the *new* query
on the cached tuples, which is what lets a different expression/predicate
reuse the same sample (Section 6.3).

Under the workload server the same machinery runs *mid-scan*: the synopsis
absorbs the shared scan's extraction cache on demand, and :meth:`seed_slot`
produces per-slot stats rows for a query admitted while the scan is running.
Because every cached window lies inside the already-scanned prefix of each
chunk's permutation (the scan cursor is at or past the window end), a seeded
window and the slot's future extraction are disjoint index sets of one keyed
permutation — their union is still a uniform without-replacement sample.
The scan *top-up* (re-opening early-closed chunks when a later query needs
more tuples) is driven by the server; the synopsis only guarantees window
alignment.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.queries import Query, compile_queries


@dataclasses.dataclass
class SynopsisChunk:
    start: int                 # window start in the chunk's permutation order
    values: np.ndarray         # (count, C) extracted tuples, window order

    @property
    def count(self) -> int:
        return int(self.values.shape[0])


class BiLevelSynopsis:
    """Budgeted cache of per-chunk permutation windows."""

    def __init__(self, n_chunks: int, num_cols: int, budget_tuples: int,
                 chunk_sizes: np.ndarray):
        self.n_chunks = int(n_chunks)
        self.num_cols = int(num_cols)
        self.budget = int(budget_tuples)
        self.chunk_sizes = np.asarray(chunk_sizes, np.int64)
        self.chunks: dict[int, SynopsisChunk] = {}
        self.origin_schedule: Optional[np.ndarray] = None
        self.columns_cached: frozenset = frozenset(range(num_cols))
        self.rebuilds = 0

    # ------------------------------------------------------------ queries --
    def supports(self, queries: Sequence[Query]) -> bool:
        """A query sequence can reuse the synopsis iff its column support is
        cached (Section 6: otherwise a full rebuild is triggered)."""
        need = set()
        for q in queries:
            need |= set(q.columns_used)
        if -1 in need:  # unknown support (Custom expression) -> all columns
            need = set(range(self.num_cols))
        return need <= set(self.columns_cached)

    @property
    def total_tuples(self) -> int:
        return sum(c.count for c in self.chunks.values())

    @property
    def coverage(self) -> float:
        return len(self.chunks) / max(self.n_chunks, 1)

    # -------------------------------------------------------------- build --
    def update_from_engine(self, state, schedule: np.ndarray,
                           query_variances: np.ndarray) -> None:
        """Absorb an engine run's extraction cache (Section 6.1/6.2).

        ``query_variances`` is the per-chunk within-variance proxy for the
        *current* (origin) query — the allocation driver.  Chunks are visited
        in schedule order (= extraction order); windows merge with any
        existing window for the same chunk (engine cursors continued from the
        synopsis window end, so cached rows align with window ordinals).

        ``state`` may come from a frozen-query engine or the slot-table
        engine — extraction counts are read from the scan-level ``scan_m``
        (identical to ``stats.m`` in frozen mode, shared across slots in
        slot mode).
        """
        cache = np.asarray(state.cache)          # (N, cap, C)
        m = np.asarray(state.scan_m)             # (N,) scan-level
        cached_m = np.asarray(state.cached_m)
        offset = np.asarray(state.offset)
        cap = cache.shape[1]
        if self.origin_schedule is None:
            self.origin_schedule = np.asarray(schedule).copy()

        for j in np.asarray(schedule):
            j = int(j)
            mj = int(m[j])
            if mj <= 0:
                continue
            have = self.chunks.get(j)
            rows = min(mj, cap)
            vals = cache[j, :rows]
            if have is not None and int(cached_m[j]) > 0:
                # engine was seeded from this window; cache rows [0, cached_m)
                # duplicate it only if the engine re-wrote them (it does not),
                # so splice: existing window + newly extracted tail.
                new_rows = cache[j, int(cached_m[j]):rows]
                vals = np.concatenate([have.values, new_rows], axis=0)
                start = have.start
            else:
                start = int(offset[j]) - mj if int(offset[j]) >= mj else 0
            self.chunks[j] = SynopsisChunk(start=start, values=np.asarray(vals))

        self._fit_budget(query_variances)

    def _fit_budget(self, variances: np.ndarray) -> None:
        """Variance-proportional allocation + keep-the-tail shrinking."""
        if self.total_tuples <= self.budget:
            return
        js = sorted(self.chunks.keys())
        v = np.maximum(np.asarray([variances[j] for j in js], np.float64), 1e-12)
        alloc = np.floor(self.budget * v / v.sum()).astype(np.int64)
        alloc = np.maximum(alloc, 1)  # every admitted chunk keeps >= 1 tuple
        # trim overshoot from the largest allocations
        while alloc.sum() > self.budget:
            k = int(np.argmax(alloc))
            alloc[k] -= 1
        for idx, j in enumerate(js):
            ch = self.chunks[j]
            keep = int(min(alloc[idx], ch.count))
            if keep < ch.count:
                drop = ch.count - keep
                # drop the *front* of the random permutation (paper Fig. 6)
                self.chunks[j] = SynopsisChunk(
                    start=(ch.start + drop) % max(int(self.chunk_sizes[j]), 1),
                    values=ch.values[drop:])

    # ---------------------------------------------------------- estimation --
    def within_variances(self, state) -> np.ndarray:
        """Per-chunk within-variance proxy from engine stats (allocation key).

        Frozen mode keys the allocation on the origin (first) query, as
        before.  In slot mode ``stats.m`` is per-slot ``(S, N)``; the
        allocation driver is the worst case (max) across slots, so the
        budget favors chunks that are high-variance for *any* live query.
        """
        m = np.asarray(state.stats.m, np.float64)
        ys = np.asarray(state.stats.ysum).astype(np.float64)
        yq = np.asarray(state.stats.ysq).astype(np.float64)
        if m.ndim == 1:
            ys, yq = ys[0], yq[0]
            ss = yq - np.where(m > 0, ys * ys / np.maximum(m, 1.0), 0.0)
            return np.maximum(ss / np.maximum(m - 1.0, 1.0), 0.0)
        ss = yq - np.where(m > 0, ys * ys / np.maximum(m, 1.0), 0.0)
        v = np.maximum(ss / np.maximum(m - 1.0, 1.0), 0.0)
        return v.max(axis=0)

    def seed(self, queries: Sequence[Query], cache_cap: int) -> dict:
        """Engine seed for a follow-up query (Section 6.3): evaluate the new
        queries over the cached tuples and pre-fill stats + cursors."""
        qn = len(queries)
        n = self.n_chunks
        evaluate = compile_queries(queries)
        m = np.zeros(n, np.int32)
        ysum = np.zeros((qn, n), np.float32)
        ysq = np.zeros((qn, n), np.float32)
        psum = np.zeros((qn, n), np.float32)
        offset = np.zeros(n, np.int32)
        cache = np.zeros((n, cache_cap, self.num_cols), np.float32)
        for j, ch in self.chunks.items():
            if ch.count == 0:
                continue
            x, p = evaluate(jnp.asarray(ch.values, jnp.float32))
            x = np.asarray(x)
            p = np.asarray(p)
            m[j] = ch.count
            ysum[:, j] = x.sum(-1)
            ysq[:, j] = (x * x).sum(-1)
            psum[:, j] = p.sum(-1)
            offset[j] = ch.start + ch.count   # cursor continues past the window
            rows = min(ch.count, cache_cap)
            cache[j, :rows] = ch.values[:rows]
        return dict(m=m, ysum=ysum, ysq=ysq, psum=psum, offset=offset,
                    cache=cache)

    def seed_slot(self, query: Query) -> Optional[dict]:
        """Per-slot sufficient-statistics rows for one mid-scan admission.

        Evaluates ``query`` over every cached window and returns
        ``dict(m (N,), ysum (N,), ysq (N,), psum (N,))`` — the slot's seed
        sample over the already-started chunk set.  Returns ``None`` when the
        synopsis is empty or cannot serve the query's column support (the
        slot then starts cold and only accumulates from future rounds).

        The window/cursor alignment argument from the module docstring makes
        the seeded sample and the scan's future extraction disjoint, so the
        engine can simply keep adding round deltas on top of these rows.
        """
        if not self.chunks or not self.supports([query]):
            return None
        n = self.n_chunks
        evaluate = compile_queries([query])
        m = np.zeros(n, np.int32)
        ysum = np.zeros(n, np.float32)
        ysq = np.zeros(n, np.float32)
        psum = np.zeros(n, np.float32)
        for j, ch in self.chunks.items():
            if ch.count == 0:
                continue
            x, p = evaluate(jnp.asarray(ch.values, jnp.float32))
            x = np.asarray(x)[0]
            p = np.asarray(p)[0]
            m[j] = ch.count
            ysum[j] = x.sum()
            ysq[j] = (x * x).sum()
            psum[j] = p.sum()
        return dict(m=m, ysum=ysum, ysq=ysq, psum=psum)

    def plan_schedule(self, base_schedule: np.ndarray,
                      by_variance: Optional[np.ndarray] = None) -> np.ndarray:
        """Chunk order for a follow-up query (Section 6.3).

        If some chunks are missing from the synopsis, they go *first* in their
        original order (new chunks have "infinite variance"); cached chunks
        follow, also in original order.  If everything is cached, the synopsis
        is a stratified sample and the order may be optimized to decreasing
        chunk variance (pass ``by_variance``).
        """
        base = np.asarray(base_schedule)
        cached = np.asarray([j in self.chunks for j in base])
        if not cached.all():
            return np.concatenate([base[~cached], base[cached]]).astype(np.int32)
        if by_variance is not None:
            order = np.argsort(-by_variance[base], kind="stable")
            return base[order].astype(np.int32)
        return base.astype(np.int32)

    def rebuild(self) -> None:
        """Full reset (Section 6: a query the synopsis cannot serve triggers
        an automatic rebuild)."""
        self.chunks.clear()
        self.origin_schedule = None
        self.rebuilds += 1

    def drop_chunks(self, chunk_ids) -> int:
        """Forget windows over quarantined chunks: a lost/corrupt chunk is
        out of the surviving population, so its cached tuples must stop
        seeding estimates.  Returns the number of windows dropped."""
        n = 0
        for j in chunk_ids:
            if self.chunks.pop(int(j), None) is not None:
                n += 1
        return n
