"""Bi-level sampling estimators — paper Section 4.3, Eq. (1), (2), (3).

Everything is computed from the per-chunk sufficient statistics of Table 1:

    M_j   tuples on chunk j           (file metadata)
    m_j   tuples sampled from chunk j
    y'_j  sum of x_i over the sample     (x_i = expr(tuple_i) * pred(tuple_i))
    y''_j sum of x_i^2 over the sample
    p_j   sum of pred(tuple_i) over the sample   (for COUNT / AVERAGE)

so the estimator state is a fixed-size array pytree over chunk *slots* and
merges trivially across workers (a ``psum``) and across rounds (an add).
All functions broadcast over leading query/group dimensions: arrays are
``(..., N)`` where N is the number of chunk slots; slots with ``m == 0`` are
outside the sample (U') and are masked out.

``m`` itself may carry leading dimensions too: under the workload server each
query slot joined the scan at a different point, so slot s has its own sample
size ``m[s, j]`` for chunk j.  Every estimator treats ``m`` as ``(..., N)``
broadcasting against ``ysum``; the classic single-scan case is the ``(N,)``
special case and is numerically unchanged.

Numerical conventions: the library computes in the dtype of its inputs
(float32 inside the engine, float64 under ``jax.experimental.enable_x64`` in
the statistical tests).  Degenerate cases follow the paper's semantics:

* ``m_j == M_j``  -> within-chunk term vanishes (the ``M_j - m_j`` factor).
* ``m_j == 1 < M_j`` -> within-chunk variance is not estimable; we take the
  conservative route of flagging the estimate (``valid=False``) rather than
  silently dropping the term, and the engine's budget rules never produce a
  1-tuple sample from a multi-tuple chunk except transiently in round 0.
* ``n == 1 < N`` -> between-chunk term not estimable -> variance = +inf
  (bounds stay open until two chunks are in the sample, matching Figure 2's
  "error infinite until estimable").
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri


class BiLevelStats(NamedTuple):
    """Pytree of per-chunk-slot sufficient statistics.

    Shapes: ``M, m`` are ``(N,)``; ``ysum, ysq, psum`` are ``(..., N)`` with
    optional leading per-query dims.  ``n_total`` is the total number of
    chunks N in the table (static), ``m_total`` the total number of tuples M.
    """

    M: jnp.ndarray
    m: jnp.ndarray
    ysum: jnp.ndarray
    ysq: jnp.ndarray
    psum: jnp.ndarray
    n_total: int
    m_total: int

    @property
    def in_sample(self) -> jnp.ndarray:
        return self.m > 0

    @property
    def n(self) -> jnp.ndarray:
        """|U'| — number of chunks currently in the sample (per leading dim
        when ``m`` carries per-slot dimensions)."""
        return jnp.sum(self.in_sample.astype(jnp.int32), axis=-1)

    def merge(self, other: "BiLevelStats") -> "BiLevelStats":
        """Combine disjoint samples of the same table (cross-worker psum/add)."""
        return BiLevelStats(
            M=self.M,
            m=self.m + other.m,
            ysum=self.ysum + other.ysum,
            ysq=self.ysq + other.ysq,
            psum=self.psum + other.psum,
            n_total=self.n_total,
            m_total=self.m_total,
        )


def init_stats(chunk_sizes: jnp.ndarray, query_shape: tuple = (), dtype=jnp.float32,
               m_total: int | None = None) -> BiLevelStats:
    """Fresh all-zero statistics for a table with the given per-chunk sizes."""
    n = chunk_sizes.shape[0]

    def zeros():
        # fresh buffer per field: aliased buffers break jit donation
        return jnp.zeros(query_shape + (n,), dtype=dtype)
    if m_total is not None:
        total = int(m_total)
    else:
        try:
            total = int(jnp.sum(chunk_sizes))
        except jax.errors.ConcretizationTypeError:
            total = -1  # traced sizes: callers must pass m_total for reporting
    return BiLevelStats(
        M=jnp.asarray(chunk_sizes),
        m=jnp.zeros((n,), dtype=jnp.int32),
        ysum=zeros(),
        ysq=zeros(),
        psum=zeros(),
        n_total=n,
        m_total=total,
    )


def _f(x, dtype):
    return jnp.asarray(x).astype(dtype)


def chunk_estimates(stats: BiLevelStats) -> jnp.ndarray:
    """Per-chunk unbiased estimator  ŷ_j = (M_j / m_j) · y'_j  (zero off-sample)."""
    dtype = stats.ysum.dtype
    m_safe = jnp.maximum(stats.m, 1)
    yhat = _f(stats.M, dtype) / _f(m_safe, dtype) * stats.ysum
    return jnp.where(stats.in_sample, yhat, jnp.zeros_like(yhat))


def tau_hat(stats: BiLevelStats) -> jnp.ndarray:
    """Eq. (1):  τ̂ = (N / n) Σ_{j∈U'} ŷ_j  — unbiased for τ = Σ_i x_i."""
    dtype = stats.ysum.dtype
    n = jnp.maximum(stats.n, 1).astype(dtype)          # (...,) per-slot |U'|
    big_n = _f(stats.n_total, dtype)
    return big_n / n * jnp.sum(chunk_estimates(stats), axis=-1)


def _within_chunk_ss(sum_a, sum_b, cross, m, dtype):
    """Σ_i (a_i - ā)(b_i - b̄) over the sample of one chunk = cross − Σa·Σb/m."""
    m_safe = jnp.maximum(m, 1).astype(dtype)
    return cross - sum_a * sum_b / m_safe


def _cov_hat(stats: BiLevelStats, sum_a, sum_b, cross) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Generic Eq. (3)-shaped unbiased (co)variance estimator.

    With ``sum_a == sum_b == ysum`` and ``cross == ysq`` this is exactly
    Theorem 2; with mixed sums it is the two-stage covariance used by the
    AVERAGE ratio estimator.  Returns ``(cov, valid)``.
    """
    dtype = sum_a.dtype
    mask = stats.in_sample
    maskf = mask.astype(dtype)
    big_n = _f(stats.n_total, dtype)
    n = jnp.maximum(stats.n, 1).astype(dtype)          # (...,) per-slot |U'|
    m = stats.m
    m_safe = jnp.maximum(m, 1).astype(dtype)
    big_m = _f(stats.M, dtype)

    scale = big_m / m_safe  # M_j / m_j
    ahat = jnp.where(mask, scale * sum_a, 0.0)
    bhat = jnp.where(mask, scale * sum_b, 0.0)

    # ---- between-chunk term:  N/n · (N-n)/(n-1) · Σ_j (âⱼ - ā)(b̂ⱼ - b̄)
    abar = jnp.sum(ahat, axis=-1, keepdims=True) / n[..., None]
    bbar = jnp.sum(bhat, axis=-1, keepdims=True) / n[..., None]
    between_ss = jnp.sum(maskf * (ahat - abar) * (bhat - bbar), axis=-1)
    n_gt1 = stats.n > 1
    between = jnp.where(
        n_gt1,
        big_n / n * (big_n - n) / jnp.maximum(n - 1.0, 1.0) * between_ss,
        jnp.inf,
    )
    # A census of the chunk space (n == N) has no between-chunk variance even
    # when N == 1: the first `where` above already yields 0 via (N - n) = 0,
    # but n == N == 1 falls into the n==1 branch, so fix it up explicitly.
    between = jnp.where(stats.n == stats.n_total, jnp.nan_to_num(between, posinf=0.0), between)

    # ---- within-chunk term:  N/n · Σ_j (M_j/m_j) (M_j-m_j)/(m_j-1) · SS_j
    ss_within = _within_chunk_ss(sum_a, sum_b, cross, m, dtype)
    fpc = (big_m - m_safe) / jnp.maximum(m_safe - 1.0, 1.0)  # (M_j - m_j)/(m_j - 1)
    within_j = jnp.where(mask, scale * fpc * ss_within, 0.0)
    # m_j == 1 on a multi-tuple chunk: term not estimable; contribute 0 but
    # mark invalid so callers can widen the report.
    singleton = mask & (m == 1) & (stats.M > 1)
    within_j = jnp.where(singleton, 0.0, within_j)
    within = big_n / n * jnp.sum(within_j, axis=-1)

    valid = jnp.logical_not(jnp.any(singleton, axis=-1)) & (
        n_gt1 | (stats.n == stats.n_total))
    return between + within, valid


def var_hat(stats: BiLevelStats) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. (3): unbiased estimator of Var(τ̂).  Returns ``(variance, valid)``."""
    return _cov_hat(stats, stats.ysum, stats.ysum, cross=stats.ysq)


def count_tau_hat(stats: BiLevelStats) -> jnp.ndarray:
    """COUNT is SUM with expression = 1 (Section 4.3): estimate from psum."""
    return tau_hat(stats._replace(ysum=stats.psum))


def count_var_hat(stats: BiLevelStats) -> tuple[jnp.ndarray, jnp.ndarray]:
    # pred is 0/1 so Σ p_i^2 = Σ p_i.
    return _cov_hat(stats, stats.psum, stats.psum, cross=stats.psum)


def avg_estimate(stats: BiLevelStats) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """AVERAGE = SUM/COUNT ratio estimator with delta-method variance.

    Following the paper ("only minor modifications ... for complex aggregates"
    via [Haas & König 2004]):  R̂ = τ̂_x / τ̂_p and

        Var(R̂) ≈ (Var_x + R̂² Var_p − 2 R̂ Cov_xp) / τ̂_p²

    where the covariance uses the same two-stage structure.  The cross moment
    Σ x_i·p_i equals Σ x_i because x_i is already predicate-masked.
    Returns ``(estimate, variance, valid)``.
    """
    dtype = stats.ysum.dtype
    tx = tau_hat(stats)
    tp = count_tau_hat(stats)
    var_x, vx_ok = var_hat(stats)
    var_p, vp_ok = count_var_hat(stats)
    cov_xp, cv_ok = _cov_hat(stats, stats.ysum, stats.psum, cross=stats.ysum)
    tp_safe = jnp.where(jnp.abs(tp) > 0, tp, jnp.ones_like(tp))
    r = tx / tp_safe
    var_r = (var_x + r * r * var_p - 2.0 * r * cov_xp) / (tp_safe * tp_safe)
    # Delta-method variances can go slightly negative near m_j == M_j; clamp.
    var_r = jnp.maximum(var_r, jnp.zeros_like(var_r))
    var_r = jnp.where(jnp.abs(tp) > 0, var_r, jnp.asarray(jnp.inf, dtype))
    return r, var_r, vx_ok & vp_ok & cv_ok


def confidence_bounds(estimate, variance, confidence: float = 0.95):
    """CLT bounds (Section 4.3): ``estimate ± z_{(1+c)/2} · sqrt(variance)``."""
    dtype = jnp.asarray(estimate).dtype
    z = ndtri(jnp.asarray((1.0 + confidence) / 2.0, dtype=dtype))
    half = z * jnp.sqrt(jnp.maximum(variance, 0.0))
    return estimate - half, estimate + half


def error_ratio(estimate, lo, hi) -> jnp.ndarray:
    """The paper's reported metric: relative CI width (high-low)/|estimate|."""
    denom = jnp.maximum(jnp.abs(estimate), jnp.asarray(1e-30, jnp.asarray(estimate).dtype))
    return (hi - lo) / denom


# HAVING op codes shared by the frozen path (string ops) and the slot-table
# path (per-slot code columns); -1 marks "no HAVING clause".
HAVING_NONE = -1
HAVING_OP_CODES = {"<": 0, "<=": 1, ">": 2, ">=": 3}


def having_decision_coded(lo, hi, op, threshold) -> jnp.ndarray:
    """Decide ``HAVING agg <op> threshold`` from the confidence interval,
    with ``op`` given as (arrays of) ``HAVING_OP_CODES`` values.

    Returns int8: 1 = decidedly true, 0 = decidedly false, -1 = undecided
    (also -1 wherever ``op == HAVING_NONE``).  The PTF early-out (Section
    1): a verification query stops as soon as the whole interval is on one
    side of the threshold.
    """
    t = jnp.asarray(threshold, jnp.asarray(lo).dtype)
    op = jnp.asarray(op, jnp.int32)
    true_ = jnp.select([op == 0, op == 1, op == 2, op == 3],
                       [hi < t, hi <= t, lo > t, lo >= t], False)
    false_ = jnp.where(op <= 1, lo > t, hi < t)
    return jnp.where(
        op == HAVING_NONE, jnp.int8(-1),
        jnp.where(true_, jnp.int8(1),
                  jnp.where(false_, jnp.int8(0), jnp.int8(-1))))


def having_decision(lo, hi, op: str, threshold) -> jnp.ndarray:
    """String-op convenience wrapper over :func:`having_decision_coded`."""
    if op not in HAVING_OP_CODES:
        raise ValueError(f"unsupported HAVING op: {op}")
    return having_decision_coded(lo, hi, HAVING_OP_CODES[op], threshold)


# ---------------------------------------------------------------------------
# Design-time (true) variance, Eq. (2) — used by tests and by the Monte-Carlo
# coverage benchmark to compare the estimator against ground truth.
# ---------------------------------------------------------------------------

def variance_true(chunk_sums: jnp.ndarray, within_ss: jnp.ndarray,
                  chunk_sizes: jnp.ndarray, n: int, m: jnp.ndarray) -> jnp.ndarray:
    """Eq. (2) for a fixed design (n chunks, m_j tuples from chunk j).

    ``chunk_sums`` are the true y_j, ``within_ss[j] = Σ_{i∈C_j}(x_i − y_j/M_j)²``.
    """
    dtype = chunk_sums.dtype
    big_n = _f(chunk_sums.shape[-1], dtype)
    n = _f(n, dtype)
    big_m = chunk_sizes.astype(dtype)
    m = jnp.maximum(m.astype(dtype), 1.0)
    ybar = jnp.mean(chunk_sums, axis=-1, keepdims=True)
    between = big_n / (big_n - 1.0) * (big_n - n) / n * jnp.sum(
        (chunk_sums - ybar) ** 2, axis=-1)
    fpc = big_m / jnp.maximum(big_m - 1.0, 1.0) * (big_m - m) / m
    within = big_n / n * jnp.sum(fpc * within_ss, axis=-1)
    return between + within


def sample_size_for_accuracy(estimate, variance, m_used, epsilon, confidence=0.95):
    """Rough inverse-CLT planning helper: how many more tuples (at the current
    per-tuple variance rate) until ``error_ratio <= epsilon``.  Used by the
    resource-aware policy's calibration (Section 5.4) to set round budgets."""
    dtype = jnp.asarray(estimate).dtype
    z = ndtri(jnp.asarray((1.0 + confidence) / 2.0, dtype=dtype))
    target_half = jnp.abs(estimate) * epsilon / 2.0
    target_var = (target_half / z) ** 2
    ratio = jnp.where(target_var > 0, variance / jnp.maximum(target_var, 1e-30), jnp.inf)
    return jnp.ceil(jnp.maximum(ratio - 1.0, 0.0) * jnp.maximum(m_used, 1))
