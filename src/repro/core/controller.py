"""Estimation controller — the paper's δ-interval reporting loop (Section 7.1
"implementation") plus the query-sequence / verification workflows.

The controller owns: the modeled wall clock (Eq. 4 — READ and EXTRACT are
overlapped, so a round costs ``max(t_io, t_cpu)``), the δ-interval estimate
reports, the HAVING-sequence early-outs (the PTF workflow of Section 1), and
the synopsis life-cycle across a query sequence (build → reuse → top-up →
rebuild).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.engine import EngineConfig, OLAEngine
from repro.core.queries import Query
from repro.core.synopsis import BiLevelSynopsis


@dataclasses.dataclass
class EstimateReport:
    """One user-visible estimate row (emitted every δ of modeled time)."""

    t_model: float            # modeled seconds since query start (Eq. 4 clock)
    t_wall: float             # measured wall seconds (CPU host, for reference)
    estimate: np.ndarray      # (Q,)
    lo: np.ndarray
    hi: np.ndarray
    err: np.ndarray           # (Q,) error ratio
    n_chunks: int
    m_tuples: int
    io_busy: float            # utilization trace for the Fig. 14 analogue
    cpu_busy: float


@dataclasses.dataclass
class QueryResult:
    reports: list[EstimateReport]
    final_estimate: np.ndarray
    final_err: np.ndarray
    decisions: np.ndarray     # (Q,) int8 HAVING verdicts
    stopped: np.ndarray       # (Q,) bool
    rounds: int
    t_model_total: float
    t_wall_total: float
    tuples_ratio: float       # fraction of the table's tuples extracted
    chunks_ratio: float       # fraction of chunks read from raw
    from_synopsis: bool = False


class EstimationController:
    """Drives an engine round loop with δ-interval reporting and synopsis reuse."""

    def __init__(self, store, config: EngineConfig, delta_model_s: float = 1.0,
                 synopsis_budget_tuples: int = 0, engine_cls=OLAEngine,
                 engine_kwargs: Optional[dict] = None):
        self.store = store
        self.config = config
        self.delta = float(delta_model_s)
        self.engine_cls = engine_cls
        self.engine_kwargs = engine_kwargs or {}
        self.synopsis: Optional[BiLevelSynopsis] = None
        if synopsis_budget_tuples > 0:
            self.synopsis = BiLevelSynopsis(
                n_chunks=store.num_chunks, num_cols=store.codec.num_cols,
                budget_tuples=synopsis_budget_tuples,
                chunk_sizes=store.chunk_sizes)

    # ----------------------------------------------------------------- run --
    def run_query(self, queries: Sequence[Query], max_rounds: int = 200_000,
                  wall_timeout_s: float = 600.0) -> QueryResult:
        queries = list(queries)
        cfg = self.config
        use_syn = (self.synopsis is not None and len(self.synopsis.chunks) > 0
                   and self.synopsis.supports(queries))
        if self.synopsis is not None and not use_syn and len(self.synopsis.chunks) > 0:
            # unservable query -> automatic rebuild (Section 6)
            self.synopsis.rebuild()

        cache_cap = cfg.cache_cap
        if self.synopsis is not None and cache_cap == 0:
            # need the extraction cache to build/maintain the synopsis
            cache_cap = max(64, int(np.ceil(
                4 * self.synopsis.budget / max(self.store.num_chunks, 1))))
            cfg = dataclasses.replace(cfg, cache_cap=cache_cap)

        schedule = None
        seed = None
        if use_syn:
            from repro.sampling.permutation import random_chunk_order

            base = random_chunk_order(cfg.seed, self.store.num_chunks)
            if self.synopsis.origin_schedule is not None:
                base = self.synopsis.origin_schedule
            schedule = self.synopsis.plan_schedule(base)
            seed = self.synopsis.seed(queries, cache_cap)

        engine = self.engine_cls(self.store, queries, cfg, schedule=schedule,
                                 **self.engine_kwargs)
        state = engine.init_state(seed)

        if seed is not None:
            zero = self._try_answer_from_seed(engine, queries, seed)
            if zero is not None:
                if self.synopsis is not None:
                    # refresh variances for subsequent allocation decisions
                    pass
                engine.close()
                return zero

        reports: list[EstimateReport] = []
        t_model = 0.0
        next_report = 0.0
        io_busy_acc = cpu_busy_acc = 0.0
        t0 = time.perf_counter()
        rounds = 0
        last = None
        for _ in range(max_rounds):
            b = engine.budget_ladder(float(state.budget))
            state, data = engine.round_data(state)
            mode, data = engine.data_mode(data)
            state, rep = engine.round_fn(b, mode)(state, data, engine.speeds)
            rounds += 1
            io_s = float(rep.round_io_s)
            cpu_s = float(rep.round_cpu_s)
            # Eq. 4 overlapped-pipeline clock
            t_model = max(float(state.t_io), float(state.t_cpu))
            step_t = max(io_s, cpu_s)
            if step_t > 0:
                io_busy_acc += io_s
                cpu_busy_acc += cpu_s
            last = rep
            if t_model >= next_report or bool(rep.all_stopped) or bool(rep.exhausted):
                reports.append(EstimateReport(
                    t_model=t_model, t_wall=time.perf_counter() - t0,
                    estimate=np.asarray(rep.estimate), lo=np.asarray(rep.lo),
                    hi=np.asarray(rep.hi), err=np.asarray(rep.err),
                    n_chunks=int(rep.n_chunks), m_tuples=int(rep.m_tuples),
                    io_busy=io_s / max(step_t, 1e-12),
                    cpu_busy=cpu_s / max(step_t, 1e-12)))
                next_report = t_model + self.delta
            if bool(rep.all_stopped) or bool(rep.exhausted):
                break
            if time.perf_counter() - t0 > wall_timeout_s:
                break

        # synopsis maintenance from this run's extraction cache
        if self.synopsis is not None:
            variances = self.synopsis.within_variances(state)
            self.synopsis.update_from_engine(
                state, np.asarray(engine.program.schedule), variances)

        # one engine per query: release its prefetcher (stream residency)
        engine.close()

        chunks_raw = int(np.asarray(state.raw_touched).sum())
        return QueryResult(
            reports=reports,
            final_estimate=np.asarray(last.estimate),
            final_err=np.asarray(last.err),
            decisions=np.asarray(last.decided),
            stopped=np.asarray(state.stopped),
            rounds=rounds,
            t_model_total=t_model,
            t_wall_total=time.perf_counter() - t0,
            tuples_ratio=float(int(last.m_tuples) / max(engine.program.total_tuples, 1)),
            chunks_ratio=chunks_raw / max(engine.program.n_chunks, 1),
            from_synopsis=use_syn,
        )

    def _try_answer_from_seed(self, engine, queries, seed):
        """Section 6.3 best case: the query is answered exclusively from the
        memory-resident synopsis — zero raw access, zero modeled time."""
        import numpy as np

        from repro.core import estimators as E

        est_v, lo, hi, err = _answer_from_stats(
            queries, engine.init_state(seed).stats)
        import jax.numpy as jnp

        decided = np.full(len(queries), -1, np.int8)
        stop = np.asarray(err) <= np.asarray([q.epsilon for q in queries])
        for qi, q in enumerate(queries):
            if q.having is not None:
                d = int(E.having_decision(lo[qi], hi[qi], q.having.op,
                                          q.having.threshold))
                decided[qi] = d
                stop[qi] |= d != -1
        if not stop.all():
            return None
        return QueryResult(
            reports=[EstimateReport(
                t_model=0.0, t_wall=0.0, estimate=np.asarray(est_v),
                lo=np.asarray(lo), hi=np.asarray(hi), err=np.asarray(err),
                n_chunks=int(np.sum(np.asarray(seed["m"]) > 0)),
                m_tuples=int(np.sum(seed["m"])), io_busy=0.0, cpu_busy=0.0)],
            final_estimate=np.asarray(est_v), final_err=np.asarray(err),
            decisions=decided, stopped=stop, rounds=0, t_model_total=0.0,
            t_wall_total=0.0,
            tuples_ratio=float(np.sum(seed["m"]) / max(self.store.num_tuples, 1)),
            chunks_ratio=0.0, from_synopsis=True)

    # -------------------------------------------------- verification chain --
    def run_verification(self, queries: Sequence[Query],
                         max_rounds: int = 200_000) -> list[QueryResult]:
        """The PTF workflow (Section 1): execute HAVING queries in sequence;
        a query runs only if every previous one passed.  Each query reuses
        (and refreshes) the synopsis."""
        results = []
        for q in queries:
            assert q.having is not None, "verification queries need HAVING"
            res = self.run_query([q], max_rounds=max_rounds)
            results.append(res)
            verdict = int(res.decisions[0])
            passed = verdict == 1 or (verdict == -1 and _having_exact_pass(q, res))
            if not passed:
                break  # batch rejected: skip the rest (the whole point of OLA)
        return results


def _answer_from_stats(queries, stats):
    import jax.numpy as jnp

    from repro.core import estimators as E

    ests, vars_ = [], []
    for qi, q in enumerate(queries):
        if q.agg == "sum":
            t = E.tau_hat(stats)[qi]
            v = E.var_hat(stats)[0][qi]
        elif q.agg == "count":
            t = E.count_tau_hat(stats)[qi]
            v = E.count_var_hat(stats)[0][qi]
        else:
            r, vv, _ = E.avg_estimate(stats)
            t, v = r[qi], vv[qi]
        ests.append(t)
        vars_.append(v)
    est_v = jnp.stack(ests)
    var_v = jnp.stack(vars_)
    lo, hi = E.confidence_bounds(est_v, var_v, queries[0].confidence)
    err = E.error_ratio(est_v, lo, hi)
    return est_v, lo, hi, err


def _having_exact_pass(q: Query, res: QueryResult) -> bool:
    """If the engine exhausted the data the estimate is exact — decide directly."""
    est = float(res.final_estimate[0])
    t = q.having.threshold
    return {"<": est < t, "<=": est <= t, ">": est > t, ">=": est >= t}[q.having.op]
