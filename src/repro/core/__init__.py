"""OLA-RAW core: the paper's contribution as composable JAX modules.

Layering (bottom-up):

* :mod:`repro.core.estimators` — Eq. (1)/(2)/(3) bi-level estimators + bounds.
* :mod:`repro.core.queries`    — aggregate-query AST + compiled tile evaluator.
* :mod:`repro.core.engine`     — the parallel sampling state machine
  (chunk-level / holistic / single-pass / resource-aware strategies).
* :mod:`repro.core.engine_spmd`— shard_map execution over a device mesh.
* :mod:`repro.core.synopsis`   — Section 6 memory-resident sample synopsis.
* :mod:`repro.core.controller` — δ-interval reporting, verification chains,
  synopsis life-cycle.
"""

from repro.core.controller import EstimationController, QueryResult
from repro.core.engine import EngineConfig, EngineState, OLAEngine, RoundReport
from repro.core.engine_spmd import SPMDEngine
from repro.core.estimators import (
    BiLevelStats,
    confidence_bounds,
    error_ratio,
    having_decision,
    init_stats,
    tau_hat,
    var_hat,
)
from repro.core.queries import (
    And,
    Cmp,
    Column,
    Custom,
    GroupEq,
    Having,
    Linear,
    Query,
    Range,
    SquaredDiff,
    TRUE,
    expand_group_by,
)
from repro.core.synopsis import BiLevelSynopsis

__all__ = [
    "And", "BiLevelStats", "BiLevelSynopsis", "Cmp", "Column", "Custom",
    "EngineConfig", "EngineState", "EstimationController", "GroupEq",
    "Having", "Linear", "OLAEngine", "Query", "QueryResult", "Range",
    "RoundReport", "SPMDEngine", "SquaredDiff", "TRUE", "confidence_bounds",
    "error_ratio", "expand_group_by", "having_decision", "init_stats",
    "tau_hat", "var_hat",
]
