"""SPMD (multi-device) execution of the OLA-RAW engine via shard_map.

The worker axis is sharded over the mesh ``data`` axis (DESIGN.md §3:
EXTRACT threads → devices); every other piece of engine state is replicated
and advanced by psum-merged deltas, so all devices hold identical state —
the SPMD analogue of the paper's shared memory.  The raw chunk buffer is
replicated too, mirroring the paper's "all threads see the file" model; a
host-sharded store with a per-host queue is the scale-out extension
(distributed/fault.py handles chunk reassignment on host loss).

Semantics are *identical* to the single-device engine with
``num_workers = devices × workers_per_device`` — property-tested in
tests/test_engine_spmd.py.  The claim step's prefix-sum sees the all-gathered
idle flags in global worker order, so chunk hand-out order is deterministic
and independent of device count.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import (
    EngineConfig,
    EngineProgram,
    EngineState,
    RoundReport,
    _Collectives,
    _ResidencyMixin,
    budget_ladder,
)
from repro.core.estimators import BiLevelStats
from repro.core.queries import Query, SlotTable

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, **kw):
    """Version shim: the replication-check kwarg was renamed
    check_rep -> check_vma across jax releases."""
    try:
        return _shard_map(f, **kw)
    except TypeError:
        if "check_vma" in kw:
            kw = dict(kw)
            kw["check_rep"] = kw.pop("check_vma")
            return _shard_map(f, **kw)
        raise


def engine_state_specs() -> EngineState:
    """PartitionSpecs for EngineState: `cur` sharded over data, rest replicated.

    The static ints inside BiLevelStats become replicated scalars under
    shard_map — harmless, they are only used arithmetically.
    """
    rep = P()
    stats_spec = BiLevelStats(M=rep, m=rep, ysum=rep, ysq=rep, psum=rep,
                              n_total=rep, m_total=rep)
    return EngineState(
        stats=stats_spec, scan_m=rep, offset=rep, closed=rep, acc_met=rep,
        head=rep, cur=P("data"), budget=rep, decay=rep, calib_sum=rep,
        calib_cnt=rep, first_est=rep, stopped=rep, round=rep, t_io=rep,
        t_cpu=rep, cpu_bound=rep, cached_m=rep, raw_touched=rep, cache=rep,
        schedule=rep, quarantined=rep, gm=rep, gys=rep, gyq=rep, gps=rep)


def report_specs() -> RoundReport:
    return RoundReport(*([P()] * len(RoundReport._fields)))


def slot_table_specs() -> SlotTable:
    """The slot table is replicated: every device evaluates every slot (the
    query plane is tiny next to the data plane)."""
    return SlotTable(*([P()] * len(SlotTable._fields)))


class _SPMDEngineBase(_ResidencyMixin):
    """Shared mesh plumbing for the SPMD engines: worker split over the
    ``data`` axis, replicated chunk buffer (packed residency) or a
    worker-sharded per-round slab (stream residency), sharded per-worker
    speeds, state sharding, the per-budget compile cache, and the t_eval
    ladder."""

    def __init__(self, store, config: EngineConfig, mesh: Mesh):
        self.store = store
        self.mesh = mesh
        self.n_dev = mesh.shape["data"]
        assert config.num_workers % self.n_dev == 0, (
            f"num_workers={config.num_workers} must divide over "
            f"data axis size {self.n_dev}")
        self.wpd = config.num_workers // self.n_dev
        self.config = config
        # slab rows are per-worker, so under stream residency the slab shards
        # over the mesh's worker axis — each device receives only its
        # workers' chunks; the packed view stays replicated
        self.chunk_sizes = self._init_residency(
            store, config,
            slab_put=lambda a: jax.device_put(
                a, NamedSharding(mesh, P("data"))),
            packed_put=lambda a: jax.device_put(
                a, NamedSharding(mesh, P())))
        self.m_max = int(store.max_chunk_tuples)
        speeds = config.worker_speed or (1.0,) * config.num_workers
        assert len(speeds) == config.num_workers
        self.speeds = jax.device_put(np.asarray(speeds, np.float32),
                                     NamedSharding(mesh, P("data")))
        self._round_fns: dict[tuple, callable] = {}

    def _put_state(self, state: EngineState) -> EngineState:
        shardings = jax.tree.map(lambda spec: NamedSharding(self.mesh, spec),
                                 engine_state_specs(),
                                 is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(state, shardings)

    def _compile_round(self, step, extra_in_specs: tuple,
                       decoded_mode: str = "none"):
        """shard_map + jit one round step; ``step`` takes
        ``(state, *extras, data, speeds)``.  The raw-data argument is
        replicated in packed residency and worker-sharded in stream
        residency (slab rows follow their workers); a decoded round's data
        is the ``(raw, dec, is_decoded)`` triple — every leaf is per-worker,
        so all three shard over the mesh worker axis."""
        specs = engine_state_specs()
        if self.config.residency == "stream":
            data_spec = ((P("data"), P("data"), P("data"))
                         if decoded_mode != "none" else P("data"))
        else:
            data_spec = P()
        sm = shard_map(step, mesh=self.mesh,
                       in_specs=(specs, *extra_in_specs, data_spec, P("data")),
                       out_specs=(specs, report_specs()),
                       check_vma=False)
        return jax.jit(sm, donate_argnums=(0,))

    def budget_ladder(self, b: float) -> int:
        return budget_ladder(self.config, self.m_max, b)


class SPMDEngine(_SPMDEngineBase):
    """Multi-device OLA engine over a mesh with a ``data`` axis."""

    def __init__(self, store, queries: Sequence[Query], config: EngineConfig,
                 mesh: Mesh, schedule: Optional[np.ndarray] = None):
        super().__init__(store, config, mesh)
        self.program = EngineProgram(
            codec=store.codec, queries=queries, config=config,
            n_chunks=store.num_chunks, m_max=store.max_chunk_tuples,
            chunk_sizes=self.chunk_sizes, schedule=schedule)

    @property
    def queries(self):
        return self.program.queries

    def init_state(self, synopsis_seed: Optional[dict] = None) -> EngineState:
        return self._put_state(self.program.init_state(synopsis_seed))

    def round_fn(self, b_static: int, decoded_mode: str = "none"):
        key = (b_static, decoded_mode)
        if key not in self._round_fns:
            coll = _Collectives(axis_name="data", workers_per_device=self.wpd)

            def step(state, packed, speeds):
                return self.program.round_body(state, packed, speeds,
                                               b_static, coll,
                                               decoded_mode=decoded_mode)

            self._round_fns[key] = self._compile_round(
                step, (), decoded_mode=decoded_mode)
        return self._round_fns[key]

    def run(self, max_rounds: int = 100_000, wall_timeout_s: float = 600.0,
            synopsis_seed: Optional[dict] = None, collect_history: bool = True):
        state = self.init_state(synopsis_seed)
        history = []
        t0 = time.perf_counter()
        for _ in range(max_rounds):
            b = self.budget_ladder(float(state.budget))
            state, data = self.round_data(state)
            mode, data = self.data_mode(data)
            state, rep = self.round_fn(b, mode)(state, data, self.speeds)
            if collect_history:
                history.append(jax.tree.map(np.asarray, rep))
            if bool(rep.all_stopped) or bool(rep.exhausted):
                break
            if time.perf_counter() - t0 > wall_timeout_s:
                break
        return state, history


class SlotSPMDEngine(_SPMDEngineBase):
    """Multi-device slot-table engine: :class:`~repro.core.engine.SlotOLAEngine`
    with the worker axis sharded over the mesh ``data`` axis.

    Drop-in round-step compatible with the single-device slot engine (the
    workload server drives either through the same
    ``round_fn(b)(state, table, packed, speeds)`` signature): the slot table
    is replicated, ``cur`` is sharded, and chunk-slot deltas are psum-merged,
    so chunk hand-out order — and therefore every slot's sample — is
    deterministic and independent of device count (the claim step's
    prefix-sum runs over all-gathered idle flags in global worker order).
    Parity is property-tested in tests/test_engine_spmd.py.
    """

    def __init__(self, store, max_slots: int, config: EngineConfig,
                 mesh: Mesh, schedule: Optional[np.ndarray] = None,
                 confidence: float = 0.95):
        super().__init__(store, config, mesh)
        self.program = EngineProgram(
            codec=store.codec, config=config, n_chunks=store.num_chunks,
            m_max=store.max_chunk_tuples, chunk_sizes=self.chunk_sizes,
            schedule=schedule, max_slots=max_slots, confidence=confidence)

    @property
    def max_slots(self) -> int:
        return self.program.max_slots

    def init_state(self) -> EngineState:
        return self._put_state(self.program.init_state())

    def round_fn(self, b_static: int, decoded_mode: str = "none"):
        key = (b_static, decoded_mode)
        if key not in self._round_fns:
            coll = _Collectives(axis_name="data", workers_per_device=self.wpd)

            def step(state, table, packed, speeds):
                return self.program.round_body(state, packed, speeds,
                                               b_static, coll, slots=table,
                                               decoded_mode=decoded_mode)

            self._round_fns[key] = self._compile_round(
                step, (slot_table_specs(),), decoded_mode=decoded_mode)
        return self._round_fns[key]
