"""Beyond-paper integrations: OLA-RAW as a first-class training-framework
feature.

* :mod:`verify`    — PTF-style ingest verification gating the trainer.
* :mod:`eval_ola`  — distributed eval with bi-level early termination.
* :mod:`gradnoise` — gradient-noise-scale estimation with Eq. (3) bounds.
"""

from repro.ola_ml.verify import IngestGate
from repro.ola_ml.eval_ola import ola_eval

__all__ = ["IngestGate", "ola_eval"]
