"""Gradient-noise-scale estimation with bi-level confidence bounds.

The critical-batch-size heuristic (McCandlish et al. 2018) needs
``B_simple = tr(Σ) / |G|²`` — both terms are population aggregates over
examples, so they are exactly OLA estimands: microbatches are *chunks*
(cheap to evaluate together), examples are *tuples*.  We estimate
``E[|g_b|²]`` at two batch sizes with Eq. (1)/(3) bounds and solve for the
noise scale, stopping when both CIs are tight.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

import jax.numpy as jnp

from repro.core import estimators as est


@dataclasses.dataclass
class NoiseScaleResult:
    b_simple: float
    lo: float
    hi: float
    gnorm_small: float
    gnorm_big: float
    batches_used: int


def estimate_noise_scale(gnorm_fn: Callable[[int, int], float],
                         b_small: int, b_big: int, num_chunks: int = 16,
                         probes_per_chunk: int = 4, epsilon: float = 0.2,
                         confidence: float = 0.9, seed: int = 0
                         ) -> NoiseScaleResult:
    """``gnorm_fn(batch_size, seed) -> |g|²`` on a fresh batch.

    Treats probe groups as chunks (bi-level: groups × probes) so the Eq. (3)
    machinery provides the CI; unbiased |G|² from the two-point identity
    |G|² = (B_b·E|g_b|² − B_s·E|g_s|²) / (B_b − B_s).
    """
    sizes = jnp.full((num_chunks,), probes_per_chunk, jnp.int32)
    stats_s = est.init_stats(sizes, dtype=jnp.float32)
    stats_b = est.init_stats(sizes, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    used = 0
    res = None
    for j in range(num_chunks):
        for _ in range(probes_per_chunk):
            gs = float(gnorm_fn(b_small, int(rng.integers(1 << 30))))
            gb = float(gnorm_fn(b_big, int(rng.integers(1 << 30))))
            used += 1
            stats_s = stats_s._replace(
                m=stats_s.m.at[j].add(1), ysum=stats_s.ysum.at[j].add(gs),
                ysq=stats_s.ysq.at[j].add(gs * gs),
                psum=stats_s.psum.at[j].add(1.0))
            stats_b = stats_b._replace(
                m=stats_b.m.at[j].add(1), ysum=stats_b.ysum.at[j].add(gb),
                ysq=stats_b.ysq.at[j].add(gb * gb),
                psum=stats_b.psum.at[j].add(1.0))
        if j < 1:
            continue
        es, vs, ok_s = est.avg_estimate(stats_s)
        eb, vb, ok_b = est.avg_estimate(stats_b)
        g2 = (b_big * float(eb) - b_small * float(es)) / (b_big - b_small)
        tr_sigma = ((float(es) - float(eb))
                    / (1.0 / b_small - 1.0 / b_big))
        b_simple = tr_sigma / max(g2, 1e-12)
        # delta-method CI on the ratio via endpoint propagation
        los, his = est.confidence_bounds(es, vs, confidence)
        lob, hib = est.confidence_bounds(eb, vb, confidence)
        cands = []
        for a in (float(los), float(his)):
            for b in (float(lob), float(hib)):
                g2c = (b_big * b - b_small * a) / (b_big - b_small)
                trc = (a - b) / (1.0 / b_small - 1.0 / b_big)
                if g2c > 0:
                    cands.append(trc / g2c)
        lo, hi = (min(cands), max(cands)) if cands else (-np.inf, np.inf)
        res = NoiseScaleResult(b_simple=b_simple, lo=lo, hi=hi,
                               gnorm_small=float(es), gnorm_big=float(eb),
                               batches_used=used)
        if bool(ok_s) and bool(ok_b) and hi - lo <= epsilon * abs(b_simple):
            return res
    return res
