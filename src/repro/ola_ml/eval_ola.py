"""OLA-based distributed evaluation with early termination.

Estimating a validation metric to ±ε is a SUM/COUNT query over eval shards:
shards are *chunks* (scheduled in a committed random order — the engine's
no-inspection-paradox queue matters here because shard eval time correlates
with content length), and per-example losses are *tuples*.  Bi-level
sampling stops the eval as soon as the CI is tight enough — typically a
small fraction of the eval set for loss-scale metrics.

This reuses Eq. (1)/(3) directly on model outputs: the per-chunk sufficient
statistics come from batched forward passes instead of raw-byte EXTRACT.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

import jax.numpy as jnp

from repro.core import estimators as est
from repro.sampling.permutation import chunk_seed, feistel_permute, random_chunk_order


@dataclasses.dataclass
class OlaEvalResult:
    estimate: float          # mean metric over the eval set
    lo: float
    hi: float
    error_ratio: float
    shards_used: int
    examples_used: int
    total_examples: int


def ola_eval(metric_fn: Callable[[np.ndarray], np.ndarray],
             shards: list, epsilon: float = 0.02, confidence: float = 0.95,
             batch: int = 64, seed: int = 0,
             max_examples: Optional[int] = None) -> OlaEvalResult:
    """``metric_fn(examples) -> per-example metric``; ``shards`` is a list of
    example arrays (leading dim = examples).  Returns the ε-accurate mean.

    Shards are visited in a committed random order; inside a shard examples
    follow the shard's keyed permutation in ``batch``-sized rounds (the
    engine's budget analog).  Stops when the AVG ratio-estimator CI meets ε.
    """
    n = len(shards)
    sizes = np.asarray([len(s) for s in shards], np.int64)
    order = random_chunk_order(seed, n)
    stats = est.init_stats(jnp.asarray(sizes, jnp.int32), dtype=jnp.float32)

    used = 0
    shards_used = 0
    offset = np.zeros(n, np.int64)
    result = None
    for pos in range(n):
        j = int(order[pos])
        shards_used += 1
        mj = int(sizes[j])
        key = chunk_seed(seed, j)
        while offset[j] < mj:
            take = min(batch, mj - int(offset[j]))
            idx = np.asarray(feistel_permute(
                key, jnp.arange(offset[j], offset[j] + take), mj))
            vals = np.asarray(metric_fn(shards[j][idx]), np.float64)
            offset[j] += take
            used += take
            stats = stats._replace(
                m=stats.m.at[j].add(take),
                ysum=stats.ysum.at[j].add(vals.sum()),
                ysq=stats.ysq.at[j].add((vals ** 2).sum()),
                psum=stats.psum.at[j].add(float(take)))
            r, v, ok = est.avg_estimate(stats)
            lo, hi = est.confidence_bounds(r, v, confidence)
            err = float(est.error_ratio(r, lo, hi))
            result = OlaEvalResult(
                estimate=float(r), lo=float(lo), hi=float(hi),
                error_ratio=err, shards_used=shards_used,
                examples_used=used, total_examples=int(sizes.sum()))
            if bool(ok) and err <= epsilon and shards_used >= 2:
                return result
            if max_examples and used >= max_examples:
                return result
            # local accuracy met for this shard? move to the next (Theorem 3)
            if _local_ok(stats, j, epsilon, confidence):
                break
    return result


def _local_ok(stats, j, epsilon, confidence) -> bool:
    import jax

    m = float(stats.m[j])
    big_m = float(stats.M[j])
    if m < 2:
        return False
    if m >= big_m:
        return True
    ys = float(stats.ysum[j])
    yq = float(stats.ysq[j])
    ss = max(yq - ys * ys / m, 0.0)
    v = (big_m / m) * (big_m - m) / (m - 1.0) * ss
    z = float(jax.scipy.special.ndtri((1 + confidence) / 2))
    yhat = big_m / m * ys
    return 2 * z * np.sqrt(v) <= epsilon * max(abs(yhat), 1e-12)
