"""Ingest verification gate: the paper's PTF workflow on training data.

Before the trainer consumes a corpus segment it runs the verification-query
sequence over the segment's raw metadata table with the OLA engine.  Queries
stop as soon as the HAVING predicate is decidable from the confidence bounds
(often after sampling a few % of the rows) — exactly the batch-verification
use-case of the paper's Section 1, with TPU-hours instead of PostgreSQL
load-hours as the resource being protected.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.controller import EstimationController, QueryResult
from repro.core.engine import EngineConfig
from repro.core.queries import Query


@dataclasses.dataclass
class GateDecision:
    admitted: bool
    results: list          # per-query QueryResult
    tuples_ratio: float    # fraction of metadata rows actually extracted
    failed_query: str = ""


class IngestGate:
    def __init__(self, queries: Sequence[Query],
                 config: EngineConfig = EngineConfig(num_workers=4,
                                                     strategy="resource_aware"),
                 synopsis_budget_tuples: int = 0):
        self.queries = list(queries)
        self.config = config
        self.synopsis_budget = synopsis_budget_tuples

    def check(self, meta_store) -> GateDecision:
        ctrl = EstimationController(
            meta_store, self.config,
            synopsis_budget_tuples=self.synopsis_budget)
        results = ctrl.run_verification(self.queries)
        admitted = len(results) == len(self.queries)
        failed = ""
        for q, r in zip(self.queries, results):
            verdict = int(r.decisions[0])
            ok = verdict == 1 or (verdict == -1 and _exact_pass(q, r))
            if not ok:
                admitted = False
                failed = q.name
                break
        ratio = (sum(r.tuples_ratio for r in results) / max(len(results), 1))
        return GateDecision(admitted=admitted, results=results,
                            tuples_ratio=ratio, failed_query=failed)


def _exact_pass(q: Query, r: QueryResult) -> bool:
    est = float(r.final_estimate[0])
    t = q.having.threshold
    return {"<": est < t, "<=": est <= t, ">": est > t, ">=": est >= t}[q.having.op]
