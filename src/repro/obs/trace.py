"""Nested span tracer with chrome-trace (Perfetto) export — zero deps.

The OLA query lifecycle is a pipeline the user is supposed to *watch*:
submit → admission decision → per-round (claims, kernel, merge, estimate)
→ retire, with the scan plane's READ / prefetch overlap running underneath
on the reader thread.  :class:`SpanTracer` records that shape as nested
spans and exports the standard chrome-trace JSON (``traceEvents`` with
complete ``"X"`` events), which https://ui.perfetto.dev or
``chrome://tracing`` open directly.

Design constraints, in order:

* **host-side only** — span boundaries wrap host calls (slab assembly, the
  jitted round dispatch, report reads); nothing jit-visible changes, so a
  traced run is round-for-round bit-exact with an untraced one;
* **allocation-light off** — the off state is :data:`NULL_TRACER`, whose
  ``span()`` returns one shared no-op context manager: the cost of
  disabled tracing is a method call, not an object graph;
* **deterministic in tests** — the clock is injected (``clock=`` any
  zero-arg callable returning seconds); a counter clock makes every
  timestamp and duration reproducible;
* **thread-safe** — the prefetcher's reader thread emits READ spans
  concurrently with the server loop; events carry a small per-thread tid
  and appends are lock-protected.  Span *nesting* state is thread-local,
  so cross-thread interleavings can never corrupt a stack;
* **bounded** — at ``max_events`` the tracer stops recording and counts
  drops (``dropped``) instead of growing without bound; the exporter
  stamps the drop count into the trace metadata rather than truncating
  silently.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Callable, Optional


class _NullSpan:
    """Shared no-op context manager: the entire cost of disabled tracing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: every call is a no-op returning shared objects."""

    enabled = False

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name: str, **args) -> None:
        return None


#: Module-level singleton — engines and pipelines default their ``tracer``
#: attribute to this so call sites never need a None check.
NULL_TRACER = NullTracer()


class _Span:
    __slots__ = ("tracer", "name", "args", "t0", "depth")

    def __init__(self, tracer: "SpanTracer", name: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        tr = self.tracer
        stack = tr._stack()
        self.depth = len(stack)
        stack.append(self)
        self.t0 = tr.clock()
        return self

    def __exit__(self, *exc):
        tr = self.tracer
        t1 = tr.clock()
        tr._stack().pop()
        tr._record(self.name, self.t0, t1 - self.t0, self.depth, self.args)
        return False


class SpanTracer:
    """Span recorder (see module docstring).

    ``clock`` must be monotone (defaults to :func:`time.perf_counter`);
    timestamps are recorded relative to the tracer's construction so the
    exported trace starts near zero.
    """

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 max_events: int = 1_000_000):
        self.clock = clock if clock is not None else time.perf_counter
        self.max_events = int(max_events)
        self.events: list[tuple] = []   # (name, ts, dur, tid, depth, args)
        self.dropped = 0
        self._t0 = self.clock()
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: dict[int, int] = {}   # thread ident -> small stable tid

    # ------------------------------------------------------------ record ----
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    def _record(self, name: str, t0: float, dur: float, depth: int,
                args: dict) -> None:
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
                return
            self.events.append(
                (name, t0 - self._t0, max(dur, 0.0), self._tid(), depth,
                 args))

    def span(self, name: str, **args) -> _Span:
        """Context manager timing a nested span; ``args`` become the
        event's chrome-trace args payload (keep them small scalars)."""
        return _Span(self, name, args)

    def event(self, name: str, **args) -> None:
        """Instantaneous event (duration 0) at the current clock."""
        self._record(name, self.clock(), 0.0, len(self._stack()), args)

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.dropped = 0
            self._t0 = self.clock()

    # ------------------------------------------------------------ export ----
    def to_chrome_trace(self, process_name: str = "ola-server") -> dict:
        """Chrome-trace JSON object: complete ``"X"`` events in
        microseconds, one chrome 'thread' per real thread (tid 0 is the
        server loop, higher tids are reader threads)."""
        with self._lock:
            events = list(self.events)
            dropped = self.dropped
            tids = dict(self._tids)
        out = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": process_name},
        }]
        for ident, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            out.append({
                "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                "args": {"name": "server-loop" if tid == 0
                         else f"reader-{tid}"},
            })
        for name, ts, dur, tid, depth, args in events:
            ev = {"name": name, "ph": "X", "pid": 0, "tid": tid,
                  "ts": ts * 1e6, "dur": dur * 1e6, "cat": "ola"}
            if args or depth:
                ev["args"] = dict(args, depth=depth) if depth else dict(args)
            out.append(ev)
        doc = {"traceEvents": out, "displayTimeUnit": "ms"}
        if dropped:
            doc["otherData"] = {"dropped_events": dropped}
        return doc

    def save(self, path: str, process_name: str = "ola-server") -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(process_name), f)


def validate_chrome_trace(doc: dict) -> list[str]:
    """Schema/consistency check for an exported chrome trace; returns the
    list of problems (empty = valid).  Checks: ``traceEvents`` is a list
    of well-formed events, durations are non-negative and finite, and the
    ``"X"`` spans of each (pid, tid) nest properly — every span is either
    disjoint from or fully contained in any span it overlaps (the
    invariant a stack-shaped tracer must produce).  The CI observability
    smoke step runs this over the workload bench's trace."""
    problems: list[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    spans: dict[tuple, list] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"event {i}: missing name")
        if ph not in ("X", "M", "B", "E", "i", "I"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph != "X":
            continue
        ts, dur = ev.get("ts"), ev.get("dur")
        if not isinstance(ts, (int, float)) or ts != ts:
            problems.append(f"event {i} ({ev.get('name')}): bad ts {ts!r}")
            continue
        if (not isinstance(dur, (int, float)) or dur != dur
                or dur < 0 or dur == float("inf")):
            problems.append(
                f"event {i} ({ev.get('name')}): bad duration {dur!r}")
            continue
        spans.setdefault((ev.get("pid", 0), ev.get("tid", 0)), []).append(
            (float(ts), float(ts) + float(dur), ev.get("name", "")))
    for key, ss in spans.items():
        # sort by start asc, end desc: a parent sorts before its children
        ss.sort(key=lambda s: (s[0], -s[1]))
        stack: list = []
        for t0, t1, name in ss:
            while stack and t0 >= stack[-1][1]:
                stack.pop()
            if stack and t1 > stack[-1][1] + 1e-9:
                problems.append(
                    f"tid {key}: span {name!r} [{t0}, {t1}] overlaps "
                    f"{stack[-1][2]!r} [{stack[-1][0]}, {stack[-1][1]}] "
                    "without nesting")
                continue
            stack.append((t0, t1, name))
    return problems
