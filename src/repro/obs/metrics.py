"""Zero-dependency metrics registry: counters, gauges, bounded histograms.

Today's telemetry lives as ad-hoc integer attributes scattered across the
stack — prefetcher read/cache counters on :class:`~repro.data.pipeline.
SlabPrefetcher`, decoded-cache hit/evict totals, rollup tier hit/promotion
counts, scheduler outcome tallies on the server, quarantine history on the
engine.  :class:`MetricsRegistry` is the one place they all surface:

* **Counter** — monotone count (``inc``);
* **Gauge** — instantaneous value (``set``), or a *pull* gauge built with
  ``fn=`` whose value is read from a callback at export time — the
  mechanism the server uses to absorb the existing scattered attributes
  without adding a single write to any hot path;
* **Histogram** — bounded fixed-bucket distribution (``observe``), with
  cumulative Prometheus semantics in the text exposition.

Exports: :meth:`MetricsRegistry.snapshot` (plain JSON-able dict — the
``OLAWorkloadServer.metrics_snapshot()`` payload) and
:meth:`MetricsRegistry.to_prometheus` (text exposition format, scrapeable
by anything Prometheus-compatible).  No third-party imports anywhere.

Instruments are identified by ``(name, labels)``: registering the same
identity twice returns the existing instrument (idempotent — safe to call
from ``__init__`` paths that may run more than once).
"""

from __future__ import annotations

import math
from typing import Callable, Optional, Sequence


def _fmt_value(v: float) -> str:
    """Prometheus float formatting: integers render without the dot."""
    f = float(v)
    if math.isfinite(f) and f == int(f) and abs(f) < 1e15:
        return str(int(f))
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    """Monotone counter.  ``inc`` only; negative increments are rejected
    (a counter that can go down is a gauge)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[dict] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative inc {n}")
        self.value += n

    def get(self) -> float:
        return self.value


class Gauge:
    """Instantaneous value.  With ``fn`` the gauge is *pull-based*: its
    value is whatever the callback returns at read time — the adapter that
    lets the registry absorb pre-existing counters (prefetcher attributes,
    rollup tallies) with zero hot-path writes.  A callback that raises is
    reported as NaN rather than poisoning the whole export."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[dict] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.fn = fn
        self._value = 0.0

    def set(self, v: float) -> None:
        if self.fn is not None:
            raise ValueError(f"gauge {self.name} is pull-based (fn=...)")
        self._value = float(v)

    def get(self) -> float:
        if self.fn is None:
            return self._value
        try:
            return float(self.fn())
        except Exception:
            return float("nan")


class Histogram:
    """Bounded fixed-bucket histogram: ``bounds`` are the upper edges of
    the finite buckets (ascending); everything above the last bound lands
    in the implicit +Inf bucket.  Memory is O(len(bounds)) forever —
    bounded by construction, never by sampling."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 bounds: Sequence[float] = (),
                 labels: Optional[dict] = None):
        bs = tuple(float(b) for b in bounds)
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram {name}: bounds must be strictly "
                             f"ascending, got {bs}")
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.bounds = bs
        self.counts = [0] * (len(bs) + 1)   # last = +Inf overflow
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        i = len(self.bounds)
        for k, b in enumerate(self.bounds):
            if v <= b:
                i = k
                break
        self.counts[i] += 1
        self.total += 1
        self.sum += v

    def get(self) -> dict:
        return {"buckets": {(_fmt_value(b)): c for b, c in
                            zip(self.bounds, self.counts)},
                "overflow": self.counts[-1],
                "count": self.total, "sum": self.sum}


#: Default latency buckets (modeled seconds): spans the smoke workloads'
#: sub-millisecond tier-1 answers up through multi-scan residencies.
LATENCY_BUCKETS_S = (1e-5, 1e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0,
                     3.0, 10.0, 30.0)


class MetricsRegistry:
    """Instrument factory + exporter (see module docstring)."""

    def __init__(self):
        self._metrics: dict[tuple, object] = {}

    def __len__(self) -> int:
        return len(self._metrics)

    def _get_or_make(self, cls, name, help, labels, **kw):
        key = (name, tuple(sorted((labels or {}).items())))
        m = self._metrics.get(key)
        if m is not None:
            if not isinstance(m, cls):
                raise ValueError(
                    f"{name}{_label_str(dict(labels or {}))} already "
                    f"registered as {type(m).__name__}")
            return m
        m = cls(name, help=help, labels=labels, **kw)
        self._metrics[key] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: Optional[dict] = None) -> Counter:
        return self._get_or_make(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[dict] = None,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get_or_make(Gauge, name, help, labels, fn=fn)
        if fn is not None:
            g.fn = fn   # re-binding a pull gauge retargets the callback
        return g

    def histogram(self, name: str, help: str = "",
                  bounds: Sequence[float] = LATENCY_BUCKETS_S,
                  labels: Optional[dict] = None) -> Histogram:
        return self._get_or_make(Histogram, name, help, labels,
                                 bounds=bounds)

    def unregister(self, name: str, labels: Optional[dict] = None) -> bool:
        """Drop one instrument (e.g. a pull gauge whose source object is
        being replaced); True when something was removed."""
        key = (name, tuple(sorted((labels or {}).items())))
        return self._metrics.pop(key, None) is not None

    # ----------------------------------------------------------- export ----
    def snapshot(self) -> dict:
        """JSON-able snapshot: ``{name[.labels]: value}`` for counters and
        gauges, the bucket dict for histograms.  Pull gauges are evaluated
        here — this is the moment scattered source counters are read."""
        out = {}
        for (name, labels), m in sorted(self._metrics.items()):
            key = name + _label_str(dict(labels))
            out[key] = m.get()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4), dependency-free."""
        by_name: dict[str, list] = {}
        for (_, _), m in sorted(self._metrics.items()):
            by_name.setdefault(m.name, []).append(m)
        lines = []
        for name, ms in by_name.items():
            m0 = ms[0]
            if m0.help:
                lines.append(f"# HELP {name} {m0.help}")
            lines.append(f"# TYPE {name} {m0.kind}")
            for m in ms:
                ls = _label_str(m.labels)
                if isinstance(m, Histogram):
                    cum = 0
                    for b, c in zip(m.bounds, m.counts):
                        cum += c
                        le = dict(m.labels, le=_fmt_value(b))
                        lines.append(f"{name}_bucket{_label_str(le)} {cum}")
                    le = dict(m.labels, le="+Inf")
                    lines.append(
                        f"{name}_bucket{_label_str(le)} {m.total}")
                    lines.append(f"{name}_sum{ls} {_fmt_value(m.sum)}")
                    lines.append(f"{name}_count{ls} {m.total}")
                else:
                    lines.append(f"{name}{ls} {_fmt_value(m.get())}")
        return "\n".join(lines) + ("\n" if lines else "")
