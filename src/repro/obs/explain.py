"""Per-query explain records: "why did my query do that", in one structure.

Every :class:`~repro.serve.ola_server.WorkloadResult` carries an
:class:`ExplainRecord` assembled over the query's lifecycle:

* **admission** — the Eq. (4) full-pass cost terms the triage priced the
  scan with (``cost_t_io_s`` / ``cost_t_cpu_s``, decoded-cache discount
  included), the plan the selector chose, and the scheduler's decision
  with its reason string (``admitted`` / ``queued`` / ``shed`` /
  ``tier1``) and service/finish predictions;
* **tier routing** — which tier answered (``scan``, ``tier1`` rollup
  cell, ``synopsis`` seed, ``shed`` best-effort) and why;
* **trajectory** — one :class:`RoundSample` per resident round: the
  slot's cumulative sample size ``m``, running estimate, CI half-width,
  the round's effective per-worker budget ``b_eff`` (the budget-ladder
  value scaled by the slot's fairness weight) and the weight itself —
  the estimate/CI convergence curve the OLA literature treats as the
  primary UX artifact.  The buffer is bounded: past ``max_samples`` the
  trajectory thins itself to every 2nd (4th, 8th, ...) round, keeping
  endpoints, so a census-length residency cannot grow a result without
  bound;
* **degradation** — quarantine events that struck while the query was
  resident (the population its final answer describes shrank);
* **final answer** — ``final_estimate`` / ``final_ci_halfwidth``, set at
  retirement from the *same floats* the result reports: bit-for-bit
  equal to ``result.estimate`` / ``result.halfwidth`` by construction.

Everything here is host-side bookkeeping over values the server already
holds; nothing reaches back into the engine.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RoundSample:
    """One resident round of one query's convergence trajectory."""

    round: int              # server round index (global, monotone)
    m: int                  # cumulative slot sample size (tuples)
    est: float              # running estimate
    ci_halfwidth: float     # (hi - lo) / 2 at this round
    b_eff: int              # effective per-worker budget this round
    weight: float           # fairness weight applied this round
    # grouped queries only: per-cell ``(value, est, ci_halfwidth)`` triples —
    # tracked cells in discovery order, then the ``__other__`` spill cell
    # (value NaN).  Plain floats, so the record stays serializable and this
    # module stays free of engine imports.  None for ungrouped rounds.
    groups: Optional[tuple] = None


@dataclasses.dataclass
class ExplainRecord:
    """Lifecycle explain for one query (see module docstring)."""

    qid: int
    name: str
    t_submit: float
    # --- admission ---
    plan: str = ""
    sched_outcome: str = ""
    admission_reason: str = ""
    predicted_service_s: Optional[float] = None
    predicted_finish_t: Optional[float] = None
    cost_t_io_s: Optional[float] = None      # Eq. (4) full-pass READ seconds
    cost_t_cpu_s: Optional[float] = None     # Eq. (4) full-pass EXTRACT seconds
    decoded_fraction: float = 0.0            # CPU-discount input at admission
    effective_epsilon: Optional[float] = None
    # --- tier routing ---
    tier: str = ""                           # scan | tier1 | synopsis | shed
    tier_reason: str = ""
    seeded_tuples: int = 0
    # --- trajectory / degradation ---
    trajectory: list = dataclasses.field(default_factory=list)
    degradation: list = dataclasses.field(default_factory=list)
    # --- timing + final answer (set at retirement) ---
    t_admit: Optional[float] = None
    t_done: Optional[float] = None
    rounds_resident: int = 0
    final_estimate: Optional[float] = None
    final_ci_halfwidth: Optional[float] = None

    #: Trajectory length bound; beyond it the record thins to every
    #: 2nd/4th/... round (class-level knob, deliberately not per-instance).
    max_samples = 4096

    _stride: int = dataclasses.field(default=1, repr=False)
    _seen: int = dataclasses.field(default=0, repr=False)

    # -------------------------------------------------------- lifecycle ----
    def record_round(self, sample: RoundSample) -> None:
        """Append one resident round, thinning past ``max_samples``."""
        self._seen += 1
        if (self._seen - 1) % self._stride:
            return
        self.trajectory.append(sample)
        if len(self.trajectory) >= self.max_samples:
            self.trajectory = self.trajectory[::2]
            self._stride *= 2

    def record_degradation(self, *, round: int, t: float,
                           chunk_ids: list) -> None:
        self.degradation.append({
            "round": int(round), "t": float(t),
            "chunk_ids": [int(j) for j in chunk_ids]})

    def finalize(self, result) -> "ExplainRecord":
        """Stamp retirement facts from the completed
        :class:`~repro.serve.ola_server.WorkloadResult` — the final
        estimate/CI are copied from the result's own floats, so equality
        is bit-for-bit by construction."""
        self.plan = result.plan
        self.sched_outcome = result.sched_outcome
        self.t_admit = result.t_admit
        self.t_done = result.t_done
        self.rounds_resident = result.rounds_resident
        self.seeded_tuples = result.seeded_tuples
        self.final_estimate = result.estimate
        self.final_ci_halfwidth = result.halfwidth
        if not self.tier:
            self.tier = ("tier1" if result.sched_outcome == "tier1" else
                         "shed" if result.sched_outcome == "shed" else
                         "synopsis" if result.from_synopsis else "scan")
        return self

    # ----------------------------------------------------------- export ----
    def to_dict(self) -> dict:
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self)
               if not f.name.startswith("_")}
        out["trajectory"] = [dataclasses.asdict(s) for s in self.trajectory]
        return out
