"""Observability: metrics registry, span tracer, per-query explain plane.

Zero third-party dependencies.  ``repro.obs`` imports nothing from the
rest of ``repro``, so any layer (data plane, engine, server, benches) can
depend on it without cycles.
"""

from repro.obs.explain import ExplainRecord, RoundSample
from repro.obs.metrics import (
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import (
    NULL_TRACER,
    NullTracer,
    SpanTracer,
    validate_chrome_trace,
)

__all__ = [
    "Counter",
    "ExplainRecord",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "RoundSample",
    "SpanTracer",
    "validate_chrome_trace",
]
