"""repro: OLA-RAW (Cheng, Zhao, Rusu 2017) as a production JAX/TPU framework.

Subpackages: core (the paper's engine), sampling, data, kernels (Pallas),
models, configs, distributed, train, serve, ola_ml, launch, roofline.
See README.md and DESIGN.md.
"""

__version__ = "1.0.0"
