"""Bi-level round hot-loop as a Pallas kernel: fused parse + eval + masked stats.

One engine round extracts, per worker, the next ``b`` tuples of its chunk in
permutation order.  The gather of scattered raw rows happens HBM-side (an XLA
gather — random access is inherent to sampling, exactly as in the paper's
in-memory shuffle); this kernel then fuses everything downstream of the
gather: parse, multi-query predicate/expression evaluation, and the
budget-masked partial statistics ``(m, y', y'', p')`` that feed Eq. (1)/(3).

Grid ``(W,)`` — one step per worker; blocks: slab ``(1, B, rec)`` uint8,
budget scalar, plan ``(Q, C)`` triple, out ``(1, Q, 4)`` f32.  B=budget is a
power of two from the engine's t_eval ladder, so block shapes are stable
across rounds and recompiles are bounded.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.data.formats import FIELD_BYTES
from repro.kernels.chunk_agg import _eval_plan_block
from repro.kernels.extract_parse import _parse_block


def _round_stats_kernel(slab_ref, beff_ref, coeffs_ref, lo_ref, hi_ref,
                        out_ref, *, num_cols: int):
    raw = slab_ref[0].astype(jnp.int32)                      # (B, rec)
    vals = _parse_block(raw, num_cols)                       # (B, C)
    x, p = _eval_plan_block(vals, coeffs_ref[...], lo_ref[...], hi_ref[...])
    b = vals.shape[0]
    ok = (jax.lax.iota(jnp.int32, b) < beff_ref[0]).astype(jnp.float32)
    x = x * ok[None, :]
    p = p * ok[None, :]
    out_ref[0] = jnp.stack([
        jnp.broadcast_to(jnp.sum(ok), (x.shape[0],)),
        jnp.sum(x, -1), jnp.sum(x * x, -1), jnp.sum(p, -1)], axis=-1)


@functools.partial(jax.jit, static_argnames=("num_cols", "interpret"))
def round_stats_pallas(slab: jnp.ndarray, b_eff: jnp.ndarray, coeffs, lo, hi,
                       num_cols: int, interpret: bool = False) -> jnp.ndarray:
    """slab (W, B, rec) uint8, b_eff (W,) int32 -> (W, Q, 4) f32."""
    w, b, rec = slab.shape
    assert rec == num_cols * FIELD_BYTES
    q = coeffs.shape[0]
    return pl.pallas_call(
        functools.partial(_round_stats_kernel, num_cols=num_cols),
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, b, rec), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((q, num_cols), lambda i: (0, 0)),
            pl.BlockSpec((q, num_cols), lambda i: (0, 0)),
            pl.BlockSpec((q, num_cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, 4), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((w, q, 4), jnp.float32),
        interpret=interpret,
    )(slab, b_eff, coeffs, lo, hi)
