"""Jitted public wrappers with platform dispatch for the kernels package.

``backend`` semantics:

* ``"auto"``    — Pallas on TPU, pure-jnp oracle elsewhere (production default:
                  the oracle compiles to decent XLA:CPU code, while
                  ``interpret=True`` is a debugging interpreter).
* ``"pallas"``  — force pallas_call; on CPU this sets ``interpret=True``
                  (used by the correctness sweeps in tests/).
* ``"pallas-interpret"`` — force the Pallas interpreter even on TPU (the
                  benchmarks' correctness-mode lane).
* ``"ref"``     — force the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.chunk_agg import chunk_agg_pallas
from repro.kernels.extract_parse import extract_parse_pallas
from repro.kernels.round_stats import round_stats_pallas
from repro.kernels.slot_extract import (
    slot_eval_decoded_pallas,
    slot_extract_grouped_pallas,
    slot_extract_pallas,
    slot_extract_stream_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    if backend == "auto":
        return (_on_tpu(), False)
    if backend == "pallas":
        return (True, not _on_tpu())
    if backend == "pallas-interpret":
        return (True, True)
    if backend == "ref":
        return (False, False)
    raise ValueError(backend)


def extract_parse(raw: jnp.ndarray, num_cols: int,
                  backend: str = "auto") -> jnp.ndarray:
    """(T, rec_bytes) uint8 fixed-width ASCII -> (T, C) f32."""
    use_pallas, interpret = _resolve(backend)
    if use_pallas:
        return extract_parse_pallas(raw, num_cols, interpret=interpret)
    return _ref.parse_ascii_ref(raw, num_cols)


def chunk_agg(raw: jnp.ndarray, sizes: jnp.ndarray, coeffs, lo, hi,
              backend: str = "auto") -> jnp.ndarray:
    """(N, M, rec) uint8 + plan -> (N, Q, 4) per-chunk (count, Σx, Σx², Σp)."""
    num_cols = int(coeffs.shape[1])
    use_pallas, interpret = _resolve(backend)
    if use_pallas:
        return chunk_agg_pallas(raw, jnp.asarray(sizes, jnp.int32),
                                jnp.asarray(coeffs, jnp.float32),
                                jnp.asarray(lo, jnp.float32),
                                jnp.asarray(hi, jnp.float32),
                                num_cols=num_cols, interpret=interpret)
    return _ref.chunk_agg_ref(raw, num_cols, jnp.asarray(coeffs, jnp.float32),
                              jnp.asarray(lo, jnp.float32),
                              jnp.asarray(hi, jnp.float32),
                              jnp.asarray(sizes, jnp.int32))


def slot_extract(packed: jnp.ndarray, jw: jnp.ndarray, idx: jnp.ndarray,
                 b_eff: jnp.ndarray, coeffs, lo, hi, is_count, gate,
                 return_cols: bool = False, backend: str = "auto",
                 weights=None, gcol=None, gval=None, gact=None, salt=None,
                 tally_buckets: int = _ref.TALLY_BUCKETS):
    """Fused round extraction: gather + parse + slot eval + partial stats.

    packed (N, M, rec) uint8, jw (W,) chunk ids, idx (W, B) window rows ->
    (stats (W, S, 4), cols (W, B, C) | None).  This is the engine round's
    ``extract_backend="pallas"`` path (see core/engine.py).

    Passing the grouped-plane descriptors (``gcol (S,)`` int32, ``gval``/
    ``gact (S, G)`` f32, ``salt`` uint32 round number) switches to the
    grouped variant, which additionally returns per-cell partial stats
    ``(W, S, G, 4)`` and salted group tallies ``(W, S, 3, H)``:
    ``(stats, cols|None, gstats, tal)``.
    """
    num_cols = int(coeffs.shape[1])
    use_pallas, interpret = _resolve(backend)
    jw, idx, b_eff = (jnp.asarray(jw, jnp.int32), jnp.asarray(idx, jnp.int32),
                      jnp.asarray(b_eff, jnp.int32))
    coeffs, lo, hi, is_count, gate = (
        jnp.asarray(a, jnp.float32) for a in (coeffs, lo, hi, is_count, gate))
    if weights is None:
        weights = jnp.ones((coeffs.shape[0],), jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    grouped = gval is not None and int(gval.shape[1]) > 0
    if grouped:
        gcol = jnp.asarray(gcol, jnp.int32)
        gval = jnp.asarray(gval, jnp.float32)
        gact = jnp.asarray(gact, jnp.float32)
        salt = (jnp.asarray(0, jnp.uint32) if salt is None
                else jnp.asarray(salt, jnp.uint32))
        if use_pallas:
            return slot_extract_grouped_pallas(
                packed, jw, idx, b_eff, coeffs, lo, hi, is_count, gate,
                weights, gcol, gval, gact, salt, num_cols=num_cols,
                tally_buckets=tally_buckets, return_cols=return_cols,
                interpret=interpret)
        return _ref.slot_extract_grouped_ref(
            packed, jw, idx, b_eff, coeffs, lo, hi, is_count, gate,
            gcol, gval, gact, salt, num_cols=num_cols,
            tally_buckets=tally_buckets, return_cols=return_cols,
            weights=weights)
    if use_pallas:
        return slot_extract_pallas(packed, jw, idx, b_eff, coeffs, lo, hi,
                                   is_count, gate, weights,
                                   num_cols=num_cols,
                                   return_cols=return_cols,
                                   interpret=interpret)
    return _ref.slot_extract_ref(packed, jw, idx, b_eff, coeffs, lo, hi,
                                 is_count, gate, num_cols=num_cols,
                                 return_cols=return_cols, weights=weights)


def slot_extract_stream(slab: jnp.ndarray, idx: jnp.ndarray,
                        b_eff: jnp.ndarray, coeffs, lo, hi, is_count, gate,
                        row_tile: int = 256, backend: str = "auto",
                        weights=None, cache_cap: int = 0, m_before=None):
    """Slab-streaming fused round extraction (``residency="stream"``).

    slab (W, R, rec) uint8 — worker w's chunk rows at slab[w] (assembled by
    ``data/pipeline.SlabPrefetcher``), idx (W, B) window rows, b_eff (W,) ->
    stats (W, S, 4).  Unlike :func:`slot_extract` the kernel grids over row
    *tiles* of the slab, so chunks larger than VMEM stream tile-by-tile.

    ``cache_cap > 0`` additionally returns the synopsis-cache delta rows
    ``(W, cache_cap, C)`` at scan positions ``m_before`` — the streaming
    path's replacement for re-decoding the whole window just to feed the
    cache: the call then returns ``(stats, cache_rows)``.
    """
    num_cols = int(coeffs.shape[1])
    use_pallas, interpret = _resolve(backend)
    idx, b_eff = jnp.asarray(idx, jnp.int32), jnp.asarray(b_eff, jnp.int32)
    coeffs, lo, hi, is_count, gate = (
        jnp.asarray(a, jnp.float32) for a in (coeffs, lo, hi, is_count, gate))
    if weights is None:
        weights = jnp.ones((coeffs.shape[0],), jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    if m_before is not None:
        m_before = jnp.asarray(m_before, jnp.int32)
    if use_pallas:
        return slot_extract_stream_pallas(slab, idx, b_eff, coeffs, lo, hi,
                                          is_count, gate, weights,
                                          num_cols=num_cols,
                                          row_tile=row_tile,
                                          cache_cap=cache_cap,
                                          m_before=m_before,
                                          interpret=interpret)
    stats = _ref.slot_extract_stream_ref(slab, idx, b_eff, coeffs, lo, hi,
                                         is_count, gate, num_cols=num_cols,
                                         weights=weights)
    if cache_cap > 0:
        if m_before is None:
            m_before = jnp.zeros((idx.shape[0],), jnp.int32)
        return stats, _ref.stream_cache_rows_ref(slab, idx, b_eff, m_before,
                                                 cache_cap, num_cols)
    return stats


def slot_eval_decoded(dec: jnp.ndarray, idx: jnp.ndarray, b_eff: jnp.ndarray,
                      coeffs, lo, hi, is_count, gate, row_tile: int = 256,
                      backend: str = "auto", weights=None, cache_cap: int = 0,
                      m_before=None):
    """Decoded-input slot eval (the parse-once fast path).

    dec (W, R, C) f32 — worker w's already-decoded chunk rows at dec[w]
    (served by the decoded-chunk cache), idx (W, B) window rows, b_eff (W,)
    -> stats (W, S, 4), skipping tokenize/parse entirely.  Same
    ``cache_cap``/``m_before`` synopsis-cache emission contract as
    :func:`slot_extract_stream`.
    """
    use_pallas, interpret = _resolve(backend)
    num_cols = int(coeffs.shape[1])
    idx, b_eff = jnp.asarray(idx, jnp.int32), jnp.asarray(b_eff, jnp.int32)
    coeffs, lo, hi, is_count, gate = (
        jnp.asarray(a, jnp.float32) for a in (coeffs, lo, hi, is_count, gate))
    if weights is None:
        weights = jnp.ones((coeffs.shape[0],), jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    if m_before is not None:
        m_before = jnp.asarray(m_before, jnp.int32)
    if use_pallas:
        return slot_eval_decoded_pallas(dec, idx, b_eff, coeffs, lo, hi,
                                        is_count, gate, weights,
                                        num_cols=num_cols, row_tile=row_tile,
                                        cache_cap=cache_cap,
                                        m_before=m_before,
                                        interpret=interpret)
    stats = _ref.slot_eval_decoded_ref(dec, idx, b_eff, coeffs, lo, hi,
                                       is_count, gate, weights=weights)
    if cache_cap > 0:
        if m_before is None:
            m_before = jnp.zeros((idx.shape[0],), jnp.int32)
        w = idx.shape[0]
        cols = dec[jnp.arange(w, dtype=jnp.int32)[:, None], idx]
        return stats, _ref.window_cache_rows_ref(cols, b_eff, m_before,
                                                 cache_cap)
    return stats


def round_stats(slab: jnp.ndarray, b_eff: jnp.ndarray, coeffs, lo, hi,
                backend: str = "auto") -> jnp.ndarray:
    """(W, B, rec) uint8 slab + budgets -> (W, Q, 4) partial stats."""
    num_cols = int(coeffs.shape[1])
    use_pallas, interpret = _resolve(backend)
    if use_pallas:
        return round_stats_pallas(slab, jnp.asarray(b_eff, jnp.int32),
                                  jnp.asarray(coeffs, jnp.float32),
                                  jnp.asarray(lo, jnp.float32),
                                  jnp.asarray(hi, jnp.float32),
                                  num_cols=num_cols, interpret=interpret)
    return _ref.round_stats_ref(slab, num_cols,
                                jnp.asarray(coeffs, jnp.float32),
                                jnp.asarray(lo, jnp.float32),
                                jnp.asarray(hi, jnp.float32),
                                jnp.asarray(b_eff, jnp.int32))
