"""Pallas TPU kernels for the paper's measured hot spots.

The paper's bottleneck is the EXTRACT stage (tokenize/parse) fused with
per-tuple aggregation — Section 3 calls out CPU-bound extraction as the very
reason bi-level sampling beats chunk-level sampling.  Three kernels cover the
three access patterns the engine uses:

* :mod:`extract_parse` — fixed-width ASCII-decimal records → f32 columns
  (the EXTRACT stage itself, VPU-vectorized digit arithmetic).
* :mod:`chunk_agg`     — fused parse + predicate + (count, Σx, Σx², Σp) per
  chunk over *full* chunks (chunk-level / holistic strategies; the analogue
  of Instant Loading's SIMD tokenizer feeding an aggregator).
* :mod:`round_stats`   — fused parse + multi-query eval + budget-masked
  partial statistics over a gathered ``(workers, budget)`` slab — the
  bi-level engine's per-round hot loop (frozen query plans, HBM-side gather).
* :mod:`slot_extract`  — the fully fused round: in-kernel permutation-window
  gather (scalar-prefetch chunk/window indexing) + parse + *slot table*
  evaluation + per-(worker, slot) sufficient statistics.  This is the
  ``EngineConfig.extract_backend="pallas"`` path of the engine round for
  both query planes.

``ref.py`` holds the pure-jnp oracles; ``ops.py`` the jitted wrappers that
dispatch to Pallas on TPU and to the oracle (or ``interpret=True``) on CPU.
"""

from repro.kernels.ops import (
    chunk_agg,
    extract_parse,
    round_stats,
    slot_extract,
)

__all__ = ["chunk_agg", "extract_parse", "round_stats", "slot_extract"]
