"""Pure-jnp oracles for every kernel in this package.

These are the semantics contract: each Pallas kernel must ``allclose`` these
functions across the shape/dtype sweeps in tests/test_kernels.py.  They are
also the CPU execution path used by the engine when no TPU is present.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.formats import FIELD_BYTES, FRAC_DIGITS, INT_DIGITS

# Group-discovery tally table width (power of two; shared by the engine's
# jnp path, the Pallas kernel, and the host-side sketch fold).
TALLY_BUCKETS = 128


def tally_hash(vals: jnp.ndarray, salt: jnp.ndarray,
               buckets: int) -> jnp.ndarray:
    """Salted multiplicative hash of f32 group values into [0, buckets).

    ``salt`` (uint32 — the engine passes the round number) re-buckets every
    round, so two values colliding this round almost surely separate next
    round: collisions are *transient*, and the host-side SpaceSaving fold
    only trusts buckets whose moments prove a single occupant
    (Σv² · count == (Σv)² within fp tolerance).
    """
    lg = int(buckets).bit_length() - 1
    assert (1 << lg) == int(buckets), "tally buckets must be a power of two"
    u = jax.lax.bitcast_convert_type(vals.astype(jnp.float32), jnp.uint32)
    h = (u ^ (salt * jnp.uint32(2654435761))) * jnp.uint32(2246822519)
    return (h >> jnp.uint32(32 - lg)).astype(jnp.int32)


def parse_ascii_ref(raw: jnp.ndarray, num_cols: int) -> jnp.ndarray:
    """(T, rec_bytes) uint8 fixed-width ASCII -> (T, C) f32."""
    t = raw.shape[0]
    f = raw.reshape(t, num_cols, FIELD_BYTES).astype(jnp.int32)
    zero = jnp.int32(ord("0"))
    ipow = jnp.asarray([10.0 ** (INT_DIGITS - 1 - d) for d in range(INT_DIGITS)],
                       jnp.float32)
    fpow = jnp.asarray([10.0 ** -(d + 1) for d in range(FRAC_DIGITS)], jnp.float32)
    ival = jnp.einsum("tcd,d->tc",
                      (f[..., 1:1 + INT_DIGITS] - zero).astype(jnp.float32), ipow)
    fval = jnp.einsum("tcd,d->tc",
                      (f[..., 2 + INT_DIGITS:] - zero).astype(jnp.float32), fpow)
    sign = jnp.where(f[..., 0] == ord("-"), -1.0, 1.0).astype(jnp.float32)
    return sign * (ival + fval)


def eval_plan_ref(vals: jnp.ndarray, coeffs: jnp.ndarray, lo: jnp.ndarray,
                  hi: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Linear-plan evaluation: vals (..., C) -> x (Q, ...), p (Q, ...).

    ``x`` is predicate-masked (Table 1 convention), ``p`` the 0/1 indicator.
    COUNT queries carry zero coefficients; callers use ``p`` for them.
    """
    qshape = (lo.shape[0],) + (1,) * (vals.ndim - 1) + (lo.shape[-1],)
    lo_b = lo.reshape(qshape)
    hi_b = hi.reshape(qshape)
    pred = jnp.all((vals[None] >= lo_b) & (vals[None] < hi_b), axis=-1)  # (Q, ...)
    expr = jnp.einsum("...c,qc->q...", vals, coeffs)
    pf = pred.astype(vals.dtype)
    return expr * pf, pf


def chunk_agg_ref(raw: jnp.ndarray, num_cols: int, coeffs, lo, hi,
                  sizes: jnp.ndarray) -> jnp.ndarray:
    """Full-chunk fused parse+eval+aggregate.

    raw (N, M, rec) uint8, sizes (N,) -> out (N, Q, 4) with
    out[j, q] = (m_valid, Σx, Σx², Σp) over the first ``sizes[j]`` rows.
    """
    n, m, _ = raw.shape
    vals = parse_ascii_ref(raw.reshape(n * m, -1), num_cols).reshape(n, m, num_cols)
    x, p = eval_plan_ref(vals, coeffs, lo, hi)    # (Q, N, M)
    row_ok = (jnp.arange(m)[None, :] < sizes[:, None]).astype(vals.dtype)  # (N, M)
    x = x * row_ok[None]
    p = p * row_ok[None]
    cnt = jnp.broadcast_to(jnp.sum(row_ok, -1)[None], x.shape[:2])  # (Q, N)
    out = jnp.stack([cnt, jnp.sum(x, -1), jnp.sum(x * x, -1), jnp.sum(p, -1)],
                    axis=-1)                      # (Q, N, 4)
    return jnp.transpose(out, (1, 0, 2))          # (N, Q, 4)


def _slot_stats_from_cols(cols: jnp.ndarray, b_eff: jnp.ndarray, coeffs, lo,
                          hi, is_count, gate, weights=None) -> jnp.ndarray:
    """Decoded window (W, B, C) f32 -> per-(worker, slot) stats (W, S, 4).

    The shared back half of :func:`slot_extract_ref` and the decoded-input
    fast path: slot eval + fairness-capped budget masking + stat sums.  Op
    order is the historic one, so the raw path stays bit-identical.
    """
    b = cols.shape[1]
    x, p = eval_plan_ref(cols, coeffs, lo, hi)    # (S, W, B)
    x = jnp.where(jnp.asarray(is_count)[:, None, None] > 0.0, p, x)
    if weights is None:
        weights = jnp.ones((x.shape[0],), jnp.float32)
    bs = jnp.minimum(jnp.ceil(jnp.asarray(weights, jnp.float32)[:, None]
                              * b_eff[None, :].astype(jnp.float32)
                              ).astype(b_eff.dtype), b_eff[None, :])  # (S, W)
    ok_s = (jnp.arange(b)[None, None, :]
            < bs[:, :, None]).astype(cols.dtype)  # (S, W, B)
    mask = ok_s * jnp.asarray(gate, cols.dtype)[:, None, None]
    x = x * mask
    p = p * mask
    cnt = jnp.sum(ok_s, -1)                       # (S, W)
    out = jnp.stack([cnt, jnp.sum(x, -1), jnp.sum(x * x, -1), jnp.sum(p, -1)],
                    axis=-1)                      # (S, W, 4)
    return jnp.transpose(out, (1, 0, 2))


def _group_stats_from_cols(cols: jnp.ndarray, b_eff: jnp.ndarray, coeffs, lo,
                           hi, is_count, gate, gcol, gval, gact, salt,
                           tally_buckets: int, weights=None,
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Grouped back half: decoded window (W, B, C) -> per-(worker, slot,
    cell) stats (W, S, G, 4) plus salted group tallies (W, S, 3, H).

    Stats lanes are ``(rows matched, Σx, Σx², Σp)`` with the same masking as
    :func:`_slot_stats_from_cols`; every mask factor is an exact 0/1 float,
    so tracked-cell sums are bit-exact against a dedicated fan-out slot
    whose predicate carries the group-membership conjunct.  Cell G-1 is the
    ``__other__`` spill: its indicator is the complement of the tracked-cell
    sum (a row matches at most one tracked value).
    """
    w, b, c = cols.shape
    x, p = eval_plan_ref(cols, coeffs, lo, hi)    # (S, W, B)
    x = jnp.where(jnp.asarray(is_count)[:, None, None] > 0.0, p, x)
    if weights is None:
        weights = jnp.ones((x.shape[0],), jnp.float32)
    bs = jnp.minimum(jnp.ceil(jnp.asarray(weights, jnp.float32)[:, None]
                              * b_eff[None, :].astype(jnp.float32)
                              ).astype(b_eff.dtype), b_eff[None, :])  # (S, W)
    ok_s = (jnp.arange(b)[None, None, :]
            < bs[:, :, None]).astype(cols.dtype)  # (S, W, B)
    mask = ok_s * jnp.asarray(gate, cols.dtype)[:, None, None]
    x = x * mask
    p = p * mask
    colv = jnp.moveaxis(cols, -1, 0)[jnp.clip(jnp.asarray(gcol), 0, c - 1)]
    gvalf = jnp.asarray(gval, cols.dtype)         # (S, G)
    gactf = jnp.asarray(gact, cols.dtype)
    eq = (colv[:, None] == gvalf[:, :, None, None]).astype(cols.dtype)
    trk = eq * gactf[:, :, None, None]            # (S, G, W, B)
    other = ((1.0 - jnp.sum(trk[:, :-1], axis=1))
             * gactf[:, -1][:, None, None])       # (S, W, B)
    ind = jnp.concatenate([trk[:, :-1], other[:, None]], axis=1)  # (S, G, W, B)
    gx = ind * x[:, None]
    gp = ind * p[:, None]
    cnt = jnp.sum(ind * mask[:, None], -1)        # (S, G, W)
    out = jnp.stack([cnt, jnp.sum(gx, -1), jnp.sum(gx * gx, -1),
                     jnp.sum(gp, -1)], axis=-1)   # (S, G, W, 4)
    gstats = jnp.transpose(out, (2, 0, 1, 3))     # (W, S, G, 4)

    h = tally_hash(colv, jnp.asarray(salt, jnp.uint32), tally_buckets)
    oh = (h[..., None] == jnp.arange(tally_buckets, dtype=jnp.int32)
          ).astype(cols.dtype)                    # (S, W, B, H)
    # tallies only exist while the slot discovers groups (__other__ cell
    # live); ungrouped slots would otherwise tally their clipped column
    moments = jnp.stack([p, p * colv, p * colv * colv], axis=2)  # (S, W, 3, B)
    moments = moments * gactf[:, -1][:, None, None, None]
    tal = jnp.einsum("swmb,swbh->wsmh", moments, oh)             # (W, S, 3, H)
    return gstats, tal


def slot_extract_grouped_ref(packed: jnp.ndarray, jw: jnp.ndarray,
                             idx: jnp.ndarray, b_eff: jnp.ndarray, coeffs,
                             lo, hi, is_count, gate, gcol, gval, gact, salt,
                             num_cols: int, tally_buckets: int = TALLY_BUCKETS,
                             return_cols: bool = False, weights=None):
    """Grouped fused-extraction oracle (packed residency).

    :func:`slot_extract_ref`'s contract plus per-cell stats and group
    tallies: returns ``(stats (W, S, 4), cols|None, gstats (W, S, G, 4),
    tal (W, S, 3, H))``.
    """
    w, b = idx.shape
    raw = packed[jw[:, None], idx]
    cols = parse_ascii_ref(raw.reshape(w * b, -1), num_cols).reshape(
        w, b, num_cols)
    stats = _slot_stats_from_cols(cols, b_eff, coeffs, lo, hi, is_count, gate,
                                  weights)
    gstats, tal = _group_stats_from_cols(cols, b_eff, coeffs, lo, hi,
                                         is_count, gate, gcol, gval, gact,
                                         salt, tally_buckets, weights)
    return stats, (cols if return_cols else None), gstats, tal


def slot_extract_ref(packed: jnp.ndarray, jw: jnp.ndarray, idx: jnp.ndarray,
                     b_eff: jnp.ndarray, coeffs, lo, hi, is_count, gate,
                     num_cols: int, return_cols: bool = False, weights=None):
    """Fused round extraction oracle (see kernels/slot_extract.py).

    packed (N, M, rec) uint8, jw (W,) chunk ids, idx (W, B) permutation-window
    rows, b_eff (W,), coeffs/lo/hi (S, C), is_count/gate (S,) ->
    (stats (W, S, 4) = (m, Σx, Σx², Σp), cols (W, B, C) | None).
    ``weights`` (S,) are the scheduler's per-slot fairness shares: slot s
    counts only the first ``ceil(weight_s·b_eff)`` window rows (``None`` or
    all-ones = the unweighted round, bit-identical to the historic path).
    """
    w, b = idx.shape
    raw = packed[jw[:, None], idx]                # (W, B, rec) gathered rows
    cols = parse_ascii_ref(raw.reshape(w * b, -1), num_cols).reshape(
        w, b, num_cols)
    stats = _slot_stats_from_cols(cols, b_eff, coeffs, lo, hi, is_count, gate,
                                  weights)
    return stats, (cols if return_cols else None)


def slot_eval_decoded_ref(dec: jnp.ndarray, idx: jnp.ndarray,
                          b_eff: jnp.ndarray, coeffs, lo, hi, is_count, gate,
                          weights=None) -> jnp.ndarray:
    """Decoded-input round extraction oracle: skip tokenize/parse entirely.

    ``dec (W, R, C)`` f32 — worker w's *already decoded* chunk rows at
    ``dec[w]`` (the parse-once decoded-chunk cache) — idx (W, B) window rows,
    b_eff (W,) -> stats (W, S, 4).  Identical contract to
    :func:`slot_extract_stream_ref` minus the EXTRACT: the gathered rows go
    straight to slot eval, which is what makes re-scans of cached chunks
    cheap.
    """
    w = idx.shape[0]
    cols = dec[jnp.arange(w, dtype=jnp.int32)[:, None], idx]  # (W, B, C)
    return _slot_stats_from_cols(cols, b_eff, coeffs, lo, hi, is_count, gate,
                                 weights)


def window_cache_rows_ref(cols: jnp.ndarray, b_eff: jnp.ndarray,
                          m_before: jnp.ndarray,
                          cache_cap: int) -> jnp.ndarray:
    """Synopsis-cache delta rows from a decoded window.

    cols (W, B, C) f32, b_eff (W,), m_before (W,) scan positions ->
    (W, cache_cap, C) where row ``r`` holds ``cols[w, r - m_before[w]]`` when
    that window position exists (``0 <= r - m_before < b_eff``) and zeros
    otherwise — exactly the rows the round scatters into the per-chunk
    synopsis cache, without materializing anything per window row.
    """
    w, b, _ = cols.shape
    k = (jnp.arange(cache_cap, dtype=jnp.int32)[None, :]
         - jnp.asarray(m_before, jnp.int32)[:, None])          # (W, cap)
    valid = (k >= 0) & (k < b_eff[:, None])
    rows = jnp.take_along_axis(cols, jnp.clip(k, 0, b - 1)[..., None], axis=1)
    return rows * valid[..., None].astype(cols.dtype)


def stream_cache_rows_ref(slab: jnp.ndarray, idx: jnp.ndarray,
                          b_eff: jnp.ndarray, m_before: jnp.ndarray,
                          cache_cap: int, num_cols: int) -> jnp.ndarray:
    """Raw-slab oracle for the in-kernel synopsis-cache emission: gather +
    parse the window, then select the cache rows (see
    :func:`window_cache_rows_ref`)."""
    w, b = idx.shape
    raw = slab[jnp.arange(w, dtype=jnp.int32)[:, None], idx]
    cols = parse_ascii_ref(raw.reshape(w * b, -1), num_cols).reshape(
        w, b, num_cols)
    return window_cache_rows_ref(cols, b_eff, m_before, cache_cap)


def slot_extract_stream_ref(slab: jnp.ndarray, idx: jnp.ndarray,
                            b_eff: jnp.ndarray, coeffs, lo, hi, is_count,
                            gate, num_cols: int, weights=None) -> jnp.ndarray:
    """Slab-streaming round extraction oracle (see kernels/slot_extract.py).

    Identical contract to :func:`slot_extract_ref` except the raw source is
    the round's per-worker slab ``(W, R, rec)`` — worker w's rows live at
    ``slab[w]`` — instead of the whole packed store, so there is no chunk-id
    indirection.  Returns stats ``(W, S, 4)`` only (the streaming path
    decodes the synopsis slab separately when it needs it).
    """
    w = idx.shape[0]
    stats, _ = slot_extract_ref(slab, jnp.arange(w, dtype=jnp.int32), idx,
                                b_eff, coeffs, lo, hi, is_count, gate,
                                num_cols=num_cols, return_cols=False,
                                weights=weights)
    return stats


def round_stats_ref(slab: jnp.ndarray, num_cols: int, coeffs, lo, hi,
                    b_eff: jnp.ndarray) -> jnp.ndarray:
    """Bi-level round slab: fused parse+eval+budget-masked stats.

    slab (W, B, rec) uint8 (rows already gathered in the chunk's permutation
    order), b_eff (W,) -> out (W, Q, 4) = (m, y', y'', p') over rows < b_eff.
    """
    w, b, _ = slab.shape
    vals = parse_ascii_ref(slab.reshape(w * b, -1), num_cols).reshape(w, b, num_cols)
    x, p = eval_plan_ref(vals, coeffs, lo, hi)    # (Q, W, B)
    ok = (jnp.arange(b)[None, :] < b_eff[:, None]).astype(vals.dtype)  # (W, B)
    x = x * ok[None]
    p = p * ok[None]
    cnt = jnp.broadcast_to(jnp.sum(ok, -1)[None], x.shape[:2])  # (Q, W)
    out = jnp.stack([cnt, jnp.sum(x, -1), jnp.sum(x * x, -1), jnp.sum(p, -1)],
                    axis=-1)                      # (Q, W, 4)
    return jnp.transpose(out, (1, 0, 2))          # (W, Q, 4)
