"""Fused engine-round extraction as a Pallas kernel: gather + parse + slot eval.

This is the bi-level round's hot loop for the *dynamic* query plane (and the
frozen plane lowered to coefficient form): for each worker, gather its
permutation-window rows from the packed chunk buffer, parse the raw bytes in
VMEM, evaluate the slot table — per-slot ``coeffs/lo/hi`` with the active
mask as a multiplicative gate — and accumulate the per-(worker, slot)
sufficient statistics ``(m, Σx, Σx², Σp)`` in one pass.  Neither the
``(S, W, B)`` evaluation tensor nor a decoded ``(W, B, C)`` copy is ever
materialized in HBM (the decoded slab is emitted *only* when the caller needs
it for the synopsis extraction cache).

Geometry (grid ``(W,)`` — one step per worker):

* ``packed (N, M_max, rec)`` uint8 stays in HBM; the worker's chunk id is a
  **scalar-prefetch** argument, so the BlockSpec index map selects block
  ``(1, M_max, rec)`` — the worker's whole chunk — for the VMEM window.
  This is the paper's in-memory chunk: M_max·rec bytes must fit VMEM
  (~16 MiB/core), which holds for the tens-of-MB/chunk guidance once a chunk
  is split across cores; beyond that, :func:`slot_extract_stream_pallas`
  below streams the round's slab through VMEM in row tiles.
* ``idx (W, B)`` int32 permutation-window rows and ``b_eff (W,)`` budgets are
  scalar-prefetch too (SMEM): row indices drive the in-kernel gather loop —
  B dynamic sublane slices chunk→scratch, the canonical Pallas gather.
* plan blocks ``coeffs/lo/hi (S, C)`` f32, ``is_count/gate (S,)`` f32 are
  whole-array VMEM blocks shared by every step.
* out ``(1, S, 4)`` f32 per step; optional ``(1, B, C)`` decoded block.

B is a power of two from the engine's t_eval ladder, so block shapes are
stable across rounds and recompiles are bounded.  VMEM per step at
B=4096, C=16: 2 MiB scratch (int32 bytes) + chunk block + small plan/out
blocks — fine; the (S, B, C) predicate temp is fused by Mosaic and never
hits HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.data.formats import FIELD_BYTES
from repro.kernels.chunk_agg import _eval_plan_block
from repro.kernels.extract_parse import _parse_block
from repro.kernels.ref import TALLY_BUCKETS

# int32 twins of the uint32 hash constants in repro.kernels.ref.tally_hash —
# two's-complement multiply/xor wrap to the same bits, so the in-kernel hash
# stays bit-identical to the oracle without uint arithmetic.
_HASH_SALT_MUL = -1640531535      # uint32 2654435761
_HASH_MIX_MUL = -2048144777       # uint32 2246822519


def _slot_extract_kernel(jw_ref, beff_ref, idx_ref, packed_ref, coeffs_ref,
                         lo_ref, hi_ref, isc_ref, gate_ref, wts_ref, *refs,
                         num_cols: int, budget: int, return_cols: bool):
    if return_cols:
        stats_ref, cols_ref, scratch = refs
    else:
        (stats_ref, scratch), cols_ref = refs, None
    w = pl.program_id(0)

    # gather the worker's permutation-window rows chunk→scratch (VMEM)
    def gather(i, carry):
        row = idx_ref[w, i]
        r = pl.load(packed_ref, (pl.ds(0, 1), pl.ds(row, 1), slice(None)))
        pl.store(scratch, (pl.ds(i, 1), slice(None)),
                 r.reshape(1, -1).astype(jnp.int32))
        return carry

    jax.lax.fori_loop(0, budget, gather, 0)

    vals = _parse_block(scratch[...], num_cols)              # (B, C) f32
    if cols_ref is not None:
        cols_ref[0] = vals
    x, p = _eval_plan_block(vals, coeffs_ref[...],
                            lo_ref[...], hi_ref[...])        # (S, B)
    # COUNT slots carry zero coefficients; their x is the indicator itself
    x = jnp.where(isc_ref[...][:, None] > 0.0, p, x)
    # per-slot budget: fairness weight w_s caps slot s at the first
    # ceil(w_s·b_eff) window rows (w_s = 1 → the full b_eff, bit-identical
    # to the unweighted round)
    beff = beff_ref[w]
    bs = jnp.minimum(jnp.ceil(wts_ref[...] * beff.astype(jnp.float32)
                              ).astype(jnp.int32), beff)     # (S,)
    ok_s = (jax.lax.iota(jnp.int32, budget)[None, :]
            < bs[:, None]).astype(jnp.float32)               # (S, B)
    mask = ok_s * gate_ref[...][:, None]                     # (S, B)
    x = x * mask
    p = p * mask
    stats_ref[0] = jnp.stack([
        jnp.sum(ok_s, -1),
        jnp.sum(x, -1), jnp.sum(x * x, -1), jnp.sum(p, -1)], axis=-1)


@functools.partial(jax.jit, static_argnames=("num_cols", "return_cols",
                                             "interpret"))
def slot_extract_pallas(packed: jnp.ndarray, jw: jnp.ndarray,
                        idx: jnp.ndarray, b_eff: jnp.ndarray,
                        coeffs, lo, hi, is_count, gate, weights,
                        num_cols: int,
                        return_cols: bool = False, interpret: bool = False):
    """Fused round extraction.

    packed (N, M_max, rec) uint8, jw (W,) chunk ids, idx (W, B) window rows,
    b_eff (W,) budgets, coeffs/lo/hi (S, C) f32, is_count/gate/weights (S,)
    f32 -> stats (W, S, 4) f32 ``(m, Σx, Σx², Σp)`` [, cols (W, B, C) f32].
    ``weights`` are the scheduler's per-slot fairness shares (1 = full
    budget, see ``repro.sched.fairness``).
    """
    n, m_max, rec = packed.shape
    assert rec == num_cols * FIELD_BYTES, (rec, num_cols)
    w, b = idx.shape
    s = coeffs.shape[0]
    out_shape = [jax.ShapeDtypeStruct((w, s, 4), jnp.float32)]
    out_specs = [pl.BlockSpec((1, s, 4), lambda i, *refs: (i, 0, 0))]
    if return_cols:
        out_shape.append(jax.ShapeDtypeStruct((w, b, num_cols), jnp.float32))
        out_specs.append(pl.BlockSpec((1, b, num_cols),
                                      lambda i, *refs: (i, 0, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,   # jw, b_eff, idx
        grid=(w,),
        in_specs=[
            # the worker's whole chunk, selected by the prefetched chunk id
            pl.BlockSpec((1, m_max, rec),
                         lambda i, jw_ref, *refs: (jw_ref[i], 0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, *refs: (0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, *refs: (0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, *refs: (0, 0)),
            pl.BlockSpec((s,), lambda i, *refs: (0,)),
            pl.BlockSpec((s,), lambda i, *refs: (0,)),
            pl.BlockSpec((s,), lambda i, *refs: (0,)),
        ],
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((b, rec), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_slot_extract_kernel, num_cols=num_cols,
                          budget=b, return_cols=return_cols),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray(jw, jnp.int32), jnp.asarray(b_eff, jnp.int32),
      jnp.asarray(idx, jnp.int32), packed,
      jnp.asarray(coeffs, jnp.float32), jnp.asarray(lo, jnp.float32),
      jnp.asarray(hi, jnp.float32), jnp.asarray(is_count, jnp.float32),
      jnp.asarray(gate, jnp.float32), jnp.asarray(weights, jnp.float32))
    return tuple(out) if return_cols else (out[0], None)


# ---------------------------------------------------------------------------
# Grouped variant: per-(worker, slot, group-cell) partials + discovery tallies.
#
# Same geometry as _slot_extract_kernel (grid (W,), whole chunk in VMEM via
# scalar-prefetch chunk id), plus three static-G/H additions, all VMEM-only:
# the slot's group column is selected with an exact one-hot matmul
# (goh (S, C) @ vals.T), tracked-cell indicators are 0/1 equality masks
# against gval with the __other__ cell as the tracked-sum complement, and the
# salted discovery tallies are per-slot (3, B) @ (B, H) one-hot matmuls.
# Only the (S, G, 4) sufficient stats and the (S, 3, H) tallies reach HBM.
# ---------------------------------------------------------------------------


def _slot_extract_grouped_kernel(jw_ref, beff_ref, idx_ref, salt_ref,
                                 packed_ref, coeffs_ref, lo_ref, hi_ref,
                                 isc_ref, gate_ref, wts_ref, goh_ref,
                                 gval_ref, gact_ref, *refs, num_cols: int,
                                 budget: int, tally_buckets: int,
                                 return_cols: bool):
    if return_cols:
        stats_ref, cols_ref, gstats_ref, tal_ref, scratch = refs
    else:
        (stats_ref, gstats_ref, tal_ref, scratch), cols_ref = refs, None
    w = pl.program_id(0)

    def gather(i, carry):
        row = idx_ref[w, i]
        r = pl.load(packed_ref, (pl.ds(0, 1), pl.ds(row, 1), slice(None)))
        pl.store(scratch, (pl.ds(i, 1), slice(None)),
                 r.reshape(1, -1).astype(jnp.int32))
        return carry

    jax.lax.fori_loop(0, budget, gather, 0)

    vals = _parse_block(scratch[...], num_cols)              # (B, C) f32
    if cols_ref is not None:
        cols_ref[0] = vals
    x, p = _eval_plan_block(vals, coeffs_ref[...],
                            lo_ref[...], hi_ref[...])        # (S, B)
    x = jnp.where(isc_ref[...][:, None] > 0.0, p, x)
    beff = beff_ref[w]
    bs = jnp.minimum(jnp.ceil(wts_ref[...] * beff.astype(jnp.float32)
                              ).astype(jnp.int32), beff)     # (S,)
    ok_s = (jax.lax.iota(jnp.int32, budget)[None, :]
            < bs[:, None]).astype(jnp.float32)               # (S, B)
    mask = ok_s * gate_ref[...][:, None]                     # (S, B)
    x = x * mask
    p = p * mask
    stats_ref[0] = jnp.stack([
        jnp.sum(ok_s, -1),
        jnp.sum(x, -1), jnp.sum(x * x, -1), jnp.sum(p, -1)], axis=-1)

    # per-slot group-column values via exact one-hot contraction over C
    colv = jax.lax.dot_general(goh_ref[...], vals,
                               (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (S, B)

    gvals = gval_ref[...]                                    # (S, G)
    gacts = gact_ref[...]
    n_slots, g = gvals.shape
    eq = (colv[:, None, :] == gvals[:, :, None]).astype(jnp.float32)
    trk = eq * gacts[:, :, None]                             # (S, G, B)
    # __other__ (cell G-1): complement of the tracked-cell sum — a row
    # matches at most one tracked value, so this is an exact 0/1 indicator
    tracked = trk * (jax.lax.broadcasted_iota(jnp.int32, (1, g, 1), 1)
                     < g - 1).astype(jnp.float32)
    other = ((1.0 - jnp.sum(tracked, axis=1))
             * gacts[:, g - 1][:, None])                     # (S, B)
    is_last = jax.lax.broadcasted_iota(jnp.int32, (1, g, 1), 1) == g - 1
    ind = jnp.where(is_last, other[:, None, :], trk)         # (S, G, B)
    gx = ind * x[:, None]
    gp = ind * p[:, None]
    gstats_ref[0] = jnp.stack([
        jnp.sum(ind * mask[:, None], -1),
        jnp.sum(gx, -1), jnp.sum(gx * gx, -1), jnp.sum(gp, -1)], axis=-1)

    # salted discovery tallies: hash bits match ref.tally_hash exactly
    # (int32 wraparound == uint32), low-bit mask recovers the logical shift
    lg = tally_buckets.bit_length() - 1
    salt = salt_ref[0]
    u = jax.lax.bitcast_convert_type(colv, jnp.int32)        # (S, B)
    h = (u ^ (salt * jnp.int32(_HASH_SALT_MUL))) * jnp.int32(_HASH_MIX_MUL)
    h = jnp.right_shift(h, jnp.int32(32 - lg)) & jnp.int32(tally_buckets - 1)
    hcol = jax.lax.broadcasted_iota(jnp.int32, (budget, tally_buckets), 1)
    rows = []
    for s_i in range(n_slots):
        oh = (h[s_i][:, None] == hcol).astype(jnp.float32)   # (B, H)
        # tallies only while the slot discovers groups (__other__ cell live)
        pt = p[s_i] * gacts[s_i, g - 1]
        mom = jnp.stack([pt, pt * colv[s_i],
                         pt * colv[s_i] * colv[s_i]], axis=0)  # (3, B)
        rows.append(jnp.dot(mom, oh, preferred_element_type=jnp.float32))
    tal_ref[0] = jnp.stack(rows, axis=0)                     # (S, 3, H)


@functools.partial(jax.jit, static_argnames=("num_cols", "tally_buckets",
                                             "return_cols", "interpret"))
def slot_extract_grouped_pallas(packed: jnp.ndarray, jw: jnp.ndarray,
                                idx: jnp.ndarray, b_eff: jnp.ndarray,
                                coeffs, lo, hi, is_count, gate, weights,
                                gcol, gval, gact, salt, num_cols: int,
                                tally_buckets: int = TALLY_BUCKETS,
                                return_cols: bool = False,
                                interpret: bool = False):
    """Grouped fused round extraction (packed residency).

    :func:`slot_extract_pallas`'s contract plus the grouped plane: gcol (S,)
    int32 group columns (-1 = ungrouped slot), gval/gact (S, G) f32 tracked
    values / live-cell mask (cell G-1 = ``__other__``), salt uint32 round
    number -> ``(stats (W, S, 4), cols|None, gstats (W, S, G, 4),
    tal (W, S, 3, H))``.  Must allclose ``ref.slot_extract_grouped_ref``.
    """
    n, m_max, rec = packed.shape
    assert rec == num_cols * FIELD_BYTES, (rec, num_cols)
    w, b = idx.shape
    s = coeffs.shape[0]
    g = gval.shape[1]
    gcol_c = jnp.clip(jnp.asarray(gcol, jnp.int32), 0, num_cols - 1)
    goh = (jnp.arange(num_cols, dtype=jnp.int32)[None, :]
           == gcol_c[:, None]).astype(jnp.float32)           # (S, C)
    salt1 = jnp.asarray(salt, jnp.uint32).astype(jnp.int32).reshape(1)
    out_shape = [jax.ShapeDtypeStruct((w, s, 4), jnp.float32)]
    out_specs = [pl.BlockSpec((1, s, 4), lambda i, *refs: (i, 0, 0))]
    if return_cols:
        out_shape.append(jax.ShapeDtypeStruct((w, b, num_cols), jnp.float32))
        out_specs.append(pl.BlockSpec((1, b, num_cols),
                                      lambda i, *refs: (i, 0, 0)))
    out_shape += [
        jax.ShapeDtypeStruct((w, s, g, 4), jnp.float32),
        jax.ShapeDtypeStruct((w, s, 3, tally_buckets), jnp.float32)]
    out_specs += [
        pl.BlockSpec((1, s, g, 4), lambda i, *refs: (i, 0, 0, 0)),
        pl.BlockSpec((1, s, 3, tally_buckets),
                     lambda i, *refs: (i, 0, 0, 0))]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,   # jw, b_eff, idx, salt
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, m_max, rec),
                         lambda i, jw_ref, *refs: (jw_ref[i], 0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, *refs: (0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, *refs: (0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, *refs: (0, 0)),
            pl.BlockSpec((s,), lambda i, *refs: (0,)),
            pl.BlockSpec((s,), lambda i, *refs: (0,)),
            pl.BlockSpec((s,), lambda i, *refs: (0,)),
            pl.BlockSpec((s, num_cols), lambda i, *refs: (0, 0)),
            pl.BlockSpec((s, g), lambda i, *refs: (0, 0)),
            pl.BlockSpec((s, g), lambda i, *refs: (0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((b, rec), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_slot_extract_grouped_kernel, num_cols=num_cols,
                          budget=b, tally_buckets=tally_buckets,
                          return_cols=return_cols),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray(jw, jnp.int32), jnp.asarray(b_eff, jnp.int32),
      jnp.asarray(idx, jnp.int32), salt1, packed,
      jnp.asarray(coeffs, jnp.float32), jnp.asarray(lo, jnp.float32),
      jnp.asarray(hi, jnp.float32), jnp.asarray(is_count, jnp.float32),
      jnp.asarray(gate, jnp.float32), jnp.asarray(weights, jnp.float32),
      goh, jnp.asarray(gval, jnp.float32), jnp.asarray(gact, jnp.float32))
    if return_cols:
        return tuple(out)
    return out[0], None, out[1], out[2]


# ---------------------------------------------------------------------------
# Slab-streaming variant (ROADMAP PR-2 follow-on): chunks larger than VMEM.
#
# The kernel above brings a worker's *whole* chunk into one VMEM window via
# scalar-prefetch indexing — fine while M_max·rec fits VMEM, impossible
# beyond.  The streaming variant takes the round's bounded (W, R, rec) slab
# (worker w's chunk at slab[w], assembled by data/pipeline.SlabPrefetcher)
# and grids over (W, R/T) *row tiles*: each step parses one (T, rec) tile,
# evaluates the plan on all T rows, and folds in only the rows the worker's
# permutation window selected — a per-tile membership weight built from the
# prefetched idx row — accumulating the same per-(worker, slot) (m, Σx, Σx²,
# Σp) contract into a VMEM-resident (1, S, 4) output block.  VMEM per step
# is O(T·rec + S·T), independent of chunk size.
# ---------------------------------------------------------------------------

# window positions are compared against a tile in sub-blocks of this many
# indices, bounding the (IDX_TILE, T) membership temp in VMEM
IDX_TILE = 512


def _slot_extract_stream_kernel(beff_ref, mb_ref, slab_ref, idx_ref,
                                coeffs_ref, lo_ref, hi_ref, isc_ref, gate_ref,
                                wts_ref, *out_refs, num_cols: int, budget: int,
                                row_tile: int, decoded_input: bool,
                                cache_cap: int):
    if cache_cap > 0:
        stats_ref, cache_ref = out_refs
    else:
        (stats_ref,), cache_ref = out_refs, None
    w = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        stats_ref[...] = jnp.zeros_like(stats_ref)
        if cache_ref is not None:
            cache_ref[...] = jnp.zeros_like(cache_ref)

    if decoded_input:
        vals = slab_ref[0]                                    # (T, C) f32
    else:
        raw = slab_ref[0].astype(jnp.int32)                   # (T, rec)
        vals = _parse_block(raw, num_cols)                    # (T, C)
    x, p = _eval_plan_block(vals, coeffs_ref[...],
                            lo_ref[...], hi_ref[...])         # (S, T)
    x = jnp.where(isc_ref[...][:, None] > 0.0, p, x)

    # per-slot membership weight: how many of *slot s's* valid window
    # positions (the first ceil(weight_s·b_eff), fairness-capped) land on
    # each tile row.  Position validity (S, bt) × membership (bt, T) is a
    # small matmul per idx sub-block; every operand is 0/1 so the f32
    # accumulation is exact (weights of 1 reproduce the unweighted round
    # bit-for-bit).
    base = t * row_tile
    beff = beff_ref[w]
    bs = jnp.minimum(jnp.ceil(wts_ref[...] * beff.astype(jnp.float32)
                              ).astype(jnp.int32), beff)      # (S,)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (1, row_tile), 1) + base

    bt = min(budget, IDX_TILE)
    n_slots = bs.shape[0]
    cap_ids = jax.lax.broadcasted_iota(jnp.int32, (max(cache_cap, 1), 1), 0)
    mb = mb_ref[w]

    def fold(i, carry):
        acc, cacc = carry
        # idx_ref is (1, B//bt, bt): sub-block i on the sublane dim
        sl = pl.load(idx_ref, (pl.ds(0, 1), pl.ds(i, 1), slice(None)))
        k = jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1) + i * bt
        valid_s = (k < bs[:, None]).astype(jnp.float32)       # (S, bt)
        mem = (sl.reshape(bt, 1) == row_ids).astype(jnp.float32)  # (bt, T)
        acc = acc + jnp.dot(valid_s, mem,
                            preferred_element_type=jnp.float32)   # (S, T)
        if cache_cap > 0:
            # synopsis-cache rows: window position k's decoded value lands at
            # cache row m_before + k.  mem @ vals picks each position's tile
            # row (0 if it lives in another tile); sel scatters positions
            # into their cache rows — only O(cap·C) ever reaches HBM.
            in_win = (k < beff).astype(jnp.float32)               # (1, bt)
            sel = ((mb + k) == cap_ids).astype(jnp.float32) * in_win
            wv = jnp.dot(mem, vals,
                         preferred_element_type=jnp.float32)      # (bt, C)
            cacc = cacc + jnp.dot(sel, wv,
                                  preferred_element_type=jnp.float32)
        return acc, cacc

    weight, cache_acc = jax.lax.fori_loop(
        0, budget // bt, fold,
        (jnp.zeros((n_slots, row_tile), jnp.float32),
         jnp.zeros((max(cache_cap, 1), num_cols), jnp.float32)))

    gate = gate_ref[...]
    xw = x * (weight * gate[:, None])                         # (S, T)
    pw = p * (weight * gate[:, None])
    stats_ref[0] += jnp.stack([
        jnp.sum(weight, -1),
        jnp.sum(xw, -1), jnp.sum(x * xw, -1), jnp.sum(pw, -1)], axis=-1)
    if cache_ref is not None:
        cache_ref[0] += cache_acc


@functools.partial(jax.jit, static_argnames=("num_cols", "row_tile",
                                             "cache_cap", "decoded_input",
                                             "interpret"))
def _stream_pallas_impl(slab, idx, b_eff, m_before, coeffs, lo, hi, is_count,
                        gate, weights, num_cols: int, row_tile: int,
                        cache_cap: int, decoded_input: bool, interpret: bool):
    w, r, width = slab.shape
    if decoded_input:
        assert width == num_cols and slab.dtype == jnp.float32, (
            slab.shape, slab.dtype)
    else:
        assert width == num_cols * FIELD_BYTES, (width, num_cols)
    b = idx.shape[1]
    s = coeffs.shape[0]
    bt = min(b, IDX_TILE)
    idx3 = jnp.asarray(idx, jnp.int32).reshape(w, b // bt, bt)
    r_pad = (r + row_tile - 1) // row_tile * row_tile
    if r_pad != r:
        slab = jnp.pad(slab, ((0, 0), (0, r_pad - r), (0, 0)))
    out_shape = [jax.ShapeDtypeStruct((w, s, 4), jnp.float32)]
    out_specs = [pl.BlockSpec((1, s, 4), lambda i, t, *refs: (i, 0, 0))]
    if cache_cap > 0:
        out_shape.append(
            jax.ShapeDtypeStruct((w, cache_cap, num_cols), jnp.float32))
        out_specs.append(pl.BlockSpec((1, cache_cap, num_cols),
                                      lambda i, t, *refs: (i, 0, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,   # b_eff, m_before
        grid=(w, r_pad // row_tile),
        in_specs=[
            pl.BlockSpec((1, row_tile, width),
                         lambda i, t, *refs: (i, t, 0)),
            pl.BlockSpec((1, b // bt, bt), lambda i, t, *refs: (i, 0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, t, *refs: (0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, t, *refs: (0, 0)),
            pl.BlockSpec((s, num_cols), lambda i, t, *refs: (0, 0)),
            pl.BlockSpec((s,), lambda i, t, *refs: (0,)),
            pl.BlockSpec((s,), lambda i, t, *refs: (0,)),
            pl.BlockSpec((s,), lambda i, t, *refs: (0,)),
        ],
        out_specs=out_specs,
    )
    out = pl.pallas_call(
        functools.partial(_slot_extract_stream_kernel, num_cols=num_cols,
                          budget=b, row_tile=row_tile,
                          decoded_input=decoded_input, cache_cap=cache_cap),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray(b_eff, jnp.int32), jnp.asarray(m_before, jnp.int32),
      slab, idx3,
      jnp.asarray(coeffs, jnp.float32), jnp.asarray(lo, jnp.float32),
      jnp.asarray(hi, jnp.float32), jnp.asarray(is_count, jnp.float32),
      jnp.asarray(gate, jnp.float32), jnp.asarray(weights, jnp.float32))
    return tuple(out) if cache_cap > 0 else out[0]


def slot_extract_stream_pallas(slab: jnp.ndarray, idx: jnp.ndarray,
                               b_eff: jnp.ndarray, coeffs, lo, hi, is_count,
                               gate, weights, num_cols: int,
                               row_tile: int = 256, cache_cap: int = 0,
                               m_before=None, interpret: bool = False):
    """Slab-streaming fused round extraction.

    slab (W, R, rec) uint8 (worker w's chunk rows at slab[w], zero-padded),
    idx (W, B) window rows, b_eff (W,) budgets, coeffs/lo/hi (S, C) f32,
    is_count/gate/weights (S,) f32 -> stats (W, S, 4) f32
    ``(m, Σx, Σx², Σp)``; ``weights`` are the per-slot fairness shares.

    With ``cache_cap > 0`` the kernel *also* emits the synopsis-cache delta
    rows ``(W, cache_cap, C)`` (window position k's decoded value at cache
    row ``m_before[w] + k``, rows ≥ cap dropped in-kernel) and returns
    ``(stats, cache_rows)`` — the whole decoded ``(W, B, C)`` slab never
    reaches HBM.

    Rows ``>= b_eff[w]`` of the window and slab rows outside the window
    contribute nothing; padded slab rows are never selected because window
    indices are drawn below the chunk's true tuple count.
    """
    if m_before is None:
        m_before = jnp.zeros((idx.shape[0],), jnp.int32)
    return _stream_pallas_impl(slab, idx, b_eff, m_before, coeffs, lo, hi,
                               is_count, gate, weights, num_cols=num_cols,
                               row_tile=row_tile, cache_cap=cache_cap,
                               decoded_input=False, interpret=interpret)


def slot_eval_decoded_pallas(dec: jnp.ndarray, idx: jnp.ndarray,
                             b_eff: jnp.ndarray, coeffs, lo, hi, is_count,
                             gate, weights, num_cols: int,
                             row_tile: int = 256, cache_cap: int = 0,
                             m_before=None, interpret: bool = False):
    """Decoded-input slot eval: the parse-once fast path.

    Same grid and stats contract as :func:`slot_extract_stream_pallas`, but
    the slab is the *already decoded* ``(W, R, C)`` f32 block from the
    decoded-chunk cache, so the tokenize/parse stage disappears from the
    round entirely — only the membership-weight fold and slot eval remain.
    """
    if m_before is None:
        m_before = jnp.zeros((idx.shape[0],), jnp.int32)
    return _stream_pallas_impl(dec, idx, b_eff, m_before, coeffs, lo, hi,
                               is_count, gate, weights, num_cols=num_cols,
                               row_tile=row_tile, cache_cap=cache_cap,
                               decoded_input=True, interpret=interpret)
