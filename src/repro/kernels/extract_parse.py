"""EXTRACT as a Pallas TPU kernel: fixed-width ASCII decimal → f32 columns.

The paper's EXTRACT stage ("identify the schema attributes ... convert from
raw format to binary type") is the measured bottleneck for text formats.  On
TPU the digit arithmetic vectorizes on the VPU: per field we run an int32
Horner evaluation over the 8 integer and 6 fraction digit lanes (static byte
offsets — the fixed-width layout is the TPU adaptation documented in
DESIGN.md §3; there is no MXU work in parsing, by nature).

Block geometry: a ``(TILE, record_bytes)`` uint8 slab per grid step lives in
VMEM (TILE=256, 16 cols ⇒ 64 KiB in + 16 KiB out, comfortably within the
~16 MiB/core budget while leaving room for double-buffering), output block
``(TILE, C)`` f32.  TILE is a multiple of the (32, 128) int8 native tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.data.formats import FIELD_BYTES, FRAC_DIGITS, INT_DIGITS

DEFAULT_TILE = 256


def _parse_block(raw: jnp.ndarray, num_cols: int) -> jnp.ndarray:
    """(tile, rec_bytes) int32 ascii bytes -> (tile, C) f32.  Shared by the
    three kernels in this package."""
    cols = []
    zero = jnp.int32(ord("0"))
    for c in range(num_cols):
        base = c * FIELD_BYTES
        sign = jnp.where(raw[:, base] == jnp.int32(ord("-")), -1.0, 1.0)
        ival = jnp.zeros_like(raw[:, 0])
        for d in range(INT_DIGITS):          # Horner over int lanes (max 1e8-1: fits i32)
            ival = ival * 10 + (raw[:, base + 1 + d] - zero)
        fval = jnp.zeros_like(raw[:, 0])
        for d in range(FRAC_DIGITS):
            fval = fval * 10 + (raw[:, base + 2 + INT_DIGITS + d] - zero)
        val = sign * (ival.astype(jnp.float32)
                      + fval.astype(jnp.float32) * jnp.float32(10.0 ** -FRAC_DIGITS))
        cols.append(val)
    return jnp.stack(cols, axis=-1)


def _extract_kernel(raw_ref, out_ref, *, num_cols: int):
    raw = raw_ref[...].astype(jnp.int32)
    out_ref[...] = _parse_block(raw, num_cols)


@functools.partial(jax.jit, static_argnames=("num_cols", "tile", "interpret"))
def extract_parse_pallas(raw: jnp.ndarray, num_cols: int,
                         tile: int = DEFAULT_TILE,
                         interpret: bool = False) -> jnp.ndarray:
    """(T, rec_bytes) uint8 -> (T, C) f32 via pallas_call.

    T is padded up to a tile multiple; padded rows parse garbage zeros and are
    sliced away (they decode the 0-byte, harmless).
    """
    t, rec = raw.shape
    assert rec == num_cols * FIELD_BYTES, (rec, num_cols)
    t_pad = (t + tile - 1) // tile * tile
    if t_pad != t:
        raw = jnp.pad(raw, ((0, t_pad - t), (0, 0)),
                      constant_values=ord("0"))
    out = pl.pallas_call(
        functools.partial(_extract_kernel, num_cols=num_cols),
        grid=(t_pad // tile,),
        in_specs=[pl.BlockSpec((tile, rec), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, num_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, num_cols), jnp.float32),
        interpret=interpret,
    )(raw)
    return out[:t]
