"""Fused full-chunk parse + predicate + aggregate Pallas kernel.

This is the chunk-level/holistic strategies' hot loop: stream a raw chunk
through VMEM once, producing the per-chunk sufficient statistics
``(count, Σx, Σx², Σp)`` for every query — no materialized binary copy, which
is the in-situ property the paper is built on.

Grid ``(N, M/TILE)`` iterates tile-steps innermost, so the ``(1, Q, 4)``
output block for chunk j stays resident in VMEM across its tile steps and is
accumulated in place (init at step 0) — the canonical Pallas reduction
pattern.  VMEM per step: ``TILE·rec`` uint8 + ``TILE·C`` f32 + tiny plan
blocks ≈ 90 KiB at TILE=256, C=16.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.data.formats import FIELD_BYTES
from repro.kernels.extract_parse import DEFAULT_TILE, _parse_block


def _eval_plan_block(vals, coeffs, lo, hi):
    """vals (tile, C) -> x (Q, tile) predicate-masked expr, p (Q, tile).

    Batched over the query axis (no Python loop): one broadcast compare and
    one broadcast multiply-reduce, so trace/compile time and the emitted code
    stop scaling with Q.  The reduction runs over the same trailing axis in
    the same order as the old per-query loop, so results are bit-identical.
    """
    v = vals[None]                                           # (1, tile, C)
    pred = jnp.all((v >= lo[:, None, :]) & (v < hi[:, None, :]), axis=-1)
    pf = pred.astype(jnp.float32)                            # (Q, tile)
    expr = jnp.sum(v * coeffs[:, None, :], axis=-1)          # (Q, tile)
    return expr * pf, pf


def _chunk_agg_kernel(raw_ref, size_ref, coeffs_ref, lo_ref, hi_ref, out_ref,
                      *, num_cols: int, tile: int):
    t_step = pl.program_id(1)

    @pl.when(t_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    raw = raw_ref[0].astype(jnp.int32)                       # (tile, rec)
    vals = _parse_block(raw, num_cols)                       # (tile, C)
    x, p = _eval_plan_block(vals, coeffs_ref[...], lo_ref[...], hi_ref[...])

    size = size_ref[0]
    row = t_step * tile + jax.lax.iota(jnp.int32, tile)
    ok = (row < size).astype(jnp.float32)                    # (tile,)
    x = x * ok[None, :]
    p = p * ok[None, :]
    partial = jnp.stack([
        jnp.broadcast_to(jnp.sum(ok), (x.shape[0],)),
        jnp.sum(x, -1), jnp.sum(x * x, -1), jnp.sum(p, -1)], axis=-1)  # (Q, 4)
    out_ref[0] += partial


@functools.partial(jax.jit,
                   static_argnames=("num_cols", "tile", "interpret"))
def chunk_agg_pallas(raw: jnp.ndarray, sizes: jnp.ndarray, coeffs, lo, hi,
                     num_cols: int, tile: int = DEFAULT_TILE,
                     interpret: bool = False) -> jnp.ndarray:
    """raw (N, M, rec) uint8, sizes (N,) -> (N, Q, 4) per-chunk stats."""
    n, m, rec = raw.shape
    assert rec == num_cols * FIELD_BYTES
    q = coeffs.shape[0]
    m_pad = (m + tile - 1) // tile * tile
    if m_pad != m:
        raw = jnp.pad(raw, ((0, 0), (0, m_pad - m), (0, 0)),
                      constant_values=ord("0"))
    return pl.pallas_call(
        functools.partial(_chunk_agg_kernel, num_cols=num_cols, tile=tile),
        grid=(n, m_pad // tile),
        in_specs=[
            pl.BlockSpec((1, tile, rec), lambda j, t: (j, t, 0)),
            pl.BlockSpec((1,), lambda j, t: (j,)),
            pl.BlockSpec((q, num_cols), lambda j, t: (0, 0)),
            pl.BlockSpec((q, num_cols), lambda j, t: (0, 0)),
            pl.BlockSpec((q, num_cols), lambda j, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, 4), lambda j, t: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, q, 4), jnp.float32),
        interpret=interpret,
    )(raw, sizes, coeffs, lo, hi)
