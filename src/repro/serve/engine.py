"""Batched serving engine: prefill + decode with slot-based continuous
batching.

Requests occupy batch *slots*; each decode step advances every active slot by
one token (recurrent/windowed/full caches per family).  Finished slots are
refilled from the queue without draining the batch — the standard
continuous-batching shape, kept deliberately simple: the paper's contribution
lives in the data-exploration plane, and serving here exists to (a) exercise
every family's cached decode path end-to-end and (b) provide the serve-shape
dry-run cells with a real consumer.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0,
                 greedy: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.params, _ = self.model.init(jax.random.PRNGKey(seed))
        self.cache = self.model.init_cache(batch_slots, max_len)
        self.decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        # slot state
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.slot_tok = np.zeros(batch_slots, np.int32)
        self.queue: list[Request] = []
        self.steps = 0

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                # prefill via teacher-forced decode steps (cache fills token
                # by token; simple and family-uniform)
                for t, tok in enumerate(req.prompt):
                    self.slot_pos[s] = t
                    self.slot_tok[s] = tok
                    self._step_single_fill(s, t, tok)
                self.slot_pos[s] = len(req.prompt)

    def _step_single_fill(self, slot: int, pos: int, tok: int):
        toks = jnp.asarray(self.slot_tok[:, None])
        toks = toks.at[slot, 0].set(int(tok))
        posv = jnp.asarray(self.slot_pos)
        posv = posv.at[slot].set(int(pos))
        logits, self.cache = self.decode(self.params, self.cache, toks, posv)
        self._last_logits = logits

    # -------------------------------------------------------------- decode --
    def step(self):
        """One batched decode step across all active slots."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return False
        toks = jnp.asarray(self.slot_tok[:, None])
        posv = jnp.asarray(self.slot_pos)
        logits, self.cache = self.decode(self.params, self.cache, toks, posv)
        self.steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in active:
            req = self.slot_req[s]
            req.out_tokens.append(int(nxt[s]))
            self.slot_tok[s] = nxt[s]
            self.slot_pos[s] += 1
            if (len(req.out_tokens) >= req.max_new
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.slot_req[s] = None
        return True

    def run(self, max_steps: int = 10_000, wall_timeout_s: float = 120.0):
        t0 = time.perf_counter()
        done: list[Request] = []
        while self.queue or any(r is not None for r in self.slot_req):
            if not self.step():
                break
            if self.steps >= max_steps or time.perf_counter() - t0 > wall_timeout_s:
                break
        return self.steps
