"""Serving plane: batched decode engine over the model zoo, plus the OLA
workload server (shared-scan multi-query serving)."""

from repro.serve.engine import ServeEngine
from repro.serve.ola_server import (
    OLAWorkloadServer,
    WorkloadQuery,
    WorkloadResult,
    poisson_workload,
    select_plan,
)

__all__ = ["ServeEngine", "OLAWorkloadServer", "WorkloadQuery",
           "WorkloadResult", "poisson_workload", "select_plan"]
