"""Serving plane: batched decode engine over the model zoo, the OLA
workload server (shared-scan multi-query serving), and the Tier-1 rollup
answer cache that fronts it."""

from repro.serve.engine import ServeEngine
from repro.serve.ola_server import (
    OLAWorkloadServer,
    WorkloadQuery,
    WorkloadResult,
    poisson_workload,
    select_plan,
)
from repro.serve.rollup import RollupConfig, RollupTier, pattern_key

__all__ = ["ServeEngine", "OLAWorkloadServer", "WorkloadQuery",
           "WorkloadResult", "poisson_workload", "select_plan",
           "RollupConfig", "RollupTier", "pattern_key"]
