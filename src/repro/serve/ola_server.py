"""Workload serving for OLA queries: one shared scan, many concurrent queries.

The paper's end goal is workload-level exploration — "OLA-RAW chooses the
sampling plan that minimizes the execution time and guarantees the required
accuracy for each query in a given workload".  This module turns the
single-batch engine into a *server*: aggregate queries arrive as a stream and
are multiplexed onto a **single shared scan** of the raw table, mirroring the
slot/queue shape of ``serve/engine.py`` (continuous batching):

* **slots** — up to ``max_slots`` queries are resident at once, described by
  a dynamic :class:`~repro.core.queries.SlotTable` the jitted round step
  takes as data (no recompilation on admission/retirement);
* **mid-scan admission** — a query can join while the scan is running: its
  per-slot sufficient statistics are seeded from the
  :class:`~repro.core.synopsis.BiLevelSynopsis` (which absorbs the scan's
  extraction cache on demand), so it starts with an estimate over the
  already-started chunk set instead of cold;
* **early leave** — a query retires the moment its HAVING verdict or ε
  target is met, freeing its slot *without* stopping the scan for others
  (the scan is query-independent, so survivors' statistics are untouched);
* **top-up passes** — if the scan wound down (chunks closed at the then-live
  accuracy targets) but a newly admitted query needs more data, the server
  re-opens non-exhausted chunks and restarts the schedule head; per-chunk
  permutation cursors continue, so samples stay prefix-of-permutation;
* **per-query plan selection** — :func:`select_plan` picks
  chunk_level/holistic/single_pass/resource_aware per admitted query from
  the Eq. (4) cost terms the resource monitor already models.

Total work is sub-additive in the number of queries: a shared scan serves the
whole workload with roughly the tuple budget of its most demanding member,
instead of one scan per query (see ``benchmarks/bench_workload.py``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.controller import _answer_from_stats
from repro.core.engine import (
    IDLE,
    EngineConfig,
    SlotOLAEngine,
    slot_group_rows,
    slot_stats_fold,
    slot_stats_snapshot,
    slot_stats_write,
    zero_group_cells,
)
from repro.core.groupby import GroupSketch, promote_values
from repro.core.queries import (
    PLAN_CODES,
    GroupResult,
    Query,
    empty_slot_table,
    encode_slot,
    group_fanout,
    slot_table_clear,
    slot_table_set,
    slot_table_set_groups,
)
from repro.core.synopsis import BiLevelSynopsis
from repro.core import estimators as est
from repro.obs.explain import ExplainRecord, RoundSample
from repro.obs.metrics import LATENCY_BUCKETS_S, MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.sched.admission import (
    SHED,
    TIER1,
    ServerLoad,
    eq4_cost_terms,
    scan_tuples_per_s,
)
from repro.sched.preempt import select_victim
from repro.sched.scheduler import SchedulerConfig, WorkloadScheduler
from repro.sched.slo import NO_SLO, QuerySLO
from repro.serve.rollup import RollupConfig, RollupTier, pattern_key


@dataclasses.dataclass(frozen=True)
class MeasuredRates:
    """Measured IO/CPU rates for the Eq. (4) cost model.

    ``cpu_tuples_per_sec`` is the *aggregate* extraction throughput of one
    engine round step across the ``workers`` workers of the calibration run,
    ``io_bytes_per_sec`` the measured raw read bandwidth — both as reported
    by ``benchmarks/bench_slot_kernel.py``.  :func:`select_plan` rescales the
    CPU rate to the serving config's worker count (extraction parallelizes
    over workers; the read path does not).  The modeled constants in
    :class:`EngineConfig` remain the fallback when no measurement is
    available.
    """

    io_bytes_per_sec: float
    cpu_tuples_per_sec: float
    workers: int = 1
    source: str = "measured"
    # extraction cost (codec.extract_cost_per_tuple()) of the *calibration*
    # store: tuples/s is codec-relative, so serving a different codec
    # rescales by the cost ratio.  0 = unknown -> no rescaling.
    cost_per_tuple: float = 0.0
    # linear fit of the benchmark's S sweep, round_us(S) = base + slot_us·S:
    # the scan-side round cost and the marginal cost of one fully-counted
    # slot evaluation.  Feeds the scheduler's measured slot capacity
    # (repro.sched.fairness.measured_slot_capacity).  0 = calibration
    # predates the fit -> measured capacity unavailable.
    round_base_us: float = 0.0
    round_slot_us: float = 0.0


def default_rates_path() -> str:
    """Default location of the ``bench_slot_kernel`` calibration file.

    Anchored to the repo root (where ``benchmarks/bench_slot_kernel.py``
    writes it), *not* the process CWD — a server started from any other
    directory used to silently fall back to modeled rates.  The
    ``OLA_RATES_PATH`` environment variable overrides it for deployments
    that keep the calibration elsewhere.
    """
    env = os.environ.get("OLA_RATES_PATH")
    if env:
        return env
    repo_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    if os.path.isdir(os.path.join(repo_root, "benchmarks")):
        return os.path.join(repo_root, "BENCH_slot_kernel.json")
    # non-editable install: the walk-up lands in site-packages, which the
    # benchmark never writes — fall back to CWD and let deployments pin
    # the location with OLA_RATES_PATH
    return "BENCH_slot_kernel.json"


def load_measured_rates(path: Optional[str] = None,
                        ) -> Optional[MeasuredRates]:
    """Load the calibration block of a ``bench_slot_kernel`` result file.

    ``path=None`` resolves via :func:`default_rates_path` (repo root, or
    ``$OLA_RATES_PATH``).  Returns ``None`` (→ the caller falls back to the
    modeled defaults) when the file is missing or has no usable
    calibration — a server deployed without ever running the benchmark
    keeps working on the modeled rates.
    """
    import math

    if path is None:
        path = default_rates_path()
    try:
        with open(path) as f:
            data = json.load(f)
        cal = data["calibration"]
        cost = float(cal.get("cost_per_tuple", 0.0))

        def _opt(key):
            v = float(cal.get(key, 0.0))
            return v if math.isfinite(v) and v > 0 else 0.0

        rates = MeasuredRates(
            io_bytes_per_sec=float(cal["io_bytes_per_sec"]),
            cpu_tuples_per_sec=float(cal["cpu_tuples_per_sec"]),
            workers=int(cal.get("workers", data.get("workers", 1))),
            source=f"{path}:{cal.get('backend', '?')}",
            cost_per_tuple=cost if math.isfinite(cost) and cost > 0 else 0.0,
            round_base_us=_opt("round_base_us"),
            round_slot_us=_opt("round_slot_us"))
        # json.load accepts the NaN literal, and NaN compares False to
        # everything — require finite positives or fall back to modeled
        if not all(math.isfinite(v) and v > 0 for v in
                   (rates.io_bytes_per_sec, rates.cpu_tuples_per_sec,
                    rates.workers)):
            return None
        return rates
    except (OSError, KeyError, TypeError, ValueError):
        return None


def select_plan(store, config: EngineConfig, query: Query,
                rates: Optional[MeasuredRates] = None,
                decoded_fraction: float = 0.0) -> str:
    """Cost-model plan selector for one admitted query.

    Uses the two Eq. (4) cost terms the resource monitor models — a full
    pass's READ time ``T_io`` and EXTRACT time ``T_cpu`` — to pick the
    strategy whose regime the paper's Fig. 11 shows it wins:

    * ``epsilon <= 0`` (an exact answer is demanded): ``chunk_level`` — the
      reordering barrier delivers fully-extracted chunks in schedule order.
    * IO-bound (``T_cpu < T_io / 2``): ``holistic`` — extraction is free
      relative to reading, so extract everything that is read.
    * CPU-bound (``T_cpu > 2 T_io``): ``single_pass`` — stop extracting a
      chunk at local accuracy; reading ahead is cheap.
    * otherwise: ``resource_aware`` — let the runtime monitor switch.

    With ``rates`` (bench-measured, see :func:`load_measured_rates`) the two
    terms use the machine's *actual* read bandwidth and round-step extraction
    throughput instead of the modeled constants — the measured analogue of
    the paper's testbed calibration.  The terms come from
    :func:`repro.sched.admission.eq4_cost_terms` — the same pricing the
    admission controller judges SLO feasibility with.

    ``decoded_fraction`` is the parse-once decoded-chunk cache's coverage
    (see :meth:`~repro.data.pipeline.SlabPrefetcher.decoded_fraction`): it
    discounts the CPU term, so a well-cached store reads as more IO-bound —
    extraction over cached chunks really is near-free on re-scans.
    """
    t_io, t_cpu = eq4_cost_terms(store, config, rates,
                                 decoded_fraction=decoded_fraction)
    if query.epsilon <= 0.0:
        return "chunk_level"
    ratio = t_cpu / max(t_io, 1e-12)
    if ratio < 0.5:
        return "holistic"
    if ratio > 2.0:
        return "single_pass"
    return "resource_aware"


@dataclasses.dataclass(frozen=True)
class ServerOptions:
    """Construction options for :class:`OLAWorkloadServer`.

    Everything beyond the two required arguments (the chunk store and the
    :class:`EngineConfig`) lives here: the server is built as
    ``OLAWorkloadServer(store, config, options=ServerOptions(...))``.  Field
    semantics are documented on :meth:`OLAWorkloadServer.__init__` (they are
    the former keyword parameters, collapsed into one options object so the
    construction surface can grow without another positional-kwarg sprawl).
    The legacy keyword form still works and warns once per process.
    """

    max_slots: int = 8
    synopsis_budget_tuples: int = 4096
    confidence: float = 0.95
    schedule: Optional[np.ndarray] = None
    mesh: object = None
    engine: object = None
    measured_rates: Optional[MeasuredRates] = None
    rates_path: Optional[str] = None
    scheduler: object = None
    rollup: object = None
    tracer: object = None
    metrics: Optional[MetricsRegistry] = None
    # grouped discovery: minimum pure-tally mass (tuples) the slot's sketch
    # must absorb before non-pinned values are promoted into tracked cells.
    # Promotion is grow-only, so promoting off a few noisy early rounds
    # would permanently lock true heavy hitters out of the cell set; the
    # warmup lets the SpaceSaving ranking stabilize first.
    group_warmup_tuples: int = 1024


_legacy_kwargs_warned = False


def _options_from_legacy(kwargs: dict) -> ServerOptions:
    """Back-compat shim: map the pre-:class:`ServerOptions` keyword surface
    onto an options object, warning once per process."""
    global _legacy_kwargs_warned
    names = {f.name for f in dataclasses.fields(ServerOptions)}
    unknown = sorted(set(kwargs) - names)
    if unknown:
        raise TypeError(
            f"OLAWorkloadServer got unexpected keyword argument(s) {unknown}; "
            f"valid ServerOptions fields: {sorted(names)}")
    if not _legacy_kwargs_warned:
        warnings.warn(
            "passing OLAWorkloadServer construction keywords directly is "
            "deprecated; use OLAWorkloadServer(store, config, "
            "options=ServerOptions(...))",
            DeprecationWarning, stacklevel=3)
        _legacy_kwargs_warned = True
    return ServerOptions(**kwargs)


@dataclasses.dataclass
class WorkloadQuery:
    """One submitted query: the aggregate plus its workload metadata."""

    qid: int
    query: Query
    arrival_t: float = 0.0          # modeled seconds on the server clock
    plan: Optional[str] = None      # None -> cost-model selector
    row: Optional[dict] = None      # slot row encoded (and validated) at submit
    slo: Optional[QuerySLO] = None  # service-level objective (scheduler)
    queued: bool = False            # waited >= one admission pass for a slot
    preempted: bool = False         # evicted mid-residence at least once
    saved_stats: Optional[dict] = None  # eviction snapshot: re-admission seed
    key: Optional[tuple] = None     # rollup pattern key (None: not cacheable
                                    # or the server runs without a rollup tier)
    explain: Optional[ExplainRecord] = None  # lifecycle explain (repro.obs)


@dataclasses.dataclass
class WorkloadResult:
    qid: int
    name: str
    estimate: float
    lo: float
    hi: float
    err: float
    decision: int                   # HAVING verdict (-1/0/1)
    plan: str
    t_submit: float                 # arrival (modeled s)
    t_admit: float                  # slot grant (modeled s)
    t_done: float                   # retirement (modeled s)
    seeded_tuples: int              # tuples supplied by the synopsis at admit
    tuples_seen: int                # slot sample size at retirement
    rounds_resident: int
    from_synopsis: bool = False     # answered at admission, zero scan rounds
    unserved: bool = False          # scan exhausted before the slot saw any
                                    # tuple (no synopsis seed): estimate is NaN
    # scheduler outcome: "admitted" (straight into a slot), "queued" (waited
    # for one), "preempted" (evicted mid-residence for a deadline query and
    # completed after re-queueing — never dropped), "shed" (never held a
    # slot — answered best-effort from the synopsis, or unserved), or
    # "tier1" (answered from the rollup cache: no slot, no scan rounds,
    # plan="rollup").  Lets benchmarks separate scan-served answers from
    # cached and degraded ones.
    sched_outcome: str = "admitted"
    queue_wait: float = 0.0         # t_admit - t_submit (slot wait, modeled s)
    slo_met: Optional[bool] = None  # None when the query carried no SLO
    priority: str = "normal"        # SLO priority class (per-class latency
                                    # curves in benchmarks/bench_workload.py)
    # degraded-answer semantics (fault-tolerant scan plane): the estimate
    # describes the *surviving* population — at least one chunk was
    # quarantined (lost or irrecoverably corrupt) before this query
    # completed, so its answer is exact/valid over N - chunks_quarantined
    # chunks, not the full table.  Transient faults healed by retries never
    # set this flag (the sample is bit-identical to a fault-free run);
    # ``read_retries`` counts the retried chunk reads during the query's
    # residency (recovery overhead, 0 on packed residency).
    degraded: bool = False
    chunks_quarantined: int = 0
    read_retries: int = 0
    # grouped answer (Query(group_by=...)): one GroupResult per live group
    # cell — the tracked heavy-hitter values in discovery order, then the
    # __other__ spill cell (is_other=True) holding everything untracked.
    # None for ungrouped queries, and for grouped ones answered without a
    # scan residency (shed); the scalar estimate/lo/hi above stay
    # authoritative for the query's *base-predicate* population either way.
    groups: Optional[list[GroupResult]] = None
    # per-query explain record (repro.obs.explain): admission pricing, tier
    # routing rationale, per-round (m, est, ci) trajectory, degradation
    # events.  Excluded from equality — parity gates compare answers, not
    # telemetry — and its final est/ci_halfwidth are copied from this
    # result's own floats at finalize (bit-for-bit by construction).
    explain: Optional[ExplainRecord] = dataclasses.field(
        default=None, compare=False, repr=False)

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit

    @property
    def halfwidth(self) -> float:
        return (self.hi - self.lo) / 2.0


class OLAWorkloadServer:
    """Admits a stream of aggregate queries onto one shared OLA scan.

    The server is a host-side loop around :class:`SlotOLAEngine`:
    ``submit`` enqueues, ``step`` runs one engine round (admitting and
    retiring between rounds), ``run`` drives to completion.  The modeled
    clock is Eq. (4)'s overlapped-pipeline time ``max(t_io, t_cpu)`` plus
    any idle gaps the server skips while waiting for arrivals.
    """

    def __init__(self, store, config: EngineConfig,
                 options: Optional[ServerOptions] = None, **legacy_kwargs):
        """``options`` collects every construction knob (see
        :class:`ServerOptions`); the former keyword surface still works via
        ``**legacy_kwargs`` but warns once per process.

        ``engine`` may be a pre-built :class:`SlotOLAEngine` or
        :class:`~repro.core.engine_spmd.SlotSPMDEngine` (the server only uses
        the shared round-step protocol); with ``mesh`` and no ``engine`` a
        :class:`SlotSPMDEngine` is built over it.  ``measured_rates`` (or a
        ``rates_path`` benchmark file, see :func:`load_measured_rates`) feeds
        the Eq. (4) plan selector bench-measured IO/CPU rates; the modeled
        :class:`EngineConfig` constants stay the fallback.

        ``scheduler`` — a :class:`~repro.sched.WorkloadScheduler` (or a
        :class:`~repro.sched.SchedulerConfig`, wrapped automatically) —
        turns on SLO-aware serving: priority-ordered admission, feasibility
        shedding, weighted max-min fairness over the round budget, deadline
        enforcement, and variance-guided claim ordering.  ``None`` (default)
        keeps the historic admit-or-FIFO-queue behavior; the *neutral*
        scheduler configuration (``repro.sched.NEUTRAL``) reproduces it
        bit-exactly (gated in tests/test_sched.py).

        ``rollup`` — a :class:`~repro.serve.rollup.RollupConfig` (or a
        pre-built :class:`~repro.serve.rollup.RollupTier`) turns on the
        Tier-1 answer cache: hot query patterns mined from the completed
        log are promoted to rollup cells maintained incrementally from the
        scan's per-chunk sufficient statistics, and repeats are answered
        from the cell — no slot, no scan rounds — whenever the cached
        answer meets their accuracy target.  ``None`` (default) keeps
        every query on the Tier-2 scan path.

        ``tracer`` — a :class:`~repro.obs.trace.SpanTracer` records the
        query lifecycle (submit → admission → per-round claims/kernel/
        merge/estimate → retire) and the scan plane's READ/prefetch
        overlap as nested spans, exportable as chrome-trace JSON.  All
        instrumentation is host-side: a traced NEUTRAL run is
        round-for-round bit-exact with an untraced one.  ``metrics`` — a
        :class:`~repro.obs.metrics.MetricsRegistry` to surface counters
        on; one is created internally when omitted (see
        :meth:`metrics_snapshot`).
        """
        if legacy_kwargs:
            if options is not None:
                raise TypeError(
                    "pass either options=ServerOptions(...) or the legacy "
                    "keyword arguments, not both")
            options = _options_from_legacy(legacy_kwargs)
        opts = options if options is not None else ServerOptions()
        max_slots = opts.max_slots
        synopsis_budget_tuples = opts.synopsis_budget_tuples
        confidence = opts.confidence
        schedule = opts.schedule
        mesh, engine = opts.mesh, opts.engine
        measured_rates, rates_path = opts.measured_rates, opts.rates_path
        scheduler, rollup = opts.scheduler, opts.rollup
        tracer, metrics = opts.tracer, opts.metrics
        if engine is not None:
            if engine.store is not store:
                raise ValueError("engine was built over a different store")
            if synopsis_budget_tuples > 0 and engine.config.cache_cap == 0:
                raise ValueError(
                    "mid-scan synopsis seeding needs the extraction cache: "
                    "build the engine with cache_cap > 0 or pass "
                    "synopsis_budget_tuples=0")
            config = engine.config
            max_slots = engine.max_slots
        elif config.cache_cap == 0 and synopsis_budget_tuples > 0:
            # mid-scan seeding needs the extraction cache
            cap = max(64, int(np.ceil(4 * synopsis_budget_tuples
                                      / max(store.num_chunks, 1))))
            config = dataclasses.replace(config, cache_cap=cap)
        self.store = store
        self.config = config
        if engine is not None:
            self.engine = engine
        elif mesh is not None:
            from repro.core.engine_spmd import SlotSPMDEngine

            self.engine = SlotSPMDEngine(store, max_slots, config, mesh,
                                         schedule=schedule,
                                         confidence=confidence)
        else:
            self.engine = SlotOLAEngine(store, max_slots, config,
                                        schedule=schedule,
                                        confidence=confidence)
        self.rates = measured_rates
        if self.rates is None and rates_path is not None:
            self.rates = load_measured_rates(rates_path)
        # grouped query plane: the table's group capacity follows the engine
        # config (0 keeps the group arrays zero-width — the grouped code
        # compiles away and ungrouped serving is statically unchanged)
        self.max_groups = int(self.config.max_groups)
        self.table = empty_slot_table(max_slots, store.codec.num_cols,
                                      self.max_groups)
        self.state = self.engine.init_state()
        self.max_slots = max_slots
        # per-slot online group discovery (grouped occupants only): the
        # SpaceSaving sketch fed from each round's tally report, and the
        # host mirror of the slot's tracked values (discovery order)
        self._slot_sketch: list[Optional[GroupSketch]] = [None] * max_slots
        self._slot_groups: list[Optional[list[float]]] = [None] * max_slots
        self._group_warmup = int(opts.group_warmup_tuples)
        self.synopsis: Optional[BiLevelSynopsis] = None
        if synopsis_budget_tuples > 0:
            self.synopsis = BiLevelSynopsis(
                n_chunks=store.num_chunks, num_cols=store.codec.num_cols,
                budget_tuples=synopsis_budget_tuples,
                chunk_sizes=store.chunk_sizes)
        self.queue: list[WorkloadQuery] = []
        self.slot_wq: list[Optional[WorkloadQuery]] = [None] * max_slots
        self.slot_admit_t = np.zeros(max_slots)
        self.slot_admit_round = np.zeros(max_slots, np.int64)
        self.slot_plan = [""] * max_slots
        self.slot_seeded = np.zeros(max_slots, np.int64)
        self.results: list[WorkloadResult] = []
        self.rounds = 0
        self.topup_passes = 0
        self.idle_offset = 0.0
        self.truncated = False
        self._next_qid = 0
        if isinstance(scheduler, SchedulerConfig):
            scheduler = WorkloadScheduler(scheduler)
        self.scheduler: Optional[WorkloadScheduler] = scheduler
        if self.scheduler is not None:
            # slot_capacity="measured": derive the fairness capacity from
            # the loaded calibration's round-cost fit
            self.scheduler.calibrate(self.rates)
        if isinstance(rollup, RollupConfig):
            rollup = RollupTier(store, rollup)
        self.rollup: Optional[RollupTier] = rollup
        if self.rollup is not None and self.rollup.store is not store:
            raise ValueError("rollup tier was built over a different store")
        self.shed_count = 0
        self.preempt_count = 0
        self._service_times: list[float] = []   # scan service per retirement
        self._preview_cache: dict[int, tuple] = {}  # per intake pass, by qid
        self._rollup_cache: dict[int, tuple] = {}   # per intake pass, by qid
        self._cur_weights = np.ones(max_slots, np.float32)
        self._last_err: Optional[np.ndarray] = None  # (S,) last round report
        # fault tolerance: surviving-population bookkeeping.  Quarantining a
        # chunk (lost / irrecoverably corrupt) shrinks the population every
        # price and estimate must describe; the server re-derives these from
        # engine.quarantine_log after each round (see _note_quarantine).
        self._quarantine_seen = 0       # quarantine_log entries consumed
        self._quarantine_count = 0      # chunks quarantined so far
        self._eff_chunks = int(store.num_chunks)
        self._eff_tuples = int(store.num_tuples)
        self._eff_bytes = (float(np.asarray(store.chunk_sizes).sum())
                           * store.codec.record_bytes)
        self._slot_retries0 = np.zeros(max_slots, np.int64)
        self._scan_rate = scan_tuples_per_s(store, self.config,
                                            rates=self.rates)
        # observability: span tracer (no-op singleton when untraced) and
        # the metrics registry every scattered counter surfaces through
        self.tracer = tracer if tracer is not None else NULL_TRACER
        set_tracer = getattr(self.engine, "set_tracer", None)
        if set_tracer is not None and self.tracer.enabled:
            set_tracer(self.tracer)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._bind_metrics()

    def _bind_metrics(self) -> None:
        """Register the server's observable state on the metrics registry —
        all pull gauges reading live attributes (zero hot-path writes), plus
        the subsystem bindings: prefetcher counters, rollup tier tallies,
        scheduler admission decisions, and fault-injector event counts when
        the store is injector-wrapped."""
        reg = self.metrics
        reg.gauge("server_rounds", help="engine rounds run",
                  fn=lambda: self.rounds)
        reg.gauge("server_topup_passes", help="schedule re-open passes",
                  fn=lambda: self.topup_passes)
        reg.gauge("server_tuples_scanned",
                  help="raw tuples extracted by the shared scan",
                  fn=lambda: self.tuples_scanned)
        reg.gauge("server_queue_depth", help="queries waiting for a slot",
                  fn=lambda: len(self.queue))
        reg.gauge("server_slots_resident", help="occupied scan slots",
                  fn=lambda: sum(w is not None for w in self.slot_wq))
        reg.gauge("server_shed_count", help="queries shed (best-effort)",
                  fn=lambda: self.shed_count)
        reg.gauge("server_preempt_count", help="slot evictions",
                  fn=lambda: self.preempt_count)
        reg.gauge("server_chunks_quarantined",
                  help="chunks removed from the population",
                  fn=lambda: self._quarantine_count)
        reg.gauge("server_quarantine_events",
                  help="engine quarantine_log length",
                  fn=lambda: len(getattr(self.engine, "quarantine_log",
                                         None) or []))
        pf = getattr(self.engine, "pipeline", None)
        if pf is not None:
            pf.bind_metrics(reg)
        if self.rollup is not None:
            self.rollup.bind_metrics(reg)
        if self.scheduler is not None:
            self.scheduler.bind_metrics(reg)
        injected = getattr(self.store, "injected", None)
        if isinstance(injected, dict):
            for kind in sorted(injected):
                reg.gauge("faults_injected",
                          help="FaultInjector events by kind",
                          labels={"kind": kind},
                          fn=(lambda k=kind: self.store.injected.get(k, 0)))

    def metrics_snapshot(self) -> dict:
        """Public JSON-able observability snapshot: every registry
        instrument (pull gauges evaluated now — prefetcher/rollup/
        scheduler/fault counters included) plus ``quarantine_log``, the
        quarantined chunk ids in quarantine order (previously reachable
        only through engine internals)."""
        snap = self.metrics.snapshot()
        snap["quarantine_log"] = [
            int(j) for j in
            (getattr(self.engine, "quarantine_log", None) or [])]
        return snap

    def _decoded_fraction(self) -> float:
        """Parse-once cache coverage of the scan engine (0.0 when the engine
        has no decoded cache — packed residency, foreign engines)."""
        fn = getattr(self.engine, "decoded_fraction", None)
        return float(fn()) if fn is not None else 0.0

    def close(self) -> None:
        """Release engine resources (the stream-residency prefetcher's
        reader thread and host chunk cache); idempotent, packed no-op."""
        self.engine.close()

    def __enter__(self) -> "OLAWorkloadServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- clock ----
    @property
    def t_model(self) -> float:
        """Modeled seconds since server start (Eq. 4 clock + idle skips)."""
        return max(float(self.state.t_io), float(self.state.t_cpu)) \
            + self.idle_offset

    @property
    def tuples_scanned(self) -> int:
        """Raw tuples the shared scan has extracted (workload total)."""
        return int(np.asarray(self.state.scan_m).sum())

    # -------------------------------------------------- fault tolerance ----
    def _pipeline_retries(self) -> int:
        """Cumulative retried chunk reads (stream residency; 0 packed)."""
        pf = getattr(self.engine, "pipeline", None)
        return int(pf.read_retries) if pf is not None else 0

    @property
    def chunks_quarantined(self) -> int:
        return self._quarantine_count

    def quarantine(self, chunk_ids) -> None:
        """Quarantine chunks by hand (operator escape hatch / tests): the
        same path round_data takes when a read exhausts its retries."""
        from repro.core.engine import quarantine_chunks

        before = int(np.asarray(self.state.quarantined).sum())
        self.state = quarantine_chunks(self.state, chunk_ids)
        after = int(np.asarray(self.state.quarantined).sum())
        if after == before:
            return
        log = getattr(self.engine, "quarantine_log", None)
        if log is not None:
            qn = np.asarray(self.state.quarantined)
            known = set(int(j) for j in log)
            log.extend(sorted(int(j) for j in np.flatnonzero(qn)
                              if int(j) not in known))
        self._note_quarantine(force=True)

    def _note_quarantine(self, force: bool = False) -> None:
        """Absorb newly quarantined chunks into every population-priced
        structure: the synopsis forgets their windows, rollup cells covering
        them die, and the scan rate / admission totals re-price over the
        survivors.  Idempotent and O(cells + new ids); a no-op round costs
        one list-length check."""
        log = getattr(self.engine, "quarantine_log", None) or []
        if len(log) <= self._quarantine_seen and not force:
            return
        new = [int(j) for j in log[self._quarantine_seen:]]
        self._quarantine_seen = len(log)
        if new:
            # degradation is a per-query fact: every resident query's answer
            # now describes a smaller population — record it on their
            # explain trajectories
            for w in self.slot_wq:
                if w is not None and w.explain is not None:
                    w.explain.record_degradation(
                        round=self.rounds, t=self.t_model, chunk_ids=new)
            if self.tracer.enabled:
                self.tracer.event("quarantine", chunks=len(new))
        qn = np.asarray(self.state.quarantined)
        self._quarantine_count = int(qn.sum())
        sizes = np.asarray(self.store.chunk_sizes)
        alive = ~qn
        self._eff_chunks = int(alive.sum())
        self._eff_tuples = int(sizes[alive].sum())
        self._eff_bytes = (float(sizes[alive].sum())
                           * self.store.codec.record_bytes)
        # quarantined chunks leave the decoded cache too (their bytes are no
        # longer trusted), and the scan-rate CPU discount re-prices over the
        # shrunken coverage
        drop = getattr(self.engine, "drop_decoded_chunks", None)
        if drop is not None and new:
            drop(new)
        self._scan_rate = scan_tuples_per_s(
            self.store, self.config, rates=self.rates,
            total_bytes=self._eff_bytes, total_tuples=self._eff_tuples,
            decoded_fraction=self._decoded_fraction())
        if self.synopsis is not None and new:
            self.synopsis.drop_chunks(new)
        if self.rollup is not None and new:
            self.rollup.invalidate_chunks(new)

    def _mask_quarantined_seed(self, seed: Optional[dict]) -> Optional[dict]:
        """Zero a seed row's quarantined columns (preemption snapshots and
        pre-quarantine cells may still carry their tuples)."""
        if seed is None or self._quarantine_count == 0:
            return seed
        alive = ~np.asarray(self.state.quarantined)
        return dict(
            m=np.where(alive, np.asarray(seed["m"]), 0),
            ysum=np.where(alive, np.asarray(seed["ysum"]), 0.0),
            ysq=np.where(alive, np.asarray(seed["ysq"]), 0.0),
            psum=np.where(alive, np.asarray(seed["psum"]), 0.0))

    # ------------------------------------------------------------ intake ----
    def submit(self, query: Query, arrival_t: Optional[float] = None,
               plan: Optional[str] = None,
               slo: Optional[QuerySLO] = None) -> int:
        """Enqueue a query; returns its qid.  ``arrival_t`` defaults to the
        current modeled time (an online submission).  ``slo`` attaches a
        service-level objective (deadline / CI half-width target / priority
        class) — it only takes effect when the server was built with a
        ``scheduler``.

        Raises at submit time (not mid-scan at admission) when the query is
        outside the slot-encodable linear+range form, the plan is unknown,
        or the scan is already fully extracted with no synopsis to answer
        from (the query could never receive a tuple).
        """
        if plan is not None and plan not in PLAN_CODES:
            raise ValueError(
                f"unknown plan {plan!r}; expected one of {sorted(PLAN_CODES)}")
        if query.group_by is not None and self.max_groups == 0:
            raise ValueError(
                f"query {query.name!r} has group_by but the server was built "
                f"ungrouped; construct it with EngineConfig(max_groups="
                f"{query.group_by.max_groups}) or higher")
        row = encode_slot(query, self.store.codec.num_cols,
                          max_groups=self.max_groups)  # validates early
        if self.synopsis is None and not (
                (np.asarray(self.state.scan_m)
                 < np.asarray(self.store.chunk_sizes))
                & ~np.asarray(self.state.quarantined)).any():
            raise ValueError(
                "scan fully extracted and no synopsis configured: the query "
                "can never be served; construct the server with "
                "synopsis_budget_tuples > 0")
        qid = self._next_qid
        self._next_qid += 1
        at = self.t_model if arrival_t is None else float(arrival_t)
        key = (pattern_key(query, self.store.codec.num_cols)
               if self.rollup is not None else None)
        wq = WorkloadQuery(qid=qid, query=query, arrival_t=at,
                           plan=plan, row=row, slo=slo, key=key,
                           explain=ExplainRecord(qid=qid, name=query.name,
                                                 t_submit=at))
        self.queue.append(wq)
        self.queue.sort(key=lambda wq: (wq.arrival_t, wq.qid))
        if self.tracer.enabled:
            self.tracer.event("submit", qid=qid, query=query.name)
        return qid

    # --------------------------------------------------------- admission ----
    def _free_slots(self) -> list[int]:
        return [s for s in range(self.max_slots) if self.slot_wq[s] is None]

    def _refresh_synopsis(self) -> None:
        """Absorb the scan's extraction cache into the synopsis (on demand,
        before seeding a newcomer)."""
        if self.synopsis is None:
            return
        if int(np.asarray(self.state.scan_m).sum()) == 0:
            return
        variances = self.synopsis.within_variances(self.state)
        self.synopsis.update_from_engine(
            self.state, np.asarray(self.state.schedule), variances)

    def _admit_ready(self) -> None:
        if self.rollup is not None:
            self.rollup.maintain(self.t_model)
            self._rollup_cache = {}
        if self.scheduler is not None:
            self._admit_ready_scheduled()
            return
        now = self.t_model
        if self.rollup is not None:
            # Tier-1 short-circuit: a rollup-served query needs no slot, so
            # every ready hit is answered now — even when the slot table is
            # full and even behind other ready work (it consumes nothing
            # the others are waiting for)
            for wq in [w for w in self.queue if w.arrival_t <= now]:
                if self._try_tier1(wq):
                    self.queue.remove(wq)
        while self.queue and self.queue[0].arrival_t <= now:
            free = self._free_slots()   # recompute: seed-answered slots refree
            if not free:
                for wq in self.queue:   # ready queries kept waiting: record it
                    if wq.arrival_t <= now:
                        wq.queued = True
                break
            wq = self.queue.pop(0)
            self._admit(free[0], wq)

    @staticmethod
    def _wants_preview(wq: WorkloadQuery) -> bool:
        slo = wq.slo or NO_SLO
        return slo.has_deadline or np.isfinite(slo.target_halfwidth)

    @staticmethod
    def _outcome(wq: WorkloadQuery) -> str:
        if wq.preempted:
            return "preempted"
        return "queued" if wq.queued else "admitted"

    def _finish(self, wq: WorkloadQuery, result: WorkloadResult) -> None:
        """Single retirement funnel for every completion path (tier-1,
        shed, seed-retire, scan-retire): finalize + attach the explain
        record (its final est/CI copied from the result's own floats —
        bit-for-bit), count the outcome, observe latency, and emit the
        retire trace event."""
        if wq.explain is not None:
            result.explain = wq.explain.finalize(result)
        self.results.append(result)
        self.metrics.counter(
            "queries_total", help="completed queries by scheduler outcome",
            labels={"outcome": result.sched_outcome}).inc()
        self.metrics.histogram(
            "query_latency_s", help="submit->done latency (modeled s)",
            bounds=LATENCY_BUCKETS_S).observe(result.latency)
        if self.tracer.enabled:
            self.tracer.event("retire", qid=result.qid,
                              outcome=result.sched_outcome,
                              rounds=result.rounds_resident)

    def _admit_ready_scheduled(self) -> None:
        """Scheduler intake: ready queries are considered in queue-policy
        order; each is admitted, left queued, shed — or, with
        ``config.preempt``, granted a slot by evicting a strictly-lower-
        priority resident when its deadline is feasible *only* that way."""
        sched = self.scheduler
        now = self.t_model
        ready = [wq for wq in self.queue if wq.arrival_t <= now]
        ready.sort(key=sched.queue_key)
        # one synopsis refresh per intake pass; per-query previews are cached
        # for the pass (reused by feasibility, shedding, and _admit's
        # effective-ε translation) instead of re-absorbing the extraction
        # cache for every waiting deadline query on every round
        self._preview_cache = {}
        if self.synopsis is not None and any(map(self._wants_preview, ready)):
            self._refresh_synopsis()
        while True:
            ready = [wq for wq in self.queue if wq.arrival_t <= now]
            ready.sort(key=sched.queue_key)
            ahead: list[WorkloadQuery] = []  # still queued, ahead of this one
            restart = False
            for wq in ready:
                free = self._free_slots()  # recompute: seed-retired slots refree
                if free and ahead:
                    # a slot freed mid-pass *behind* queued work (a preempt-
                    # admitted query retired instantly from its seed):
                    # restart so the highest-priority queued query gets
                    # first claim — continuing here would hand the slot to
                    # a later, lower-priority candidate and price the
                    # earlier ones against a stale no-free-slot snapshot
                    restart = True
                    break
                decision = self._decide_admission(wq, len(free), ahead)
                if wq.explain is not None:
                    wq.explain.admission_reason = decision.reason
                    wq.explain.predicted_service_s = \
                        decision.predicted_service_s
                    wq.explain.predicted_finish_t = \
                        decision.predicted_finish_t
                if self.tracer.enabled:
                    self.tracer.event("admission", qid=wq.qid,
                                      action=decision.action)
                if decision.action == TIER1 and self._try_tier1(wq):
                    # rollup cache answered: no slot consumed, the slot
                    # picture is unchanged — no restart needed
                    self.queue.remove(wq)
                    continue
                if not free and self._try_preempt(wq, decision):
                    # a victim was evicted exactly because the deadline fits
                    # if the query runs now — the freed slot is the
                    # candidate's
                    self.queue.remove(wq)
                    self._admit(self._free_slots()[0], wq)
                elif decision.action == SHED:
                    self.queue.remove(wq)
                    self._shed(wq)
                elif free:
                    self.queue.remove(wq)
                    self._admit(free[0], wq)
                else:
                    wq.queued = True
                    ahead.append(wq)
            if not restart:
                break
            # termination: the restarted pass sees free slots with nothing
            # ahead, so its head query is admitted or shed — the queue
            # strictly shrinks every restart

    def _try_preempt(self, wq: WorkloadQuery, decision) -> bool:
        """Evict a strictly-lower-priority resident slot for ``wq`` when its
        deadline would die in the queue but fits if the query runs *now*.
        Returns True when a slot was freed (the victim is snapshotted and
        re-queued — see :func:`repro.sched.preempt.select_victim`)."""
        sched = self.scheduler
        slo = wq.slo or NO_SLO
        if not (sched.config.preempt and slo.has_deadline):
            return False
        deadline_t = wq.arrival_t + slo.deadline_s
        if decision.predicted_finish_t <= deadline_t:
            return False                # feasible by waiting: don't evict
        now = self.t_model
        if max(now, wq.arrival_t) + decision.predicted_service_s > deadline_t:
            return False                # hopeless even with a slot right now
        stopped = np.asarray(self.state.stopped)
        # grouped residents are not evictable: the eviction snapshot saves
        # only the scalar stats row, so a re-admitted grouped query would
        # silently lose its per-group cells and its discovered value set
        evictable = [self.slot_wq[s] is not None and not stopped[s]
                     and self.slot_wq[s].query.group_by is None
                     for s in range(self.max_slots)]
        victim = select_victim(
            wq.slo, [w.slo if w is not None else None for w in self.slot_wq],
            self.slot_admit_t, evictable)
        if victim is None:
            return False
        self._evict(victim)
        return True

    def _evict(self, s: int) -> None:
        """Preempt slot ``s``: snapshot its statistics row as the occupant's
        re-admission seed, release the slot, and re-queue the occupant
        (flagged ``preempted`` — it completes later, never dropped)."""
        wq = self.slot_wq[s]
        wq.saved_stats = slot_stats_snapshot(self.state, s)
        wq.preempted = True
        wq.queued = True
        self.preempt_count += 1
        if self.tracer.enabled:
            self.tracer.event("preempt", qid=wq.qid, slot=s)
        self._release(s)
        self.queue.append(wq)
        self.queue.sort(key=lambda w: (w.arrival_t, w.qid))

    def _cached_preview(self, wq: WorkloadQuery) -> tuple:
        out = self._preview_cache.get(wq.qid)
        if out is None:
            out = self._seed_answer(wq.query, seed=wq.saved_stats, key=wq.key)
            self._preview_cache[wq.qid] = out
        return out

    def _rollup_answer(self, wq: WorkloadQuery) -> Optional[tuple]:
        """Tier-1 answer preview from the query's promoted rollup cell:
        ``(m, estimate, lo, hi, err, having_decision)`` — exact over the
        cell's fully-covered chunks (the FPC zeroes their variance), CI
        over the remainder — or None when no cell serves the pattern.
        Cached per intake pass (cells only change between rounds)."""
        if self.rollup is None or wq.key is None:
            return None
        cell = self.rollup.get(wq.key)
        if cell is None or int(cell.m.sum()) == 0:
            return None
        out = self._rollup_cache.get(wq.qid)
        if out is None:
            m, est_v, lo, hi, err = self._seed_answer(
                wq.query, seed=cell.seed_dict())
            q = wq.query
            decision = -1
            if q.having is not None and m > 0:
                decision = int(est.having_decision(lo, hi, q.having.op,
                                                   q.having.threshold))
            out = (m, est_v, lo, hi, err, decision)
            self._rollup_cache[wq.qid] = out
        return out

    def _try_tier1(self, wq: WorkloadQuery) -> bool:
        """Serve ``wq`` from the rollup cache iff the cached answer meets
        its accuracy ask (the slot-effective ε, or a decided HAVING).
        Tier-1 answers hold no slot and consume zero scan rounds."""
        if wq.query.group_by is not None:
            # a rollup cell carries only base-predicate scalar stats — it
            # cannot produce the per-group cells a grouped answer promises
            return False
        ans = self._rollup_answer(wq)
        if ans is None:
            return False
        m, est_v, lo, hi, err, decision = ans
        if m == 0:
            return False
        eps_eff = wq.query.epsilon
        if self.scheduler is not None:
            eps_eff = self.scheduler.effective_epsilon(wq.query, wq.slo,
                                                       est_v)
        if err > eps_eff and decision == -1:
            return False
        now = self.t_model
        cell = self.rollup.get(wq.key)
        cell.touch(now)
        self.rollup.tier1_hits += 1
        self.rollup.observe(wq.query, wq.key, now)  # hits keep patterns hot
        latency = now - wq.arrival_t
        slo_met = None
        if wq.slo is not None:
            slo_met = wq.slo.met(latency, (hi - lo) / 2.0)
        if wq.explain is not None:
            wq.explain.tier = "tier1"
            wq.explain.tier_reason = (
                "promoted rollup cell decided the HAVING verdict"
                if err > eps_eff else
                f"promoted rollup cell meets target (err {err:.3g} <= "
                f"eps {eps_eff:.3g}); no slot, no scan rounds")
        self._finish(wq, WorkloadResult(
            qid=wq.qid, name=wq.query.name, estimate=est_v, lo=lo, hi=hi,
            err=err, decision=decision, plan="rollup",
            t_submit=wq.arrival_t, t_admit=now, t_done=now,
            seeded_tuples=m, tuples_seen=m, rounds_resident=0,
            sched_outcome="tier1", queue_wait=latency, slo_met=slo_met,
            priority=(wq.slo or NO_SLO).priority,
            degraded=self._quarantine_count > 0,
            chunks_quarantined=self._quarantine_count))
        return True

    def _rollup_on_retire(self, wq: WorkloadQuery, s: Optional[int],
                          valid: bool) -> None:
        """Completion hook for the rollup miner: log the pattern (promoting
        it when the workload has shown it hot) and, when the query retired
        from a slot with real statistics, fold that final row into its
        cell.  A newly promoted cell is birth-seeded from the synopsis so
        the *next* repeat already starts warm even if no slot runs the
        pattern again before then."""
        if self.rollup is None or wq.key is None:
            return
        promoted = self.rollup.observe(wq.query, wq.key, self.t_model)
        if promoted is not None and self.synopsis is not None:
            seed = self.synopsis.seed_slot(wq.query)
            if seed is not None:
                promoted.fold(seed)
        if s is not None and valid:
            self.rollup.fold(wq.key, slot_stats_snapshot(self.state, s))

    def _observed_mean_service_s(self) -> Optional[float]:
        """Mean scan service over completed queries; None before the first
        retirement.  Single source for every admission-path consumer."""
        st = self._service_times
        return (sum(st) / len(st)) if st else None

    def _service_prior_s(self) -> float:
        """Cold-start per-job service prior for wait pricing: the observed
        mean service when any query has completed, else one full pass at
        the scan rate (the CLT worst case).  Never the *candidate's* own
        seed-discounted prediction — the queue is other people's work."""
        mean = self._observed_mean_service_s()
        if mean is not None:
            return mean
        return float(self._eff_tuples) / max(self._scan_rate, 1e-12)

    def _wait_components(self, ahead: list) -> tuple:
        """Model-priced wait parts for the admission snapshot:
        ``(slot_drain_s, queue_ahead_service_s)``.  Each resident slot's
        remaining service is its class quantile minus its elapsed
        residence; the drain is the *minimum* across slots (any slot
        freeing admits the head of the queue).  Each queued job ahead is
        priced at its own class's quantile — not the candidate's."""
        model = self.scheduler.service_model
        prior = self._service_prior_s()
        now = self.t_model
        drains = []
        for s in range(self.max_slots):
            w = self.slot_wq[s]
            if w is None:
                continue
            pred = model.predict((w.slo or NO_SLO).priority, prior)
            drains.append(max(pred - max(now - self.slot_admit_t[s], 0.0),
                              0.0))
        drain = min(drains) if drains else None
        ahead_s = sum(model.predict((w.slo or NO_SLO).priority, prior)
                      for w in ahead)
        return drain, float(ahead_s)

    def _decide_admission(self, wq: WorkloadQuery, n_free: int, ahead: list):
        slo = wq.slo or NO_SLO
        grouped = wq.query.group_by is not None
        seed_m, seed_err, seed_est = 0, float("inf"), None
        rollup_err = float("inf")
        rollup = self._rollup_answer(wq)
        if rollup is not None:
            r_m, r_est, _, _, r_err, r_dec = rollup
            # Tier-1 routing input: a decided HAVING is as good as err 0;
            # the cell also doubles as the feasibility seed (Eq. (4) prices
            # only the *remaining* scan when the cache falls short of ε)
            rollup_err = 0.0 if r_dec != -1 else r_err
            seed_m, seed_est, seed_err = r_m, r_est, r_err
        if self._wants_preview(wq):     # feasibility needs the seed preview
            m, e, _, _, err = self._cached_preview(wq)
            if m > seed_m:
                seed_m, seed_est, seed_err = m, e, err
        if grouped:
            # a cached scalar answer can neither serve nor seed the
            # per-group cells (they fill only from scan rounds while live):
            # never tier-1 route, and price the scan without a seed discount
            # (seed_est survives as the ε-translation magnitude anchor)
            rollup_err = float("inf")
            seed_m, seed_err = 0, float("inf")
        drain, ahead_s = self._wait_components(ahead)
        load = ServerLoad(
            now=self.t_model, free_slots=n_free, queue_ahead=len(ahead),
            scan_rate=self._scan_rate,
            total_tuples=int(self._eff_tuples),
            mean_service_s=self._observed_mean_service_s(),
            slot_drain_s=drain, queue_ahead_service_s=ahead_s)
        # feasibility must be judged against the ε the slot will actually
        # run at — a finite target_halfwidth tightens it (same translation
        # _admit applies to the slot row)
        eps_eff = self.scheduler.effective_epsilon(wq.query, wq.slo, seed_est)
        return self.scheduler.admission.decide(
            arrival_t=wq.arrival_t, slo=slo, epsilon=eps_eff,
            load=load, seed_m=seed_m, seed_err=seed_err,
            rollup_err=rollup_err,
            group_count=(wq.query.group_by.effective_top_k if grouped else 0))

    def _seed_answer(self, query: Query, seed: Optional[dict] = None,
                     key: Optional[tuple] = None) -> tuple:
        """Best scan-free answer available right now: ``(m, estimate, lo,
        hi, err)`` — ``(0, nan, nan, nan, inf)`` when nothing can serve the
        query.  ``seed`` overrides the lookups (a preempted query's
        statistics snapshot is a richer seed than the synopsis); otherwise
        the synopsis row and — when ``key`` names a promoted rollup cell —
        the cell row compete by sample size, and the caller is assumed to
        have refreshed the synopsis (the scheduled intake pass does,
        once).  Single construction shared by admission feasibility, the
        effective-ε translation, shedding, and the rollup preview."""
        if seed is None:
            if self.synopsis is not None:
                seed = self.synopsis.seed_slot(query)
            if self.rollup is not None and key is not None:
                cell = self.rollup.get(key)
                if cell is not None and (
                        seed is None or int(cell.m.sum())
                        > int(np.asarray(seed["m"]).sum())):
                    seed = cell.seed_dict()
        seed = self._mask_quarantined_seed(seed)
        if seed is None or int(seed["m"].sum()) == 0:
            return 0, float("nan"), float("nan"), float("nan"), float("inf")
        # population substitution: after quarantine the estimator's N/M are
        # the surviving totals (the same rescale the jitted round applies)
        stats_row = self.state.stats._replace(
            m=jnp.asarray(seed["m"], jnp.int32),
            ysum=jnp.asarray(seed["ysum"])[None],
            ysq=jnp.asarray(seed["ysq"])[None],
            psum=jnp.asarray(seed["psum"])[None],
            n_total=self._eff_chunks, m_total=self._eff_tuples)
        est_v, lo, hi, err = _answer_from_stats([query], stats_row)
        return (int(seed["m"].sum()), float(np.asarray(est_v)[0]),
                float(np.asarray(lo)[0]), float(np.asarray(hi)[0]),
                float(np.asarray(err)[0]))

    def _shed(self, wq: WorkloadQuery) -> None:
        """Answer a shed query immediately from the synopsis (flagged
        best-effort) — or flag it unserved when no seed exists.  A shed
        query never holds a slot and never costs a scan round."""
        now = self.t_model
        q = wq.query
        m_seen, estimate, lo, hi, err = self._cached_preview(wq)
        if m_seen == 0:
            decision = -1
            unserved, from_syn = True, False
        else:
            decision = -1
            if q.having is not None:
                decision = int(est.having_decision(lo, hi, q.having.op,
                                                   q.having.threshold))
            unserved, from_syn = False, True
        latency = now - wq.arrival_t
        slo_met = None
        if wq.slo is not None:
            # a shed answer arrives instantly, so the deadline alone would
            # always "hit" — honesty requires the best-effort estimate to
            # also meet the query's accuracy ask (ε or a HAVING verdict)
            accurate = (not unserved) and (err <= q.epsilon or decision != -1)
            slo_met = accurate and wq.slo.met(latency, (hi - lo) / 2.0)
        if wq.explain is not None and not wq.explain.tier_reason:
            wq.explain.tier_reason = (
                "shed: no seed available, answer unserved" if unserved
                else "shed: best-effort synopsis answer, no scan rounds")
        self._finish(wq, WorkloadResult(
            qid=wq.qid, name=q.name, estimate=estimate, lo=lo, hi=hi,
            err=err, decision=decision, plan="shed",
            t_submit=wq.arrival_t, t_admit=now, t_done=now,
            seeded_tuples=m_seen, tuples_seen=m_seen, rounds_resident=0,
            from_synopsis=from_syn, unserved=unserved, sched_outcome="shed",
            queue_wait=now - wq.arrival_t, slo_met=slo_met,
            priority=(wq.slo or NO_SLO).priority,
            degraded=self._quarantine_count > 0,
            chunks_quarantined=self._quarantine_count))
        self.shed_count += 1
        # a shed still evidences demand for the pattern: mine it (no fold —
        # the query never held a slot, there are no statistics to merge)
        self._rollup_on_retire(wq, None, False)

    def _admit(self, s: int, wq: WorkloadQuery) -> None:
        plan = wq.plan or select_plan(self.store, self.config, wq.query,
                                      rates=self.rates,
                                      decoded_fraction=self._decoded_fraction())
        row = wq.row or encode_slot(wq.query, self.store.codec.num_cols,
                                    max_groups=self.max_groups)
        row["plan"] = np.int32(PLAN_CODES[plan])
        self._refresh_synopsis()
        if wq.saved_stats is not None:
            # preempted query returning to a slot: its eviction snapshot is
            # the seed — every tuple it already counted, at full per-chunk
            # resolution (strictly richer than the synopsis)
            seed = wq.saved_stats
        else:
            seed = self.synopsis.seed_slot(wq.query) if self.synopsis else None
            if self.rollup is not None and wq.key is not None:
                cell = self.rollup.get(wq.key)
                if cell is not None and (
                        seed is None or int(cell.m.sum())
                        > int(np.asarray(seed["m"]).sum())):
                    # Tier-2 with a Tier-1 discount: the cell alone missed
                    # the target, but it out-samples the synopsis — the
                    # slot starts from the cached partial aggregate and
                    # scans only the remainder (both are permutation-window
                    # samples inside the scanned prefix, so future round
                    # deltas compose without overlap)
                    seed = cell.seed_dict()
        if (self.scheduler is not None and wq.slo is not None
                and np.isfinite(wq.slo.target_halfwidth)):
            # absolute CI half-width target -> effective relative ε for the
            # slot row, anchored on the synopsis magnitude estimate (the
            # pass-cached preview — the same one admission feasibility used)
            _, seed_est, *_ = self._cached_preview(wq)
            eps_eff = self.scheduler.effective_epsilon(wq.query, wq.slo,
                                                       seed_est)
            row["eps"] = np.float32(eps_eff)

        n = self.store.num_chunks
        stats, seeded = slot_stats_write(self.state.stats, s, seed, n)
        self.state = self.state._replace(
            stats=stats, stopped=self.state.stopped.at[s].set(False))
        if self._last_err is not None:
            # the previous occupant's round-report error is stale for the
            # new one; claim weighting treats it as "no estimate yet"
            self._last_err = self._last_err.copy()
            self._last_err[s] = np.inf
        self.table = slot_table_set(self.table, s, row)
        # slot_table_set reset the row's fairness weight to 1.0 — keep the
        # written-weights cache in sync, or _apply_scheduling could skip the
        # next write (computed vector unchanged) and leave the new occupant
        # running at full budget instead of its max-min share
        self._cur_weights = self._cur_weights.copy()
        self._cur_weights[s] = np.float32(row.get("weight", 1.0))
        self.slot_wq[s] = wq
        self.slot_admit_t[s] = self.t_model
        self.slot_admit_round[s] = self.rounds
        self.slot_plan[s] = plan
        self.slot_seeded[s] = seeded
        self._slot_retries0[s] = self._pipeline_retries()
        gb = wq.query.group_by
        if gb is not None:
            # group cells start from zero for the new occupant (a prior
            # grouped resident may have left stale per-cell rows); pinned
            # values are live from the row write, the rest get discovered
            self.state = zero_group_cells(self.state, s)
            self._slot_sketch[s] = GroupSketch(max(2 * gb.max_groups, 8))
            self._slot_groups[s] = [float(v) for v in (gb.values or ())]
        else:
            self._slot_sketch[s] = None
            self._slot_groups[s] = None
        if wq.explain is not None:
            # the Eq. (4) pricing the plan was chosen under, frozen at the
            # admission instant (population-adjusted, cache-discounted)
            df = self._decoded_fraction()
            t_io, t_cpu = eq4_cost_terms(
                self.store, self.config, self.rates,
                total_bytes=self._eff_bytes,
                total_tuples=self._eff_tuples, decoded_fraction=df)
            wq.explain.plan = plan
            wq.explain.cost_t_io_s = float(t_io)
            wq.explain.cost_t_cpu_s = float(t_cpu)
            wq.explain.decoded_fraction = float(df)
            wq.explain.effective_epsilon = float(
                row.get("eps", wq.query.epsilon))
            if not wq.explain.admission_reason:
                wq.explain.admission_reason = "fifo: free slot"
        if self.tracer.enabled:
            self.tracer.event("admit", qid=wq.qid, slot=s, plan=plan)

        # Section 6.3 best case, per slot: the seed alone may already meet
        # the target — answer at admission without consuming scan rounds.
        # No top-up here: while the newcomer is live its accuracy votes keep
        # chunks from closing early, and if the scan still winds down before
        # it is satisfied, step()'s exhausted branch re-opens chunks then —
        # top-up passes happen only when provably needed.
        if seed is not None:
            self._try_retire_from_seed(s, wq)

    def _try_retire_from_seed(self, s: int, wq: WorkloadQuery) -> bool:
        q = wq.query
        if q.group_by is not None:
            # the seed meets the scalar target at best; the per-group cells
            # only fill from scan rounds, so a grouped query always scans
            return False
        stats_row = self.state.stats._replace(
            m=self.state.stats.m[s], ysum=self.state.stats.ysum[s][None],
            ysq=self.state.stats.ysq[s][None],
            psum=self.state.stats.psum[s][None],
            n_total=self._eff_chunks, m_total=self._eff_tuples)
        est_v, lo, hi, err = _answer_from_stats([q], stats_row)
        e = float(np.asarray(err)[0])
        decision = -1
        if q.having is not None:
            decision = int(est.having_decision(
                np.asarray(lo)[0], np.asarray(hi)[0], q.having.op,
                q.having.threshold))
        if e > q.epsilon and decision == -1:
            return False
        self._rollup_on_retire(wq, s, True)
        lo_f, hi_f = float(np.asarray(lo)[0]), float(np.asarray(hi)[0])
        slo_met = None
        if wq.slo is not None:
            slo_met = wq.slo.met(self.t_model - wq.arrival_t,
                                 (hi_f - lo_f) / 2.0)
        if wq.explain is not None and not wq.explain.tier_reason:
            wq.explain.tier_reason = ("seed met the target at admission "
                                      "(answered without scan rounds)")
        self._finish(wq, WorkloadResult(
            qid=wq.qid, name=q.name, estimate=float(np.asarray(est_v)[0]),
            lo=lo_f, hi=hi_f, err=e,
            decision=decision, plan=self.slot_plan[s],
            t_submit=wq.arrival_t, t_admit=self.slot_admit_t[s],
            t_done=self.t_model, seeded_tuples=int(self.slot_seeded[s]),
            tuples_seen=int(np.asarray(self.state.stats.m[s]).sum()),
            rounds_resident=0, from_synopsis=True,
            sched_outcome=self._outcome(wq),
            queue_wait=self.slot_admit_t[s] - wq.arrival_t, slo_met=slo_met,
            priority=(wq.slo or NO_SLO).priority,
            degraded=self._quarantine_count > 0,
            chunks_quarantined=self._quarantine_count,
            read_retries=max(self._pipeline_retries()
                             - int(self._slot_retries0[s]), 0)))
        self._release(s)
        return True

    def _release(self, s: int) -> None:
        self.table = slot_table_clear(self.table, s)
        self.state = self.state._replace(
            stopped=self.state.stopped.at[s].set(True))
        self.slot_wq[s] = None
        self._slot_sketch[s] = None
        self._slot_groups[s] = None

    # ----------------------------------------------------------- top-up ----
    def _begin_topup_pass(self) -> bool:
        """Re-open early-closed chunks and rewind the schedule head to the
        first not-closed position (not all the way to 0 — fully-extracted
        prefix chunks would only burn a claim round each).  Worker claims
        are dropped to IDLE so re-claiming is race-free; a re-opened chunk
        is charged as a fresh raw READ when extraction resumes past its
        cached tuples.  Per-chunk permutation cursors continue where they
        left off, so samples stay prefixes of each chunk's random order.
        Returns False when every chunk is fully extracted (nothing to top
        up)."""
        sizes = np.asarray(self.store.chunk_sizes)
        scan_m = np.asarray(self.state.scan_m)
        # a quarantined chunk is permanently out of the population: it can
        # never be topped up, and re-opening it would stall the scan on a
        # chunk whose reads always fail
        not_exhausted = ((scan_m < sizes)
                         & ~np.asarray(self.state.quarantined))
        if not not_exhausted.any():
            return False
        reopened = np.asarray(self.state.closed) & not_exhausted
        closed = np.asarray(self.state.closed) & ~not_exhausted
        schedule = np.asarray(self.state.schedule)
        done_sched = closed[schedule]
        new_head = (len(schedule) if done_sched.all()
                    else int(np.argmax(~done_sched)))
        raw_touched = np.asarray(self.state.raw_touched) & ~reopened
        self.state = self.state._replace(
            closed=jnp.asarray(closed),
            head=jnp.asarray(new_head, jnp.int32),
            cur=jnp.full_like(self.state.cur, IDLE),
            raw_touched=jnp.asarray(raw_touched))
        self.topup_passes += 1
        return True

    # ---------------------------------------------------------- grouping ----
    def _group_results(self, rep, s: int, wq: WorkloadQuery,
                       ) -> Optional[list[GroupResult]]:
        """Assemble slot ``s``'s grouped answer from the round report: one
        :class:`GroupResult` per tracked value (discovery order) plus the
        ``__other__`` spill cell.  HAVING is judged per cell, host-side, on
        the same CI the report carries."""
        q = wq.query
        if q.group_by is None:
            return None
        tracked = self._slot_groups[s] or []
        g_est = np.asarray(rep.g_est[s], float)
        g_lo = np.asarray(rep.g_lo[s], float)
        g_hi = np.asarray(rep.g_hi[s], float)
        g_err = np.asarray(rep.g_err[s], float)
        g_n = np.asarray(rep.g_n[s])
        cells = [(i, float(v), False) for i, v in enumerate(tracked)]
        cells.append((self.max_groups, float("nan"), True))
        out = []
        for i, value, is_other in cells:
            decision = -1
            if q.having is not None and int(g_n[i]) > 0:
                decision = int(est.having_decision(
                    float(g_lo[i]), float(g_hi[i]), q.having.op,
                    q.having.threshold))
            out.append(GroupResult(
                value=value, estimate=float(g_est[i]), lo=float(g_lo[i]),
                hi=float(g_hi[i]), err=float(g_err[i]), n=int(g_n[i]),
                decision=decision, is_other=is_other))
        return out

    def _rollup_group_cells(self, wq: WorkloadQuery, s: int) -> None:
        """Per-group rollup mining at retirement: each tracked cell is the
        completed run of the equivalent :func:`group_fanout` scalar pattern,
        so it feeds the Tier-1 miner under that pattern's key and — once
        promoted — folds the cell's per-chunk stats row through the same
        cell-fold contract scalar slots use.  A later fan-out-style repeat
        of a hot group then starts warm (or answers Tier-1 outright)."""
        gb = wq.query.group_by
        if self.rollup is None or gb is None:
            return
        tracked = self._slot_groups[s] or []
        if not tracked:
            return
        rows = slot_group_rows(self.state, s)
        base = dataclasses.replace(wq.query, group_by=None)
        for i, v in enumerate(tracked):
            fq = group_fanout(base, gb.col, [v])[0]
            key = pattern_key(fq, self.store.codec.num_cols)
            if key is None:
                continue
            self.rollup.observe(fq, key, self.t_model)
            self.rollup.fold(key, dict(
                m=rows["gm"][i], ysum=rows["gys"][i],
                ysq=rows["gyq"][i], psum=rows["gps"][i]))

    def _fold_group_discovery(self, rep) -> None:
        """Post-round online discovery for live grouped slots: fold the
        round's tally report into each slot's SpaceSaving sketch, promote
        newly-heavy values into free tracked cells (grow-only), and restart
        the ``__other__`` window whenever the tracked set changes (the spill
        cell's meaning shrank, so its stats must restart — the post-restart
        sample window stays a uniform without-replacement sample)."""
        if self.max_groups == 0:
            return
        g_tal = None
        stopped = np.asarray(self.state.stopped)
        for s in range(self.max_slots):
            wq = self.slot_wq[s]
            if (wq is None or stopped[s] or wq.query.group_by is None
                    or self._slot_sketch[s] is None):
                continue
            if g_tal is None:
                g_tal = np.asarray(rep.g_tal)
            sketch = self._slot_sketch[s]
            sketch.fold(g_tal[s])
            if sketch.mass < self._group_warmup:
                continue    # ranking not yet trustworthy (see ServerOptions)
            gb = wq.query.group_by
            tracked = self._slot_groups[s]
            new = promote_values(sketch, tracked, gb.max_groups)
            if not new:
                continue
            tracked.extend(float(v) for v in new)
            g = self.max_groups + 1
            gval = np.zeros((g,), np.float32)
            gact = np.zeros((g,), np.float32)
            gval[:len(tracked)] = np.asarray(tracked, np.float32)
            gact[:len(tracked)] = 1.0
            gact[g - 1] = 1.0   # __other__ stays live
            self.table = slot_table_set_groups(self.table, s, gval, gact)
            self.state = zero_group_cells(self.state, s, cells=[g - 1])
            if self.tracer.enabled:
                self.tracer.event("group_promote", qid=wq.qid, slot=s,
                                  values=[float(v) for v in new])

    # -------------------------------------------------------------- step ----
    def _retire_finished(self, rep, unserved: frozenset = frozenset()) -> None:
        stopped = np.asarray(self.state.stopped)
        m_rows = np.asarray(self.state.stats.m)
        for s in range(self.max_slots):
            wq = self.slot_wq[s]
            if wq is None or not stopped[s]:
                continue
            # a slot that never received a single tuple (no scan round, no
            # synopsis seed — e.g. deadline-enforced before its first round
            # after the scan became a census) has no answer: flag it
            # unserved rather than reporting a fabricated zero
            bad = s in unserved or int(m_rows[s].sum()) == 0
            lo_f, hi_f = float(rep.lo[s]), float(rep.hi[s])
            slo_met = None
            if wq.slo is not None:
                slo_met = wq.slo.met(self.t_model - wq.arrival_t,
                                     float("nan") if bad
                                     else (hi_f - lo_f) / 2.0)
            if wq.explain is not None and not wq.explain.tier_reason:
                wq.explain.tier_reason = (
                    "scan exhausted before the slot saw any tuple" if bad
                    else "scan-served: retired at its stop condition")
            self._finish(wq, WorkloadResult(
                qid=wq.qid, name=wq.query.name,
                estimate=float("nan") if bad else float(rep.estimate[s]),
                lo=lo_f,
                hi=hi_f, err=float(rep.err[s]),
                decision=int(rep.decided[s]), plan=self.slot_plan[s],
                t_submit=wq.arrival_t, t_admit=self.slot_admit_t[s],
                t_done=self.t_model, seeded_tuples=int(self.slot_seeded[s]),
                tuples_seen=int(np.asarray(self.state.stats.m[s]).sum()),
                rounds_resident=int(self.rounds - self.slot_admit_round[s]),
                unserved=bad,
                sched_outcome=self._outcome(wq),
                queue_wait=float(self.slot_admit_t[s] - wq.arrival_t),
                slo_met=slo_met,
                priority=(wq.slo or NO_SLO).priority,
                degraded=self._quarantine_count > 0,
                chunks_quarantined=self._quarantine_count,
                read_retries=max(self._pipeline_retries()
                                 - int(self._slot_retries0[s]), 0),
                groups=None if bad else self._group_results(rep, s, wq)))
            service = self.t_model - self.slot_admit_t[s]
            self._service_times.append(service)
            if self.scheduler is not None:
                # feed the per-class service-time sketch (quantile admission)
                self.scheduler.observe_service(wq.slo, service)
            self._rollup_on_retire(wq, s, not bad)
            if not bad:
                self._rollup_group_cells(wq, s)
            self._release(s)

    def _any_active(self) -> bool:
        return any(wq is not None for wq in self.slot_wq)

    def _apply_scheduling(self) -> None:
        """Pre-round scheduler hooks: write this round's fairness weights
        into the slot table and (claim_policy="variance") permute the
        schedule's unclaimed tail.  Both are host-side writes the jitted
        round takes as data — and both run *before* ``round_data``, so the
        streaming claim prediction/prefetch follow the same order."""
        sched = self.scheduler
        active = np.asarray([wq is not None for wq in self.slot_wq])
        w = sched.round_weights(
            [wq.slo if wq is not None else None for wq in self.slot_wq],
            active)
        if not np.array_equal(w, self._cur_weights):
            self.table = self.table._replace(
                weight=jnp.asarray(w, jnp.float32))
            self._cur_weights = w
        order = sched.claim_order(self.state, self.store.chunk_sizes,
                                  active=active,
                                  slot_need=self._slot_need())
        if order is not None:
            self.state = self.state._replace(
                schedule=jnp.asarray(order, jnp.int32))

    def _slot_need(self) -> Optional[np.ndarray]:
        """Per-slot ε-distance weights for the claim key: how far each
        resident slot's last-round error ratio still is from its ε target
        (``max(err/ε − 1, 0)``); slots with no estimate yet weigh 1.0.
        ``None`` before the first round (claims fall back to the unweighted
        max key — there is nothing measured to weight anyway)."""
        if self._last_err is None:
            return None
        eps = np.asarray(self.table.eps, np.float64)
        err = self._last_err
        return np.where(np.isfinite(err),
                        np.maximum(err / np.maximum(eps, 1e-12) - 1.0, 0.0),
                        1.0)

    def _enforce_deadlines(self) -> None:
        """Stop slots whose SLO deadline has passed: the query retires this
        round with the best estimate available — the OLA contract is that
        time bounds trade against accuracy, not against an answer."""
        now = self.t_model
        stopped = np.asarray(self.state.stopped)
        late = [s for s in range(self.max_slots)
                if self.slot_wq[s] is not None and not stopped[s]
                and self.slot_wq[s].slo is not None
                and self.slot_wq[s].slo.has_deadline
                and now >= self.slot_wq[s].arrival_t
                + self.slot_wq[s].slo.deadline_s]
        if late:
            self.state = self.state._replace(
                stopped=self.state.stopped.at[jnp.asarray(late)].set(True))

    def _record_trajectory(self, rep, b) -> None:
        """Append this round's ``(m, est, ci_halfwidth, b_eff, weight)``
        point to every resident query's explain record — host-side reads of
        round-report fields the retire path materializes anyway."""
        live = [(s, self.slot_wq[s]) for s in range(self.max_slots)
                if self.slot_wq[s] is not None
                and self.slot_wq[s].explain is not None]
        if not live:
            return
        est_a = np.asarray(rep.estimate, float)
        lo = np.asarray(rep.lo, float)
        hi = np.asarray(rep.hi, float)
        m_rows = np.asarray(self.state.stats.m).sum(axis=1)
        g_est = g_lo = g_hi = None
        for s, wq in live:
            w = float(self._cur_weights[s])
            groups = None
            if wq.query.group_by is not None:
                if g_est is None:
                    g_est = np.asarray(rep.g_est, float)
                    g_lo = np.asarray(rep.g_lo, float)
                    g_hi = np.asarray(rep.g_hi, float)
                tracked = self._slot_groups[s] or []
                idx = list(range(len(tracked))) + [self.max_groups]
                vals = [float(v) for v in tracked] + [float("nan")]
                groups = tuple(
                    (v, float(g_est[s, i]),
                     float((g_hi[s, i] - g_lo[s, i]) / 2.0))
                    for v, i in zip(vals, idx))
            wq.explain.record_round(RoundSample(
                round=self.rounds, m=int(m_rows[s]),
                est=float(est_a[s]),
                ci_halfwidth=float((hi[s] - lo[s]) / 2.0),
                b_eff=int(round(float(b) * w)), weight=w,
                groups=groups))

    def step(self) -> bool:
        """Admit ready arrivals, run one engine round, retire finished
        queries.  Returns False when there is nothing to do right now."""
        tr = self.tracer
        self._admit_ready()
        if not self._any_active():
            return False
        with tr.span("round", round=self.rounds):
            if self.scheduler is not None:
                self._apply_scheduling()
            b = self.engine.budget_ladder(float(self.state.budget))
            # round_data: the packed device view, or (stream residency) a
            # slab assembled from the predicted claims — which also covers
            # top-up passes, since _begin_topup_pass rewrites cur/head
            # *before* the prediction runs, so re-opened chunks are
            # re-requested from the prefetcher exactly when a worker is
            # about to claim them
            with tr.span("claims"):
                self.state, data = self.engine.round_data(self.state)
            # a failed read may have quarantined chunks inside round_data:
            # fold the survivors into every population-priced structure
            # before the round estimates over them
            self._note_quarantine()
            mode, data = self.engine.data_mode(data)
            with tr.span("kernel", b=b, mode=mode):
                self.state, rep = self.engine.round_fn(b, mode)(
                    self.state, self.table, data, self.engine.speeds)
            self.rounds += 1
            with tr.span("merge"):
                if self.rollup is not None and self.rollup.cells:
                    # incremental maintenance: resident slots running a
                    # promoted pattern fold their round-accumulated stats
                    # into the cell — one batched device→host copy for all
                    # such slots (near-free; empty in the
                    # no-promoted-occupant common case)
                    ids = [s for s in range(self.max_slots)
                           if self.slot_wq[s] is not None
                           and self.rollup.get(self.slot_wq[s].key)
                           is not None]
                    for s, row in slot_stats_fold(self.state, ids).items():
                        self.rollup.fold(self.slot_wq[s].key, row)
            with tr.span("estimate"):
                self._record_trajectory(rep, b)
                if self.scheduler is not None:
                    # next round's ε-distance claim weights read this report
                    self._last_err = np.asarray(rep.err, float)
                if (self.scheduler is not None
                        and self.scheduler.config.deadline_enforcement):
                    self._enforce_deadlines()
                self._retire_finished(rep)
                self._fold_group_discovery(rep)
                if self._any_active() and bool(rep.exhausted):
                    if not self._begin_topup_pass():
                        # census complete: estimates are as good as they
                        # will get
                        self._force_retire_exhausted(rep)
        return True

    def _force_retire_exhausted(self, rep) -> None:
        """Every chunk is fully extracted; retire survivors with their final
        (near-exact for slots that saw the whole scan) estimates.  A slot
        that never received a single tuple (admitted post-exhaustion with no
        synopsis seed) cannot be answered — its result is flagged
        ``unserved`` with a NaN estimate rather than a plausible-looking 0."""
        m = np.asarray(self.state.stats.m)
        unserved = frozenset(
            s for s in range(self.max_slots)
            if self.slot_wq[s] is not None and int(m[s].sum()) == 0)
        self.state = self.state._replace(
            stopped=jnp.ones_like(self.state.stopped))
        self._retire_finished(rep, unserved=unserved)

    # --------------------------------------------------------------- run ----
    def run(self, max_rounds: int = 200_000, wall_timeout_s: float = 600.0,
            on_round=None) -> list[WorkloadResult]:
        """Drive until the queue drains and every resident query retires.

        If ``max_rounds`` or ``wall_timeout_s`` cuts the loop short,
        ``self.truncated`` is set and the returned list is missing the
        unfinished queries — callers indexing results by name/qid should
        check it rather than assume completeness.  ``on_round(server)`` is
        called after every engine round (monitoring hooks: the benchmarks
        sample peak device residency through it).
        """
        self.truncated = False
        t0 = time.perf_counter()
        while self.queue or self._any_active():
            if self.rounds >= max_rounds:
                self.truncated = True
                break
            if time.perf_counter() - t0 > wall_timeout_s:
                self.truncated = True
                break
            stepped = self.step()
            if stepped and on_round is not None:
                on_round(self)
            if not stepped:
                if not self.queue:
                    break
                # idle: jump the modeled clock to the next arrival
                nxt = self.queue[0].arrival_t
                if nxt > self.t_model:
                    self.idle_offset += nxt - self.t_model
        self.results.sort(key=lambda r: r.qid)
        return self.results


def poisson_workload(queries: Sequence[Query], rate_per_model_s: float,
                     seed: int = 0,
                     rng: Optional[np.random.Generator] = None,
                     ) -> list[tuple[Query, float]]:
    """Poisson arrival process over a fixed query list (benchmark helper):
    returns ``(query, arrival_t)`` pairs with exponential inter-arrivals at
    ``rate_per_model_s`` arrivals per modeled second.

    Deterministic run-to-run: the same ``seed`` always yields the same
    arrival times (scheduler benchmarks compare policies on identical
    traffic).  Pass an explicit ``rng`` instead to draw from a
    caller-owned :class:`numpy.random.Generator` stream (e.g. one shared
    across several workload sections); ``seed`` is then ignored.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for q in queries:
        t += float(rng.exponential(1.0 / rate_per_model_s))
        out.append((q, t))
    return out
