"""Tier-1 rollup answer cache in front of the OLA workload server.

OLA-RAW's central economy is never paying the scan/tokenize/parse cost
twice — yet a *repeated* query pattern still costs scan rounds every time
it arrives.  This module adds the two-tier shape production OLAP serving
converges on (pre-aggregated rollup cells answer the hot patterns
instantly; the shared raw scan serves only the long tail):

* **pattern mining** — every completed query's ``(measure,
  predicate-template)`` pattern is logged; a pattern observed
  ``promote_hits`` times inside the sliding mining window is *promoted* to
  a rollup cell (query-feedback-driven refinement: the workload itself
  decides what is worth materializing);
* **incremental maintenance** — a promoted cell holds per-chunk sufficient
  statistics ``{m, ysum, ysq, psum}`` (the same ``(N,)``-row contract as
  :meth:`~repro.core.synopsis.BiLevelSynopsis.seed_slot` and
  :func:`~repro.core.engine.slot_stats_snapshot`), folded from the rows
  the engine already emits: resident slots running the pattern fold out
  once per round (:func:`~repro.core.engine.slot_stats_fold`, a near-free
  hook — one batched device→host copy, empty in the common case), and
  every retirement folds the final row.  Folding is *replacement by
  larger per-chunk sample*: each slot row is a union of windows of the
  chunk's committed random permutation, so the bigger row subsumes the
  smaller one and stays a valid uniform without-replacement sample —
  never added, never double counted;
* **tiered answers** — a cell answers through the engine's bi-level
  estimators: chunks with ``m == M_j`` are fully covered and contribute
  *exactly* (the FPC zeroes their within-chunk variance), the remainder
  contributes a synopsis-style CI.  A fully-covered cell's answer is
  bit-identical to a fresh census scan of the pattern;
* **cost-model routing** — the server routes each admission Tier-1 vs
  Tier-2 with the Eq. (4) terms: a rollup answer that meets the query's
  accuracy target costs zero scan seconds and beats any admit/queue/shed
  plan (:data:`repro.sched.admission.TIER1`, checked before the
  feasibility triage); when the cell alone cannot meet ε it still
  discounts the Tier-2 plan as a seed richer than the synopsis (CLT
  ``err ∝ 1/√m`` — fewer tuples left to scan);
* **invalidation / demotion** — cells pin the
  :attr:`~repro.data.chunkstore.ChunkStore.content_version` they were
  built over and are dropped wholesale when the raw bytes change; cold
  patterns (no hit for ``cold_after_s`` modeled seconds) are demoted, and
  the cell store is LRU-bounded at ``max_cells``.

Statistical validity: every row folded into a cell describes tuples drawn
from windows of each chunk's committed permutation that lie inside the
scan's already-extracted prefix (synopsis windows and slot deltas both
are).  Future scan extraction continues past the scan cursor, so a cell
row used as an admission seed composes with later round deltas without
overlap — the same argument that makes preemption snapshots re-seedable.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Optional

import numpy as np

from repro.core.queries import Query, linear_plan


def pattern_key(query: Query, num_cols: int) -> Optional[tuple]:
    """Canonical ``(measure, predicate-template)`` cell key for a query.

    The key is the slot-encodable coefficient form — aggregate kind plus
    the exact f32 ``coeffs/lo/hi`` lowering of :func:`linear_plan` — so
    textually different but semantically identical predicates collide.
    Accuracy parameters (ε, confidence) and HAVING are deliberately *not*
    part of the key: repeats of the same measure at different targets
    share one cell and re-judge the answer against their own target.
    Returns ``None`` for queries outside the linear+range form (those are
    never cacheable and always route Tier-2).
    """
    try:
        plan = linear_plan([query], num_cols)
    except ValueError:
        return None
    return (query.agg, plan.coeffs[0].tobytes(), plan.lo[0].tobytes(),
            plan.hi[0].tobytes())


@dataclasses.dataclass(frozen=True)
class RollupConfig:
    """Promotion/demotion policy knobs for the Tier-1 cell store."""

    # completions of a pattern (inside the mining window) before promotion
    promote_hits: int = 2
    # LRU capacity of the cell store
    max_cells: int = 64
    # demote a cell untouched for this many modeled seconds (inf = never)
    cold_after_s: float = math.inf
    # sliding completed-query log length the pattern miner counts over
    mine_window: int = 256

    def __post_init__(self):
        assert self.promote_hits >= 1, self.promote_hits
        assert self.max_cells >= 1, self.max_cells
        assert self.mine_window >= 1, self.mine_window


class RollupCell:
    """One promoted pattern's partial aggregate: per-chunk sufficient
    statistics over the chunks the scan has covered for it so far."""

    def __init__(self, key: tuple, query: Query, n_chunks: int,
                 now: float, content_version: int):
        self.key = key
        self.query = query              # exemplar (ε/HAVING ignored at answer)
        self.content_version = content_version
        self.created_t = now
        self.last_hit_t = now
        self.hits = 0                   # Tier-1 answers served from this cell
        self.folds = 0
        self.m = np.zeros(n_chunks, np.int64)
        self.ysum = np.zeros(n_chunks, np.float64)
        self.ysq = np.zeros(n_chunks, np.float64)
        self.psum = np.zeros(n_chunks, np.float64)

    def fold(self, row: dict) -> int:
        """Merge one engine stats row (``slot_stats_snapshot`` /
        ``seed_slot`` contract) into the cell: per chunk, the row with the
        larger sample *replaces* the cell's (both are unions of windows of
        the same committed permutation — the larger subsumes the smaller;
        adding would double count).  Returns the number of chunks
        upgraded."""
        m = np.asarray(row["m"], np.int64)
        take = m > self.m
        n = int(take.sum())
        if n:
            self.m[take] = m[take]
            self.ysum[take] = np.asarray(row["ysum"], np.float64)[take]
            self.ysq[take] = np.asarray(row["ysq"], np.float64)[take]
            self.psum[take] = np.asarray(row["psum"], np.float64)[take]
            self.folds += 1
        return n

    def seed_dict(self) -> dict:
        """The cell as a ``{m, ysum, ysq, psum}`` seed row — drop-in for
        :func:`~repro.core.engine.slot_stats_write` and the server's
        ``_seed_answer`` (the same contract the synopsis emits)."""
        return dict(m=self.m.copy(), ysum=self.ysum.copy(),
                    ysq=self.ysq.copy(), psum=self.psum.copy())

    def covered(self, chunk_sizes: np.ndarray) -> np.ndarray:
        """Fully-covered mask (the exact part of a tiered answer)."""
        return self.m >= np.asarray(chunk_sizes, np.int64)

    def touch(self, now: float) -> None:
        self.hits += 1
        self.last_hit_t = max(self.last_hit_t, now)


class RollupTier:
    """The Tier-1 cell store + pattern miner (see module docstring).

    Host-side and engine-free: the server owns answer construction (it
    reuses the estimator stack on :meth:`RollupCell.seed_dict` rows) and
    feeds completions/fold rows in; this class owns which patterns are
    materialized and when cells die.
    """

    def __init__(self, store, config: RollupConfig = RollupConfig(),
                 num_cols: Optional[int] = None):
        self.store = store
        self.config = config
        self.num_cols = (store.codec.num_cols if num_cols is None
                         else int(num_cols))
        self.n_chunks = store.num_chunks
        self.content_version = int(getattr(store, "content_version", 0))
        self.cells: dict[tuple, RollupCell] = {}
        self._log: deque[tuple] = deque()   # completed-query pattern log
        self._counts: dict[tuple, int] = {}
        # observability counters (surfaced by benchmarks/bench_workload.py
        # and the server's metrics registry via counters()/bind_metrics)
        self.tier1_hits = 0
        self.promotions = 0
        self.demotions = 0
        self.invalidations = 0

    COUNTER_FIELDS = ("tier1_hits", "promotions", "demotions",
                      "invalidations")

    def counters(self) -> dict:
        """Point-in-time snapshot of the tier's monotone counters plus the
        current cell population."""
        out = {f: int(getattr(self, f)) for f in self.COUNTER_FIELDS}
        out["cells"] = len(self.cells)
        return out

    def bind_metrics(self, registry, prefix: str = "rollup") -> None:
        """Register pull gauges for every counter on a
        :class:`~repro.obs.metrics.MetricsRegistry` (read at snapshot
        time, zero hot-path writes)."""
        for f in self.COUNTER_FIELDS:
            registry.gauge(f"{prefix}_{f}",
                           help=f"RollupTier.{f} (cumulative)",
                           fn=(lambda f=f: getattr(self, f)))
        registry.gauge(f"{prefix}_cells", help="materialized rollup cells",
                       fn=lambda: len(self.cells))

    # ----------------------------------------------------------- mining ----
    def observe(self, query: Query, key: Optional[tuple],
                now: float) -> Optional[RollupCell]:
        """Log one completed query.  Returns the cell iff this completion
        *newly promoted* the pattern (the caller seeds/folds it); already-
        promoted patterns just refresh their recency."""
        if key is None:
            return None
        self._log.append(key)
        self._counts[key] = self._counts.get(key, 0) + 1
        while len(self._log) > self.config.mine_window:
            old = self._log.popleft()
            self._counts[old] = max(self._counts.get(old, 1) - 1, 0)
        cell = self.cells.get(key)
        if cell is not None:
            cell.last_hit_t = max(cell.last_hit_t, now)
            return None
        if self._counts[key] < self.config.promote_hits:
            return None
        cell = RollupCell(key, query, self.n_chunks, now,
                          self.content_version)
        self.cells[key] = cell
        self.promotions += 1
        self._evict_lru()
        return cell

    def _evict_lru(self) -> None:
        while len(self.cells) > self.config.max_cells:
            lru = min(self.cells.values(), key=lambda c: c.last_hit_t)
            self._demote(lru.key)

    def _demote(self, key: tuple) -> None:
        self.cells.pop(key, None)
        # demand fresh evidence before re-promoting: a demoted pattern's
        # stale log entries must not instantly resurrect the cell
        self._counts[key] = 0
        self.demotions += 1

    # ------------------------------------------------------- maintenance ----
    def maintain(self, now: float) -> None:
        """Invalidate on store content change, demote cold cells.  Called
        by the server once per intake pass (cheap: O(cells))."""
        version = int(getattr(self.store, "content_version", 0))
        if version != self.content_version:
            # the raw bytes changed under the cells: every partial
            # aggregate is stale — drop them all, keep the miner's log
            # (the patterns are still hot; they re-promote and rebuild
            # over the new content)
            if self.cells:
                self.invalidations += len(self.cells)
                self.cells.clear()
            self.content_version = version
        if math.isfinite(self.config.cold_after_s):
            cold = [k for k, c in self.cells.items()
                    if now - c.last_hit_t > self.config.cold_after_s]
            for k in cold:
                self._demote(k)

    def invalidate_chunks(self, chunk_ids) -> int:
        """Drop every cell whose partial aggregate covers a quarantined
        chunk: the cell's answer counts tuples that left the surviving
        population, so it can no longer serve Tier-1 (or seed Tier-2).
        Cells with zero sample over the quarantined ids keep serving —
        their statistics already describe only surviving chunks.  Returns
        the number of cells invalidated; the miner's pattern log survives
        (hot patterns re-promote and rebuild over the survivors)."""
        ids = [int(j) for j in chunk_ids]
        if not ids:
            return 0
        stale = [k for k, c in self.cells.items()
                 if int(c.m[ids].sum()) > 0]
        for k in stale:
            self.cells.pop(k, None)
        self.invalidations += len(stale)
        return len(stale)

    # ------------------------------------------------------------ lookup ----
    def get(self, key: Optional[tuple]) -> Optional[RollupCell]:
        """The promoted cell for a pattern key, or None.  Callers run
        :meth:`maintain` at intake, so a returned cell is content-current."""
        if key is None:
            return None
        return self.cells.get(key)

    def fold(self, key: Optional[tuple], row: dict) -> None:
        cell = self.get(key)
        if cell is not None:
            cell.fold(row)
