"""Decoder-only transformer LM: dense (qwen2.5 / qwen3 / smollm / granite) and
MoE (mixtral / phi-3.5) variants; also the text backbone reused by the VLM.

Layer stack is ``lax.scan`` over stacked params with optional
``jax.checkpoint`` (remat) around the block body — one traced layer
regardless of depth (88-layer granite compiles as fast as 12-layer smollm).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.distributed.autoshard import constrain


def _attn_config(cfg: ModelConfig) -> attn.AttnConfig:
    hp, hkp = attn.padded_heads(cfg.num_heads, cfg.num_kv_heads, cfg.tp)
    return attn.AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
        heads_padded=hp, kv_heads_padded=hkp, qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta, causal=True,
        window=cfg.window, use_rope=cfg.use_rope,
        mrope_sections=cfg.mrope_sections)


def _moe_config(cfg: ModelConfig) -> moe_mod.MoEConfig:
    axis = "experts" if cfg.num_experts % max(cfg.tp, 1) == 0 else "experts_unsharded"
    return moe_mod.MoEConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff, num_experts=cfg.num_experts,
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        expert_axis=axis)


class DecoderLM:
    """Functional decoder-only LM."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.acfg = _attn_config(cfg)
        self.mcfg = _moe_config(cfg) if cfg.num_experts else None

    # ------------------------------------------------------------- params --
    def _layer_init(self, key) -> tuple:
        cfg = self.cfg
        col = L.ParamCollector(key)
        col.ones("ln1", (cfg.d_model,), ("embed",))
        attn.attn_init(col.sub("attn"), self.acfg)
        col.ones("ln2", (cfg.d_model,), ("embed",))
        if self.mcfg is not None:
            moe_mod.moe_init(col.sub("moe"), self.mcfg)
        elif cfg.mlp == "swiglu":
            L.swiglu_init(col.sub("mlp"), cfg.d_model, cfg.d_ff)
        else:
            L.gelu_mlp_init(col.sub("mlp"), cfg.d_model, cfg.d_ff)
        params, specs = col.done()
        params["attn"] = attn.mask_padded_heads(params["attn"], self.acfg)
        return params, specs

    def init(self, key) -> tuple:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 2)
        col = L.ParamCollector(keys[0])
        L.embed_init(col, cfg.vocab_size, cfg.d_model)
        if not cfg.tie_embeddings:
            v_pad = L.pad_to(cfg.vocab_size, 256)
            col.dense("unembed", (v_pad, cfg.d_model), ("vocab", "embed"))
        col.ones("final_norm", (cfg.d_model,), ("embed",))
        params, specs = col.done()
        layer_trees = [self._layer_init(keys[i + 1]) for i in range(cfg.num_layers)]
        params["layers"], specs["layers"] = L.stack_layers(layer_trees)
        return params, specs

    # ------------------------------------------------------------ forward --
    def _block(self, lp, x, positions, positions3):
        cfg = self.cfg
        norm = functools.partial(L.rms_norm) if cfg.norm == "rms" else None
        h = L.rms_norm(x, lp["ln1"])
        h = attn.full_attention(lp["attn"], self.acfg, h, positions=positions,
                                positions3=positions3)
        x = x + h
        h = L.rms_norm(x, lp["ln2"])
        aux = jnp.zeros((), jnp.float32)
        if self.mcfg is not None:
            h, aux = moe_mod.moe_apply(lp["moe"], self.mcfg, h, return_aux=True)
        elif cfg.mlp == "swiglu":
            h = L.swiglu_apply(lp["mlp"], h)
        else:
            h = L.gelu_mlp_apply(lp["mlp"], h)
        return x + h, aux

    def forward(self, params, tokens, positions=None, positions3=None,
                inputs_embeds=None):
        """tokens (B, S) -> logits (B, S, V_pad); also returns aux loss."""
        cfg = self.cfg
        x = L.embed_apply(params, tokens) if inputs_embeds is None else inputs_embeds
        x = x.astype(jnp.dtype(cfg.compute_dtype))
        x = constrain(x, "btd")

        block = self._block
        if cfg.remat:
            block = jax.checkpoint(block, prevent_cse=False)

        def scan_fn(carry, lp):
            x, aux = carry
            x, a = block(lp, x, positions, positions3)
            return (constrain(x, "btd"), aux + a), None

        (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"], unroll=cfg.scan_unroll)
        x = L.rms_norm(x, params["final_norm"])
        logits = L.unembed_apply(params, x, tied=cfg.tie_embeddings)
        return constrain(logits, "btv"), aux

    def loss(self, params, batch) -> jnp.ndarray:
        logits, aux = self.forward(
            params, batch["tokens"], positions=batch.get("positions"),
            positions3=batch.get("positions3"),
            inputs_embeds=batch.get("inputs_embeds"))
        ce = L.cross_entropy_loss(logits, batch["labels"], self.cfg.vocab_size)
        return ce + 0.01 * aux

    # ------------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        """Stacked (L, ...) KV cache for scan-decode."""
        one = attn.init_kv_cache(batch, max_len, self.acfg, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.cfg.num_layers,) + x.shape).copy(),
            one)

    def decode_step(self, params, cache, tokens, pos):
        """tokens (B, 1), pos (B,) -> (logits (B,1,V), new_cache)."""
        cfg = self.cfg
        x = L.embed_apply(params, tokens).astype(jnp.dtype(cfg.compute_dtype))
        x = constrain(x, "btd")

        def scan_fn(x, inp):
            lp, lcache = inp
            h = L.rms_norm(x, lp["ln1"])
            h, new_cache = attn.decode_attention(lp["attn"], self.acfg, h,
                                                 lcache, pos)
            x = x + h
            h = L.rms_norm(x, lp["ln2"])
            if self.mcfg is not None:
                h, _ = moe_mod.moe_apply(lp["moe"], self.mcfg, h, return_aux=True)
            elif cfg.mlp == "swiglu":
                h = L.swiglu_apply(lp["mlp"], h)
            else:
                h = L.gelu_mlp_apply(lp["mlp"], h)
            return constrain(x + h, "btd"), new_cache

        x, new_cache = jax.lax.scan(scan_fn, x, (params["layers"], cache),
                                    unroll=cfg.scan_unroll)
        x = L.rms_norm(x, params["final_norm"])
        logits = L.unembed_apply(params, x, tied=cfg.tie_embeddings)
        return logits, new_cache

    def prefill(self, params, tokens, positions=None, positions3=None,
                inputs_embeds=None):
        """Full-sequence forward returning last-position logits (prefill
        benchmark shape; cache writing is fused into serve engines)."""
        logits, _ = self.forward(params, tokens, positions, positions3,
                                 inputs_embeds)
        return logits[:, -1:]
