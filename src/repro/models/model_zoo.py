"""Model zoo dispatch: config -> model instance."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM
from repro.models.vlm import VLM
from repro.models.xlstm_model import XLSTMLM
from repro.models.zamba import ZambaLM

_FAMILIES = {
    "dense": DecoderLM,
    "moe": DecoderLM,       # MoE is a DecoderLM with num_experts > 0
    "encdec": EncDecLM,
    "vlm": VLM,
    "hybrid": ZambaLM,
    "xlstm": XLSTMLM,
}


def build_model(cfg: ModelConfig):
    try:
        cls = _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family: {cfg.family}") from None
    return cls(cfg)
