"""Attention variants: GQA/MQA/MHA, causal / bidirectional / cross / sliding
window, qk-norm, QKV bias, M-RoPE — with full-sequence and cached-decode paths.

TP strategy (DESIGN.md §6): Q heads are padded up to a multiple of the mesh
model-axis size and sharded on the "q_heads" logical axis; KV heads stay
*replicated*, which is numerically exact for GQA and avoids distorting the KV
cache.  Padded Q heads attend normally but their output-projection rows are
zero, so logits are unchanged; the extra FLOPs appear in the roofline
useful-FLOPs ratio.

Sliding-window attention (mixtral, zamba2-long) uses a banded mask in the
full-sequence path and a ring-buffer cache (size = window) in decode — the
cache never exceeds the window, which is what makes long_500k decode cheap.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamCollector, apply_mrope, apply_rope, rms_norm

NEG_INF = -1e9


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int          # real Q heads
    num_kv_heads: int       # real KV heads
    head_dim: int
    heads_padded: int       # Q heads after TP padding (>= num_heads)
    kv_heads_padded: int    # KV heads padded so heads_padded % kv_padded == 0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    window: Optional[int] = None     # sliding-window size (None = full)
    cross: bool = False              # cross-attention (enc-dec)
    use_rope: bool = True
    mrope_sections: Optional[tuple] = None  # qwen2-vl


def padded_heads(num_heads: int, num_kv_heads: int, tp: int) -> tuple[int, int]:
    """(heads_padded, kv_heads_padded) for a given model-axis size.

    Q heads pad up to a multiple of ``tp``; KV heads pad up to the smallest
    divisor of the padded Q count that is >= the real KV count, so grouped
    attention stays well-formed.  Real-head masking keeps numerics exact.
    """
    from repro.models.layers import pad_to

    hp = pad_to(num_heads, tp)
    hk_pad = num_kv_heads
    while hp % hk_pad != 0:
        hk_pad += 1
    return hp, hk_pad


def real_head_mask(cfg: AttnConfig) -> jnp.ndarray:
    """(heads_padded,) 1.0 for slots carrying a real architecture head.

    Padded-group layout: KV slot j serves Q slots [j*g', (j+1)*g');
    the first ``g_real`` Q slots of the first ``num_kv_heads`` KV groups are
    real — exactly ``num_heads`` real Q heads grouped ``g_real``-to-1 onto
    ``num_kv_heads`` real KV heads, i.e. the assigned GQA architecture.
    """
    g_prime = cfg.heads_padded // cfg.kv_heads_padded
    g_real = cfg.num_heads // cfg.num_kv_heads
    slots = jnp.arange(cfg.heads_padded)
    j = slots // g_prime
    i = slots % g_prime
    return ((j < cfg.num_kv_heads) & (i < g_real)).astype(jnp.float32)


def attn_init(col: ParamCollector, cfg: AttnConfig):
    hp, hk, d, dm = (cfg.heads_padded, cfg.kv_heads_padded, cfg.head_dim,
                     cfg.d_model)
    col.dense("wq", (dm, hp, d), ("embed", "q_heads", "head"))
    col.dense("wk", (dm, hk, d), ("embed", "kv_heads", "head"))
    col.dense("wv", (dm, hk, d), ("embed", "kv_heads", "head"))
    # zero rows for padded heads are created at build time by masking wo
    col.dense("wo", (hp, d, dm), ("q_heads", "head", "embed"))
    if cfg.qkv_bias:
        col.zeros("bq", (hp, d), ("q_heads", "head"))
        col.zeros("bk", (hk, d), ("kv_heads", "head"))
        col.zeros("bv", (hk, d), ("kv_heads", "head"))
    if cfg.qk_norm:
        col.ones("q_norm", (d,), ("head",))
        col.ones("k_norm", (d,), ("head",))


def mask_padded_heads(params: dict, cfg: AttnConfig) -> dict:
    """Zero the output projection of non-real head slots (numerical exactness:
    padded heads attend but contribute nothing)."""
    if (cfg.heads_padded == cfg.num_heads
            and cfg.kv_heads_padded == cfg.num_kv_heads):
        return params
    keep = real_head_mask(cfg)
    params = dict(params)
    params["wo"] = params["wo"] * keep[:, None, None]
    return params


def _project_qkv(p, cfg: AttnConfig, x, x_kv=None):
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("...d,dhk->...hk", x_kv, p["wk"].astype(x.dtype))
    v = jnp.einsum("...d,dhk->...hk", x_kv, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _rope(cfg: AttnConfig, q, k, q_pos, k_pos, positions3=None):
    if not cfg.use_rope:
        return q, k
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
        return q, k
    return (apply_rope(q, q_pos, cfg.rope_theta),
            apply_rope(k, k_pos, cfg.rope_theta))


def _grouped_scores(q, k):
    """q (B,S,Hq,D), k (B,T,Hk,D) -> scores (B,Hk,G,S,T) with G=Hq/Hk."""
    b, s, hq, d = q.shape
    hk = k.shape[2]
    g = hq // hk
    qg = q.reshape(b, s, hk, g, d)
    return jnp.einsum("bshgd,bthd->bhgst", qg, k)


def _grouped_out(probs, v):
    """probs (B,Hk,G,S,T), v (B,T,Hk,D) -> (B,S,Hq,D)."""
    b, hk, g, s, t = probs.shape
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(b, s, hk * g, v.shape[-1])


def full_attention(p, cfg: AttnConfig, x, *, x_kv=None, positions=None,
                   kv_positions=None, positions3=None, seg_mask=None):
    """Full-sequence attention (train / prefill).

    ``positions`` (B, S) query positions; ``kv_positions`` (B, T).  A banded
    causal / sliding-window mask is built from positions, so packed or padded
    batches work by passing the right position ids.
    """
    b, s, _ = x.shape
    t = s if x_kv is None else x_kv.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if kv_positions is None:
        kv_positions = positions if x_kv is None else jnp.broadcast_to(
            jnp.arange(t), (b, t))
    q, k, v = _project_qkv(p, cfg, x, x_kv)
    q, k = _rope(cfg, q, k, positions, kv_positions, positions3)

    scores = _grouped_scores(q, k) / math.sqrt(cfg.head_dim)   # (B,Hk,G,S,T)
    mask = jnp.ones((b, 1, 1, s, t), bool)
    if cfg.causal and not cfg.cross:
        mask &= (kv_positions[:, None, None, None, :]
                 <= positions[:, None, None, :, None])
    if cfg.window is not None and not cfg.cross:
        mask &= (positions[:, None, None, :, None]
                 - kv_positions[:, None, None, None, :]) < cfg.window
    if seg_mask is not None:
        mask &= seg_mask[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _grouped_out(probs, v)
    return jnp.einsum("...hk,hkd->...d", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, cfg: AttnConfig,
                  dtype=jnp.bfloat16) -> dict:
    """Cache buffers for one layer.  Sliding-window archs allocate only the
    window (ring buffer); full attention allocates max_len."""
    length = min(max_len, cfg.window) if cfg.window is not None else max_len
    shape = (batch, length, cfg.kv_heads_padded, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.full((batch, length), -1, jnp.int32)}


def decode_attention(p, cfg: AttnConfig, x, cache: dict, pos: jnp.ndarray,
                     positions3=None):
    """One-token decode step.  x (B, 1, d); pos (B,) absolute positions.

    Returns (out (B,1,d), new_cache).  The ring-buffer slot is ``pos % length``
    for SWA; cached absolute positions make masking exact (slots whose stored
    position is outside the window or unwritten are masked out).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)                       # (B,1,H,D)
    if cfg.mrope_sections is not None:
        # text-phase decode: all three position streams advance together
        pos3 = jnp.broadcast_to(pos[None, :, None], (3, b, 1))
        q, k = _rope(cfg, q, k, None, None, pos3)
    else:
        q, k = _rope(cfg, q, k, pos[:, None], pos[:, None])

    length = cache["k"].shape[1]
    slot = (pos % length).astype(jnp.int32)                 # (B,)
    bi = jnp.arange(b)
    ck = cache["k"].at[bi, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[bi, slot].set(v[:, 0].astype(cache["v"].dtype))
    cpos = cache["pos"].at[bi, slot].set(pos)

    scores = _grouped_scores(q, ck.astype(x.dtype)) / math.sqrt(cfg.head_dim)
    # (B,Hk,G,1,T)
    ok = (cpos >= 0) & (cpos <= pos[:, None])
    if cfg.window is not None:
        ok &= (pos[:, None] - cpos) < cfg.window
    scores = jnp.where(ok[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _grouped_out(probs, cv.astype(x.dtype))
    out = jnp.einsum("...hk,hkd->...d", out, p["wo"].astype(x.dtype))
    return out, {"k": ck, "v": cv, "pos": cpos}
