"""Mamba2 (State Space Duality) block — the SSM family + zamba2's backbone.

Training/prefill uses the *chunked SSD algorithm* (Mamba2 paper, Listing 1):
the sequence is split into chunks of length ``Qc``; within a chunk the
recurrence is materialized as a masked quadratic form (an MXU matmul — this
is precisely why SSD maps well to TPU), and across chunks only the
``(H, P, N)`` states are carried.  Decode is the O(1) recurrence.

Shapes follow the paper: ``x (B,S,H,P)``, shared single-group ``B,C (B,S,N)``,
scalar-per-head ``A (H,)``, ``dt (B,S,H)``.  ``d_inner = expand · d_model``,
``H = d_inner / headdim``.

Sharding: the ``heads_ssm`` logical axis (H) → mesh model axis; states and
conv channels follow.  H is padded to a model-axis multiple like attention
heads (out-projection masking keeps numerics exact).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import ParamCollector, pad_to, rms_norm


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 128            # SSD chunk length
    heads_padded: int = 0       # set by model builder (TP multiple)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def nheads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def nheads_padded(self) -> int:
        return self.heads_padded or self.nheads

    @property
    def d_inner_padded(self) -> int:
        return self.nheads_padded * self.headdim


def mamba_init(col: ParamCollector, cfg: MambaConfig):
    dm, din, n, h = cfg.d_model, cfg.d_inner_padded, cfg.d_state, cfg.nheads_padded
    # in_proj -> [z, x, B, C, dt]
    col.dense("in_z", (dm, din), ("embed", "mlp"))
    col.dense("in_x", (dm, din), ("embed", "mlp"))
    col.dense("in_B", (dm, n), ("embed", "state"))
    col.dense("in_C", (dm, n), ("embed", "state"))
    col.dense("in_dt", (dm, h), ("embed", "heads_ssm"))
    col.zeros("dt_bias", (h,), ("heads_ssm",))
    col.zeros("A_log", (h,), ("heads_ssm",))      # A = -exp(A_log) ~ -1
    col.zeros("D", (h,), ("heads_ssm",))
    col.dense("conv", (cfg.d_conv, din + 2 * n), ("conv", "mlp"))
    col.ones("norm", (din,), ("mlp",))
    col.dense("out", (din, dm), ("mlp", "embed"))


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):  # K=4: unrolled shifts beat a conv op at this size
        out = out + pad[:, i:i + xbc.shape[1]] * w[i][None, None, :]
    return jax.nn.silu(out)


def _segsum(log_a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = Σ_{j<k<=i} log_a[..., k] (else -inf)."""
    l = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """Chunked SSD scan.

    x (B,S,H,P), dt (B,S,H) [post-softplus], a_log = A (H,) negative reals,
    b/c (B,S,N) single group.  Returns y (B,S,H,P) and final state
    (B,H,P,N).
    """
    bsz, s_orig, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s_orig) if s_orig < chunk else chunk
    # pad S to a chunk multiple: padded steps carry dt=0 (x·dt=0, decay=1),
    # so they contribute nothing to states and their outputs are sliced off.
    s = (s_orig + q - 1) // q * q
    if s != s_orig:
        pad = ((0, 0), (0, s - s_orig))
        x = jnp.pad(x, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))
        b = jnp.pad(b, pad + ((0, 0),))
        c = jnp.pad(c, pad + ((0, 0),))
    nc = s // q

    # per-step log decay: dA[b,s,h] = dt * A  (negative)
    da = dt * a_log[None, None, :]                       # (B,S,H)
    xdt = x * dt[..., None]                              # fold dt into x

    xc = xdt.reshape(bsz, nc, q, h, p)
    dac = da.reshape(bsz, nc, q, h)
    bc = b.reshape(bsz, nc, q, n)
    cc = c.reshape(bsz, nc, q, n)

    # ---- intra-chunk (diagonal blocks): quadratic masked form ----
    l = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))      # (B,NC,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)       # (B,NC,Q,Q)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp",
                        scores, l, xc)                   # (B,NC,Q,H,P)

    # ---- chunk states: decay-weighted outer products ----
    da_cum = jnp.cumsum(dac, axis=2)                     # (B,NC,Q,H)
    da_tot = da_cum[:, :, -1]                            # (B,NC,H)
    decay_to_end = jnp.exp(da_tot[:, :, None] - da_cum)  # (B,NC,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        bc, decay_to_end, xc)            # (B,NC,H,P,N)

    # ---- inter-chunk recurrence over chunk states ----
    def scan_fn(h_prev, inp):
        st, dtot = inp                                   # (B,H,P,N), (B,H)
        h_new = h_prev * jnp.exp(dtot)[..., None, None] + st
        return h_new, h_prev                             # emit state *entering* chunk

    h0 = jnp.zeros((bsz, h, p, n), x.dtype)
    h_last, h_in = jax.lax.scan(
        scan_fn, h0, (states.transpose(1, 0, 2, 3, 4), da_tot.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                 # (B,NC,H,P,N)

    # ---- off-diagonal contribution: C_t · (decayed incoming state) ----
    decay_from_start = jnp.exp(da_cum)                   # (B,NC,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                       cc, decay_from_start, h_in)
    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y, h_last


def mamba_forward(p, cfg: MambaConfig, u: jnp.ndarray):
    """Full-sequence Mamba2 block. u (B, S, d_model) -> (B, S, d_model)."""
    din, n = cfg.d_inner_padded, cfg.d_state
    z = jnp.einsum("bsd,df->bsf", u, p["in_z"].astype(u.dtype))
    xraw = jnp.einsum("bsd,df->bsf", u, p["in_x"].astype(u.dtype))
    braw = jnp.einsum("bsd,dn->bsn", u, p["in_B"].astype(u.dtype))
    craw = jnp.einsum("bsd,dn->bsn", u, p["in_C"].astype(u.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, p["in_dt"].astype(u.dtype))
        + p["dt_bias"].astype(u.dtype))

    xbc = jnp.concatenate([xraw, braw, craw], axis=-1)
    xbc = _causal_conv(xbc, p["conv"].astype(u.dtype))
    x, b, c = jnp.split(xbc, [din, din + n], axis=-1)

    h = cfg.nheads_padded
    x = x.reshape(*x.shape[:2], h, cfg.headdim)
    a = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(u.dtype)
    y, _ = ssd_chunked(x, dt, a, b, c, cfg.chunk)
    y = y + x * p["D"].astype(u.dtype)[None, None, :, None]
    y = y.reshape(*u.shape[:2], din)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("bsf,fd->bsd", y, p["out"].astype(u.dtype))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_mamba_cache(batch: int, cfg: MambaConfig, dtype=jnp.float32) -> dict:
    din, n = cfg.d_inner_padded, cfg.d_state
    return {
        "ssm": jnp.zeros((batch, cfg.nheads_padded, cfg.headdim, n), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, din + 2 * n), dtype),
    }


def mamba_decode(p, cfg: MambaConfig, u: jnp.ndarray, cache: dict):
    """One-token step. u (B, 1, d_model) -> (out (B,1,d), new_cache)."""
    din, n = cfg.d_inner_padded, cfg.d_state
    z = jnp.einsum("bsd,df->bsf", u, p["in_z"].astype(u.dtype))
    xraw = jnp.einsum("bsd,df->bsf", u, p["in_x"].astype(u.dtype))
    braw = jnp.einsum("bsd,dn->bsn", u, p["in_B"].astype(u.dtype))
    craw = jnp.einsum("bsd,dn->bsn", u, p["in_C"].astype(u.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, p["in_dt"].astype(u.dtype))
        + p["dt_bias"].astype(u.dtype))[:, 0]            # (B,H)

    xbc_t = jnp.concatenate([xraw, braw, craw], axis=-1)[:, 0]   # (B, C)
    conv_win = jnp.concatenate([cache["conv"].astype(u.dtype),
                                xbc_t[:, None]], axis=1)          # (B, K, C)
    w = p["conv"].astype(u.dtype)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_win, w))
    new_conv = conv_win[:, 1:]

    x, b, c = jnp.split(xbc, [din, din + n], axis=-1)
    h = cfg.nheads_padded
    x = x.reshape(-1, h, cfg.headdim)
    a = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(u.dtype)
    decay = jnp.exp(dt * a[None])                        # (B,H)
    ssm = cache["ssm"].astype(u.dtype)
    ssm = (ssm * decay[..., None, None]
           + jnp.einsum("bhp,bh,bn->bhpn", x, dt, b))
    y = jnp.einsum("bhpn,bn->bhp", ssm, c) + x * p["D"].astype(u.dtype)[None, :, None]
    y = y.reshape(-1, 1, din)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bsf,fd->bsd", y, p["out"].astype(u.dtype))
    return out, {"ssm": ssm.astype(cache["ssm"].dtype),
                 "conv": new_conv.astype(cache["conv"].dtype)}
