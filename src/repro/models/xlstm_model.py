"""xLSTM LM assembly: mLSTM blocks with sLSTM blocks at configured positions
(the paper's mLSTM:sLSTM ratio), embedding + final norm + tied unembedding.

Twelve layers is small enough for a Python-level layer loop (heterogeneous
blocks don't scan); the recurrent families' value is the O(1)-state decode
path exercised by the ``long_500k`` shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import xlstm as X
from repro.distributed.autoshard import constrain


class XLSTMLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.xcfg = X.XLSTMConfig(d_model=cfg.d_model,
                                  num_heads=cfg.num_heads,
                                  chunk=cfg.ssm_chunk)
        self.kinds = ["slstm" if i in cfg.slstm_at else "mlstm"
                      for i in range(cfg.num_layers)]

    def init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 1)
        col = L.ParamCollector(keys[0])
        L.embed_init(col, cfg.vocab_size, cfg.d_model)
        col.ones("final_norm", (cfg.d_model,), ("embed",))
        params, specs = col.done()
        blocks, bspecs = [], []
        for i, kind in enumerate(self.kinds):
            c = L.ParamCollector(keys[i + 1])
            (X.slstm_init if kind == "slstm" else X.mlstm_init)(c, self.xcfg)
            p, s = c.done()
            blocks.append(p)
            bspecs.append(s)
        params["blocks"] = tuple(blocks)
        specs["blocks"] = tuple(bspecs)
        return params, specs

    def forward(self, params, tokens):
        cfg = self.cfg
        x = constrain(L.embed_apply(params, tokens).astype(
            jnp.dtype(cfg.compute_dtype)), "btd")
        for i, kind in enumerate(self.kinds):
            fwd = X.slstm_forward if kind == "slstm" else X.mlstm_forward
            if cfg.remat:
                fwd = jax.checkpoint(fwd, prevent_cse=False, static_argnums=(1,))
            x = constrain(fwd(params["blocks"][i], self.xcfg, x), "btd")
        x = L.rms_norm(x, params["final_norm"])
        return L.unembed_apply(params, x, tied=True)

    def loss(self, params, batch):
        logits = self.forward(params, batch["tokens"])
        return L.cross_entropy_loss(logits, batch["labels"], self.cfg.vocab_size)

    def prefill(self, params, tokens):
        return self.forward(params, tokens)[:, -1:]

    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32):
        del max_len  # recurrent state: O(1) in sequence length
        caches = []
        for kind in self.kinds:
            init = X.init_slstm_cache if kind == "slstm" else X.init_mlstm_cache
            caches.append(init(batch, self.xcfg, dtype))
        return tuple(caches)

    def decode_step(self, params, cache, tokens, pos):
        del pos  # recurrences are position-free
        cfg = self.cfg
        x = L.embed_apply(params, tokens).astype(jnp.dtype(cfg.compute_dtype))
        new = []
        for i, kind in enumerate(self.kinds):
            step = X.slstm_decode if kind == "slstm" else X.mlstm_decode
            x, nc = step(params["blocks"][i], self.xcfg, x, cache[i])
            new.append(nc)
        x = L.rms_norm(x, params["final_norm"])
        return L.unembed_apply(params, x, tied=True), tuple(new)
