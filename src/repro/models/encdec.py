"""Whisper-style encoder-decoder backbone (audio family).

The conv frontend is a STUB per the assignment: ``input_specs`` supplies
pre-computed frame embeddings ``(B, S_enc, d_model)`` directly (the two conv
layers + GELU of real Whisper live outside the measured backbone).  Encoder
uses fixed sinusoidal positions and bidirectional attention; decoder uses
learned positions, causal self-attention and cross-attention; LayerNorm +
GELU MLPs throughout (pre-LN).  Whisper-large-v3 has 32 encoder AND 32
decoder layers — both stacks are built (the assignment's "32L").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.distributed.autoshard import constrain


def _ln(x, p, name):
    return L.layer_norm(x, p[f"{name}_w"], p[f"{name}_b"])


def _ln_init(col: L.ParamCollector, name: str, d: int):
    col.ones(f"{name}_w", (d,), ("embed",))
    col.zeros(f"{name}_b", (d,), ("embed",))


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        hp, hkp = attn.padded_heads(cfg.num_heads, cfg.num_kv_heads, cfg.tp)
        base = dict(d_model=cfg.d_model, num_heads=cfg.num_heads,
                    num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
                    heads_padded=hp, kv_heads_padded=hkp, use_rope=False)
        self.enc_cfg = attn.AttnConfig(**base, causal=False)
        self.self_cfg = attn.AttnConfig(**base, causal=True)
        self.cross_cfg = attn.AttnConfig(**base, causal=False, cross=True)
        self.max_dec_len = 4096 * 8  # learned positions table bound

    # ------------------------------------------------------------- params --
    def _enc_layer(self, key):
        cfg = self.cfg
        col = L.ParamCollector(key)
        _ln_init(col, "ln1", cfg.d_model)
        attn.attn_init(col.sub("attn"), self.enc_cfg)
        _ln_init(col, "ln2", cfg.d_model)
        L.gelu_mlp_init(col.sub("mlp"), cfg.d_model, cfg.d_ff)
        params, specs = col.done()
        params["attn"] = attn.mask_padded_heads(params["attn"], self.enc_cfg)
        return params, specs

    def _dec_layer(self, key):
        cfg = self.cfg
        col = L.ParamCollector(key)
        _ln_init(col, "ln1", cfg.d_model)
        attn.attn_init(col.sub("self_attn"), self.self_cfg)
        _ln_init(col, "ln_x", cfg.d_model)
        attn.attn_init(col.sub("cross_attn"), self.cross_cfg)
        _ln_init(col, "ln2", cfg.d_model)
        L.gelu_mlp_init(col.sub("mlp"), cfg.d_model, cfg.d_ff)
        params, specs = col.done()
        params["self_attn"] = attn.mask_padded_heads(params["self_attn"], self.self_cfg)
        params["cross_attn"] = attn.mask_padded_heads(params["cross_attn"], self.cross_cfg)
        return params, specs

    def init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, 2 * cfg.num_layers + 2)
        col = L.ParamCollector(keys[0])
        L.embed_init(col, cfg.vocab_size, cfg.d_model)
        col.dense("dec_pos", (self.max_dec_len, cfg.d_model), ("pos", "embed"),
                  scale=0.01)
        _ln_init(col, "enc_final", cfg.d_model)
        _ln_init(col, "dec_final", cfg.d_model)
        params, specs = col.done()
        enc = [self._enc_layer(keys[1 + i]) for i in range(cfg.num_layers)]
        dec = [self._dec_layer(keys[1 + cfg.num_layers + i])
               for i in range(cfg.num_layers)]
        params["enc_layers"], specs["enc_layers"] = L.stack_layers(enc)
        params["dec_layers"], specs["dec_layers"] = L.stack_layers(dec)
        return params, specs

    # ------------------------------------------------------------ encoder --
    def encode(self, params, enc_embeds):
        cfg = self.cfg
        s = enc_embeds.shape[1]
        x = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
        x = x + L.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
        x = constrain(x, "btd")

        def block(lp, x):
            h = _ln(x, lp, "ln1")
            x = x + attn.full_attention(lp["attn"], self.enc_cfg, h)
            h = _ln(x, lp, "ln2")
            return x + L.gelu_mlp_apply(lp["mlp"], h)

        if cfg.remat:
            block = jax.checkpoint(block, prevent_cse=False)

        def scan_fn(x, lp):
            return constrain(block(lp, x), "btd"), None

        x, _ = jax.lax.scan(scan_fn, x, params["enc_layers"],
                            unroll=cfg.scan_unroll)
        return _ln(x, params, "enc_final")

    # ------------------------------------------------------------ decoder --
    def decode_full(self, params, tokens, enc_out):
        cfg = self.cfg
        s = tokens.shape[1]
        x = L.embed_apply(params, tokens).astype(enc_out.dtype)
        x = x + params["dec_pos"][:s].astype(x.dtype)[None]
        x = constrain(x, "btd")

        def block(lp, x, enc_out):
            h = _ln(x, lp, "ln1")
            x = x + attn.full_attention(lp["self_attn"], self.self_cfg, h)
            h = _ln(x, lp, "ln_x")
            x = x + attn.full_attention(lp["cross_attn"], self.cross_cfg, h,
                                        x_kv=enc_out)
            h = _ln(x, lp, "ln2")
            return x + L.gelu_mlp_apply(lp["mlp"], h)

        if cfg.remat:
            block = jax.checkpoint(block, prevent_cse=False)

        def scan_fn(x, lp):
            return constrain(block(lp, x, enc_out), "btd"), None

        x, _ = jax.lax.scan(scan_fn, x, params["dec_layers"],
                            unroll=cfg.scan_unroll)
        x = _ln(x, params, "dec_final")
        return constrain(L.unembed_apply(params, x, tied=True), "btv")

    def forward(self, params, batch):
        enc_out = self.encode(params, batch["enc_embeds"])
        return self.decode_full(params, batch["tokens"], enc_out)

    def loss(self, params, batch):
        logits = self.forward(params, batch)
        return L.cross_entropy_loss(logits, batch["labels"], self.cfg.vocab_size)

    # ------------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        one = attn.init_kv_cache(batch, max_len, self.self_cfg, dtype)
        self_cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.cfg.num_layers,) + x.shape).copy(),
            one)
        return {"self": self_cache, "cross_k": None, "cross_v": None}

    def precompute_cross(self, params, enc_out):
        """Cross-attention K/V are position-independent: computed once."""
        def one_layer(lp):
            k = jnp.einsum("btd,dhk->bthk", enc_out,
                           lp["cross_attn"]["wk"].astype(enc_out.dtype))
            v = jnp.einsum("btd,dhk->bthk", enc_out,
                           lp["cross_attn"]["wv"].astype(enc_out.dtype))
            return k, v

        return jax.vmap(one_layer, in_axes=0)(params["dec_layers"])

    def decode_step(self, params, cache, tokens, pos, cross_kv):
        cfg = self.cfg
        x = L.embed_apply(params, tokens).astype(jnp.dtype(cfg.compute_dtype))
        x = x + jnp.take(params["dec_pos"], pos, axis=0)[:, None].astype(x.dtype)
        ck, cv = cross_kv

        import math

        def scan_fn(x, inp):
            lp, lcache, k_x, v_x = inp
            h = _ln(x, lp, "ln1")
            h, new_cache = attn.decode_attention(lp["self_attn"], self.self_cfg,
                                                 h, lcache, pos)
            x = x + h
            h = _ln(x, lp, "ln_x")
            q = jnp.einsum("bsd,dhk->bshk", h,
                           lp["cross_attn"]["wq"].astype(x.dtype))
            scores = attn._grouped_scores(q, k_x) / math.sqrt(self.cross_cfg.head_dim)
            probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
            o = attn._grouped_out(probs, v_x)
            x = x + jnp.einsum("...hk,hkd->...d", o,
                               lp["cross_attn"]["wo"].astype(x.dtype))
            h = _ln(x, lp, "ln2")
            return constrain(x + L.gelu_mlp_apply(lp["mlp"], h), "btd"), new_cache

        x, new_self = jax.lax.scan(scan_fn, x,
                                   (params["dec_layers"], cache["self"], ck, cv),
                                   unroll=cfg.scan_unroll)
        x = _ln(x, params, "dec_final")
        logits = L.unembed_apply(params, x, tied=True)
        return logits, {"self": new_self, "cross_k": cache["cross_k"],
                        "cross_v": cache["cross_v"]}
