"""Model zoo: the 10 assigned architectures as functional JAX models.

Design notes:

* Models are *functional*: ``params`` is a plain pytree of arrays, and a
  parallel ``specs`` pytree carries **logical axis names** per parameter
  (MaxText-style); ``repro.distributed.sharding`` maps logical axes to mesh
  axes.  No framework dependency.
* Layer stacks are ``jax.lax.scan`` over stacked parameters (leading ``layers``
  dim) with a configurable remat policy — essential for compile times at 88
  layers and for activation-memory control at scale.
* Tensor-parallel head padding: Q heads are padded up to a multiple of the
  mesh model-axis size (KV heads stay *replicated* under TP, which is exact
  for GQA); vocab is padded to a multiple of 256.  Padding waste is charged
  to the roofline useful-FLOPs ratio, never hidden.
"""

from repro.models.model_zoo import build_model

__all__ = ["build_model"]
