"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, genuinely recurrent) — arXiv:2405.04517.

mLSTM training/prefill uses the paper's parallel quadratic form: with
log-sigmoid forget gates F and input gates I,

    D[i,j] = exp( Σ_{k=j+1..i} log σ(f_k) + i_j − m_i )       (stabilized)
    H      = ((Q Kᵀ/√d ⊙ D) V) / max(|row-sum|, 1)

which is attention-like (MXU-friendly) — the reason the family runs the
``long_500k`` shape is the O(1)-state decode path, not the train path.
Decode carries ``C (B,H,P,P)``, ``n (B,H,P)``, ``m (B,H)`` per layer.

sLSTM is implemented as a true sequential ``lax.scan`` over time with
exponential-gate stabilization and block-diagonal recurrent weights (4 heads).
Projection factors follow the paper: mLSTM pf=2 (up/gate), sLSTM pf=4/3
(post-block gated MLP); neither family has a separate FFN (the assignment's
``d_ff=0``).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.layers import ParamCollector, rms_norm


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    num_heads: int = 4
    heads_padded: int = 0
    conv_kernel: int = 4
    mlstm_pf: float = 2.0
    slstm_pf: float = 4.0 / 3.0
    chunk: int = 256       # chunkwise-parallel block length (long sequences)

    @property
    def hp(self) -> int:
        # xLSTM heads are few (4) and its models small: rather than padding
        # heads 4x to the TP width, the whole family runs with replicated
        # params and batch sharded over BOTH mesh axes (DESIGN.md §6).
        return self.num_heads

    @property
    def d_inner(self) -> int:
        return int(self.mlstm_pf * self.d_model)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(col: ParamCollector, cfg: XLSTMConfig):
    dm = cfg.d_model
    din = cfg.d_inner
    h = cfg.hp
    hd = din // h
    col.ones("ln", (dm,), ("embed",))
    col.dense("up", (dm, din), ("embed", "mlp"))
    col.dense("up_z", (dm, din), ("embed", "mlp"))
    col.dense("conv", (cfg.conv_kernel, din), ("conv", "mlp"))
    col.dense("wq", (din, h, hd), ("mlp", "q_heads", "head"))
    col.dense("wk", (din, h, hd), ("mlp", "q_heads", "head"))
    col.dense("wv", (din, h, hd), ("mlp", "q_heads", "head"))
    col.dense("w_i", (din, h), ("mlp", "q_heads"), scale=0.01)
    col.dense("w_f", (din, h), ("mlp", "q_heads"), scale=0.01)
    col.zeros("b_i", (h,), ("q_heads",))
    col.zeros("b_f", (h,), ("q_heads",))   # +3 offset applied in forward
    col.ones("mnorm", (din,), ("mlp",))
    col.dense("down", (din, dm), ("mlp", "embed"))


def _mlstm_gates(p, xc, dtype):
    i_pre = jnp.einsum("bsf,fh->bsh", xc, p["w_i"].astype(dtype)) + p["b_i"].astype(dtype)
    f_pre = (jnp.einsum("bsf,fh->bsh", xc, p["w_f"].astype(dtype))
             + p["b_f"].astype(dtype) + 3.0)   # bias toward remembering
    return i_pre.astype(jnp.float32), f_pre.astype(jnp.float32)


def _causal_conv(x, w):
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i:i + x.shape[1]] * w[i][None, None, :]
    return jax.nn.silu(out)


def mlstm_forward(p, cfg: XLSTMConfig, u: jnp.ndarray) -> jnp.ndarray:
    """Parallel (quadratic) mLSTM block. u (B,S,d) -> (B,S,d)."""
    b, s, dm = u.shape
    h = cfg.hp
    din = cfg.d_inner
    hd = din // h
    x = rms_norm(u, p["ln"])
    xu = jnp.einsum("bsd,df->bsf", x, p["up"].astype(x.dtype))
    z = jnp.einsum("bsd,df->bsf", x, p["up_z"].astype(x.dtype))
    xc = _causal_conv(xu, p["conv"].astype(x.dtype))

    q = jnp.einsum("bsf,fhk->bshk", xc, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsf,fhk->bshk", xc, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsf,fhk->bshk", xu, p["wv"].astype(x.dtype))

    i_pre, f_pre = _mlstm_gates(p, xc, x.dtype)          # (B,S,H) f32
    if s > 2 * cfg.chunk:
        # chunkwise-parallel form: O(S·Qc) memory instead of O(S²)
        out = mlstm_inner_chunked(q, k, v, i_pre, f_pre, cfg.chunk)
    else:
        logf = jax.nn.log_sigmoid(f_pre)
        fcum = jnp.cumsum(logf, axis=1)                  # (B,S,H)
        # log decay matrix: dmat[i,j] = fcum_i - fcum_j + i_pre_j  (j <= i)
        dmat = (fcum[:, :, None, :] - fcum[:, None, :, :]
                + i_pre[:, None, :, :])                  # (B,S,S,H)
        causal = jnp.tril(jnp.ones((s, s), bool))
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        m = jnp.max(dmat, axis=2, keepdims=True)         # stabilizer
        d = jnp.exp(dmat - m)

        scores = jnp.einsum("bihk,bjhk->bijh", q, k) / math.sqrt(hd)
        w = scores.astype(jnp.float32) * d
        norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), 1.0)  # (B,S,H)
        out = (jnp.einsum("bijh,bjhk->bihk", w, v.astype(jnp.float32))
               / norm[..., None]).astype(x.dtype)

    out = out.reshape(b, s, din)
    out = rms_norm(out, p["mnorm"]) * jax.nn.silu(z)
    return u + jnp.einsum("bsf,fd->bsd", out, p["down"].astype(x.dtype))


def mlstm_inner_chunked(q, k, v, i_pre, f_pre, chunk: int):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic + carried (C, n, m).

    q/k/v (B,S,H,D), gates (B,S,H) f32.  Exactly equals the quadratic form
    (same stabilization convention: running max m, row normalizer
    ``max(|ñ·q|, 1)``) but materializes (Qc, Qc) blocks instead of (S, S) —
    the §Perf iteration that takes xlstm prefill_32k off the memory wall.
    Returns h (B,S,H,D).
    """
    b, s, hh, dd = q.shape
    qc = min(chunk, s)
    s_pad = (s + qc - 1) // qc * qc
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        # padded steps: f=1 (logf=0 after sigmoid(+inf)->1? use big positive),
        # i = -inf so they inject nothing
        gpad = ((0, 0), (0, s_pad - s), (0, 0))
        i_pre = jnp.pad(i_pre, gpad, constant_values=-1e9)
        f_pre = jnp.pad(f_pre, gpad, constant_values=1e9)
    nc = s_pad // qc
    scale = 1.0 / math.sqrt(dd)

    def reshape_c(x):
        return x.reshape(b, nc, qc, *x.shape[2:])

    qs, ks, vs = map(reshape_c, (q, k, v))
    ip = reshape_c(i_pre)
    logf = jax.nn.log_sigmoid(reshape_c(f_pre))
    a = jnp.cumsum(logf, axis=2)                     # (B,NC,Qc,H) within-chunk
    a_tot = a[:, :, -1]                              # (B,NC,H)
    w = ip - a                                       # log weight rel chunk start

    # carried state: Ĉ (B,H,D,D), n̂ (B,H,D), m̂ (B,H) with C = Ĉ·exp(m̂)
    c0 = jnp.zeros((b, hh, dd, dd), jnp.float32)
    n0 = jnp.zeros((b, hh, dd), jnp.float32)
    m0 = jnp.full((b, hh), -1e30, jnp.float32)

    def step(carry, inp):
        c_h, n_h, m_h = carry
        qj, kj, vj, aj, wj, atot = inp               # (B,Qc,H,D)... (B,H)
        w_max = jnp.max(wj, axis=1)                  # (B,H)
        # ---- row outputs ------------------------------------------------
        # m_i = a_i + max(m̂, max_{j<=i} w_j)
        w_run = jax.lax.cummax(wj, axis=1)           # (B,Qc,H)
        m_row = aj + jnp.maximum(m_h[:, None], w_run)
        # dmat[i,j] = a_i - a_j + i_j = a_i + w_j
        dmat = aj[:, :, None] + wj[:, None, :]       # (B,Qc,Qc,H)
        causal = jnp.tril(jnp.ones((qj.shape[1], qj.shape[1]), bool))
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        dstab = jnp.exp(dmat - m_row[:, :, None])    # (B,Qc,Qc,H)
        scores = jnp.einsum("bihd,bjhd->bijh", qj, kj) * scale
        wmat = scores.astype(jnp.float32) * dstab
        s_coef = jnp.exp(aj + m_h[:, None] - m_row)  # (B,Qc,H)
        num = (jnp.einsum("bijh,bjhd->bihd", wmat, vj.astype(jnp.float32))
               + s_coef[..., None] * jnp.einsum(
                   "bhdk,bihd->bihk", c_h, qj.astype(jnp.float32)))
        den = jnp.sum(wmat, axis=2) + s_coef * jnp.einsum(
            "bhd,bihd->bih", n_h, qj.astype(jnp.float32))
        h_out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # ---- state update ------------------------------------------------
        m_new = jnp.maximum(m_h + atot, atot + w_max)
        decay = jnp.exp(m_h + atot - m_new)          # (B,H)
        inw = jnp.exp(wj + atot[:, None] - m_new[:, None])   # (B,Qc,H)
        kv = jnp.einsum("bjh,bjhd,bjhk->bhdk", inw,
                        kj.astype(jnp.float32) * scale, vj.astype(jnp.float32))
        c_new = c_h * decay[..., None, None] + kv
        n_new = n_h * decay[..., None] + jnp.einsum(
            "bjh,bjhd->bhd", inw, kj.astype(jnp.float32) * scale)
        return (c_new, n_new, m_new), h_out

    seq = (qs.transpose(1, 0, 2, 3, 4), ks.transpose(1, 0, 2, 3, 4),
           vs.transpose(1, 0, 2, 3, 4), a.transpose(1, 0, 2, 3),
           w.transpose(1, 0, 2, 3), a_tot.transpose(1, 0, 2))
    _, hs = jax.lax.scan(step, (c0, n0, m0), seq)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, hh, dd)
    return hs[:, :s].astype(q.dtype)


def init_mlstm_cache(batch: int, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    h = cfg.hp
    hd = cfg.d_inner // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), dtype),
        "n": jnp.zeros((batch, h, hd), dtype),
        "m": jnp.full((batch, h), -1e9, dtype),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
    }


def mlstm_decode(p, cfg: XLSTMConfig, u: jnp.ndarray, cache: dict):
    """Recurrent one-token step. u (B,1,d)."""
    bsz = u.shape[0]
    h = cfg.hp
    din = cfg.d_inner
    hd = din // h
    x = rms_norm(u, p["ln"])
    xu = jnp.einsum("bsd,df->bsf", x, p["up"].astype(x.dtype))
    z = jnp.einsum("bsd,df->bsf", x, p["up_z"].astype(x.dtype))
    conv_win = jnp.concatenate([cache["conv"].astype(x.dtype), xu], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_win,
                                p["conv"].astype(x.dtype)))[:, None]
    new_conv = conv_win[:, 1:]

    q = jnp.einsum("bsf,fhk->bshk", xc, p["wq"].astype(x.dtype))[:, 0]
    k = jnp.einsum("bsf,fhk->bshk", xc, p["wk"].astype(x.dtype))[:, 0]
    v = jnp.einsum("bsf,fhk->bshk", xu, p["wv"].astype(x.dtype))[:, 0]
    i_pre, f_pre = _mlstm_gates(p, xc, x.dtype)
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]              # (B,H)
    logf = jax.nn.log_sigmoid(f_pre)

    m_old = cache["m"].astype(jnp.float32)
    m_new = jnp.maximum(logf + m_old, i_pre)
    decay = jnp.exp(logf + m_old - m_new)[..., None, None]
    inp = jnp.exp(i_pre - m_new)[..., None, None]
    c_new = cache["C"].astype(jnp.float32) * decay + inp * jnp.einsum(
        "bhk,bhl->bhkl", v.astype(jnp.float32), k.astype(jnp.float32) / math.sqrt(hd))
    n_new = (cache["n"].astype(jnp.float32) * decay[..., 0]
             + inp[..., 0] * k.astype(jnp.float32) / math.sqrt(hd))
    num = jnp.einsum("bhkl,bhl->bhk", c_new, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhl,bhl->bh", n_new,
                                         q.astype(jnp.float32))), 1.0)
    out = (num / den[..., None]).astype(x.dtype).reshape(bsz, 1, din)
    out = rms_norm(out, p["mnorm"]) * jax.nn.silu(z)
    y = u + jnp.einsum("bsf,fd->bsd", out, p["down"].astype(x.dtype))
    return y, {"C": c_new.astype(cache["C"].dtype),
               "n": n_new.astype(cache["n"].dtype),
               "m": m_new.astype(cache["m"].dtype),
               "conv": new_conv.astype(cache["conv"].dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(col: ParamCollector, cfg: XLSTMConfig):
    dm = cfg.d_model
    h = cfg.hp
    hd = dm // cfg.num_heads            # head width from the *real* head count
    dh = h * hd                         # padded recurrent width
    col.ones("ln", (dm,), ("embed",))
    col.dense("conv", (cfg.conv_kernel, dm), ("conv", "embed"))
    for g in ("i", "f", "z", "o"):
        col.dense(f"w_{g}", (dm, dh), ("embed", "mlp"))
        col.dense(f"r_{g}", (h, hd, hd), ("q_heads", "head", "head"), scale=0.1)
        col.zeros(f"b_{g}", (dh,), ("mlp",))
    col.ones("gnorm", (dh,), ("mlp",))
    col.dense("proj_up", (dh, int(cfg.slstm_pf * dm) * 2), ("mlp", "mlp2"))
    col.dense("proj_down", (int(cfg.slstm_pf * dm), dm), ("mlp2", "embed"))


def init_slstm_cache(batch: int, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    h = cfg.hp
    hd = cfg.d_model // cfg.num_heads

    def z():  # fresh buffer per field: aliasing breaks jit donation
        return jnp.zeros((batch, h, hd), dtype)

    return {"c": z(), "n": z() + 1e-6, "h": z(), "m": z(),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_model), dtype)}


def _slstm_cell(p, cfg: XLSTMConfig, x_t, xc_t, state):
    """One sLSTM time step.  x_t (B, d_model) raw, xc_t conv-silu'd."""
    h = cfg.hp
    hd = cfg.d_model // cfg.num_heads
    hprev = state["h"]                                    # (B,H,hd)

    def gate(name, src):
        wx = jnp.einsum("bd,df->bf", src, p[f"w_{name}"].astype(src.dtype))
        wx = wx.reshape(-1, h, hd)
        rh = jnp.einsum("bhk,hkl->bhl", hprev, p[f"r_{name}"].astype(src.dtype))
        return (wx + rh + p[f"b_{name}"].astype(src.dtype).reshape(h, hd)).astype(
            jnp.float32)

    i_pre = gate("i", xc_t)
    f_pre = gate("f", xc_t) + 3.0
    z_pre = gate("z", x_t)
    o_pre = gate("o", x_t)

    m_old = state["m"].astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m_old, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m_old - m_new)
    c_new = f_g * state["c"].astype(jnp.float32) + i_g * jnp.tanh(z_pre)
    n_new = f_g * state["n"].astype(jnp.float32) + i_g
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    dt = state["h"].dtype
    return {"c": c_new.astype(dt), "n": n_new.astype(dt),
            "h": h_new.astype(dt), "m": m_new.astype(dt)}


def slstm_forward(p, cfg: XLSTMConfig, u: jnp.ndarray) -> jnp.ndarray:
    """Sequential sLSTM block (lax.scan over time). u (B,S,d)."""
    b, s, dm = u.shape
    h = cfg.hp
    hd = dm // cfg.num_heads
    x = rms_norm(u, p["ln"])
    xc = _causal_conv(x, p["conv"].astype(x.dtype))

    state0 = {k: v for k, v in init_slstm_cache(b, cfg, x.dtype).items()
              if k != "conv"}

    def step(state, inp):
        x_t, xc_t = inp
        new = _slstm_cell(p, cfg, x_t, xc_t, state)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0,
                         (x.transpose(1, 0, 2), xc.transpose(1, 0, 2)))
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, h * hd)
    hs = rms_norm(hs, p["gnorm"])
    up = jnp.einsum("bsf,fg->bsg", hs, p["proj_up"].astype(x.dtype))
    a, g = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", a * jax.nn.gelu(g, approximate=True),
                     p["proj_down"].astype(x.dtype))
    return u + out


def slstm_decode(p, cfg: XLSTMConfig, u: jnp.ndarray, cache: dict):
    b = u.shape[0]
    h = cfg.hp
    hd = cfg.d_model // cfg.num_heads
    x = rms_norm(u, p["ln"])
    conv_win = jnp.concatenate([cache["conv"].astype(x.dtype), x], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_win,
                                p["conv"].astype(x.dtype)))
    state = {k: cache[k] for k in ("c", "n", "h", "m")}
    new = _slstm_cell(p, cfg, x[:, 0], xc, state)
    hs = rms_norm(new["h"].reshape(b, 1, h * hd), p["gnorm"])
    up = jnp.einsum("bsf,fg->bsg", hs, p["proj_up"].astype(x.dtype))
    a, g = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", a * jax.nn.gelu(g, approximate=True),
                     p["proj_down"].astype(x.dtype))
    new["conv"] = conv_win[:, 1:].astype(cache["conv"].dtype)
    return u + out, new
