"""Qwen2-VL-style VLM backbone: the decoder LM with M-RoPE and a stubbed
vision frontend.

Per the assignment, the modality frontend is a STUB: ``input_specs`` provides
pre-computed patch embeddings ``(B, S_vis, d_model)``; the backbone
concatenates them with the text embeddings and runs M-RoPE attention with the
supplied 3-stream (t, h, w) position ids.  Labels cover text positions only.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import DecoderLM


def build_positions3(batch: int, s_vis: int, s_txt: int,
                     grid: tuple[int, int] = None) -> np.ndarray:
    """Default M-RoPE id layout: vision tokens on an (h, w) grid at t=0..T_img,
    text tokens advance all three streams together after the vision span."""
    if grid is None:
        side = max(int(np.sqrt(s_vis)), 1)
        grid = (side, (s_vis + side - 1) // side)
    h_ids = (np.arange(s_vis) // grid[1]) % grid[0]
    w_ids = np.arange(s_vis) % grid[1]
    t_ids = np.zeros(s_vis)
    base = max(grid[0], grid[1])
    txt = base + np.arange(s_txt)
    pos3 = np.stack([
        np.concatenate([t_ids, txt]),
        np.concatenate([h_ids, txt]),
        np.concatenate([w_ids, txt]),
    ])                                                   # (3, S)
    return np.broadcast_to(pos3[:, None], (3, batch, s_vis + s_txt)).astype(np.int32)


class VLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.mrope_sections is not None
        self.cfg = cfg
        self.lm = DecoderLM(cfg)

    def init(self, key):
        return self.lm.init(key)

    def forward(self, params, batch):
        """batch: vis_embeds (B,S_vis,d), tokens (B,S_txt), positions3 (3,B,S)."""
        vis = batch["vis_embeds"].astype(jnp.dtype(self.cfg.compute_dtype))
        txt = L.embed_apply(params, batch["tokens"]).astype(vis.dtype)
        x = jnp.concatenate([vis, txt], axis=1)
        logits, aux = self.lm.forward(params, tokens=None,
                                      positions3=batch["positions3"],
                                      inputs_embeds=x)
        return logits, aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        s_vis = batch["vis_embeds"].shape[1]
        txt_logits = logits[:, s_vis:]
        ce = L.cross_entropy_loss(txt_logits, batch["labels"],
                                  self.cfg.vocab_size)
        return ce + 0.01 * aux

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return self.lm.init_cache(batch, max_len, dtype)

    def decode_step(self, params, cache, tokens, pos):
        return self.lm.decode_step(params, cache, tokens, pos)
