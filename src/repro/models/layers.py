"""Shared layers: norms, RoPE/M-RoPE, dense/embedding params, MLPs.

Parameter convention: every ``*_init`` returns ``(params, specs)`` where
``specs`` mirrors ``params`` with a tuple of logical axis names per array.
Logical axes used across the zoo:

  "embed"   — d_model            (never sharded: activations shard on data)
  "vocab"   — vocabulary         (→ model axis)
  "q_heads" — query heads        (→ model axis; padded to multiple)
  "kv_heads"— kv heads           (replicated under TP)
  "head"    — per-head dim
  "mlp"     — ffn hidden         (→ model axis)
  "experts" — MoE experts        (→ model axis when divisible, else "mlp")
  "conv"/"state"/"heads_ssm" ... — SSM internals (replicated or mlp-sharded)
  "layers"  — scan dimension     (never sharded)
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def pad_to(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def dense_init(key, shape, axes, scale: Optional[float] = None):
    """Truncated-normal dense parameter with fan-in scaling."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    w = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return w, axes


def zeros_init(shape, axes):
    return jnp.zeros(shape, jnp.float32), axes


def ones_init(shape, axes):
    return jnp.ones(shape, jnp.float32), axes


class ParamCollector:
    """Tiny helper that accumulates ``(params, specs)`` trees."""

    def __init__(self, key):
        self.key = key
        self.params: dict = {}
        self.specs: dict = {}

    def sub(self, name: str) -> "ParamCollector":
        self.key, sub = jax.random.split(self.key)
        c = ParamCollector(sub)
        self.params[name] = c.params
        self.specs[name] = c.specs
        return c

    def dense(self, name, shape, axes, scale=None):
        self.key, sub = jax.random.split(self.key)
        w, ax = dense_init(sub, shape, axes, scale)
        self.params[name] = w
        self.specs[name] = ax

    def zeros(self, name, shape, axes):
        self.params[name], self.specs[name] = zeros_init(shape, axes)

    def ones(self, name, shape, axes):
        self.params[name], self.specs[name] = ones_init(shape, axes)

    def done(self):
        return self.params, self.specs


def stack_layers(trees: list):
    """Stack per-layer (params, specs) into scan-ready (L, ...) params."""
    params = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                          *[t[0] for t in trees])
    specs = jax.tree.map(lambda ax, _: ("layers",) + tuple(ax),
                         trees[0][1], trees[0][0],
                         is_leaf=lambda x: isinstance(x, tuple))
    return params, specs


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * weight.astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * weight.astype(dt) + bias.astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x (..., S, H, D); positions (..., S) -> rotated x.

    Interleaved-pair convention (llama).  Computed in f32 for stability.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, sections: tuple,
                theta: float = 10000.0) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the head dim is split into (t, h, w)
    frequency sections, each rotated by its own position id.

    x (..., S, H, D); positions3 (3, ..., S); sections are half-dim sizes
    summing to D/2 (e.g. (16, 24, 24) for D=128).
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                              # (D/2,)
    # section id per frequency slot
    sec_id = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    sec_id = jnp.asarray(sec_id, jnp.int32)                   # (D/2,)
    # pick the position stream per slot: angles[..., k] uses positions3[sec_id[k]]
    pos = jnp.take(positions3, sec_id, axis=0)                # (D/2, ..., S)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)        # (..., S, D/2)
    angles = pos * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, jnp.float32)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(col: ParamCollector, d_model: int, d_ff: int):
    col.dense("gate", (d_model, d_ff), ("embed", "mlp"))
    col.dense("up", (d_model, d_ff), ("embed", "mlp"))
    col.dense("down", (d_ff, d_model), ("mlp", "embed"))


def swiglu_apply(p, x):
    g = jnp.einsum("...d,df->...f", x, p["gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["down"].astype(x.dtype))


def gelu_mlp_init(col: ParamCollector, d_model: int, d_ff: int):
    col.dense("fc1", (d_model, d_ff), ("embed", "mlp"))
    col.zeros("b1", (d_ff,), ("mlp",))
    col.dense("fc2", (d_ff, d_model), ("mlp", "embed"))
    col.zeros("b2", (d_model,), ("embed",))


def gelu_mlp_apply(p, x):
    h = jnp.einsum("...d,df->...f", x, p["fc1"].astype(x.dtype)) + p["b1"].astype(x.dtype)
    h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("...f,fd->...d", h, p["fc2"].astype(x.dtype)) + p["b2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(col: ParamCollector, vocab: int, d_model: int, pad_mult: int = 256):
    v_pad = pad_to(vocab, pad_mult)
    col.dense("embedding", (v_pad, d_model), ("vocab", "embed"), scale=1.0)
    return v_pad


def embed_apply(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed_apply(p, x, tied: bool = True):
    w = p["embedding"] if tied else p["unembed"]
    return jnp.einsum("...d,vd->...v", x, w.astype(x.dtype))


def cross_entropy_loss(logits, labels, vocab_real: int, ignore_id: int = -100):
    """Mean next-token CE over valid positions; padded vocab columns masked."""
    v_pad = logits.shape[-1]
    if v_pad > vocab_real:
        neg = jnp.asarray(-1e9, logits.dtype)
        mask = jnp.arange(v_pad) >= vocab_real
        logits = jnp.where(mask, neg, logits)
    valid = labels != ignore_id
    labels_safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * valid) / jnp.maximum(jnp.sum(valid), 1)
