"""Zamba2-style hybrid: Mamba2 backbone with a weight-SHARED attention block
applied every ``shared_attn_every`` layers (arXiv:2411.15242).

Faithful structure: one set of attention+MLP weights reused at every shared
site; the shared block's input is ``concat(x, x0)`` (current activations and
the original embeddings) through a per-site projection — per-site projections
are the only unshared pieces, playing the role of zamba2's per-invocation
LoRA adapters (adaptation noted in DESIGN.md).

For the ``long_500k`` serve shape the shared attention runs with a sliding
window (``cfg.long_window``) so its cache is O(window); the Mamba state is
O(1) in sequence by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import ssm
from repro.distributed.autoshard import constrain


class ZambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        hp, hkp = attn.padded_heads(cfg.num_heads, cfg.num_kv_heads, cfg.tp)
        self.acfg = attn.AttnConfig(
            d_model=cfg.d_model, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
            heads_padded=hp, kv_heads_padded=hkp, causal=True,
            window=cfg.long_window, rope_theta=cfg.rope_theta)
        mcfg = ssm.MambaConfig(
            d_model=cfg.d_model, d_state=cfg.ssm_state,
            headdim=cfg.ssm_headdim, chunk=cfg.ssm_chunk)
        hp_ssm = L.pad_to(mcfg.nheads, cfg.tp)
        self.mcfg = ssm.MambaConfig(
            d_model=cfg.d_model, d_state=cfg.ssm_state,
            headdim=cfg.ssm_headdim, chunk=cfg.ssm_chunk,
            heads_padded=hp_ssm)
        self.sites = list(range(cfg.shared_attn_every - 1, cfg.num_layers,
                                cfg.shared_attn_every))

    # ------------------------------------------------------------- params --
    def init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 3)
        col = L.ParamCollector(keys[0])
        L.embed_init(col, cfg.vocab_size, cfg.d_model)
        col.ones("final_norm", (cfg.d_model,), ("embed",))
        # shared attention block (single weight set)
        shared = col.sub("shared")
        shared.ones("ln1", (cfg.d_model,), ("embed",))
        attn.attn_init(shared.sub("attn"), self.acfg)
        shared.ones("ln2", (cfg.d_model,), ("embed",))
        L.swiglu_init(shared.sub("mlp"), cfg.d_model, cfg.d_ff)
        # per-site input projections concat(x, x0) -> d
        col.dense("site_proj", (len(self.sites), 2 * cfg.d_model, cfg.d_model),
                  ("sites", "embed2", "embed"))
        params, specs = col.done()
        params["shared"]["attn"] = attn.mask_padded_heads(
            params["shared"]["attn"], self.acfg)

        def one_mamba(k):
            c = L.ParamCollector(k)
            c.ones("ln", (cfg.d_model,), ("embed",))
            ssm.mamba_init(c.sub("m"), self.mcfg)
            return c.done()

        layer_trees = [one_mamba(keys[i + 1]) for i in range(cfg.num_layers)]
        params["layers"], specs["layers"] = L.stack_layers(layer_trees)
        return params, specs

    # ------------------------------------------------------------ forward --
    def _mamba_span(self, params, x, lo, hi):
        span = jax.tree.map(lambda a: a[lo:hi], params["layers"])

        def block(lp, x):
            return x + ssm.mamba_forward(lp["m"], self.mcfg,
                                         L.rms_norm(x, lp["ln"]))

        if self.cfg.remat:
            block = jax.checkpoint(block, prevent_cse=False)

        def scan_fn(x, lp):
            return constrain(block(lp, x), "btd"), None

        x, _ = jax.lax.scan(scan_fn, x, span, unroll=self.cfg.scan_unroll)
        return x

    def _shared_block(self, params, x, x0, site_idx, positions=None):
        sp = params["shared"]
        inp = jnp.concatenate([x, x0], axis=-1)
        h = jnp.einsum("bsd,dk->bsk", inp,
                       params["site_proj"][site_idx].astype(x.dtype))
        h = L.rms_norm(h, sp["ln1"])
        h = attn.full_attention(sp["attn"], self.acfg, h, positions=positions)
        x = x + h
        h = L.rms_norm(x, sp["ln2"])
        return x + L.swiglu_apply(sp["mlp"], h)

    def forward(self, params, tokens, positions=None):
        cfg = self.cfg
        x0 = constrain(L.embed_apply(params, tokens).astype(
            jnp.dtype(cfg.compute_dtype)), "btd")
        x = x0
        prev = 0
        for si, site in enumerate(self.sites):
            x = self._mamba_span(params, x, prev, site + 1)
            x = self._shared_block(params, x, x0, si, positions)
            prev = site + 1
        if prev < cfg.num_layers:
            x = self._mamba_span(params, x, prev, cfg.num_layers)
        x = L.rms_norm(x, params["final_norm"])
        return constrain(L.unembed_apply(params, x, tied=True), "btv")

    def loss(self, params, batch):
        logits = self.forward(params, batch["tokens"],
                              positions=batch.get("positions"))
        return L.cross_entropy_loss(logits, batch["labels"], self.cfg.vocab_size)

    def prefill(self, params, tokens):
        return self.forward(params, tokens)[:, -1:]

    # ------------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        one = ssm.init_mamba_cache(batch, self.mcfg, jnp.float32)
        mamba = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (self.cfg.num_layers,) + x.shape).copy(), one)
        akv = attn.init_kv_cache(batch, max_len, self.acfg, dtype)
        shared = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (len(self.sites),) + x.shape).copy(), akv)
        return {"mamba": mamba, "shared": shared}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x0 = L.embed_apply(params, tokens).astype(jnp.dtype(cfg.compute_dtype))
        x = x0
        new_mamba = []
        new_shared = []
        prev = 0

        def mamba_one(lidx, x):
            lp = jax.tree.map(lambda a: a[lidx], params["layers"])
            lc = jax.tree.map(lambda a: a[lidx], cache["mamba"])
            out, nc = ssm.mamba_decode(lp["m"], self.mcfg,
                                       L.rms_norm(x, lp["ln"]), lc)
            return x + out, nc

        for si, site in enumerate(self.sites):
            for l in range(prev, site + 1):
                x, nc = mamba_one(l, x)
                new_mamba.append(nc)
            sp = params["shared"]
            inp = jnp.concatenate([x, x0], axis=-1)
            h = jnp.einsum("bsd,dk->bsk", inp,
                           params["site_proj"][si].astype(x.dtype))
            h = L.rms_norm(h, sp["ln1"])
            sc = jax.tree.map(lambda a: a[si], cache["shared"])
            h, nsc = attn.decode_attention(sp["attn"], self.acfg, h, sc, pos)
            new_shared.append(nsc)
            x = x + h
            h = L.rms_norm(x, sp["ln2"])
            x = x + L.swiglu_apply(sp["mlp"], h)
            prev = site + 1
        for l in range(prev, cfg.num_layers):
            x, nc = mamba_one(l, x)
            new_mamba.append(nc)

        x = L.rms_norm(x, params["final_norm"])
        logits = L.unembed_apply(params, x, tied=True)
        stack = lambda trees: jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        return logits, {"mamba": stack(new_mamba), "shared": stack(new_shared)}
