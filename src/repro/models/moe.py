"""Mixture-of-Experts layer: top-k routing with capacity-based scatter dispatch.

Dispatch strategy (TPU-native, DESIGN.md §7): tokens are scattered into a
``(E, capacity, d)`` buffer by (expert, position-in-expert) — an O(T·d) data
movement — and experts run as one batched GEMM ``(E, C, d) × (E, d, f)``, so
compiled FLOPs ≈ ``top_k · capacity_factor · T · d · f``: the *active* FLOPs
of the MoE, which is what the roofline's ``6·N_active·D`` model expects.  The
one-hot-matmul dispatch of early GShard implementations is O(T²) and was
rejected (see EXPERIMENTS.md §Perf napkin math).

Sharding: ``experts`` logical axis → mesh model axis when the expert count
divides it (phi-3.5: 16e on 16-way TP = 1 expert/shard, pure EP); otherwise
the ``mlp`` axis shards each expert's FFN (mixtral: 8e, TP within experts).
Router params are tiny and replicated.

Overflowed tokens (beyond capacity) are dropped with zero contribution —
standard practice; the load-balancing auxiliary loss keeps overflow rare.
Phi-3.5's SparseMixer-v2 router is approximated by standard normalized top-2
softmax routing (deviation noted in DESIGN.md).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import ParamCollector
from repro.distributed.autoshard import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    expert_axis: str = "experts"   # logical axis for the expert dim


def moe_init(col: ParamCollector, cfg: MoEConfig):
    e, dm, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ax = cfg.expert_axis
    col.dense("router", (dm, e), ("embed", "router_experts"))
    col.dense("gate", (e, dm, f), (ax, "embed", "mlp"))
    col.dense("up", (e, dm, f), (ax, "embed", "mlp"))
    col.dense("down", (e, f, dm), (ax, "mlp", "embed"))


def moe_apply(p, cfg: MoEConfig, x: jnp.ndarray,
              return_aux: bool = False):
    """x (B, S, d) -> (B, S, d) [, aux_loss].

    Dispatch is *grouped* on the data axis (GShard's ``group_size``): each
    data shard routes its own tokens into a per-group buffer with per-group
    capacity, so scatter, expert GEMM and combine are collective-free under
    the (groups→data, d_ff→model) sharding — measured 4.3x collective-byte
    reduction on mixtral train_4k (EXPERIMENTS.md §Perf iteration 1).
    Outside a sharding scope the group count is 1 (identical semantics up to
    per-group capacity rounding).
    """
    from repro.distributed.autoshard import data_group_count

    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    n_grp = data_group_count(t)
    tg = t // n_grp
    xt = x.reshape(n_grp, tg, d)
    xt = constrain(xt, "btd")                 # groups → data axis

    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (G, Tg, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)              # renormalize

    capacity = max(int(cfg.capacity_factor * k * tg / e), 4)

    # position-in-expert within each group; slot-0 first (GShard priority)
    pos_list, keep_list = [], []
    counts = jnp.zeros((n_grp, e), jnp.int32)
    for slot in range(k):
        oh = jax.nn.one_hot(expert_idx[..., slot], e, dtype=jnp.int32)  # (G,Tg,E)
        pos_in = jnp.cumsum(oh, axis=1) - oh
        pos = jnp.take_along_axis(
            pos_in, expert_idx[..., slot:slot + 1], axis=2)[..., 0]
        pos = pos + jnp.take_along_axis(counts, expert_idx[..., slot], axis=1)
        keep = pos < capacity
        pos_list.append(jnp.where(keep, pos, capacity))  # capacity == dropped
        keep_list.append(keep)
        counts = counts + jnp.sum(oh, axis=1)

    # group-local scatter (mode='drop' eats overflow).  vmap over groups so
    # the group dim is a scatter *batching* dim — GSPMD then proves the
    # scatter local to each data shard (explicit index arrays defeat it and
    # cost a full-buffer all-reduce; §Perf iteration 2).
    buf = jnp.zeros((n_grp, e, capacity + 1, d), x.dtype)

    def _scatter_group(b, ei, pi, xg):
        return b.at[ei, pi].add(xg)

    for slot in range(k):
        buf = jax.vmap(_scatter_group)(buf, expert_idx[..., slot],
                                       pos_list[slot], xt)
    buf = buf[:, :, :capacity]
    buf = constrain(buf, "gecd")   # groups → data; experts → model if divisible

    # batched expert SwiGLU (weights pre-cast: collectives move bf16)
    wg = p["gate"].astype(x.dtype)
    wu = p["up"].astype(x.dtype)
    wd = p["down"].astype(x.dtype)
    g = jnp.einsum("gecd,edf->gecf", buf, wg)
    u = jnp.einsum("gecd,edf->gecf", buf, wu)
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("gecf,efd->gecd", h, wd)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((n_grp, e, 1, d), x.dtype)], axis=2)

    # group-local gather + weighted combine (vmap: batching dims again)
    def _gather_group(ob, ei, pi):
        return ob[ei, pi]

    out = jnp.zeros((n_grp, tg, d), x.dtype)
    for slot in range(k):
        piece = jax.vmap(_gather_group)(out_buf, expert_idx[..., slot],
                                        pos_list[slot])
        w = (gate_vals[..., slot] * keep_list[slot]).astype(x.dtype)
        out = out + piece * w[..., None]
    out = out.reshape(b, s, d)

    if not return_aux:
        return out
    # Switch-style load-balancing loss: E · Σ_e fraction_e · router_prob_e
    frac = jnp.zeros((e,), jnp.float32)
    for slot in range(k):
        frac = frac + jnp.mean(
            jax.nn.one_hot(expert_idx[..., slot], e), axis=(0, 1))
    frac = frac / k
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * mean_prob)
    return out, aux
