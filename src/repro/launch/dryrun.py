import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on the
production meshes and record memory/cost/collective evidence.

MUST be executed as a module entry point (``python -m repro.launch.dryrun``)
— the XLA_FLAGS assignment above runs before any jax import, giving this
process 512 virtual host devices.  Never import this module from tests.

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod both --out results/dryrun
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str = None,
             save_hlo: bool = False) -> dict:
    import jax

    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell, lower_cell
    from repro.roofline.analysis import analyze_lowered

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    # rolled scans: fast compiles; the roofline walker multiplies while-body
    # costs by parsed trip counts (validated against unrolled compiles)
    cell = build_cell(arch, shape, mesh, unroll_for_cost=False)
    lowered = lower_cell(cell)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    n_chips = mesh.size
    record = {
        "arch": arch, "shape": shape,
        "mesh": dict(mesh.shape),
        "chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "flops": cost.get("flops") if cost else None,
        "bytes_accessed": cost.get("bytes accessed") if cost else None,
    }
    record.update(analyze_lowered(lowered, compiled, arch=arch, shape=shape,
                                  n_chips=n_chips))
    print(json.dumps(record))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape}__{'multipod' if multi_pod else 'pod'}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
                f.write(compiled.as_text())
    return record


def run_verify_cell(layout: str, multi_pod: bool, out_dir: str = None,
                    save_hlo: bool = False) -> dict:
    import jax

    from repro.launch.mesh import make_production_mesh
    from repro.launch.verify_cell import build_verify_cell
    from repro.roofline.analysis import analyze_lowered

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    fn, args, program = build_verify_cell(mesh, layout=layout)
    lowered = jax.jit(fn, donate_argnums=(0,)).lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    record = {
        "arch": f"ola-verify-{layout}", "shape": "verify_round",
        "mesh": dict(mesh.shape), "chips": mesh.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    record.update(analyze_lowered(lowered, compiled, arch="smollm-135m",
                                  shape="train_4k", n_chips=mesh.size))
    # model_flops is an LM concept; null it out for the engine cell
    record["roofline"]["model_flops"] = None
    record["roofline"]["useful_flops_ratio"] = None
    record["roofline"]["roofline_fraction"] = None
    print(json.dumps(record))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"ola-verify-{layout}__{'multipod' if multi_pod else 'pod'}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
                f.write(compiled.as_text())
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--verify-cell", choices=("replicated", "sharded"),
                    default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("no", "yes", "both"), default="no")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    from repro.configs.registry import cells

    if args.verify_cell:
        for mp in {"no": [False], "yes": [True],
                   "both": [False, True]}[args.multi_pod]:
            run_verify_cell(args.verify_cell, mp, args.out, args.save_hlo)
        return

    todo = []
    if args.all:
        todo = [(a, s) for a, s, skipped in cells() if not skipped]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]
    failures = []
    for arch, shape in todo:
        for mp in pods:
            try:
                run_cell(arch, shape, mp, args.out, args.save_hlo)
            except Exception as e:  # noqa: BLE001 — report, continue sweep
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    if failures:
        print("FAILURES:", json.dumps(failures, indent=1))
        raise SystemExit(1)
    print("DRYRUN OK:", len(todo) * len(pods), "cells")


if __name__ == "__main__":
    main()
