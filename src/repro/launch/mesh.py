"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import;
tests run with 1 visible device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: ``data`` carries batch + FSDP weight sharding; ``model`` carries
    tensor/expert parallelism; ``pod`` (multi-pod only) is outer data
    parallelism with hierarchical gradient reduction over DCI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
