"""Cell builders: (architecture × shape × mesh) -> jit-able step + abstract
inputs with shardings.

This is the single place that knows how every family's train / prefill /
decode step is shaped and sharded; the dry-run, the roofline harness and the
real drivers all call :func:`build_cell`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.registry import SHAPES, ShapeSpec, get_config
from repro.distributed.autoshard import sharding_scope
from repro.distributed.sharding import (
    ShardingRules,
    activation_sharding,
    param_shardings,
    rules_for,
)
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainState, init_train_state, make_train_step


class Cell(NamedTuple):
    arch: str
    shape: str
    fn: callable              # step function to jit
    args: tuple               # abstract args (ShapeDtypeStruct w/ shardings)
    model: object
    cfg: ModelConfig
    donate: tuple = ()
    mesh: object = None
    batch_axes: tuple = ("pod", "data")


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _abstract_params(model, mesh: Mesh, rules: ShardingRules):
    """(params ShapeDtypeStructs with shardings, specs) without allocating."""
    captured = {}

    def initfn(k):
        p, s = model.init(k)
        captured["specs"] = s
        return p

    params_shape = jax.eval_shape(initfn, jax.random.PRNGKey(0))
    shardings = param_shardings(params_shape, captured["specs"], rules, mesh)
    params_abs = jax.tree.map(
        lambda t, s: _sds(t.shape, t.dtype, s), params_shape, shardings)
    return params_abs, captured["specs"], shardings


def _batch_sharding(mesh, rules, batch):
    return activation_sharding(mesh, rules, batch)


def _token_specs(cfg: ModelConfig, spec: ShapeSpec, mesh, rules):
    """Abstract train/prefill batch for each family."""
    b, s = spec.global_batch, spec.seq_len
    bs = _batch_sharding(mesh, rules, b)
    toks = _sds((b, s), jnp.int32, bs)
    if cfg.family == "encdec":
        return {"enc_embeds": _sds((b, s, cfg.d_model), jnp.bfloat16, bs),
                "tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        s_vis = s // 4
        s_txt = s - s_vis
        return {"vis_embeds": _sds((b, s_vis, cfg.d_model), jnp.bfloat16, bs),
                "tokens": _sds((b, s_txt), jnp.int32, bs),
                "labels": _sds((b, s_txt), jnp.int32, bs),
                "positions3": _sds((3, b, s), jnp.int32,
                                   NamedSharding(mesh, P(None))),
                }
    return {"tokens": toks, "labels": toks}


# ---------------------------------------------------------------------------
# Serve-cache shardings (family-specific leaf layouts)
# ---------------------------------------------------------------------------

def _kv_cache_shardings(cache_abs, mesh, batch):
    """Stacked attention cache {(L,B,T,H,D) k/v, (L,B,T) pos}."""
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)
    b_ax = "data" if (batch % dsize == 0 and batch > 1) else None

    def one(t):
        if t.ndim == 5:
            l, b, tt, h, d = t.shape
            if h % msize == 0 and h >= msize:
                return NamedSharding(mesh, P(None, b_ax, None, "model"))
            if tt % msize == 0:
                return NamedSharding(mesh, P(None, b_ax, "model"))
            return NamedSharding(mesh, P(None, b_ax))
        if t.ndim == 3:   # pos
            l, b, tt = t.shape
            if tt % msize == 0:
                return NamedSharding(mesh, P(None, b_ax, "model"))
            return NamedSharding(mesh, P(None, b_ax))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, cache_abs)


def _mamba_cache_shardings(cache_abs, mesh, batch):
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)
    b_ax = "data" if (batch % dsize == 0 and batch > 1) else None

    def one(t):
        if t.ndim == 5:  # ssm (L,B,H,P,N)
            h = t.shape[2]
            h_ax = "model" if h % msize == 0 else None
            return NamedSharding(mesh, P(None, b_ax, h_ax))
        if t.ndim == 4:  # conv (L,B,K,C)
            c = t.shape[3]
            c_ax = "model" if c % msize == 0 else None
            return NamedSharding(mesh, P(None, b_ax, None, c_ax))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, cache_abs)


def _replicated_batch_shardings(cache_abs, mesh, batch):
    """xLSTM caches: leaves (B, ...) — batch over data when divisible."""
    dsize = mesh.shape.get("data", 1)
    b_ax = "data" if (batch % dsize == 0 and batch > 1) else None

    def one(t):
        return NamedSharding(mesh, P(b_ax))

    return jax.tree.map(one, cache_abs)


def _cache_shardings(model, cfg, cache_abs, mesh, batch):
    if cfg.family == "xlstm":
        return _replicated_batch_shardings(cache_abs, mesh, batch)
    if cfg.family == "hybrid":
        return {"mamba": _mamba_cache_shardings(cache_abs["mamba"], mesh, batch),
                "shared": _kv_cache_shardings(cache_abs["shared"], mesh, batch)}
    if cfg.family == "encdec":
        return {"self": _kv_cache_shardings(cache_abs["self"], mesh, batch),
                "cross_k": None, "cross_v": None}
    return _kv_cache_shardings(cache_abs, mesh, batch)


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh: Mesh,
               opt_cfg: AdamWConfig = AdamWConfig(),
               unroll_for_cost: bool = True,
               overrides: dict | None = None) -> Cell:
    spec = SHAPES[shape_name]
    tp = mesh.shape.get("model", 1)
    cfg = get_config(arch, tp=tp)
    if shape_name != "long_500k" and cfg.family == "hybrid":
        # long_window is a long-context-serve-only adaptation
        cfg = dataclasses.replace(cfg, long_window=None)
    if unroll_for_cost:
        # rolled scans hide (trip_count-1)/trip_count of the FLOPs from
        # XLA cost analysis — unroll for honest roofline accounting
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    rules = rules_for(cfg.family)
    params_abs, specs, p_shardings = _abstract_params(model, mesh, rules)

    if spec.kind == "train":
        batch_abs = _token_specs(cfg, spec, mesh, rules)
        loss_fn = (lambda p, b: model.loss(p, b))
        step = make_train_step(loss_fn, opt_cfg)
        from repro.train.optimizer import OptState

        rep = NamedSharding(mesh, P())
        state_shape = jax.eval_shape(init_train_state, params_abs)
        # Adam moments inherit the param shardings (FSDP scales optimizer
        # memory with the full chip count); scalars replicated.
        state_shard = TrainState(
            params=p_shardings,
            opt=OptState(mu=p_shardings, nu=p_shardings, step=rep),
            step=rep, compress_error=None)
        state_abs = jax.tree.map(lambda t, s: _sds(t.shape, t.dtype, s),
                                 state_shape, state_shard)
        return Cell(arch, shape_name, step, (state_abs, batch_abs), model,
                    cfg, donate=(0,), mesh=mesh, batch_axes=rules.batch_axes)

    if spec.kind == "prefill":
        batch_abs = _token_specs(cfg, spec, mesh, rules)

        if cfg.family == "encdec":
            def prefill(params, batch):
                enc_out = model.encode(params, batch["enc_embeds"])
                logits = model.decode_full(params, batch["tokens"], enc_out)
                return logits[:, -1:]
        elif cfg.family == "vlm":
            def prefill(params, batch):
                logits, _ = model.forward(params, batch)
                return logits[:, -1:]
        else:
            def prefill(params, batch):
                return model.prefill(params, batch["tokens"])

        batch_abs.pop("labels", None)
        return Cell(arch, shape_name, prefill, (params_abs, batch_abs),
                    model, cfg, mesh=mesh, batch_axes=rules.batch_axes)

    # ---- decode ----
    b = spec.global_batch
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(b, spec.seq_len))
    c_shardings = _cache_shardings(model, cfg, cache_abs, mesh, b)
    cache_in = jax.tree.map(lambda t, s: _sds(t.shape, t.dtype, s),
                            cache_abs, c_shardings)
    bs = _batch_sharding(mesh, rules, b) if b > 1 else NamedSharding(mesh, P())
    tok = _sds((b, 1), jnp.int32, bs)
    pos = _sds((b,), jnp.int32, bs)

    if cfg.family == "encdec":
        hp = model.self_cfg.kv_heads_padded
        hd = model.self_cfg.head_dim
        ckv_shape = (cfg.num_layers, b, spec.seq_len, hp, hd)
        ckv_shard = _kv_cache_shardings(
            {"k": jax.ShapeDtypeStruct(ckv_shape, jnp.bfloat16)}, mesh, b)["k"]
        ckv = (_sds(ckv_shape, jnp.bfloat16, ckv_shard),
               _sds(ckv_shape, jnp.bfloat16, ckv_shard))

        def decode(params, cache, tokens, pos, cross_kv):
            return model.decode_step(params, cache, tokens, pos, cross_kv)

        return Cell(arch, shape_name, decode,
                    (params_abs, cache_in, tok, pos, ckv), model, cfg,
                    donate=(1,), mesh=mesh, batch_axes=rules.batch_axes)

    def decode(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return Cell(arch, shape_name, decode, (params_abs, cache_in, tok, pos),
                model, cfg, donate=(1,), mesh=mesh, batch_axes=rules.batch_axes)


def lower_cell(cell: Cell):
    fn = jax.jit(cell.fn, donate_argnums=cell.donate)
    if cell.mesh is not None:
        # activation constraints (autoshard) bind to the mesh at trace time
        with sharding_scope(cell.mesh, batch_axes=cell.batch_axes):
            return fn.lower(*cell.args)
    return fn.lower(*cell.args)
