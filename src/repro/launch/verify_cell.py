"""The OLA-verify dry-run cell: the paper's engine round at production scale.

This is the hillclimb cell "most representative of the paper's technique":
one SPMD engine round (claim → extract → merge → decide → estimate) lowered
on the production mesh for a production-sized raw metadata table
(4096 chunks × 65536 tuples × 6 ASCII columns ≈ 25.8 GB raw).

Two store layouts are measured:

* ``replicated``  — the paper's shared-memory model verbatim: every device
  sees the whole raw buffer (baseline; the dry-run's memory analysis shows
  this cannot scale — ~26 GB of raw bytes per chip, over v5e HBM).
* ``sharded``     — chunks sharded over the data axis with per-shard queues:
  each shard owns a contiguous chunk range and processes it in its own
  committed random order.  Chunk inclusion is still decided before execution
  (content-independent), so the no-inspection-paradox argument survives; the
  single global prefix becomes a union of per-shard prefixes (stratified
  SRSWOR over the committed orders — Eq. (1)/(3) apply unchanged).  Raw
  bytes per chip drop by the data-axis factor (16x), and the claim step's
  all-gather disappears (claims are shard-local).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import estimators as est
from repro.core.engine import EngineConfig, EngineProgram, _Collectives
from repro.core.engine_spmd import engine_state_specs, report_specs
from repro.core.queries import Column, Having, Query, Range, TRUE
from repro.data.formats import AsciiFixedFormat
from repro.sampling.permutation import permutation_window_dyn, random_chunk_order

# version-shimmed (check_rep -> check_vma rename handled there)
from repro.core.engine_spmd import shard_map


def production_verify_program(n_chunks: int = 4096, m_per_chunk: int = 65536,
                              num_cols: int = 6, workers: int = 256,
                              budget: int = 256):
    codec = AsciiFixedFormat(num_cols)
    queries = [
        Query(agg="avg", expr=Column(1), pred=TRUE, having=Having(">", 75.0),
              epsilon=0.05, name="avg_quality"),
        Query(agg="avg", expr=Column(3), pred=TRUE, having=Having("<", 10.0),
              epsilon=0.05, name="avg_dup"),
        Query(agg="count", pred=Range(0, 0.0, 16.0), having=Having("<", 1e6),
              epsilon=0.05, name="short_docs"),
    ]
    cfg = EngineConfig(num_workers=workers, strategy="resource_aware",
                       budget_init=budget, seed=0)
    sizes = np.full(n_chunks, m_per_chunk, np.int64)
    program = EngineProgram(codec=codec, queries=queries, config=cfg,
                            n_chunks=n_chunks, m_max=m_per_chunk,
                            chunk_sizes=sizes)
    return program, cfg, codec


def _sharded_round(program: EngineProgram, n_dev: int, budget: int):
    """Per-shard-queue engine round (one worker per device, local chunks).

    The device's current/next chunk is *derived* from the replicated state
    (open chunk in my range, else my local schedule at my closed-count), so
    no new engine state is needed and checkpointing is unchanged.
    """
    n = program.n_chunks
    nl = n // n_dev
    # committed per-shard schedules: row d permutes shard d's chunk range
    rng_rows = [random_chunk_order(program.config.seed + 17 * d, nl) + d * nl
                for d in range(n_dev)]
    sched2d = jnp.asarray(np.stack(rng_rows), jnp.int32)      # (D, nl)
    z = float(jax.scipy.special.ndtri((1.0 + program.conf) / 2.0))

    def round_step(state, packed_local, speeds_local):
        dtype = state.stats.ysum.dtype
        cfg = program.config
        d = jax.lax.axis_index("data")
        sizes = state.stats.M
        mine = (jnp.arange(n, dtype=jnp.int32) // nl) == d

        open_mine = (state.stats.m > 0) & ~state.closed & mine
        has_open = jnp.any(open_mine)
        local_head = jnp.sum((state.closed & mine).astype(jnp.int32))
        nxt = sched2d[d, jnp.clip(local_head, 0, nl - 1)]
        j = jnp.where(has_open, jnp.argmax(open_mine), nxt)
        active = has_open | (local_head < nl)

        mj = sizes[j]
        off = state.offset[j]
        m_before = state.stats.m[j]
        b_eff = jnp.minimum(jnp.floor(budget * speeds_local[0]).astype(jnp.int32),
                            jnp.maximum(mj - m_before, 0))
        b_eff = jnp.where(active, b_eff, 0)

        idx = permutation_window_dyn(program.seeds[j], off, budget, mj,
                                     program.m_max)
        raw = packed_local[j - d * nl][idx]                     # local slab
        cols = program.codec.decode_ref(raw)
        x, pr = program.evaluate(cols)                          # (Q, B)
        valid = (jnp.arange(budget) < b_eff).astype(dtype)
        x = x.astype(dtype) * valid
        pr = pr.astype(dtype) * valid

        q = len(program.queries)
        af = active.astype(jnp.int32)
        deltas = jax.lax.psum(dict(
            dm=jnp.zeros((n,), jnp.int32).at[j].add(b_eff * af),
            dys=jnp.zeros((q, n), dtype).at[:, j].add(jnp.sum(x, -1) * af),
            dyq=jnp.zeros((q, n), dtype).at[:, j].add(jnp.sum(x * x, -1) * af),
            dps=jnp.zeros((q, n), dtype).at[:, j].add(jnp.sum(pr, -1) * af),
            doff=jnp.zeros((n,), jnp.int32).at[j].add(b_eff * af),
        ), "data")
        stats = state.stats._replace(
            m=state.stats.m + deltas["dm"], ysum=state.stats.ysum + deltas["dys"],
            ysq=state.stats.ysq + deltas["dyq"], psum=state.stats.psum + deltas["dps"])
        offset = state.offset + deltas["doff"]

        # local accuracy (Theorem 3) on my chunk; close + io accounting
        mj_new = stats.m[j].astype(dtype)
        big_m = sizes[j].astype(dtype)
        scale = big_m / jnp.maximum(mj_new, 1.0)
        ys_j = stats.ysum[:, j]
        ss = stats.ysq[:, j] - ys_j * ys_j / jnp.maximum(mj_new, 1.0)
        fpc = (big_m - mj_new) / jnp.maximum(mj_new - 1.0, 1.0)
        v_local = scale * fpc * jnp.maximum(ss, 0.0)
        yhat = scale * ys_j
        local_ok = jnp.all(2.0 * z * jnp.sqrt(jnp.maximum(v_local, 0.0))
                           <= program.eps.astype(dtype)
                           * jnp.maximum(jnp.abs(yhat), 1e-12))
        local_ok &= mj_new >= 2.0
        exhausted = stats.m[j] >= sizes[j]
        close = active & (exhausted | (local_ok & state.cpu_bound))
        closed = state.closed | (jax.lax.psum(
            jnp.zeros((n,), jnp.int32).at[j].add(close.astype(jnp.int32)),
            "data") > 0)
        newly_raw = active & (b_eff > 0) & ~state.raw_touched[j]
        raw_touched = state.raw_touched | (jax.lax.psum(
            jnp.zeros((n,), jnp.int32).at[j].add(newly_raw.astype(jnp.int32)),
            "data") > 0)
        bytes_round = jax.lax.psum(
            jnp.where(newly_raw, program.chunk_bytes[j], 0.0), "data")
        tuples = jax.lax.psum(b_eff, "data")
        round_cpu = (tuples.astype(jnp.float32) * program.cost_per_tuple
                     / cfg.cpu_tuple_ops_per_sec / cfg.num_workers)
        round_io = bytes_round.astype(jnp.float32) / cfg.io_bytes_per_sec

        # global estimate over the union of per-shard prefixes
        mask = stats.m > 0
        stats_est = stats._replace(
            m=jnp.where(mask, stats.m, 0),
            ysum=jnp.where(mask[None], stats.ysum, 0),
            ysq=jnp.where(mask[None], stats.ysq, 0),
            psum=jnp.where(mask[None], stats.psum, 0))
        avg_t, avg_v, _ = est.avg_estimate(stats_est)
        cnt_t = est.count_tau_hat(stats_est)
        cnt_v, _ = est.count_var_hat(stats_est)
        estimate = jnp.stack([avg_t[0], avg_t[1], cnt_t[2]])
        variance = jnp.stack([avg_v[0], avg_v[1], cnt_v[2]])
        lo, hi = est.confidence_bounds(estimate, variance, program.conf)
        err = est.error_ratio(estimate, lo, hi)
        decided = jnp.stack([
            est.having_decision(lo[0], hi[0], ">", 75.0),
            est.having_decision(lo[1], hi[1], "<", 10.0),
            est.having_decision(lo[2], hi[2], "<", 1e6)])
        stopped = state.stopped | (err <= program.eps.astype(dtype)) | (
            decided != -1)

        from repro.core.engine import EngineState, RoundReport

        new_state = EngineState(
            stats=stats, scan_m=state.scan_m + deltas["dm"],
            offset=offset, closed=closed, acc_met=state.acc_met,
            head=state.head + 1, cur=state.cur, budget=state.budget,
            decay=state.decay, calib_sum=state.calib_sum,
            calib_cnt=state.calib_cnt, first_est=jnp.asarray(True),
            stopped=stopped, round=state.round + 1,
            t_io=state.t_io + round_io, t_cpu=state.t_cpu + round_cpu,
            cpu_bound=round_cpu > round_io, cached_m=state.cached_m,
            raw_touched=raw_touched, cache=state.cache,
            schedule=state.schedule, quarantined=state.quarantined,
            gm=state.gm, gys=state.gys, gyq=state.gyq, gps=state.gps)
        # grouped plane is zero-width here (cfg.max_groups == 0)
        gz = jnp.zeros((q, program.group_cells), dtype)
        report = RoundReport(
            estimate=estimate, lo=lo, hi=hi, err=err, decided=decided,
            n_chunks=stats_est.n, m_tuples=jnp.sum(stats_est.m),
            round_io_s=round_io, round_cpu_s=round_cpu, tuples_round=tuples,
            bytes_round=bytes_round, all_stopped=jnp.all(stopped),
            exhausted=jnp.all(closed),
            g_est=gz, g_lo=gz, g_hi=gz, g_err=gz,
            g_n=jnp.zeros((q, program.group_cells), jnp.int32),
            g_tal=jnp.zeros((q, 3, program.tally_buckets), dtype))
        return new_state, report

    return round_step


def build_verify_cell(mesh: Mesh, layout: str = "replicated",
                      budget: int = 256):
    """-> (fn_shardmapped, abstract_args, program)."""
    n_dev = mesh.shape["data"]
    program, cfg, codec = production_verify_program(budget=budget,
                                                    workers=n_dev)
    wpd = 1
    specs = engine_state_specs()
    n, m, rb = program.n_chunks, program.m_max, codec.record_bytes

    if layout == "replicated":
        packed_spec = P()
        coll = _Collectives(axis_name="data", workers_per_device=wpd)

        def step(state, packed, speeds):
            return program.round_body(state, packed, speeds, budget, coll)
    else:
        packed_spec = P("data")
        step = _sharded_round(program, n_dev, budget)

    sm = shard_map(step, mesh=mesh,
                   in_specs=(specs, packed_spec, P("data")),
                   out_specs=(specs, report_specs()),
                   check_vma=False)

    state_abs = jax.eval_shape(program.init_state)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    state_in = jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
        state_abs, shardings)
    packed_in = jax.ShapeDtypeStruct((n, m, rb), jnp.uint8,
                                     sharding=NamedSharding(mesh, packed_spec))
    speeds_in = jax.ShapeDtypeStruct((cfg.num_workers,), jnp.float32,
                                     sharding=NamedSharding(mesh, P("data")))
    return sm, (state_in, packed_in, speeds_in), program
