"""Training driver.

    python -m repro.launch.train --arch smollm-135m --steps 200 \
        --batch 8 --seq 256 [--reduced] [--ckpt-dir ckpts/run0]

On TPU fleets this runs the full config against the production mesh; on CPU
use ``--reduced`` (family-preserving small config).  The loop is the
OLA-gated segment trainer (repro.train.trainer): every corpus segment passes
the paper's verification battery before consuming training FLOPs.
"""

from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--segments", type=int, default=6)
    ap.add_argument("--docs-per-segment", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a device failure at this step (FT demo)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data.corpus import SyntheticCorpus
    from repro.distributed.fault import FailureInjector
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch, reduced=args.reduced)
    tcfg = TrainerConfig(
        steps_per_segment=max(args.steps // args.segments, 1),
        batch=args.batch, seq_len=args.seq, ckpt_dir=args.ckpt_dir,
        max_steps=args.steps, seed=args.seed)
    injector = (FailureInjector(fail_at_steps=(args.fail_at,), kill_devices=0)
                if args.fail_at else None)
    corpus = SyntheticCorpus(vocab=cfg.vocab_size,
                             num_segments=args.segments,
                             docs_per_segment=args.docs_per_segment,
                             doc_len=max(args.seq // 2, 64), seed=args.seed)
    trainer = Trainer(cfg, tcfg, injector=injector)
    result = trainer.run(corpus)
    result.pop("state")
    print(json.dumps(result, indent=1))
    gates = [e for e in trainer.log if e["event"] == "gate"]
    print("gate decisions:", json.dumps(gates, indent=1))


if __name__ == "__main__":
    main()
