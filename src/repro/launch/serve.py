"""Serving driver.

    python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.family == "encdec":
        raise SystemExit("enc-dec serving requires audio features; use the "
                         "decode dry-run cells for whisper")
    eng = ServeEngine(cfg, batch_slots=args.slots, max_len=args.max_len,
                      seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        req = Request(rid=i,
                      prompt=rng.integers(0, cfg.vocab_size,
                                          args.prompt_len).astype(np.int32),
                      max_new=args.max_new)
        reqs.append(req)
        eng.submit(req)
    t0 = time.perf_counter()
    steps = eng.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(json.dumps({
        "requests": len(reqs), "decode_steps": steps,
        "new_tokens": total_new, "wall_s": round(dt, 2),
        "tok_per_s": round(total_new / max(dt, 1e-9), 1),
        "all_done": all(r.done for r in reqs),
        "sample_output": reqs[0].out_tokens[:8],
    }, indent=1))


if __name__ == "__main__":
    main()
