"""Confidence-bound coverage (paper Table 3, reduced Monte Carlo).

Bi-level bounds must cover the truth ≈ nominal; the deliberately-unordered
chunk-level variant (inspection-paradox-vulnerable) must under-cover when
chunk completion order correlates with content (uneven chunk sizes).
"""

import numpy as np
import pytest

from repro.core.engine import EngineConfig, OLAEngine
from repro.core.queries import Linear, Query
from repro.data.generator import make_synthetic_zipf, store_dataset

COEF = tuple(1.0 / (k + 1) for k in range(8))
RUNS = 24
FRACTION = 0.25


def _coverage(strategy, runs=RUNS):
    vals = make_synthetic_zipf(4096, 8, seed=11)
    # uneven chunks: size correlates with content mass -> completion order
    # correlates with the aggregate, arming the paradox for unordered C
    store = store_dataset(vals, 24, "ascii", uneven=True, seed=2)
    truth = float((vals @ np.asarray(COEF)).sum())
    hits = 0
    for r in range(runs):
        q = Query(agg="sum", expr=Linear(COEF), epsilon=1e-9)
        eng = OLAEngine(store, [q],
                        EngineConfig(num_workers=4, strategy=strategy,
                                     budget_init=64, seed=100 + r))
        state = eng.init_state()
        rep = None
        while True:
            b = eng.budget_ladder(float(state.budget))
            state, rep = eng.round_fn(b)(state, eng.packed, eng.speeds)
            if int(rep.n_chunks) >= FRACTION * store.num_chunks:
                break
            if bool(rep.exhausted):
                break
        lo, hi = float(rep.lo[0]), float(rep.hi[0])
        hits += int(lo <= truth <= hi)
    return hits / runs


@pytest.mark.slow
def test_bilevel_bounds_cover():
    cov = _coverage("resource_aware")
    assert cov >= 0.80, cov   # 95% nominal; small-sample MC tolerance


@pytest.mark.slow
def test_unordered_chunk_level_undercovers_or_matches():
    cov_bad = _coverage("chunk_level_unordered")
    cov_good = _coverage("resource_aware")
    # the paradox-vulnerable estimator must not beat the sound one
    assert cov_bad <= cov_good + 0.10, (cov_bad, cov_good)
