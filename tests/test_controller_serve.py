"""Estimation controller δ-reporting + verification chain; serve engine."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.controller import EstimationController
from repro.core.engine import EngineConfig
from repro.core.queries import Having, Linear, Query, TRUE
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.serve.engine import Request, ServeEngine

COEF = tuple(1.0 / (k + 1) for k in range(8))


@pytest.fixture(scope="module")
def store_and_truth():
    vals = make_synthetic_zipf(4096, 8, seed=3)
    store = store_dataset(vals, 32, "ascii")
    return store, float(vals @ np.asarray(COEF) @ np.ones(len(vals)))


def test_delta_reports_monotone_time(store_and_truth):
    store, truth = store_and_truth
    ctrl = EstimationController(store, EngineConfig(num_workers=2, seed=1),
                                delta_model_s=0.0005)
    res = ctrl.run_query([Query(agg="sum", expr=Linear(COEF), epsilon=0.03)],
                         max_rounds=4000)
    ts = [r.t_model for r in res.reports]
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    assert len(res.reports) >= 2
    errs = [float(r.err[0]) for r in res.reports]
    assert errs[-1] <= errs[0]   # accuracy improves over the run


def test_verification_chain_stops_at_failure(store_and_truth):
    store, truth = store_and_truth
    qs = [
        Query(agg="sum", expr=Linear(COEF), having=Having("<", truth * 2),
              epsilon=0.05, name="q_pass"),
        Query(agg="sum", expr=Linear(COEF), having=Having("<", truth * 0.5),
              epsilon=0.05, name="q_fail"),
        Query(agg="count", pred=TRUE, having=Having(">", 0.0),
              epsilon=0.05, name="q_never"),
    ]
    ctrl = EstimationController(store, EngineConfig(num_workers=2, seed=1))
    results = ctrl.run_verification(qs)
    assert len(results) == 2          # stopped after the failing query
    assert int(results[0].decisions[0]) == 1
    assert int(results[1].decisions[0]) == 0


@pytest.mark.parametrize("arch", ["smollm-135m", "zamba2-1.2b", "xlstm-125m"])
def test_serve_engine_families(arch):
    cfg = get_config(arch, reduced=True)
    eng = ServeEngine(cfg, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 4)
                    .astype(np.int32), max_new=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run(wall_timeout_s=300.0)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)
