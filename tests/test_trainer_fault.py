"""Trainer loop: OLA ingest gating, failure injection, elastic-mesh math."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.data.corpus import SyntheticCorpus, standard_ingest_queries
from repro.distributed.fault import (
    FailureInjector, best_mesh_shape, preserved_global_batch, rebalance_accum,
)
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def run_result(tmp_path_factory):
    cfg = get_config("smollm-135m", reduced=True)
    tcfg = TrainerConfig(steps_per_segment=4, batch=2, seq_len=64,
                         max_steps=20, ckpt_every=4,
                         ckpt_dir=str(tmp_path_factory.mktemp("ckpt")))
    corpus = SyntheticCorpus(vocab=cfg.vocab_size, num_segments=4,
                             docs_per_segment=64, doc_len=64,
                             poison_every=2, seed=0)
    injector = FailureInjector(fail_at_steps=(6,), kill_devices=0)
    trainer = Trainer(cfg, tcfg, injector=injector)
    result = trainer.run(corpus)
    return trainer, result, corpus


def test_gate_rejects_poisoned_segments(run_result):
    trainer, result, corpus = run_result
    gates = {e["segment"]: e for e in trainer.log if e["event"] == "gate"}
    for seg in corpus.segments:
        if seg.index in gates:
            assert gates[seg.index]["admitted"] == (not seg.poison), seg.index


def test_gate_samples_fraction(run_result):
    trainer, result, _ = run_result
    gates = [e for e in trainer.log if e["event"] == "gate"]
    # verification is sampled, not a full scan
    assert all(g["tuples_ratio"] <= 1.0 for g in gates)
    assert any(g["tuples_ratio"] < 1.0 for g in gates)


def test_training_progressed_and_recovered(run_result):
    trainer, result, _ = run_result
    assert result["steps"] > 0
    assert result["restarts"] == 1
    assert np.isfinite(result["last_loss"])
    fails = [e for e in trainer.log if e["event"] == "failure"]
    assert len(fails) == 1


def test_loss_improves_when_overfitting():
    cfg = get_config("smollm-135m", reduced=True)
    tcfg = TrainerConfig(steps_per_segment=30, batch=2, seq_len=64,
                         max_steps=30)
    # enough docs that the segment's sampled quality stats are stable and
    # the (statistically sound!) gate admits it
    corpus = SyntheticCorpus(vocab=cfg.vocab_size, num_segments=1,
                             docs_per_segment=128, doc_len=64,
                             poison_every=0, seed=1)
    trainer = Trainer(cfg, tcfg)
    result = trainer.run(corpus)
    assert result["last_loss"] < result["first_loss"]


def test_best_mesh_shape():
    assert best_mesh_shape(256, 16) == (16, 16)
    assert best_mesh_shape(240, 16) == (15, 16)
    assert best_mesh_shape(512, 16, pod_axis=2) == (2, 16, 16)
    assert best_mesh_shape(384, 16, pod_axis=2) == (2, 12, 16)
    assert best_mesh_shape(17, 16) == (1, 16)
    with pytest.raises(RuntimeError):
        best_mesh_shape(8, 16)


def test_preserved_global_batch():
    b, acc = preserved_global_batch(256, old_data=16, new_data=12)
    assert b % 12 == 0 and acc >= 2
    b2, acc2 = preserved_global_batch(256, 16, 16)
    assert (b2, acc2) == (256, 1)


def test_rebalance_accum():
    times = np.asarray([1.0, 1.0, 2.0, 1.0])
    out = rebalance_accum(times, base_accum=4)
    assert out[2] < out[0]          # straggler gets fewer microbatches
    assert out.min() >= 1
