"""Model zoo: per-arch smoke + decode-vs-forward consistency oracles."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.models.attention import padded_heads, real_head_mask, AttnConfig
from repro.models.vlm import build_positions3

B, S = 2, 32


def _batch(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(99), (B, S), 0,
                                cfg.vocab_size)
    if cfg.family == "encdec":
        return {"enc_embeds": jax.random.normal(rng, (B, S, cfg.d_model)),
                "tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        sv = S // 4
        return {"vis_embeds": jax.random.normal(rng, (B, sv, cfg.d_model)),
                "tokens": tokens[:, : S - sv], "labels": labels[:, : S - sv],
                "positions3": jnp.asarray(build_positions3(B, sv, S - sv))}
    return {"tokens": tokens, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    """Reduced config: one loss eval + one decode step, finite outputs."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, jax.random.PRNGKey(0))
    loss = float(jax.jit(model.loss)(params, batch))
    assert np.isfinite(loss), (arch, loss)
    cache = model.init_cache(B, 64)
    tok = batch["tokens"][:, :1]
    pos = jnp.zeros((B,), jnp.int32)
    if cfg.family == "encdec":
        enc_out = model.encode(params, batch["enc_embeds"])
        ckv = model.precompute_cross(params, enc_out)
        logits, _ = model.decode_step(params, cache, tok, pos, ckv)
    else:
        logits, _ = model.decode_step(params, cache, tok, pos)
    assert logits.shape[:2] == (B, 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


def _teacher_forced_decode(model, params, tokens, cfg, max_len=64):
    cache = model.init_cache(tokens.shape[0], max_len, dtype=jnp.float32)
    outs = []
    for t in range(tokens.shape[1]):
        pos = jnp.full((tokens.shape[0],), t, jnp.int32)
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1], pos)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-0.6b",
                                  "mixtral-8x7b", "zamba2-1.2b",
                                  "xlstm-125m"])
def test_decode_matches_forward(arch):
    """Cached decode must reproduce the full-sequence forward logits —
    validates KV caches, ring buffers, SSM states and matrix memories."""
    cfg = get_config(arch, reduced=True)
    import dataclasses
    # capacity high enough that MoE never drops: token-drop patterns differ
    # between full-sequence and one-token dispatch and are not what this
    # oracle tests
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              capacity_factor=8.0)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 12), 0,
                                cfg.vocab_size)
    if cfg.family in ("dense", "moe"):
        full, _ = model.forward(params, tokens)
    else:
        full = model.forward(params, tokens)
    step = _teacher_forced_decode(model, params, tokens, cfg)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_buffer_masks_old_tokens():
    """With window w, decode at position p must ignore tokens < p-w+1."""
    import dataclasses
    cfg = get_config("mixtral-8x7b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", window=4,
                              capacity_factor=8.0)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    t = 10
    tokens = jax.random.randint(jax.random.PRNGKey(0), (1, t), 0,
                                cfg.vocab_size)
    # full forward with banded mask == teacher-forced windowed decode
    full, _ = model.forward(params, tokens)
    step = _teacher_forced_decode(model, params, tokens, cfg, max_len=64)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_padded_heads_math():
    hp, hk = padded_heads(9, 3, 16)
    assert hp == 16 and hp % hk == 0 and hk >= 3
    hp2, hk2 = padded_heads(40, 8, 16)
    assert hp2 == 48 and hk2 == 8
    cfg = AttnConfig(d_model=64, num_heads=9, num_kv_heads=3, head_dim=8,
                     heads_padded=hp, kv_heads_padded=hk)
    mask = np.asarray(real_head_mask(cfg))
    assert mask.sum() == 9  # exactly the real architecture heads survive


def test_whisper_decode_consistency():
    import dataclasses
    cfg = get_config("whisper-large-v3", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    enc_embeds = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(0), (1, 6), 0,
                                cfg.vocab_size)
    enc_out = model.encode(params, enc_embeds)
    full = model.decode_full(params, tokens, enc_out)
    ckv = model.precompute_cross(params, enc_out)
    cache = model.init_cache(1, 32, dtype=jnp.float32)
    outs = []
    for t in range(tokens.shape[1]):
        pos = jnp.full((1,), t, jnp.int32)
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                          pos, ckv)
        outs.append(logits[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_matches_stepwise():
    """Mamba2 chunked SSD == naive recurrence."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 16, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 1.5, h), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y, hl = ssd_chunked(x, dt, a, bb, cc, chunk=4)
    # naive recurrence
    hstate = np.zeros((b, h, p, n))
    ys = []
    xn, dtn, bn, cn = map(np.asarray, (x, dt, bb, cc))
    an = np.asarray(a)
    for t in range(s):
        decay = np.exp(dtn[:, t] * an[None])           # (b,h)
        hstate = hstate * decay[..., None, None] + np.einsum(
            "bhp,bh,bn->bhpn", xn[:, t] * dtn[:, t][..., None], np.ones_like(decay), bn[:, t])
        ys.append(np.einsum("bhpn,bn->bhp", hstate, cn[:, t]))
    ys = np.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
