import os
import sys

# tests must see exactly ONE device (the dry-run sets its own flags in a
# separate process); never inherit a sweep-process environment
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# Optional-dependency shim: `hypothesis` powers the property-based modules
# but is not required for the rest of the tier-1 suite.  Without it those
# modules are skipped at collection (each also carries a pytest.importorskip
# guard for direct invocation); with it, everything runs.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

collect_ignore = [] if HAS_HYPOTHESIS else [
    "test_estimators.py",
    "test_formats_data.py",
    "test_permutation.py",
]
