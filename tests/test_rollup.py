"""Tier-1 rollup answer cache (repro.serve.rollup + server wiring).

Pins the ISSUE 6 acceptance behavior: a repeated hot-pattern query is
answered from the rollup tier without consuming any scan round; a fully
covered cell's answer matches a fresh census scan; a partially covered
cell's answer is still a valid confidence interval; and cells die when
the store's content changes or the pattern goes cold.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.engine import EngineConfig, slot_stats_fold, slot_stats_snapshot
from repro.core.queries import Custom, Linear, Query, Range
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.sched import TIER1, SchedulerConfig, WorkloadScheduler
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions
from repro.serve.rollup import RollupConfig, RollupTier, pattern_key

COEF = tuple(1.0 / (k + 1) for k in range(8))


@pytest.fixture(scope="module")
def setup():
    vals = make_synthetic_zipf(4096, 8, seed=3)
    store = store_dataset(vals, 32, "ascii")
    return vals, store


def _hot(name: str, epsilon: float = 0.08, hi: float = 6e7) -> Query:
    """A fresh Query object per call — the cache must match on *pattern*,
    never on object identity."""
    return Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, hi),
                 epsilon=epsilon, name=name)


def _truth(vals, hi: float = 6e7) -> float:
    sel = (vals[:, 0] >= 0.0) & (vals[:, 0] < hi)
    return float((vals @ np.asarray(COEF)) @ sel)


# ---------------------------------------------------------------------------
# Pattern keys
# ---------------------------------------------------------------------------

def test_pattern_key_collapses_equivalent_queries():
    a = pattern_key(_hot("a", epsilon=0.08), 8)
    b = pattern_key(_hot("b", epsilon=0.01), 8)      # different ε and name
    c = pattern_key(dataclasses.replace(_hot("c"), confidence=0.99), 8)
    assert a is not None
    assert a == b == c                # accuracy knobs are not the pattern
    other = pattern_key(_hot("d", hi=7e7), 8)
    assert other != a                 # different predicate is
    count = pattern_key(Query(agg="count", pred=Range(0, 0.0, 6e7)), 8)
    assert count != a                 # different measure is
    weird = Query(agg="sum", expr=Custom(lambda c: c[..., 0] ** 2))
    assert pattern_key(weird, 8) is None   # non-linear: never cacheable


# ---------------------------------------------------------------------------
# Cell fold semantics
# ---------------------------------------------------------------------------

def test_fold_replaces_by_larger_sample_never_adds():
    cell_cfg = dict(key=("k",), query=_hot("q"), n_chunks=3, now=0.0,
                    content_version=0)
    from repro.serve.rollup import RollupCell

    cell = RollupCell(**cell_cfg)
    row1 = dict(m=np.array([4, 0, 2]), ysum=np.array([4.0, 0.0, 2.0]),
                ysq=np.array([8.0, 0.0, 3.0]), psum=np.array([4.0, 0.0, 1.0]))
    assert cell.fold(row1) == 2
    # re-folding the same row must be a no-op (replacement, not addition —
    # adding would double count the shared permutation-prefix windows)
    assert cell.fold(dict(row1)) == 0
    np.testing.assert_array_equal(cell.m, [4, 0, 2])
    # a row larger on chunk 1 only upgrades chunk 1
    row2 = dict(m=np.array([1, 5, 2]), ysum=np.array([9.0, 5.0, 9.0]),
                ysq=np.array([9.0, 7.0, 9.0]), psum=np.array([9.0, 5.0, 9.0]))
    assert cell.fold(row2) == 1
    np.testing.assert_array_equal(cell.m, [4, 5, 2])
    np.testing.assert_array_equal(cell.ysum, [4.0, 5.0, 2.0])
    np.testing.assert_array_equal(cell.covered(np.array([4, 5, 8])),
                                  [True, True, False])


# ---------------------------------------------------------------------------
# Miner / maintenance policy (no server needed)
# ---------------------------------------------------------------------------

def test_miner_promotes_after_threshold(setup):
    _, store = setup
    tier = RollupTier(store, RollupConfig(promote_hits=3))
    q = _hot("q")
    key = pattern_key(q, 8)
    assert tier.observe(q, key, now=0.0) is None
    assert tier.observe(q, key, now=0.1) is None
    cell = tier.observe(q, key, now=0.2)     # third completion promotes
    assert cell is not None and tier.get(key) is cell
    # already promoted: further completions refresh recency, not re-promote
    assert tier.observe(q, key, now=0.3) is None
    assert cell.last_hit_t == 0.3
    assert tier.promotions == 1


def test_lru_eviction_and_cold_demotion(setup):
    _, store = setup
    tier = RollupTier(store, RollupConfig(promote_hits=1, max_cells=1,
                                          cold_after_s=10.0))
    qa, qb = _hot("a", hi=5e7), _hot("b", hi=6e7)
    ka, kb = pattern_key(qa, 8), pattern_key(qb, 8)
    assert tier.observe(qa, ka, now=0.0) is not None
    assert tier.observe(qb, kb, now=1.0) is not None
    assert tier.get(ka) is None              # LRU-evicted by the second cell
    assert tier.get(kb) is not None
    assert tier.demotions == 1
    # demotion zeroed the miner count: stale log entries must not instantly
    # resurrect the cell... one fresh completion re-promotes (promote_hits=1)
    tier.maintain(now=12.0)                  # 11s > cold_after_s: b demoted
    assert tier.get(kb) is None
    assert tier.demotions == 2


def test_invalidation_on_content_version_change(setup):
    _, store = setup
    tier = RollupTier(store, RollupConfig(promote_hits=1))
    q = _hot("q")
    key = pattern_key(q, 8)
    cell = tier.observe(q, key, now=0.0)
    cell.fold(dict(m=np.ones(store.num_chunks, np.int64),
                   ysum=np.ones(store.num_chunks),
                   ysq=np.ones(store.num_chunks),
                   psum=np.ones(store.num_chunks)))
    store.mark_content_changed()
    tier.maintain(now=1.0)
    assert tier.get(key) is None             # stale aggregate dropped
    assert tier.invalidations == 1
    # the pattern is still hot in the miner: the next completion rebuilds
    assert tier.observe(q, key, now=2.0) is not None


# ---------------------------------------------------------------------------
# Engine fold-out hook
# ---------------------------------------------------------------------------

def test_slot_stats_fold_matches_snapshot(setup):
    _, store = setup
    cfg = EngineConfig(num_workers=2, seed=5)
    srv = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=3))
    srv.submit(_hot("a", epsilon=0.02, hi=5e7), arrival_t=0.0)
    srv.submit(_hot("b", epsilon=0.02, hi=7e7), arrival_t=0.0)
    for _ in range(3):
        srv.step()
    ids = [s for s in range(3) if srv.slot_wq[s] is not None]
    assert ids, "no resident slots to fold"
    batched = slot_stats_fold(srv.state, ids)
    assert set(batched) == set(ids)
    for s in ids:
        single = slot_stats_snapshot(srv.state, s)
        for k in ("m", "ysum", "ysq", "psum"):
            np.testing.assert_array_equal(np.asarray(batched[s][k]),
                                          np.asarray(single[k]))
    assert slot_stats_fold(srv.state, []) == {}
    srv.close()


# ---------------------------------------------------------------------------
# Acceptance: hot repeat answered Tier-1 with zero scan cost
# ---------------------------------------------------------------------------

def test_hot_repeat_answered_from_rollup_without_scan_rounds(setup):
    """ISSUE 6 acceptance: after the promotion threshold, a repeated
    hot-pattern query is answered from the rollup tier — no slot, no scan
    round, no extracted tuple."""
    vals, store = setup
    srv = OLAWorkloadServer(
              store, EngineConfig(num_workers=2, seed=5),
              options=ServerOptions(max_slots=4,
                  rollup=RollupConfig(promote_hits=2)))
    srv.submit(_hot("r0"), arrival_t=0.0)
    srv.submit(_hot("r1"), arrival_t=0.0)
    srv.run()
    assert len(srv.rollup.cells) == 1        # two completions promoted it
    tuples_before, rounds_before = srv.tuples_scanned, srv.rounds

    srv.submit(_hot("r2"))
    res = srv.run()
    r2 = next(r for r in res if r.name == "r2")
    assert r2.sched_outcome == "tier1"
    assert r2.plan == "rollup"
    assert r2.rounds_resident == 0
    assert srv.tuples_scanned == tuples_before   # not one extracted tuple
    assert srv.rounds == rounds_before           # not one engine round
    assert srv.rollup.tier1_hits == 1
    # the answer is a real estimate with a CI containing the truth
    truth = _truth(vals)
    assert r2.lo <= truth <= r2.hi
    assert r2.err <= 0.08
    srv.close()


def test_fully_covered_cell_matches_fresh_census(setup):
    """A cell whose every chunk is fully extracted answers *exactly*: the
    FPC zeroes all variance, and the estimate matches a fresh full scan of
    the same query bit for bit."""
    vals, store = setup
    q_census = lambda name: _hot(name, epsilon=1e-9)   # forces a census
    cfg = EngineConfig(num_workers=2, seed=5)

    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=4,
                  rollup=RollupConfig(promote_hits=2)))
    srv.submit(q_census("c0"), arrival_t=0.0)
    srv.submit(q_census("c1"), arrival_t=0.0)
    srv.run()
    (cell,) = srv.rollup.cells.values()
    assert cell.covered(store.chunk_sizes).all()

    srv.submit(q_census("c2"))
    res = srv.run()
    r2 = next(r for r in res if r.name == "c2")
    assert r2.sched_outcome == "tier1"
    assert r2.err == 0.0                      # FPC: census answer is exact
    assert r2.tuples_seen == store.num_tuples

    fresh = OLAWorkloadServer(
                store, cfg,
                options=ServerOptions(max_slots=4, synopsis_budget_tuples=0))
    fresh.submit(q_census("ref"), arrival_t=0.0)
    (ref,) = fresh.run()
    assert r2.estimate == ref.estimate        # bit-identical, not just close
    np.testing.assert_allclose(r2.estimate, _truth(vals), rtol=1e-5)
    srv.close()
    fresh.close()


def test_partially_covered_cell_answer_is_ci_valid(setup):
    """A cell built from an early-stopping scan covers only part of each
    chunk; its Tier-1 answer must still be a statistically valid interval
    (contains the ground truth) rather than pretending to be exact."""
    vals, store = setup
    srv = OLAWorkloadServer(
              store, EngineConfig(num_workers=2, seed=5),
              options=ServerOptions(max_slots=4,
                  rollup=RollupConfig(promote_hits=2)))
    srv.submit(_hot("p0", epsilon=0.10), arrival_t=0.0)
    srv.submit(_hot("p1", epsilon=0.10), arrival_t=0.0)
    srv.run()
    (cell,) = srv.rollup.cells.values()
    assert not cell.covered(store.chunk_sizes).all(), (
        "scan ran to census; the partial-coverage scenario is vacuous")

    srv.submit(_hot("p2", epsilon=0.10))
    res = srv.run()
    r2 = next(r for r in res if r.name == "p2")
    assert r2.sched_outcome == "tier1"
    assert r2.err > 0.0                        # honest uncertainty
    assert r2.lo < r2.hi
    assert r2.lo <= _truth(vals) <= r2.hi
    srv.close()


def test_repeat_with_tighter_target_routes_tier2_with_cell_seed(setup):
    """A repeat whose ε the cell cannot meet is *not* answered Tier-1 — it
    takes a slot, but seeded from the cell's partial aggregate (richer than
    the synopsis), so it scans only the remainder."""
    _, store = setup
    srv = OLAWorkloadServer(
              store, EngineConfig(num_workers=2, seed=5),
              options=ServerOptions(max_slots=4,
                  rollup=RollupConfig(promote_hits=2)))
    srv.submit(_hot("s0", epsilon=0.10), arrival_t=0.0)
    srv.submit(_hot("s1", epsilon=0.10), arrival_t=0.0)
    srv.run()
    (cell,) = srv.rollup.cells.values()
    cell_m = int(cell.m.sum())
    assert cell_m < store.num_tuples

    srv.submit(_hot("s2", epsilon=1e-9))       # cache can't meet a census ask
    res = srv.run()
    r2 = next(r for r in res if r.name == "s2")
    assert r2.sched_outcome != "tier1"
    assert r2.seeded_tuples >= cell_m          # started from the cell, not 0
    srv.close()


def test_content_change_forces_rescan(setup):
    """After the raw bytes change, a hot repeat must NOT be served from the
    (now stale) cell — the version-pinned cache drops it and the query goes
    back to the scan."""
    _, store = setup
    srv = OLAWorkloadServer(
              store, EngineConfig(num_workers=2, seed=5),
              options=ServerOptions(max_slots=4,
                  rollup=RollupConfig(promote_hits=2)))
    srv.submit(_hot("v0"), arrival_t=0.0)
    srv.submit(_hot("v1"), arrival_t=0.0)
    srv.run()
    assert len(srv.rollup.cells) == 1
    store.mark_content_changed()

    srv.submit(_hot("v2"))
    res = srv.run()
    r2 = next(r for r in res if r.name == "v2")
    assert r2.sched_outcome != "tier1"
    assert srv.rollup.invalidations == 1
    srv.close()


def test_scheduled_path_serves_tier1(setup):
    """With the SLO scheduler active, admission's TIER1 decision routes the
    repeat to the cache before the admit/queue/shed triage."""
    _, store = setup
    sched = WorkloadScheduler(SchedulerConfig(slot_capacity=2.0))
    srv = OLAWorkloadServer(
              store, EngineConfig(num_workers=2, seed=5),
              options=ServerOptions(max_slots=4, scheduler=sched,
                  rollup=RollupConfig(promote_hits=2)))
    srv.submit(_hot("t0"), arrival_t=0.0)
    srv.submit(_hot("t1"), arrival_t=0.0)
    srv.run()
    rounds_before = srv.rounds

    srv.submit(_hot("t2"))
    res = srv.run()
    r2 = next(r for r in res if r.name == "t2")
    assert r2.sched_outcome == TIER1 == "tier1"
    assert srv.rounds == rounds_before
    srv.close()
