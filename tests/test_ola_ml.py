"""Beyond-paper OLA integrations: eval early-stop, ingest gate, noise scale."""

import numpy as np
import pytest

from repro.core.engine import EngineConfig
from repro.data.corpus import SyntheticCorpus, standard_ingest_queries
from repro.ola_ml.eval_ola import ola_eval
from repro.ola_ml.gradnoise import estimate_noise_scale
from repro.ola_ml.verify import IngestGate


def test_ola_eval_early_stops_and_is_accurate():
    rng = np.random.default_rng(0)
    shards = [rng.normal(5.0, 1.0, size=rng.integers(300, 500))
              for _ in range(20)]
    all_vals = np.concatenate(shards)

    res = ola_eval(lambda x: x, shards, epsilon=0.02, seed=3)
    assert res.error_ratio <= 0.021
    truth = all_vals.mean()
    assert abs(res.estimate - truth) <= 0.05 * abs(truth)
    assert res.examples_used < res.total_examples  # early termination


def test_ola_eval_exhausts_on_tight_epsilon():
    rng = np.random.default_rng(1)
    shards = [rng.normal(0.0, 50.0, 100) for _ in range(4)]
    res = ola_eval(lambda x: x, shards, epsilon=1e-9, seed=0,
                   max_examples=10_000)
    assert res.examples_used == res.total_examples


def test_ingest_gate_separates_segments():
    corpus = SyntheticCorpus(vocab=128, num_segments=4, docs_per_segment=256,
                             doc_len=8, poison_every=2, seed=5)
    gate = IngestGate(standard_ingest_queries(0.05),
                      config=EngineConfig(num_workers=2,
                                          strategy="resource_aware",
                                          budget_init=32, seed=1))
    for seg in corpus.segments:
        d = gate.check(seg.meta_store)
        assert d.admitted == (not seg.poison), (seg.index, d.failed_query)


def test_noise_scale_estimation():
    rng = np.random.default_rng(2)
    true_g2 = 4.0   # |G|^2
    tr_sigma = 8.0  # per-example gradient variance trace

    def gnorm_fn(batch_size, seed):
        r = np.random.default_rng(seed)
        # E|g_b|^2 = |G|^2 + tr(Sigma)/b, with sampling noise
        return (true_g2 + tr_sigma / batch_size
                + r.normal(0, 0.05))

    res = estimate_noise_scale(gnorm_fn, b_small=4, b_big=64,
                               num_chunks=12, probes_per_chunk=4,
                               epsilon=0.5, seed=0)
    expect = tr_sigma / true_g2
    assert res is not None
    assert abs(res.b_simple - expect) < 0.8 * expect
