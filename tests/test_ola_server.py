"""Workload server: slot engine parity, mid-scan admission, early leave,
synopsis-seeded slots."""

import dataclasses

import numpy as np
import pytest

from repro.core.controller import EstimationController
from repro.core.engine import EngineConfig, OLAEngine, SlotOLAEngine
from repro.core.queries import (
    Having,
    Linear,
    Query,
    Range,
    empty_slot_table,
    encode_slot,
    slot_table_set,
)
from repro.core.synopsis import BiLevelSynopsis
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.serve.ola_server import (
    MeasuredRates,
    OLAWorkloadServer,
    ServerOptions,
    load_measured_rates,
    select_plan,
)

COEF = tuple(1.0 / (k + 1) for k in range(8))


@pytest.fixture(scope="module")
def setup():
    vals = make_synthetic_zipf(4096, 8, seed=3)
    store = store_dataset(vals, 32, "ascii")
    return vals, store


def _truth_sum(vals):
    return float((vals @ np.asarray(COEF)).sum())


# ---------------------------------------------------------------------------
# Slot engine ≡ frozen engine for an equivalent static workload
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["single_pass", "chunk_level",
                                      "holistic", "resource_aware"])
def test_slot_engine_matches_frozen_engine(setup, strategy):
    """A single query run through the dynamic slot table must reproduce the
    frozen-query engine round for round (same scan, same estimators), for
    every plan/strategy."""
    vals, store = setup
    q = Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 6e7),
              epsilon=0.04)
    cfg = EngineConfig(num_workers=2, strategy=strategy, seed=5)

    frozen = OLAEngine(store, [q], cfg)
    slot = SlotOLAEngine(store, max_slots=3, config=cfg)
    table = slot_table_set(empty_slot_table(3, 8),
                           0, encode_slot(q, 8, plan=strategy))

    fs = frozen.init_state()
    ss = slot.init_state()
    ss = ss._replace(stopped=ss.stopped.at[0].set(False))
    for _ in range(200):
        b = frozen.budget_ladder(float(fs.budget))
        assert b == slot.budget_ladder(float(ss.budget))
        fs, fr = frozen.round_fn(b)(fs, frozen.packed, frozen.speeds)
        ss, sr = slot.round_fn(b)(ss, table, slot.packed, slot.speeds)
        np.testing.assert_allclose(np.asarray(fr.estimate[0]),
                                   np.asarray(sr.estimate[0]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(fr.err[0]),
                                   np.asarray(sr.err[0]), rtol=1e-4, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(fs.scan_m),
                                      np.asarray(ss.scan_m))
        if bool(fr.all_stopped) or bool(fr.exhausted):
            assert bool(ss.stopped[0])
            break
    else:
        pytest.fail("frozen engine never stopped")


def test_per_slot_confidence_honored(setup):
    """Two slots running the same query at different confidence levels must
    report interval widths scaled by their own z — not an engine-wide one."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, strategy="single_pass", seed=5)
    eng = SlotOLAEngine(store, max_slots=2, config=cfg)
    q_lo = Query(agg="sum", expr=Linear(COEF), epsilon=1e-6, confidence=0.80)
    q_hi = Query(agg="sum", expr=Linear(COEF), epsilon=1e-6, confidence=0.99)
    table = empty_slot_table(2, 8)
    table = slot_table_set(table, 0, encode_slot(q_lo, 8))
    table = slot_table_set(table, 1, encode_slot(q_hi, 8))
    state = eng.init_state()
    state = state._replace(stopped=state.stopped & False)
    for _ in range(3):
        b = eng.budget_ladder(float(state.budget))
        state, rep = eng.round_fn(b)(state, table, eng.packed, eng.speeds)
    w_lo = float(rep.hi[0] - rep.lo[0])
    w_hi = float(rep.hi[1] - rep.lo[1])
    # identical stats, so widths differ exactly by the z ratio (1.282/2.576)
    from jax.scipy.special import ndtri
    z_ratio = float(ndtri(0.995) / ndtri(0.90))
    assert w_lo > 0
    np.testing.assert_allclose(w_hi / w_lo, z_ratio, rtol=1e-4)


# ---------------------------------------------------------------------------
# Mid-scan admission
# ---------------------------------------------------------------------------

def test_mid_scan_admission_matches_cold_start(setup):
    """A query admitted mid-scan (synopsis-seeded, over the already-started
    chunk set) must land within tolerance of the same query cold-started on
    its own scan — mid-scan joining costs coverage, not correctness."""
    vals, store = setup
    truth = _truth_sum(vals)
    occupant = Query(agg="sum", expr=Linear(COEF), epsilon=0.02, name="long")
    joiner = Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 8e7),
                   epsilon=0.06, name="late")
    sel = (vals[:, 0] >= 0) & (vals[:, 0] < 8e7)
    truth_j = float((vals @ np.asarray(COEF)) @ sel)

    cfg = EngineConfig(num_workers=2, seed=9)
    # warm: joiner arrives while the occupant's scan is in flight
    warm = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=4))
    warm.submit(occupant, arrival_t=0.0)
    warm.submit(joiner, arrival_t=1e-4)
    warm_res = {r.name: r for r in warm.run()}
    # cold: the joiner alone on a fresh scan
    cold = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=4))
    cold.submit(joiner, arrival_t=0.0)
    cold_res = {r.name: r for r in cold.run()}

    w, c = warm_res["late"], cold_res["late"]
    assert abs(w.estimate - truth_j) / abs(truth_j) < 3 * joiner.epsilon
    assert abs(c.estimate - truth_j) / abs(truth_j) < 3 * joiner.epsilon
    assert abs(w.estimate - c.estimate) / abs(truth_j) < 3 * joiner.epsilon
    # the warm joiner was genuinely seeded mid-scan
    assert warm_res["late"].seeded_tuples > 0
    assert abs(warm_res["long"].estimate - truth) / truth < 3 * occupant.epsilon


# ---------------------------------------------------------------------------
# Early leave isolation
# ---------------------------------------------------------------------------

def test_early_leaver_does_not_perturb_survivor(setup):
    """With plans that never close chunks early (holistic), the shared scan
    is query-independent — so a HAVING query that retires early must leave
    the survivor's statistics bit-for-bit unchanged vs running alone."""
    vals, store = setup
    truth = _truth_sum(vals)
    survivor = Query(agg="sum", expr=Linear(COEF), epsilon=0.03, name="surv")
    leaver = Query(agg="sum", expr=Linear(COEF),
                   having=Having("<", truth * 4), epsilon=0.05, name="quick")

    cfg = EngineConfig(num_workers=2, seed=11)
    alone = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=4))
    alone.submit(survivor, plan="holistic", arrival_t=0.0)
    res_alone = {r.name: r for r in alone.run()}

    shared = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=4))
    shared.submit(survivor, plan="holistic", arrival_t=0.0)
    shared.submit(leaver, plan="holistic", arrival_t=0.0)
    res_shared = {r.name: r for r in shared.run()}

    # the leaver decided its HAVING and left before the survivor finished
    assert res_shared["quick"].decision == 1
    assert res_shared["quick"].t_done <= res_shared["surv"].t_done
    # survivor's answer is unchanged by the co-resident query
    np.testing.assert_allclose(res_shared["surv"].estimate,
                               res_alone["surv"].estimate, rtol=1e-6)
    np.testing.assert_allclose(res_shared["surv"].err,
                               res_alone["surv"].err, rtol=1e-5, atol=1e-8)
    assert res_shared["surv"].tuples_seen == res_alone["surv"].tuples_seen


# ---------------------------------------------------------------------------
# Synopsis-seeded slots ≡ controller synopsis reuse
# ---------------------------------------------------------------------------

def test_seed_slot_agrees_with_controller_seed(setup):
    """`seed_slot` (per-slot, workload server) and `seed` (frozen engine,
    EstimationController reuse) must derive identical sufficient statistics
    from the same synopsis."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=13)
    ctrl = EstimationController(store, cfg, synopsis_budget_tuples=2048)
    ctrl.run_query([Query(agg="sum", expr=Linear(COEF), epsilon=0.04)])
    syn = ctrl.synopsis
    assert syn is not None and len(syn.chunks) > 0

    follow = Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 5e7),
                   epsilon=0.08)
    batch_seed = syn.seed([follow], cache_cap=64)
    slot_seed = syn.seed_slot(follow)
    assert slot_seed is not None
    np.testing.assert_array_equal(slot_seed["m"], batch_seed["m"])
    np.testing.assert_allclose(slot_seed["ysum"], batch_seed["ysum"][0],
                               rtol=1e-6)
    np.testing.assert_allclose(slot_seed["ysq"], batch_seed["ysq"][0],
                               rtol=1e-6)
    np.testing.assert_allclose(slot_seed["psum"], batch_seed["psum"][0],
                               rtol=1e-6)


def test_server_synopsis_answer_matches_truth(setup):
    """End to end: a repeat query answered purely from the server's synopsis
    (zero extra scan rounds) is still a statistically sound estimate."""
    vals, store = setup
    truth = _truth_sum(vals)
    cfg = EngineConfig(num_workers=2, seed=17)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=4, synopsis_budget_tuples=4096))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.03, name="warm"),
               arrival_t=0.0)
    srv.run()
    scanned_before = srv.tuples_scanned
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.10,
                     name="repeat"))
    res = {r.name: r for r in srv.run()}
    rep = res["repeat"]
    assert rep.from_synopsis
    assert rep.rounds_resident == 0
    assert srv.tuples_scanned == scanned_before  # no extra raw access
    assert abs(rep.estimate - truth) / truth < 3 * 0.10


# ---------------------------------------------------------------------------
# Plan selector + top-up
# ---------------------------------------------------------------------------

def test_select_plan_regimes(setup):
    vals, store = setup
    q = Query(agg="sum", expr=Linear(COEF), epsilon=0.05)
    # CPU-bound regime (slow extraction) -> single_pass
    cpu_cfg = EngineConfig(num_workers=1, cpu_tuple_ops_per_sec=1e6,
                           io_bytes_per_sec=1e12)
    assert select_plan(store, cpu_cfg, q) == "single_pass"
    # IO-bound regime (slow disk) -> holistic
    io_cfg = EngineConfig(num_workers=8, cpu_tuple_ops_per_sec=1e12,
                          io_bytes_per_sec=1e3)
    assert select_plan(store, io_cfg, q) == "holistic"
    # exact answers -> chunk_level
    assert select_plan(store, cpu_cfg,
                       Query(agg="sum", expr=Linear(COEF),
                             epsilon=0.0)) == "chunk_level"


def test_select_plan_measured_rates_override(setup, tmp_path):
    """Bench-measured rates override the modeled constants in Eq. (4); a
    missing/garbled measurement file falls back to the modeled defaults."""
    vals, store = setup
    q = Query(agg="sum", expr=Linear(COEF), epsilon=0.05)
    # modeled config says CPU-bound, the measurement says IO-bound
    cpu_cfg = EngineConfig(num_workers=1, cpu_tuple_ops_per_sec=1e6,
                           io_bytes_per_sec=1e12)
    assert select_plan(store, cpu_cfg, q) == "single_pass"
    io_rates = MeasuredRates(io_bytes_per_sec=1e3, cpu_tuples_per_sec=1e12)
    assert select_plan(store, cpu_cfg, q, rates=io_rates) == "holistic"

    # loader round-trip through a bench result file
    path = tmp_path / "BENCH_slot_kernel.json"
    path.write_text('{"calibration": {"backend": "ref", '
                    '"cpu_tuples_per_sec": 1e12, "io_bytes_per_sec": 1e3}}')
    rates = load_measured_rates(str(path))
    assert rates is not None and rates.io_bytes_per_sec == 1e3
    assert select_plan(store, cpu_cfg, q, rates=rates) == "holistic"
    # the measured CPU rate is aggregate over the calibration run's worker
    # count and must be rescaled to the serving config's: with these rates a
    # same-shape deployment is CPU-bound, a 16x-wider one IO-bound
    few = EngineConfig(num_workers=8)
    many = EngineConfig(num_workers=128)
    tb = float(store.chunk_sizes.sum()) * store.codec.record_bytes
    bal = MeasuredRates(io_bytes_per_sec=tb,                       # t_io = 1s
                        cpu_tuples_per_sec=store.num_tuples / 4.0,  # 4s @ 8w
                        workers=8)
    assert select_plan(store, few, q, rates=bal) == "single_pass"
    assert select_plan(store, many, q, rates=bal) == "holistic"
    # fallback paths: missing file, unusable payload, NaN rates
    assert load_measured_rates(str(tmp_path / "nope.json")) is None
    path.write_text('{"calibration": {"cpu_tuples_per_sec": 0}}')
    assert load_measured_rates(str(path)) is None
    path.write_text('{"calibration": {"cpu_tuples_per_sec": NaN, '
                    '"io_bytes_per_sec": 1e6}}')
    assert load_measured_rates(str(path)) is None
    srv = OLAWorkloadServer(
              store, EngineConfig(num_workers=2),
              options=ServerOptions(rates_path=str(tmp_path / "nope.json")))
    assert srv.rates is None  # modeled defaults still in force


def test_measured_rates_loader_round_cost_fit(tmp_path):
    """The loader carries the calibration's S-sweep round-cost fit (the
    scheduler's measured-capacity input) and treats absent/garbage fit
    fields as 'fit unavailable' (0.0) without rejecting the calibration."""
    path = tmp_path / "BENCH_slot_kernel.json"
    path.write_text('{"calibration": {"backend": "ref", '
                    '"cpu_tuples_per_sec": 1e6, "io_bytes_per_sec": 1e8, '
                    '"round_base_us": 3000.0, "round_slot_us": 250.0}}')
    rates = load_measured_rates(str(path))
    assert rates.round_base_us == 3000.0
    assert rates.round_slot_us == 250.0
    # predates the fit -> 0.0 sentinels, calibration still usable
    path.write_text('{"calibration": {"backend": "ref", '
                    '"cpu_tuples_per_sec": 1e6, "io_bytes_per_sec": 1e8}}')
    rates = load_measured_rates(str(path))
    assert rates is not None
    assert rates.round_base_us == 0.0 and rates.round_slot_us == 0.0
    # NaN/negative fit values are sanitized, not propagated
    path.write_text('{"calibration": {"backend": "ref", '
                    '"cpu_tuples_per_sec": 1e6, "io_bytes_per_sec": 1e8, '
                    '"round_base_us": NaN, "round_slot_us": -4.0}}')
    rates = load_measured_rates(str(path))
    assert rates.round_base_us == 0.0 and rates.round_slot_us == 0.0


def test_measured_rates_rescale_across_codecs(setup):
    """The calibrated tuple rate is codec-relative (ASCII parsing vs
    near-free binary decode): with the calibration's cost_per_tuple
    recorded, select_plan rescales it for the serving store's codec instead
    of treating a binary store as ASCII-slow."""
    vals, store = setup                                  # ascii store
    bstore = store_dataset(vals, 32, "binary")
    q = Query(agg="sum", expr=Linear(COEF), epsilon=0.05)
    cfg = EngineConfig(num_workers=4)
    tb = float(store.chunk_sizes.sum()) * store.codec.record_bytes
    # tuned so the ASCII store sits in the balanced band (resource_aware)
    rates = MeasuredRates(io_bytes_per_sec=tb,            # t_io = 1 s
                          cpu_tuples_per_sec=store.num_tuples,  # t_cpu = 1 s
                          workers=4,
                          cost_per_tuple=store.codec.extract_cost_per_tuple())
    assert select_plan(store, cfg, q, rates=rates) == "resource_aware"
    # binary decode is far cheaper per tuple -> the same calibration must
    # classify the binary store as IO-bound (holistic), not CPU-bound
    assert (bstore.codec.extract_cost_per_tuple()
            < store.codec.extract_cost_per_tuple() / 4)
    tbb = float(bstore.chunk_sizes.sum()) * bstore.codec.record_bytes
    rates_b = dataclasses.replace(rates, io_bytes_per_sec=tbb)
    assert select_plan(bstore, cfg, q, rates=rates_b) == "holistic"
    # without the recorded cost the loader/selector keep the raw rate
    raw = dataclasses.replace(rates_b, cost_per_tuple=0.0)
    assert select_plan(bstore, cfg, q, rates=raw) == "resource_aware"


def test_default_rates_path_ignores_cwd(tmp_path, monkeypatch):
    """The default calibration path is anchored to the repo root (or the
    OLA_RATES_PATH env knob), not the process CWD — a server started from
    another directory must still find (or cleanly miss) the bench file."""
    import os

    from repro.serve.ola_server import default_rates_path

    monkeypatch.delenv("OLA_RATES_PATH", raising=False)
    monkeypatch.chdir(tmp_path)                     # CWD must be irrelevant
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    assert default_rates_path() == os.path.join(repo_root,
                                                "BENCH_slot_kernel.json")

    # hit: env knob points at a usable calibration; loader default finds it
    path = tmp_path / "elsewhere" / "cal.json"
    path.parent.mkdir()
    path.write_text('{"calibration": {"backend": "ref", "workers": 4, '
                    '"cpu_tuples_per_sec": 2e9, "io_bytes_per_sec": 5e8}}')
    monkeypatch.setenv("OLA_RATES_PATH", str(path))
    rates = load_measured_rates()
    assert rates is not None
    assert rates.io_bytes_per_sec == 5e8 and rates.workers == 4

    # miss: knob points nowhere -> None -> modeled fallback stays in force
    monkeypatch.setenv("OLA_RATES_PATH", str(tmp_path / "nope.json"))
    assert load_measured_rates() is None


def test_post_exhaustion_without_synopsis_fails_loud(setup):
    """Once the scan is a census and there is no synopsis, a new query can
    never be served: submit() rejects it, and one already queued retires
    flagged `unserved` with a NaN estimate — never a plausible-looking 0."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=23)
    exact = Query(agg="sum", expr=Linear(COEF), epsilon=1e-9, name="census")
    late = Query(agg="sum", expr=Linear(COEF), epsilon=0.1, name="late")

    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(synopsis_budget_tuples=0))
    srv.submit(exact)
    assert srv.run()[0].tuples_seen == store.num_tuples
    with pytest.raises(ValueError, match="synopsis"):
        srv.submit(late)

    srv2 = OLAWorkloadServer(
               store, cfg,
               options=ServerOptions(max_slots=1, synopsis_budget_tuples=0))
    srv2.submit(exact, arrival_t=0.0)
    srv2.submit(late, arrival_t=0.0)   # queued behind the census
    res = {r.name: r for r in srv2.run()}
    assert res["late"].unserved
    assert np.isnan(res["late"].estimate)
    assert not res["census"].unserved


def test_topup_pass_serves_late_tight_query(setup):
    """A tight-ε query arriving after the scan wound down forces a top-up
    pass (re-opened chunks) and still converges."""
    vals, store = setup
    truth = _truth_sum(vals)
    cfg = EngineConfig(num_workers=2, seed=19)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2, synopsis_budget_tuples=512))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.10,
                     name="loose"), arrival_t=0.0)
    srv.run()
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.02,
                     name="tight"))
    res = {r.name: r for r in srv.run()}
    tight = res["tight"]
    assert abs(tight.estimate - truth) / truth < 3 * 0.02
    assert tight.err <= 0.02 + 1e-6 or srv.topup_passes > 0
