"""Training substrate: optimizer, accumulation, checkpointing, compression."""

import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.compression import int8_compressor, topk_compressor
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.train.train_step import init_train_state, make_train_step


def _tiny_problem():
    """Quadratic bowl: params should converge toward the target."""
    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss(params, batch):
        return jnp.sum((params["w"] - target) ** 2) * batch["scale"]

    params = {"w": jnp.zeros(3)}
    return loss, params, target


def test_adamw_converges_quadratic():
    loss, params, target = _tiny_problem()
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=400,
                      weight_decay=0.0)
    step = jax.jit(make_train_step(loss, cfg))
    state = init_train_state(params)
    for _ in range(300):
        state, m = step(state, {"scale": jnp.asarray(1.0)})
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(target), atol=0.05)


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)


def test_grad_accumulation_equivalence():
    """accum_steps=k on batch B == single step on the same batch."""
    cfg = get_config("smollm-135m", reduced=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    s1 = init_train_state(params)
    s2 = init_train_state(params)
    step1 = jax.jit(make_train_step(model.loss, ocfg, accum_steps=1))
    step2 = jax.jit(make_train_step(model.loss, ocfg, accum_steps=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    # losses equal; params close (grad means vs mean-of-split-grads identical
    # for CE-mean over equal micro shards up to f32 summation order)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    # compare the accumulated gradient via its norm: step-1 Adam is sign-SGD
    # (m̂/√v̂ = ±1 for any |g| >> eps), so param-space comparison is chaotic
    # for near-zero-gradient params; the gradient itself must match.
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]),
                                                   rel=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    loss, params, _ = _tiny_problem()
    cfg = AdamWConfig()
    step = jax.jit(make_train_step(loss, cfg))
    state = init_train_state(params)
    for _ in range(3):
        state, _ = step(state, {"scale": jnp.asarray(1.0)})
    path = ckpt.save(str(tmp_path), 3, state)
    assert os.path.exists(os.path.join(path, "COMMIT"))
    template = jax.tree.map(np.zeros_like, jax.tree.map(np.asarray, state))
    restored = ckpt.restore(str(tmp_path), 3, template)
    np.testing.assert_allclose(np.asarray(restored.params["w"]),
                               np.asarray(state.params["w"]))
    np.testing.assert_allclose(np.asarray(restored.opt.mu["w"]),
                               np.asarray(state.opt.mu["w"]))


def test_checkpoint_prune_and_latest(tmp_path):
    loss, params, _ = _tiny_problem()
    state = init_train_state(params)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep=3)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_uncommitted_checkpoint_ignored(tmp_path):
    loss, params, _ = _tiny_problem()
    state = init_train_state(params)
    ckpt.save(str(tmp_path), 1, state)
    # fake a torn write
    os.makedirs(os.path.join(tmp_path, "step_2"))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_int8_compressor_error_feedback():
    g = {"w": jnp.asarray(np.linspace(-1, 1, 1000), jnp.float32)}
    e = jax.tree.map(jnp.zeros_like, g)
    total = jnp.zeros_like(g["w"])
    # over many steps, transmitted sum ≈ true sum (error feedback property)
    for _ in range(50):
        out, e = int8_compressor(g, e)
        total = total + out["w"]
    np.testing.assert_allclose(np.asarray(total) / 50, np.asarray(g["w"]),
                               atol=2e-3)


def test_topk_compressor_sparsity():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=2000),
                          jnp.float32)}
    e = jax.tree.map(jnp.zeros_like, g)
    out, e2 = topk_compressor(g, e, frac=0.01)
    nz = int(jnp.sum(out["w"] != 0))
    assert nz <= 0.02 * 2000
    # residual keeps the rest
    np.testing.assert_allclose(np.asarray(out["w"] + e2["w"]),
                               np.asarray(g["w"]), atol=1e-6)
