"""Fault-tolerant scan plane: injection, retry/backoff, CRC integrity,
quarantine, and degraded-answer semantics.

Contracts under test (``repro.data.faults`` + the wiring through the
pipeline, engines, and workload server):

* :class:`RetryPolicy` — bounded exponential backoff with deterministic
  jitter; exhaustion/deadline converts the failure into a
  :class:`ChunkLostError` carrying the chunk id and retry count, while a
  direct :class:`ChunkLostError` propagates immediately;
* :class:`FaultInjector` — seeded and deterministic; an all-zero config is
  a bit-exact pass-through across every engine (ref/pallas × packed/stream)
  and the scheduled server (NEUTRAL config), so the wrapper can stay on in
  CI without perturbing any parity gate;
* per-chunk CRC32 — recorded at ingest, verified on disk re-reads and
  end-to-end by the prefetcher (injected bit flips are caught even though
  the disk bytes are fine); legacy manifests without checksums still open;
* the reader thread stashes failures per chunk id instead of swallowing
  them, and ``close()`` joins it;
* quarantine oracle — after a chunk is permanently lost, the masked
  N-slot estimator state (zeroed columns + surviving ``n_total/m_total``)
  is *bit-for-bit* the compact survivors-only computation, and a census
  run's estimate equals a fresh scan over the surviving chunks;
* acceptance gates — a seeded transient-fault run heals bit-exactly with
  zero quarantines (``degraded=False``); a permanently lost chunk finishes
  every query ``degraded=True`` over the surviving population without a
  stall or raise.
"""

import json
import zlib

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.core import estimators as E
from repro.core.engine import EngineConfig, OLAEngine, quarantine_chunks
from repro.core.estimators import BiLevelStats
from repro.core.queries import Linear, Query, Range
from repro.data.chunkstore import ChunkStore
from repro.data.faults import (
    ChunkLostError,
    CorruptChunkError,
    FaultConfig,
    FaultInjector,
    RetryPolicy,
    TransientReadError,
    _unit_hash,
)
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.data.pipeline import SlabPrefetcher
from repro.sched import NEUTRAL, WorkloadScheduler
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions

COEF = tuple(1.0 / (k + 1) for k in range(8))


def _queries(eps):
    return [
        Query(agg="sum", expr=Linear(COEF), epsilon=eps, name="q-sum"),
        Query(agg="count", pred=Range(1, 0.0, 7e7), epsilon=eps,
              name="q-count"),
        Query(agg="avg", expr=Linear(COEF), epsilon=eps, name="q-avg"),
    ]


def _vals(t=512, seed=3):
    return make_synthetic_zipf(t, 8, seed=seed)


def _store(vals=None, chunks=6, directory=None):
    return store_dataset(vals if vals is not None else _vals(), chunks,
                         "ascii", directory=directory)


def _cfg(**kw):
    base = dict(num_workers=2, strategy="single_pass", budget_init=64,
                seed=5, residency="stream")
    base.update(kw)
    return EngineConfig(**base)


def _no_sleep_retry(**kw):
    return RetryPolicy(sleep=lambda s: None, **kw)


def _run_engine(store, queries, cfg, quarantine0=(), max_rounds=4000):
    """Drive an engine loop to stop/exhaustion; returns (state, last report,
    rounds).  ``quarantine0`` marks chunks lost before round 1 — the "fresh
    scan over the survivors" arm of the oracle test."""
    eng = OLAEngine(store, queries, cfg)
    if eng.pipeline is not None:
        eng.pipeline.retry = _no_sleep_retry()
    try:
        state = eng.init_state()
        if quarantine0:
            state = quarantine_chunks(state, list(quarantine0))
        rep = None
        rounds = 0
        for _ in range(max_rounds):
            b = eng.budget_ladder(float(state.budget))
            state, data = eng.round_data(state)
            state, rep = eng.round_fn(b)(state, data, eng.speeds)
            rounds += 1
            if bool(rep.all_stopped) or bool(rep.exhausted):
                break
        else:
            raise AssertionError("engine did not converge")
        return state, rep, rounds, list(eng.quarantine_log)
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# RetryPolicy units
# ---------------------------------------------------------------------------

def test_retry_policy_heals_transient_deterministically():
    sleeps = []
    pol = RetryPolicy(max_attempts=4, seed=11, sleep=sleeps.append)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise TransientReadError("flaky", chunk_id=3)
        return "ok"

    out, retries = pol.call(flaky, 3)
    assert out == "ok" and retries == 2
    # backoff schedule is a pure function of (seed, chunk, attempt)
    assert sleeps == [pol.delay_s(3, 0), pol.delay_s(3, 1)]
    assert sleeps == [RetryPolicy(max_attempts=4, seed=11).delay_s(3, a)
                      for a in range(2)]
    assert sleeps[1] > sleeps[0] > 0  # exponential growth survives jitter


def test_retry_policy_exhaustion_raises_chunk_lost():
    pol = _no_sleep_retry(max_attempts=3)

    def always():
        raise OSError("EIO")

    with pytest.raises(ChunkLostError) as ei:
        pol.call(always, 7)
    assert ei.value.chunk_id == 7
    assert ei.value.retries == 3
    assert isinstance(ei.value.__cause__, OSError)


def test_retry_policy_lost_propagates_immediately():
    calls = {"n": 0}

    def gone():
        calls["n"] += 1
        raise ChunkLostError("gone", chunk_id=2)

    with pytest.raises(ChunkLostError):
        _no_sleep_retry(max_attempts=5).call(gone, 2)
    assert calls["n"] == 1  # not retried: the store says it is gone


def test_retry_policy_deadline_stops_backoff():
    sleeps = []
    pol = RetryPolicy(max_attempts=8, deadline_s=0.0, sleep=sleeps.append)

    def always():
        raise TransientReadError("flaky", chunk_id=1)

    with pytest.raises(ChunkLostError) as ei:
        pol.call(always, 1)
    assert sleeps == []          # first backoff would cross the deadline
    assert ei.value.retries == 1


# ---------------------------------------------------------------------------
# FaultInjector determinism + pass-through
# ---------------------------------------------------------------------------

def test_fault_injector_is_deterministic():
    store = _store()
    cfg = FaultConfig(seed=7, transient_rate=0.5, transient_fails=1)
    rolls = [FaultInjector(store, cfg).chunk_is_transient(j)
             for j in range(store.num_chunks)]
    assert rolls == [_unit_hash(7, "transient", j) < 0.5
                     for j in range(store.num_chunks)]
    assert any(rolls) and not all(rolls)  # seed 7 splits the 6-chunk store

    def read_all(inj):
        out = []
        for j in range(store.num_chunks):
            try:
                out.append(inj.chunk_bytes(j).tobytes())
            except TransientReadError:
                out.append(None)
        return out, dict(inj.injected)

    a = read_all(FaultInjector(store, cfg))
    b = read_all(FaultInjector(store, cfg))
    assert a == b
    assert a[1]["transient"] == sum(rolls)


def test_fault_injector_transient_heals_after_k_failures():
    store = _store()
    inj = FaultInjector(store, FaultConfig(seed=7, transient_rate=1.0,
                                           transient_fails=2))
    for _ in range(2):
        with pytest.raises(TransientReadError):
            inj.chunk_bytes(0)
    np.testing.assert_array_equal(inj.chunk_bytes(0), store.chunk_bytes(0))
    assert inj.injected["transient"] == 2


def test_fault_injector_zero_config_is_passthrough():
    store = _store()
    inj = FaultInjector(store, FaultConfig())
    for j in range(store.num_chunks):
        np.testing.assert_array_equal(inj.chunk_bytes(j),
                                      store.chunk_bytes(j))
    assert all(v == 0 for v in inj.injected.values())
    # attribute delegation: the wrapper is store-shaped
    assert inj.num_chunks == store.num_chunks
    np.testing.assert_array_equal(inj.chunk_sizes, store.chunk_sizes)


# ---------------------------------------------------------------------------
# CRC32 integrity at the ChunkStore boundary
# ---------------------------------------------------------------------------

def test_crc_recorded_and_verified_on_disk_reread(tmp_path):
    vals = _vals(t=256, seed=1)
    store = _store(vals, chunks=4, directory=str(tmp_path))
    for j in range(store.num_chunks):
        raw = store.chunk_bytes(j)
        assert store.meta[j].crc32 == zlib.crc32(raw.tobytes()) & 0xFFFFFFFF

    reopened = ChunkStore.open(str(tmp_path), "dataset")
    np.testing.assert_array_equal(reopened.chunk_bytes(2),
                                  store.chunk_bytes(2))

    # flip one byte in the backing file -> CorruptChunkError on re-read
    path = reopened.meta[1].path
    blob = bytearray(open(path, "rb").read())
    blob[5] ^= 0x04
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CorruptChunkError) as ei:
        reopened.chunk_bytes(1)
    assert ei.value.chunk_id == 1

    # truncation -> short read, also CorruptChunkError
    open(path, "wb").write(bytes(blob[:-7]))
    with pytest.raises(CorruptChunkError):
        reopened.chunk_bytes(1)


def test_crc_legacy_manifest_opens_and_skips_verification(tmp_path):
    store = _store(_vals(t=256, seed=1), chunks=4, directory=str(tmp_path))
    manifest_path = str(tmp_path / "dataset.manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    for m in manifest["chunks"]:
        del m["crc32"]           # pre-checksum manifest shape
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)

    legacy = ChunkStore.open(str(tmp_path), "dataset")
    assert all(m.crc32 is None for m in legacy.meta)
    np.testing.assert_array_equal(legacy.chunk_bytes(0),
                                  store.chunk_bytes(0))
    # corruption is NOT caught without a manifest CRC (size still is)
    path = legacy.meta[0].path
    blob = bytearray(open(path, "rb").read())
    blob[0] ^= 0x01
    open(path, "wb").write(bytes(blob))
    legacy.evict(0)
    assert legacy.chunk_bytes(0) is not None


# ---------------------------------------------------------------------------
# SlabPrefetcher: retry wiring, end-to-end CRC, reader-thread error slots
# ---------------------------------------------------------------------------

def test_prefetcher_retries_injected_corruption(tmp_path):
    store = _store(_vals(t=256, seed=1), chunks=4, directory=str(tmp_path))
    inj = FaultInjector(store, FaultConfig(seed=7, corrupt_chunks=(2,),
                                           corrupt_once=True))
    pf = SlabPrefetcher(inj, num_workers=2, lookahead=2,
                        retry=_no_sleep_retry(max_attempts=4))
    try:
        # the injected bit flip passes the store's own disk-boundary check
        # (the disk bytes are fine) but is caught by the prefetcher's
        # end-to-end CRC verification and healed by the retried re-read
        got = pf._read_chunk(2)
        np.testing.assert_array_equal(got, ChunkStore.open(
            str(tmp_path), "dataset").chunk_bytes(2))
        assert pf.read_retries == 1
        assert pf.chunk_reads == 1
        assert inj.injected["corrupt"] == 1
        assert pf.read_errors == {}
    finally:
        pf.close()


def test_prefetcher_persistent_corruption_exhausts_to_lost(tmp_path):
    store = _store(_vals(t=256, seed=1), chunks=4, directory=str(tmp_path))
    inj = FaultInjector(store, FaultConfig(seed=7, corrupt_chunks=(1,)))
    pf = SlabPrefetcher(inj, num_workers=2, lookahead=2,
                        retry=_no_sleep_retry(max_attempts=2))
    try:
        with pytest.raises(ChunkLostError) as ei:
            pf._read_chunk(1)
        assert ei.value.chunk_id == 1
        assert isinstance(ei.value.__cause__, CorruptChunkError)
        assert pf.read_retries == 2
    finally:
        pf.close()


def test_reader_thread_stashes_failures_and_close_joins():
    store = _store()
    inj = FaultInjector(store, FaultConfig(seed=7, lost_chunks=(4,)))
    pf = SlabPrefetcher(inj, num_workers=2, lookahead=2,
                        retry=_no_sleep_retry(max_attempts=2))
    try:
        pf.prefetch([4])
        deadline = 5.0
        import time
        t0 = time.monotonic()
        while pf.read_failures == 0 and time.monotonic() - t0 < deadline:
            time.sleep(0.01)
        assert pf.read_failures >= 1, "reader thread swallowed the failure"
        assert isinstance(pf.read_errors[4], ChunkLostError)
        # assemble retries synchronously and surfaces the loss to the caller
        with pytest.raises(ChunkLostError):
            pf.assemble(np.array([4, 0]), np.array([True, False]))
    finally:
        pf.close()
    assert not pf._reader.is_alive()     # close() joined the reader


# ---------------------------------------------------------------------------
# Zero-fault wrapper parity: ref/pallas × packed/stream + scheduled server
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("residency", ["packed", "stream"])
def test_zero_fault_wrapper_engine_parity(backend, residency):
    vals = _vals(t=384, seed=3)
    queries = _queries(0.05)
    cfg = _cfg(extract_backend=backend, residency=residency)

    def run(store):
        state, rep, rounds, qlog = _run_engine(store, queries, cfg)
        assert qlog == []
        return (np.asarray(rep.estimate).tobytes(),
                np.asarray(rep.lo).tobytes(),
                np.asarray(rep.hi).tobytes(), rounds, int(rep.m_tuples))

    base = run(_store(vals))
    wrapped = run(FaultInjector(_store(vals), FaultConfig()))
    assert wrapped == base


def test_zero_fault_wrapper_server_parity_neutral():
    vals = _vals(t=512, seed=3)
    cfg = EngineConfig(num_workers=2, seed=9, residency="stream")
    workload = [(q, 1e-5 * i) for i, q in enumerate(_queries(0.08))]

    def run(store):
        srv = OLAWorkloadServer(
                  store, cfg,
                  options=ServerOptions(max_slots=2,
                      scheduler=WorkloadScheduler(NEUTRAL)))
        for q, at in workload:
            srv.submit(q, arrival_t=at)
        trace = []
        res = srv.run(on_round=lambda s: trace.append(
            (int(s.tuples_scanned), int(np.asarray(s.state.head)))))
        out = [(r.qid, r.estimate, r.lo, r.hi, r.err, r.tuples_seen,
                r.degraded, r.chunks_quarantined, r.read_retries)
               for r in res]
        srv.close()
        return out, trace

    base = run(_store(vals, chunks=8))
    wrapped = run(FaultInjector(_store(vals, chunks=8), FaultConfig()))
    assert wrapped[1] == base[1], "per-round scan trace diverged"
    assert wrapped[0] == base[0], "results diverged (must be bit-exact)"
    assert all(not r[6] and r[7] == 0 for r in base[0])


# ---------------------------------------------------------------------------
# Acceptance gate 1: transient faults + retries heal bit-exactly
# ---------------------------------------------------------------------------

def test_transient_faults_heal_bit_exact_ref():
    vals = _vals()
    queries = _queries(0.05)
    cfg = _cfg()
    state0, rep0, rounds0, _ = _run_engine(_store(vals), queries, cfg)

    inj = FaultInjector(_store(vals),
                        FaultConfig(seed=7, transient_rate=0.5,
                                    transient_fails=2))
    state1, rep1, rounds1, qlog = _run_engine(inj, queries, cfg)
    assert inj.injected["transient"] > 0, "sweep injected nothing"
    assert qlog == []                        # retries absorbed every fault
    assert rounds1 == rounds0
    np.testing.assert_array_equal(np.asarray(rep1.estimate),
                                  np.asarray(rep0.estimate))
    np.testing.assert_array_equal(np.asarray(rep1.lo), np.asarray(rep0.lo))
    np.testing.assert_array_equal(np.asarray(rep1.hi), np.asarray(rep0.hi))


# ---------------------------------------------------------------------------
# Acceptance gate 2 + oracle: lost chunk -> quarantine-rescaled estimates
# ---------------------------------------------------------------------------

def _compact_survivors(stats, alive, sizes):
    """The survivors-only estimator state a fresh scan over the surviving
    chunks would hold (same samples, quarantined columns removed)."""
    k = int(alive.sum())
    m_tot = int(sizes[alive].sum())
    return BiLevelStats(
        M=jnp.asarray(np.asarray(stats.M)[alive]),
        m=jnp.asarray(np.asarray(stats.m)[..., alive]),
        ysum=jnp.asarray(np.asarray(stats.ysum)[..., alive]),
        ysq=jnp.asarray(np.asarray(stats.ysq)[..., alive]),
        psum=jnp.asarray(np.asarray(stats.psum)[..., alive]),
        n_total=k, m_total=m_tot)


def test_lost_chunk_quarantine_oracle_ref():
    vals = _vals()
    lost = 3
    queries = _queries(1e-9)     # unreachable eps -> census of the survivors
    cfg = _cfg()
    inj = FaultInjector(_store(vals), FaultConfig(seed=7, lost_chunks=(lost,)))
    state, rep, rounds, qlog = _run_engine(inj, queries, cfg)

    # no stall, no raise: the scan quarantined the chunk and ran to census
    assert qlog == [lost]
    assert bool(np.asarray(state.quarantined)[lost])
    assert bool(rep.exhausted)

    sizes = np.asarray(inj.chunk_sizes)
    alive = ~np.asarray(state.quarantined)
    assert int(np.asarray(state.stats.m)[lost]) == 0

    # --- oracle (bit-exact): masked N-slot stats with the surviving
    # population totals ARE the compact survivors-only computation --------
    masked = state.stats._replace(n_total=int(alive.sum()),
                                  m_total=int(sizes[alive].sum()))
    compact = _compact_survivors(state.stats, alive, sizes)
    for fn in (E.tau_hat, E.count_tau_hat):
        np.testing.assert_array_equal(np.asarray(fn(masked)),
                                      np.asarray(fn(compact)))
    for fn in (E.var_hat, E.count_var_hat):
        vm, okm = fn(masked)
        vc, okc = fn(compact)
        np.testing.assert_array_equal(np.asarray(vm), np.asarray(vc))
        np.testing.assert_array_equal(np.asarray(okm), np.asarray(okc))
    rm, vrm, _ = E.avg_estimate(masked)
    rc, vrc, _ = E.avg_estimate(compact)
    np.testing.assert_array_equal(np.asarray(rm), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(vrm), np.asarray(vrc))

    # the engine's reported estimate is that same rescaled computation, and
    # a census of the survivors is exact: zero-width intervals
    np.testing.assert_array_equal(np.asarray(rep.estimate)[0],
                                  np.asarray(E.tau_hat(masked))[0])
    np.testing.assert_allclose(np.asarray(rep.hi) - np.asarray(rep.lo),
                               0.0, atol=1e-6)

    # --- fresh scan over the survivors: same store, chunk marked lost
    # before round 1 -> same census answer ---------------------------------
    state2, rep2, _, _ = _run_engine(_store(vals), queries, cfg,
                                     quarantine0=(lost,))
    np.testing.assert_allclose(np.asarray(rep.estimate),
                               np.asarray(rep2.estimate), rtol=1e-5)

    # --- ground truth over the surviving tuples (f64) ---------------------
    offs = np.concatenate([[0], np.cumsum(sizes)])
    keep = np.ones(len(vals), bool)
    keep[offs[lost]:offs[lost + 1]] = False
    x = vals[keep].astype(np.float64) @ np.asarray(COEF, np.float64)
    np.testing.assert_allclose(float(np.asarray(rep.estimate)[0]),
                               float(x.sum()), rtol=1e-5)


def test_lost_chunk_server_degraded_answers():
    vals = _vals(t=512, seed=3)
    cfg = EngineConfig(num_workers=2, seed=9, residency="stream")
    inj = FaultInjector(_store(vals, chunks=8), FaultConfig())
    srv = OLAWorkloadServer(
              inj, cfg,
              options=ServerOptions(max_slots=2,
                  scheduler=WorkloadScheduler(NEUTRAL)))
    if srv.engine.pipeline is not None:
        srv.engine.pipeline.retry = _no_sleep_retry(max_attempts=2)
    # lose the first chunk the scan will claim: the quarantine lands in
    # round 1, before any retirement, so every answer must be degraded
    lost = int(np.asarray(srv.state.schedule)[0])
    inj.config = FaultConfig(seed=7, lost_chunks=(lost,))
    for i, q in enumerate(_queries(0.08)):
        srv.submit(q, arrival_t=1e-5 * i)
    res = srv.run()
    assert not srv.truncated, "lost chunk stalled the workload"
    assert srv.chunks_quarantined == 1
    assert len(res) == 3 and all(r.degraded for r in res)
    assert all(r.chunks_quarantined == 1 for r in res)

    # estimates describe the surviving population: census ground truth
    sizes = np.asarray(inj.chunk_sizes)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    keep = np.ones(len(vals), bool)
    keep[offs[lost]:offs[lost + 1]] = False
    x = vals[keep].astype(np.float64) @ np.asarray(COEF, np.float64)
    for r in res:
        if r.qid == "q-sum":
            lo, hi = float(r.lo), float(r.hi)
            assert lo <= x.sum() * (1 + 1e-4) and hi >= x.sum() * (1 - 1e-4)
    srv.close()
