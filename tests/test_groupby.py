"""Grouped OLA query plane: discovery sketch, grouped-vs-fanout oracle,
kernel parity, ServerOptions surface, and admission pricing.

The load-bearing invariant (ISSUE 10): a ``Query(group_by=...)`` over
*pre-known* group values must be bit-exact against the Section 2.2 fan-out
(:func:`repro.core.queries.group_fanout`) on the ref backend — every mask
factor in the grouped kernels is an exact 0/1 float, so a tracked cell's
sufficient stats are the same IEEE sums a dedicated fan-out slot computes.
"""

import dataclasses
import math
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

import repro.serve.ola_server as ola_server_mod
from repro.core.engine import EngineConfig, SlotOLAEngine
from repro.core.groupby import GroupSketch, promote_values, pure_buckets
from repro.core.queries import (
    GroupBy, Linear, Query, Range, empty_slot_table, encode_slot,
    expand_group_by, group_fanout, slot_table_set,
)
from repro.data.generator import make_wiki_like, store_dataset
from repro.kernels.ops import slot_extract
from repro.sched.admission import AdmissionController, ServerLoad
from repro.sched.slo import QuerySLO
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions


# ---------------------------------------------------------------------------
# discovery sketch (host-side, plain numpy)
# ---------------------------------------------------------------------------

def test_sketch_offer_evict_guaranteed_mass():
    sk = GroupSketch(2)
    sk.offer(1.0, 5.0)
    sk.offer(2.0, 3.0)
    sk.offer(1.0, 4.0)                  # tracked value accumulates
    assert sk.counts[1.0] == 9.0
    assert sk.mass == 12.0
    sk.offer(3.0, 2.0)                  # evicts the min (2.0 @ 3), inherits
    assert 2.0 not in sk.counts
    assert sk.counts[3.0] == 5.0        # floor 3 + count 2
    assert sk.guaranteed(3.0) == 2.0    # count - inherited error
    assert sk.guaranteed(1.0) == 9.0
    assert sk.top(1) == [(1.0, 9.0)]
    sk.offer(4.0, 0.0)                  # zero-count offers are ignored
    assert sk.mass == 14.0


def test_pure_buckets_moment_test():
    h = 8
    tal = np.zeros((3, h), np.float32)
    # bucket 0: 5 copies of value 3.0 -> pure
    tal[:, 0] = [5.0, 15.0, 45.0]
    # bucket 1: values 2.0 and 4.0 mixed -> nonzero variance, dropped
    tal[:, 1] = [2.0, 6.0, 20.0]
    # bucket 2: empty -> dropped
    out = dict(pure_buckets(tal))
    assert out == {3.0: 5.0}


def test_promote_values_grow_only():
    sk = GroupSketch(8)
    for v, c in [(1.0, 50.0), (2.0, 40.0), (3.0, 30.0), (4.0, 20.0)]:
        sk.offer(v, c)
    # 1.0 already tracked; two free cells -> next-heaviest untracked pair
    assert promote_values(sk, [1.0], 3) == [2.0, 3.0]
    assert promote_values(sk, [1.0, 2.0, 3.0], 3) == []   # no free cells


# ---------------------------------------------------------------------------
# engine-level oracle: grouped slot == fan-out slots, bit-exact
# ---------------------------------------------------------------------------

def _wiki_store(t=2048, chunks=8, langs=6, seed=11):
    vals, _ = make_wiki_like(t, num_languages=langs, seed=seed)
    return store_dataset(vals, chunks, "ascii", uneven=True, seed=seed)


def _drive(engine, table, rounds):
    state = engine.init_state()
    reports = []
    for _ in range(rounds):
        b = engine.budget_ladder(float(state.budget))
        state, data = engine.round_data(state)
        state, rep = engine.round_fn(b)(state, table, data, engine.speeds)
        reports.append(rep)
    return state, reports


def test_grouped_vs_fanout_bit_exact():
    """Pinned tracked cells == dedicated fan-out slots through exhaustion:
    same per-round estimates and bitwise-identical sufficient stats, and the
    ``__other__`` spill conserves the base predicate's mass."""
    store = _wiki_store()
    pinned = [0.0, 1.0, 2.0]
    base = Query(agg="sum", expr=Linear((0.0, 1.0, 0.0, 0.0)),
                 pred=Range(3, 0.0, 18.0), epsilon=1e-9)
    gq = dataclasses.replace(base, group_by=GroupBy(
        col=0, max_groups=4, top_k=3, values=pinned))
    fq = group_fanout(base, 0, pinned)

    # fixed budget ladder: both drives hand out chunks in schedule order
    cfg = EngineConfig(num_workers=4, budget_init=64, budget_min=64,
                       budget_max=64, seed=5, cache_cap=16)
    cfg_g = dataclasses.replace(cfg, max_groups=4)

    tg = empty_slot_table(1, 4, max_groups=4)
    tg = slot_table_set(tg, 0, encode_slot(gq, 4, plan="holistic",
                                           max_groups=4))
    tf = empty_slot_table(len(fq), 4)
    for i, q in enumerate(fq):
        tf = slot_table_set(tf, i, encode_slot(q, 4, plan="holistic"))

    sg, rg = _drive(SlotOLAEngine(store, 1, cfg_g), tg, 40)
    sf, rf = _drive(SlotOLAEngine(store, len(fq), cfg), tf, 40)
    assert float(np.asarray(sg.scan_m).sum()) == 2048.0   # exhausted

    for a, b in zip(rg, rf):
        ge = np.asarray(a.g_est)[0, :len(pinned)]
        fe = np.asarray(b.estimate)[:len(pinned)]
        assert np.array_equal(ge, fe, equal_nan=True), (ge, fe)

    gm = np.asarray(sg.gm)[0]
    gys = np.asarray(sg.gys)[0]
    gyq = np.asarray(sg.gyq)[0]
    gps = np.asarray(sg.gps)[0]
    for i in range(len(pinned)):
        # a live cell samples every row its slot samples, so gm == fan-out m
        assert np.array_equal(gm[i], np.asarray(sf.stats.m[i]))
        assert np.array_equal(gys[i], np.asarray(sf.stats.ysum[i]))
        assert np.array_equal(gyq[i], np.asarray(sf.stats.ysq[i]))
        assert np.array_equal(gps[i], np.asarray(sf.stats.psum[i]))

    # mass conservation: cells partition the base slot's matched rows, and
    # 0/1-indicator sums are exact integers, so psum splits exactly
    base_psum = np.asarray(sg.stats.psum[0])
    assert np.array_equal(gps.sum(axis=0), base_psum)
    # the untracked languages actually spill: __other__ saw matched rows
    assert float(gps[-1].sum()) > 0.0


def test_grouped_stream_pallas_rejected():
    store = _wiki_store(256, 2)
    cfg = EngineConfig(num_workers=2, max_groups=2, residency="stream",
                       extract_backend="pallas")
    with pytest.raises(ValueError, match="packed"):
        SlotOLAEngine(store, 1, cfg)


# ---------------------------------------------------------------------------
# kernel parity: ref oracle vs pallas interpret, grouped plane
# ---------------------------------------------------------------------------

def test_grouped_kernel_matches_ref_oracle():
    rng = np.random.default_rng(0)
    from repro.data.formats import AsciiFixedFormat

    n, m, c, w, b, s, g = 6, 37, 6, 4, 16, 3, 4
    codec = AsciiFixedFormat(c)
    vals = rng.uniform(-1e6, 1e6, (n * m, c))
    vals[:, 0] = rng.integers(0, 5, n * m)     # integer group column
    packed = jnp.asarray(codec.encode(vals).reshape(n, m, codec.record_bytes))
    jw = rng.integers(0, n, w).astype(np.int32)
    idx = rng.integers(0, m, (w, b)).astype(np.int32)
    b_eff = np.array([b, 7, 0, 3], np.int32)
    coeffs = rng.normal(size=(s, c)).astype(np.float32)
    lo = np.full((s, c), -np.inf, np.float32)
    hi = np.full((s, c), np.inf, np.float32)
    lo[:, 1] = rng.uniform(-1e6, 0, s)
    hi[:, 1] = rng.uniform(0, 1e6, s)
    is_count = np.array([0, 1, 0], np.float32)
    gate = np.array([1, 1, 1], np.float32)
    # slot 0: three tracked values + live __other__; slot 1 ungrouped;
    # slot 2: discovery mode (only __other__ live, tallies on)
    gcol = np.array([0, -1, 0], np.int32)
    gval = np.zeros((s, g), np.float32)
    gval[0, :3] = [0.0, 1.0, 2.0]
    gact = np.zeros((s, g), np.float32)
    gact[0, :3] = 1.0
    gact[0, -1] = 1.0
    gact[2, -1] = 1.0

    outs = {}
    for be in ("ref", "pallas"):
        st, _, gs, tal = slot_extract(
            packed, jw, idx, b_eff, coeffs, lo, hi, is_count, gate,
            backend=be, gcol=gcol, gval=gval, gact=gact, salt=7)
        outs[be] = (np.asarray(st), np.asarray(gs), np.asarray(tal))
    np.testing.assert_allclose(outs["ref"][0], outs["pallas"][0],
                               rtol=2e-5, atol=1e-2)
    np.testing.assert_allclose(outs["ref"][1], outs["pallas"][1],
                               rtol=2e-5, atol=1e-2)
    # tallies are integer-weighted moment sums of identical products
    np.testing.assert_array_equal(outs["ref"][2], outs["pallas"][2])
    # ungrouped slot contributes no cells or tallies
    assert np.all(outs["ref"][1][:, 1] == 0.0)
    assert np.all(outs["ref"][2][:, 1] == 0.0)


# ---------------------------------------------------------------------------
# server: NEUTRAL ungrouped bit-exactness with grouped support compiled in
# ---------------------------------------------------------------------------

def test_ungrouped_server_unchanged_by_group_capacity():
    """An ungrouped workload on a grouped-capable server (max_groups > 0) is
    round-for-round bit-identical to the max_groups=0 server."""
    store = _wiki_store(1024, 6)
    queries = [
        Query(agg="sum", expr=Linear((0.0, 1.0, 0.0, 0.0)),
              pred=Range(3, 0.0, 12.0), epsilon=0.05),
        Query(agg="count", pred=Range(0, 0.0, 3.0), epsilon=0.08),
        Query(agg="avg", expr=Linear((0.0, 0.0, 1.0, 0.0)), epsilon=0.06),
    ]

    def run(max_groups):
        cfg = EngineConfig(num_workers=2, seed=9, max_groups=max_groups)
        srv = OLAWorkloadServer(store, cfg, options=ServerOptions(
            max_slots=2, synopsis_budget_tuples=0))
        for i, q in enumerate(queries):
            srv.submit(q, arrival_t=1e-5 * i)
        trace = []
        res = srv.run(on_round=lambda s: trace.append(
            int(s.tuples_scanned)))
        out = [(r.qid, r.estimate, r.lo, r.hi, r.err, r.tuples_seen,
                r.groups) for r in res]
        return out, trace

    a = run(0)
    b = run(4)
    assert a == b


# ---------------------------------------------------------------------------
# server: online discovery, __other__ spill, top-K recall on Zipf data
# ---------------------------------------------------------------------------

def test_server_discovery_topk_recall_zipf():
    vals, _ = make_wiki_like(8192, num_languages=16, seed=0)
    store = store_dataset(vals, 12, "ascii", uneven=True, seed=0)
    cfg = EngineConfig(num_workers=4, seed=7, max_groups=8)
    srv = OLAWorkloadServer(store, cfg, options=ServerOptions(
        max_slots=2, synopsis_budget_tuples=0))
    q = Query(agg="sum", expr=Linear((0.0, 1.0, 0.0, 0.0)), epsilon=0.05,
              group_by=GroupBy(col=0, max_groups=8, top_k=5))
    srv.submit(q, arrival_t=0.0)
    res = srv.run(max_rounds=4000)
    assert len(res) == 1
    groups = res[0].groups
    assert groups is not None
    tracked = [g for g in groups if not g.is_other]
    other = [g for g in groups if g.is_other]
    assert len(other) == 1 and math.isnan(other[0].value)
    assert 1 <= len(tracked) <= 8

    # ground truth: top-5 languages by total hits
    per_lang = {}
    for lang, hits in zip(vals[:, 0], vals[:, 1]):
        per_lang[float(lang)] = per_lang.get(float(lang), 0.0) + float(hits)
    true_top = {v for v, _ in
                sorted(per_lang.items(), key=lambda kv: -kv[1])[:5]}
    got = {g.value for g in tracked}
    recall = len(true_top & got) / len(true_top)
    assert recall >= 0.9, (sorted(got), sorted(true_top))

    # spill cell absorbed the untracked languages' mass
    assert other[0].n > 0
    # tracked estimates approximate the exact per-language totals
    for gres in tracked:
        if gres.value in per_lang and per_lang[gres.value] > 0:
            assert abs(gres.estimate - per_lang[gres.value]) <= max(
                0.15 * per_lang[gres.value], 1e3), gres


def test_grouped_requires_group_capacity():
    store = _wiki_store(256, 2)
    srv = OLAWorkloadServer(store, EngineConfig(num_workers=2),
                            options=ServerOptions(max_slots=1))
    q = Query(agg="count", group_by=GroupBy(col=0, max_groups=4))
    with pytest.raises(ValueError, match="max_groups"):
        srv.submit(q, arrival_t=0.0)


# ---------------------------------------------------------------------------
# API surface: expand_group_by deprecation, ServerOptions shim
# ---------------------------------------------------------------------------

def test_expand_group_by_deprecated_and_equivalent():
    base = Query(agg="sum", expr=Linear((1.0, 0.0)), pred=Range(1, 0.0, 5.0))
    with pytest.warns(DeprecationWarning, match="group_by"):
        old = expand_group_by(base, group_col=0, group_values=[1.0, 2.0])
    new = group_fanout(base, 0, [1.0, 2.0])
    assert old == new


def test_server_options_legacy_shim():
    store = _wiki_store(256, 2)
    cfg = EngineConfig(num_workers=2)
    ola_server_mod._legacy_kwargs_warned = False
    try:
        with pytest.warns(DeprecationWarning, match="ServerOptions"):
            srv = OLAWorkloadServer(store, cfg, max_slots=2)
        assert srv.max_slots == 2
        # warns once per process, not per construction
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            OLAWorkloadServer(store, cfg, max_slots=2)
    finally:
        ola_server_mod._legacy_kwargs_warned = False

    with pytest.raises(TypeError, match="max_slotz"):
        OLAWorkloadServer(store, cfg, max_slotz=2)
    with pytest.raises(TypeError):
        OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=2),
                          max_slots=2)


# ---------------------------------------------------------------------------
# admission: per-group Eq. (4) pricing
# ---------------------------------------------------------------------------

def test_admission_prices_group_cells():
    load = ServerLoad(now=0.0, free_slots=1, queue_ahead=0,
                      scan_rate=1000.0, total_tuples=100_000)
    slo = QuerySLO()

    def service(group_count, **kw):
        ctl = AdmissionController()
        return ctl.decide(arrival_t=0.0, slo=slo, epsilon=0.05, load=load,
                          group_count=group_count, **kw).predicted_service_s

    seed = dict(seed_m=1000, seed_err=0.1)
    s1 = service(0, **seed)       # CLT: 1000*(0.1/0.05)^2 - 1000 = 3000
    s5 = service(5, **seed)       # x5 cells, still under a full pass
    s50 = service(50, **seed)     # capped at one full pass (a census
    assert s1 == pytest.approx(3.0)            # answers every cell)
    assert s5 == pytest.approx(15.0)
    assert s50 == pytest.approx(100.0)
    # no seed: already the full-pass bound; cells cannot exceed it
    assert service(5) == service(0) == pytest.approx(100.0)
