"""OLA-verify production cell: sharded-store round soundness (subprocess)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import repro.launch.verify_cell as vc

# shrink the production program for a functional run
def small_program(budget):
    from repro.core.engine import EngineConfig, EngineProgram
    from repro.core.queries import Column, Having, Query, Range, TRUE
    from repro.data.formats import AsciiFixedFormat
    codec = AsciiFixedFormat(6)
    queries = [
        Query(agg="avg", expr=Column(1), pred=TRUE, having=Having(">", 75.0),
              epsilon=1e-9, name="avg_quality"),
        Query(agg="avg", expr=Column(3), pred=TRUE, having=Having("<", 10.0),
              epsilon=1e-9, name="avg_dup"),
        Query(agg="count", pred=Range(0, 0.0, 16.0), having=Having("<", 1e6),
              epsilon=1e-9, name="short_docs"),
    ]
    cfg = EngineConfig(num_workers=8, strategy="resource_aware",
                       budget_init=budget, seed=0)
    sizes = np.full(16, 64, np.int64)
    return EngineProgram(codec=codec, queries=queries, config=cfg,
                         n_chunks=16, m_max=64, chunk_sizes=sizes), cfg, codec

vc.production_verify_program = lambda **kw: small_program(kw.get("budget", 16))

mesh = jax.make_mesh((8,), ("data",))
fn, args, program = vc.build_verify_cell(mesh, layout="sharded", budget=16)
step = jax.jit(fn, donate_argnums=(0,))

# real data: 16 chunks x 64 tuples x 6 cols
rng = np.random.default_rng(0)
vals = np.stack([rng.uniform(0, 100, (64, 6)) for _ in range(16)])
raw = np.stack([program.codec.encode(v) for v in vals])
packed = jax.device_put(jnp.asarray(raw), NamedSharding(mesh, P("data")))
speeds = jax.device_put(jnp.ones(8, jnp.float32), NamedSharding(mesh, P("data")))
state = jax.device_put(program.init_state(),
                       jax.tree.map(lambda s: NamedSharding(mesh, s),
                                    __import__("repro.core.engine_spmd",
                                               fromlist=["engine_state_specs"]).engine_state_specs(),
                                    is_leaf=lambda x: isinstance(x, P)))
rep = None
for _ in range(100):
    state, rep = step(state, packed, speeds)
    if bool(rep.exhausted):
        break
flat = vals.reshape(-1, 6)
truth_q = flat[:, 1].mean()
truth_d = flat[:, 3].mean()
truth_c = float(((flat[:, 0] >= 0) & (flat[:, 0] < 16)).sum())
est = np.asarray(rep.estimate, np.float64)
print(json.dumps({
    "exhausted": bool(rep.exhausted),
    "est": est.tolist(),
    "truth": [truth_q, truth_d, truth_c],
    "rel_err": [abs(est[0]-truth_q)/truth_q, abs(est[1]-truth_d)/truth_d,
                abs(est[2]-truth_c)/max(truth_c,1)],
}))
"""


@pytest.mark.slow
def test_sharded_verify_round_exact_at_exhaustion():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["exhausted"], res
    assert all(e < 5e-3 for e in res["rel_err"]), res
