"""Disk-backed :class:`ChunkStore`: spill, evict/re-read, restart, residency.

The docstring has long claimed tests exercise restart-from-metadata; these
are those tests.  Also covers the ``packed_device_view`` host-memory fix (a
spilled store must never end up resident twice) and streaming-vs-packed
engine parity over a store whose READ stage is real disk I/O.
"""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.engine import EngineConfig, OLAEngine
from repro.core.queries import Linear, Query, Range
from repro.data.chunkstore import ChunkStore
from repro.data.generator import make_synthetic_zipf, store_dataset

COEF = tuple(1.0 / (k + 1) for k in range(8))


def _disk_store(tmp_path, t=1024, chunks=8, seed=3):
    return store_dataset(make_synthetic_zipf(t, 8, seed=seed), chunks,
                         "ascii", uneven=True, directory=str(tmp_path))


def test_evict_reread_round_trip(tmp_path):
    store = _disk_store(tmp_path)
    originals = [store.chunk_bytes(j).copy() for j in range(store.num_chunks)]
    assert all(c is None for c in store._chunks)      # spilled at append
    for j in range(store.num_chunks):
        store.cache(j)
        assert store._chunks[j] is not None
        store.evict(j)
        assert store._chunks[j] is None
        np.testing.assert_array_equal(store.chunk_bytes(j), originals[j])
        assert store._chunks[j] is None               # chunk_bytes never caches


def test_restart_from_metadata(tmp_path):
    store = _disk_store(tmp_path)
    truth = store.decode_all()
    reopened = ChunkStore.open(str(tmp_path), "dataset")
    assert reopened.num_chunks == store.num_chunks
    assert reopened.num_tuples == store.num_tuples
    np.testing.assert_array_equal(reopened.chunk_sizes, store.chunk_sizes)
    assert type(reopened.codec) is type(store.codec)
    assert reopened.codec.num_cols == store.codec.num_cols
    for j in range(store.num_chunks):
        np.testing.assert_array_equal(reopened.chunk_bytes(j),
                                      store.chunk_bytes(j))
    np.testing.assert_array_equal(reopened.decode_all(), truth)


def test_packed_device_view_evicts_disk_backed(tmp_path):
    """packed_device_view must not leave a second full copy of the store
    resident on the host: chunks cached before the call are evicted after
    their rows are copied into the packed tensor."""
    store = _disk_store(tmp_path)
    for j in range(store.num_chunks):
        store.cache(j)                                # fully resident
    packed, sizes = store.packed_device_view()
    assert all(c is None for c in store._chunks)      # evicted after copy
    for j in range(store.num_chunks):
        raw = store.chunk_bytes(j)
        np.testing.assert_array_equal(packed[j, : raw.shape[0]], raw)
    # in-memory stores keep their (only) copy — evict is a no-op there
    mem = store_dataset(make_synthetic_zipf(256, 8, seed=0), 4, "ascii")
    mem.packed_device_view()
    assert all(c is not None for c in mem._chunks)


def test_stream_matches_packed_on_disk_store(tmp_path):
    """Streaming residency over real disk READs: bit-exact vs packed, and
    the store never accumulates resident chunks (host O(slab))."""
    store = _disk_store(tmp_path)
    q = Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 0.5e8),
              epsilon=0.05)
    runs = {}
    for residency in ("packed", "stream"):
        cfg = EngineConfig(num_workers=4, strategy="single_pass",
                           budget_init=32, seed=5, residency=residency)
        eng = OLAEngine(store, [q], cfg)
        state, hist = eng.run(max_rounds=300)
        runs[residency] = (
            np.array([float(r.estimate[0]) for r in hist]),
            np.asarray(state.stats.ysum), np.asarray(state.scan_m))
        if eng.pipeline is not None:
            assert eng.pipeline.chunk_reads > 0
            eng.close()
        assert all(c is None for c in store._chunks)
    for a, b in zip(runs["packed"], runs["stream"]):
        np.testing.assert_array_equal(a, b)
