"""Raw formats, generators, chunk store."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.data.chunkstore import ChunkStore
from repro.data.formats import AsciiFixedFormat, BinaryBigEndianFormat
from repro.data.generator import (
    bounded_zipf, make_ptf_like, make_synthetic_zipf, make_wiki_like,
    store_dataset,
)


@settings(max_examples=20, deadline=None)
@given(vals=st.lists(st.floats(-9e7, 9e7, allow_nan=False, width=32),
                     min_size=1, max_size=20))
def test_ascii_roundtrip_property(vals):
    arr = np.asarray(vals, np.float64)[:, None]
    fmt = AsciiFixedFormat(1)
    dec = np.asarray(fmt.decode_ref(jnp.asarray(fmt.encode(arr))))
    # f32 relative precision + fixed 1e-6 absolute fraction resolution
    np.testing.assert_allclose(dec[:, 0], arr[:, 0], rtol=2e-6, atol=5e-6)


def test_binary_roundtrip_exact():
    rng = np.random.default_rng(0)
    vals = rng.normal(scale=1e6, size=(64, 5))
    fmt = BinaryBigEndianFormat(5)
    dec = np.asarray(fmt.decode_ref(jnp.asarray(fmt.encode(vals))))
    np.testing.assert_array_equal(dec, vals.astype(np.float32))


def test_zipf_skew_ordering():
    rng = np.random.default_rng(1)
    flat = bounded_zipf(rng, 0.0, 4000)
    skew = bounded_zipf(rng, 3.0, 4000)
    assert skew.mean() < flat.mean()  # heavy skew concentrates at small ranks


def test_generators_shapes():
    assert make_synthetic_zipf(1000, 16, 0).shape == (1000, 16)
    assert make_ptf_like(1000, 10, 0).shape == (1000, 8)
    w, langs = make_wiki_like(1000, 10, 0)
    assert w.shape == (1000, 4) and len(langs) == 10
    # ptf time-sortedness within nights produces clumped chunks
    p = make_ptf_like(2000, 20, 0)
    assert (np.diff(p[:100, 2]) >= 0).all()


def test_store_even_uneven_and_disk(tmp_path):
    vals = make_synthetic_zipf(512, 4, 0)
    st_even = store_dataset(vals, 8, "ascii")
    assert st_even.num_tuples == 512 and st_even.num_chunks == 8
    st_un = store_dataset(vals, 8, "ascii", uneven=True)
    assert st_un.num_tuples == 512
    assert st_un.chunk_sizes.std() > 0
    st_disk = store_dataset(vals, 4, "binary", directory=str(tmp_path),
                            name="t")
    again = ChunkStore.open(str(tmp_path), "t")
    np.testing.assert_array_equal(again.chunk_bytes(2), st_disk.chunk_bytes(2))
    full = again.decode_all()
    np.testing.assert_allclose(full, vals.astype(np.float32), rtol=1e-6)


def test_packed_view_masks_padding():
    vals = make_synthetic_zipf(100, 3, 0)
    store = store_dataset(vals, 7, "ascii", uneven=True, seed=3)
    packed, sizes = store.packed_device_view()
    assert packed.shape[0] == 7
    assert packed.shape[1] == sizes.max()
    j = int(np.argmin(sizes))
    assert (packed[j, sizes[j]:] == 0).all()
