"""OLA engine behaviour: strategies, prefix invariant, convergence, stopping."""

import numpy as np
import pytest

import jax

from repro.core.engine import EngineConfig, OLAEngine, STRATEGIES
from repro.core.queries import Having, Linear, Query, Range, TRUE, group_fanout
from repro.data.generator import make_synthetic_zipf, store_dataset


@pytest.fixture(scope="module")
def small_store():
    vals = make_synthetic_zipf(4096, 8, seed=3)
    return vals, store_dataset(vals[:, :8], 32, "ascii", uneven=True)


COEF = tuple(1.0 / (k + 1) for k in range(8))


def _truth(vals, lo=0.0, hi=0.5e8):
    sel = (vals[:, 0] >= lo) & (vals[:, 0] < hi)
    return float((vals[:, :8] @ np.asarray(COEF)) @ sel)


@pytest.mark.parametrize("strategy", ["holistic", "single_pass",
                                      "resource_aware", "chunk_level"])
def test_strategy_converges(small_store, strategy):
    vals, store = small_store
    q = Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 0.5e8),
              epsilon=0.08)
    eng = OLAEngine(store, [q],
                    EngineConfig(num_workers=4, strategy=strategy,
                                 budget_init=64, seed=5))
    state, hist = eng.run(max_rounds=2000)
    rep = hist[-1]
    assert bool(rep.all_stopped) or bool(rep.exhausted)
    truth = _truth(vals)
    est = float(rep.estimate[0])
    err = float(rep.err[0])
    # estimate within its own reported CI of the truth (generous factor)
    assert abs(est - truth) <= max(2.0 * err, 0.10) * abs(truth)


def test_full_pass_is_exact(small_store):
    """Holistic run to exhaustion == exact answer (census degeneracy)."""
    vals, store = small_store
    q = Query(agg="sum", expr=Linear(COEF), pred=TRUE, epsilon=1e-9)
    eng = OLAEngine(store, [q], EngineConfig(num_workers=4,
                                             strategy="holistic",
                                             budget_init=256, seed=1))
    state, hist = eng.run(max_rounds=5000)
    rep = hist[-1]
    assert bool(rep.exhausted)
    truth = float(vals[:, :8] @ np.asarray(COEF) @ np.ones(len(vals)))
    assert abs(float(rep.estimate[0]) - truth) / abs(truth) < 1e-3
    assert float(rep.err[0]) < 1e-3


def test_prefix_invariant(small_store):
    """Inspection-paradox guard: the started chunk set is always a prefix of
    the committed schedule (paper §3/§4.2)."""
    vals, store = small_store
    q = Query(agg="sum", expr=Linear(COEF), epsilon=0.001)
    eng = OLAEngine(store, [q], EngineConfig(num_workers=4,
                                             strategy="single_pass",
                                             budget_init=32, seed=9))
    state = eng.init_state()
    sched = np.asarray(eng.program.schedule)
    for _ in range(60):
        b = eng.budget_ladder(float(state.budget))
        state, rep = eng.round_fn(b)(state, eng.packed, eng.speeds)
        started = np.asarray(state.stats.m) > 0
        head = int(state.head)
        assert started[sched[:head]].all()
        assert not started[sched[head:]].any()
        if bool(rep.exhausted):
            break


def test_straggler_speeds(small_store):
    """Slow workers claim fewer chunks; the run still completes and is sound
    (the global-queue mitigation, DESIGN.md §7)."""
    vals, store = small_store
    q = Query(agg="sum", expr=Linear(COEF), epsilon=1e-9)
    eng = OLAEngine(store, [q],
                    EngineConfig(num_workers=4, strategy="holistic",
                                 budget_init=64, seed=2,
                                 worker_speed=(1.0, 1.0, 0.25, 1.0)))
    state, hist = eng.run(max_rounds=5000)
    assert bool(hist[-1].exhausted)
    truth = float((vals[:, :8] @ np.asarray(COEF)).sum())
    assert abs(float(hist[-1].estimate[0]) - truth) / abs(truth) < 1e-3


def test_having_early_stop(small_store):
    vals, store = small_store
    truth = _truth(vals, 0.0, np.inf)
    q = Query(agg="sum", expr=Linear(COEF), pred=TRUE,
              having=Having("<", truth * 2), epsilon=1e-9)
    eng = OLAEngine(store, [q], EngineConfig(num_workers=4,
                                             strategy="resource_aware",
                                             budget_init=64, seed=5))
    state, hist = eng.run(max_rounds=2000)
    rep = hist[-1]
    assert int(rep.decided[0]) == 1          # decidedly below 2x truth
    assert int(rep.m_tuples) < len(vals)     # early: not a full pass


def test_group_by_runs_simultaneously(small_store):
    vals, store = small_store
    base = Query(agg="count", pred=TRUE, epsilon=0.2)
    qs = group_fanout(base, 7,
                      np.unique(vals[:, 7] // 2.0e7)[:2] * 2.0e7)
    eng = OLAEngine(store, qs, EngineConfig(num_workers=2,
                                            strategy="holistic",
                                            budget_init=128, seed=3))
    state, hist = eng.run(max_rounds=3000)
    assert hist[-1].estimate.shape == (len(qs),)


def test_chunk_level_barrier(small_store):
    """chunk_level only estimates from the done-prefix (reordering barrier)."""
    vals, store = small_store
    q = Query(agg="sum", expr=Linear(COEF), epsilon=1e-9)
    eng = OLAEngine(store, [q], EngineConfig(num_workers=4,
                                             strategy="chunk_level",
                                             budget_init=32, seed=4))
    state = eng.init_state()
    sched = np.asarray(eng.program.schedule)
    for _ in range(40):
        b = eng.budget_ladder(float(state.budget))
        state, rep = eng.round_fn(b)(state, eng.packed, eng.speeds)
        closed = np.asarray(state.closed)
        done_prefix = 0
        for j in sched:
            if closed[j]:
                done_prefix += 1
            else:
                break
        assert int(rep.n_chunks) == done_prefix
        if bool(rep.exhausted):
            break


def test_all_strategies_valid():
    for s in STRATEGIES:
        EngineConfig(strategy=s)
    with pytest.raises(AssertionError):
        EngineConfig(strategy="bogus")
