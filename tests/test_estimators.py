"""Eq. (1)/(2)/(3) statistical correctness (paper §4.3)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import estimators as E


def _stats_from(data, chosen, m_per, rng, dtype=jnp.float32):
    n, mj = data.shape
    ysum = np.zeros(n)
    ysq = np.zeros(n)
    ms = np.zeros(n, np.int32)
    ps = np.zeros(n)
    for j in chosen:
        idx = rng.choice(mj, size=m_per, replace=False)
        v = data[j, idx]
        ysum[j] = v.sum()
        ysq[j] = (v ** 2).sum()
        ms[j] = m_per
        ps[j] = m_per
    st_ = E.init_stats(jnp.full((n,), mj), dtype=dtype)
    return st_._replace(m=jnp.asarray(ms), ysum=jnp.asarray(ysum, dtype),
                        ysq=jnp.asarray(ysq, dtype), psum=jnp.asarray(ps, dtype))


def test_census_is_exact():
    rng = np.random.default_rng(0)
    data = rng.normal(2.0, 1.0, (6, 30))
    st_ = _stats_from(data, range(6), 30, rng)
    tau = float(E.tau_hat(st_))
    var, ok = E.var_hat(st_)
    assert abs(tau - data.sum()) < 1e-2
    assert abs(float(var)) < 1e-2
    assert bool(ok)


def test_unbiasedness_montecarlo():
    rng = np.random.default_rng(7)
    n, mj, nn, mm = 12, 24, 5, 8
    data = rng.normal(1.0, 1.0, (n, mj)) * (1 + np.arange(n))[:, None] * 0.2
    taus, vs = [], []
    for _ in range(800):
        chosen = rng.choice(n, nn, replace=False)
        st_ = _stats_from(data, chosen, mm, rng)
        taus.append(float(E.tau_hat(st_)))
        vs.append(float(E.var_hat(st_)[0]))
    taus = np.asarray(taus)
    se = taus.std() / np.sqrt(len(taus))
    assert abs(taus.mean() - data.sum()) < 4 * se
    y = data.sum(1)
    ss = ((data - data.mean(1, keepdims=True)) ** 2).sum(1)
    vt = float(E.variance_true(jnp.asarray(y), jnp.asarray(ss),
                               jnp.full((n,), mj), nn, jnp.full((n,), mm)))
    assert abs(np.mean(vs) - vt) / vt < 0.15
    assert abs(taus.var() - vt) / vt < 0.25


def test_merge_equals_union():
    """Worker-merge additivity: stats(A) ⊕ stats(B) == stats(A ∪ B)."""
    rng = np.random.default_rng(3)
    data = rng.normal(size=(4, 20))
    a = _stats_from(data, [0, 1], 5, np.random.default_rng(1))
    b = _stats_from(data, [2, 3], 7, np.random.default_rng(2))
    merged = a.merge(b)
    assert int(merged.n) == 4
    np.testing.assert_allclose(np.asarray(merged.ysum),
                               np.asarray(a.ysum) + np.asarray(b.ysum))


def test_single_chunk_variance_is_inf():
    rng = np.random.default_rng(0)
    data = rng.normal(size=(5, 10))
    st_ = _stats_from(data, [2], 4, rng)
    var, ok = E.var_hat(st_)
    assert np.isinf(float(var))


def test_avg_ratio_estimator():
    rng = np.random.default_rng(5)
    n, mj = 8, 64
    data = rng.uniform(0, 10, (n, mj))
    sel = data > 4.0  # predicate
    x = data * sel
    st_ = E.init_stats(jnp.full((n,), mj))
    st_ = st_._replace(
        m=jnp.full((n,), mj, jnp.int32),
        ysum=jnp.asarray(x.sum(1), jnp.float32),
        ysq=jnp.asarray((x ** 2).sum(1), jnp.float32),
        psum=jnp.asarray(sel.sum(1).astype(np.float32)))
    r, v, ok = E.avg_estimate(st_)
    truth = data[sel].mean()
    assert abs(float(r) - truth) < 1e-3
    assert float(v) < 1e-3  # census: variance ~ 0


@pytest.mark.parametrize("op,thr,expect", [
    ("<", 200.0, 1), ("<", 50.0, 0), ("<", 100.0, -1),
    (">", 50.0, 1), (">", 200.0, 0),
])
def test_having_decisions(op, thr, expect):
    lo, hi = jnp.asarray(90.0), jnp.asarray(110.0)
    assert int(E.having_decision(lo, hi, op, thr)) == expect


def test_error_ratio_matches_paper_definition():
    lo, hi, estv = 90.0, 110.0, 100.0
    assert abs(float(E.error_ratio(jnp.asarray(estv), jnp.asarray(lo),
                                   jnp.asarray(hi))) - 0.2) < 1e-6


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.1, 100.0), n_chunks=st.integers(2, 10))
def test_tau_scales_linearly(scale, n_chunks):
    rng = np.random.default_rng(11)
    data = rng.normal(1.0, 1.0, (n_chunks, 16))
    st1 = _stats_from(data, range(n_chunks), 8, np.random.default_rng(4))
    st2 = st1._replace(ysum=st1.ysum * scale, ysq=st1.ysq * scale ** 2)
    t1, t2 = float(E.tau_hat(st1)), float(E.tau_hat(st2))
    assert t2 == pytest.approx(t1 * scale, rel=1e-4)
