"""Observability plane: metrics registry, span tracer, explain records.

* registry semantics — idempotent registration, pull gauges, bounded
  histograms, snapshot/Prometheus export;
* tracer — deterministic under an injected clock, chrome-trace export
  passes (and the validator catches broken documents);
* explain — every retired query carries a record whose final
  estimate/CI equal the answer bit-for-bit; a census-converging query's
  CI-half-width trajectory is non-increasing; tier-1 rollup answers have
  a zero-round trajectory;
* server wiring — ``metrics_snapshot`` surfaces the quarantine log and
  injected-fault tallies; the NEUTRAL server is round-for-round
  bit-exact with tracing on;
* prefetcher counter lifecycle — ``close()`` preserves counters,
  ``reset_counters()`` is the only reset path (the satellite-6 bugfix).
"""

import json
import math

import numpy as np
import pytest

from repro.core.engine import EngineConfig, OLAEngine
from repro.core.queries import Linear, Query, Range
from repro.data.faults import FaultConfig, FaultInjector, RetryPolicy
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.obs.explain import ExplainRecord, RoundSample
from repro.obs.metrics import Counter, Gauge, MetricsRegistry
from repro.obs.trace import NULL_TRACER, SpanTracer, validate_chrome_trace
from repro.sched import WorkloadScheduler
from repro.sched.scheduler import NEUTRAL
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions
from repro.serve.rollup import RollupConfig

COEF = tuple(1.0 / (k + 1) for k in range(8))


@pytest.fixture(scope="module")
def setup():
    vals = make_synthetic_zipf(2048, 8, seed=3)
    store = store_dataset(vals, 16, "ascii")
    return vals, store


def _q(name: str, epsilon: float = 0.05, hi: float = 6e7) -> Query:
    return Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, hi),
                 epsilon=epsilon, name=name)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counter_and_histogram_semantics():
    reg = MetricsRegistry()
    c = reg.counter("reqs", help="requests", labels={"kind": "a"})
    c.inc()
    c.inc(3)
    with pytest.raises(ValueError):
        c.inc(-1)
    # idempotent: same (name, labels) returns the same instrument
    assert reg.counter("reqs", labels={"kind": "a"}) is c
    assert reg.counter("reqs", labels={"kind": "b"}) is not c

    h = reg.histogram("lat", help="latency", bounds=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(100.0)            # lands in the +Inf overflow bucket
    snap = reg.snapshot()
    assert snap['reqs{kind="a"}'] == 4
    assert snap["lat"]["count"] == 3
    assert snap["lat"]["sum"] == pytest.approx(100.55)


def test_registry_pull_gauge_tracks_source():
    reg = MetricsRegistry()
    box = {"v": 1}
    g = reg.gauge("depth", help="queue depth", fn=lambda: box["v"])
    assert reg.snapshot()["depth"] == 1
    box["v"] = 7
    assert reg.snapshot()["depth"] == 7          # evaluated at read time
    with pytest.raises(ValueError):
        g.set(3)                                 # pull gauges reject pushes


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("reqs", help="requests", labels={"kind": "a"}).inc(2)
    reg.histogram("lat", help="latency", bounds=(0.1, 1.0)).observe(0.5)
    text = reg.to_prometheus()
    assert "# HELP reqs requests" in text
    assert "# TYPE reqs counter" in text
    assert 'reqs{kind="a"} 2' in text
    # histogram buckets are cumulative and end at +Inf
    assert 'lat_bucket{le="1"} 1' in text
    assert 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_count 1" in text


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_tracer_deterministic_under_injected_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = SpanTracer(clock=clock)
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
    doc = tr.to_chrome_trace()
    assert validate_chrome_trace(doc) == []
    xs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    # outer: enter t=2 exit t=5; inner: enter t=3 exit t=4 (t=1 is the
    # tracer's construction-time epoch read)
    assert xs["outer"]["ts"] == pytest.approx(1e6)
    assert xs["outer"]["dur"] == pytest.approx(3e6)
    assert xs["inner"]["dur"] == pytest.approx(1e6)
    assert xs["outer"]["args"] == {"k": 1}
    json.dumps(doc)                              # export is JSON-clean


def test_null_tracer_records_nothing():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x", a=1):
        NULL_TRACER.event("y")
    # and the real tracer's buffer caps instead of growing without bound
    tr = SpanTracer(max_events=2)
    for i in range(5):
        tr.event(f"e{i}")
    assert len(tr.events) == 2 and tr.dropped == 3


def test_chrome_trace_validator_catches_breakage():
    tr = SpanTracer()
    with tr.span("a"):
        pass
    doc = tr.to_chrome_trace()
    assert validate_chrome_trace(doc) == []

    bad_phase = {"traceEvents": [dict(doc["traceEvents"][0], ph="Z")]}
    assert validate_chrome_trace(bad_phase)
    bad_ts = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": float("nan"),
         "dur": 1.0}]}
    assert validate_chrome_trace(bad_ts)
    # partially overlapping same-tid spans cannot nest
    overlap = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 10.0},
        {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5.0, "dur": 10.0}]}
    assert validate_chrome_trace(overlap)
    assert validate_chrome_trace({"traceEvents": "nope"})


# ---------------------------------------------------------------------------
# explain records
# ---------------------------------------------------------------------------

def test_explain_trajectory_thins_past_cap(monkeypatch):
    monkeypatch.setattr(ExplainRecord, "max_samples", 8)
    rec = ExplainRecord(qid=0, name="q", t_submit=0.0)
    for r in range(100):
        rec.record_round(RoundSample(round=r, m=r, est=1.0,
                                     ci_halfwidth=0.1, b_eff=4, weight=1.0))
    assert len(rec.trajectory) <= 8
    rounds = [s.round for s in rec.trajectory]
    assert rounds == sorted(rounds) and rounds[0] == 0
    d = rec.to_dict()
    assert "_stride" not in d and isinstance(d["trajectory"][0], dict)


def test_explain_final_equals_answer_bit_for_bit(setup):
    _, store = setup
    cfg = EngineConfig(num_workers=2, seed=5)
    srv = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=3))
    for i in range(3):
        srv.submit(_q(f"q{i}", epsilon=0.05), arrival_t=1e-5 * i)
    res = srv.run()
    srv.close()
    assert len(res) == 3
    for r in res:
        ex = r.explain
        assert ex is not None
        assert ex.final_estimate == r.estimate          # bit-for-bit
        assert ex.final_ci_halfwidth == r.halfwidth
        assert ex.sched_outcome == r.sched_outcome
        assert ex.tier == "scan" and ex.rounds_resident > 0
        assert ex.plan == r.plan and ex.admission_reason
        assert ex.cost_t_io_s > 0 and ex.cost_t_cpu_s > 0
        assert ex.effective_epsilon == pytest.approx(0.05)
        # trajectory endpoints are consistent with the lifecycle
        assert len(ex.trajectory) == ex.rounds_resident
        assert ex.trajectory[-1].m == r.tuples_seen
        json.dumps(ex.to_dict())


def test_census_trajectory_ci_halfwidth_non_increasing(setup):
    """A census-converging query (ε ≈ 0 forces a full scan) on the ref
    backend: its CI half-width trajectory converges to zero.  The
    half-width is itself a *sample-variance estimate*, so individual
    rounds can tick up as new strata enter the sample — the check allows
    bounded per-round noise, and pins the envelope: every round must stay
    under 1.5x the running minimum's last improvement, the trajectory must
    collapse by an order of magnitude, and the census endpoint is exactly
    tight (FPC drives the width to zero at full coverage)."""
    _, store = setup
    cfg = EngineConfig(num_workers=2, seed=5, extract_backend="ref")
    srv = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=2))
    srv.submit(_q("census", epsilon=1e-9), arrival_t=0.0)
    res = srv.run()
    srv.close()
    (r,) = res
    hw = [s.ci_halfwidth for s in r.explain.trajectory]
    assert len(hw) >= 2
    # non-increasing up to statistical noise: no round may exceed 1.5x its
    # predecessor, and the running minimum never regresses
    assert all(b <= a * 1.5 for a, b in zip(hw, hw[1:])), hw
    assert hw[-1] <= hw[0] / 10.0, hw                  # real convergence
    assert hw[-1] == pytest.approx(0.0, abs=1e-6)      # census: exact
    ms = [s.m for s in r.explain.trajectory]
    assert ms == sorted(ms) and ms[-1] > ms[0]         # sample only grows


def test_tier1_answer_has_zero_round_trajectory(setup):
    _, store = setup
    cfg = EngineConfig(num_workers=2, seed=5)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=4,
                  rollup=RollupConfig(promote_hits=2)))
    for i in range(2):                       # promote the pattern...
        srv.submit(_q(f"h{i}", epsilon=0.08), arrival_t=1e-5 * i)
    srv.run()
    srv.submit(_q("hot", epsilon=0.08))      # ...then hit the cell
    r = srv.run()[-1]
    srv.close()
    assert r.sched_outcome == "tier1"
    ex = r.explain
    assert ex.tier == "tier1" and "rollup" in ex.tier_reason
    assert ex.trajectory == [] and ex.rounds_resident == 0
    assert ex.final_estimate == r.estimate
    assert ex.final_ci_halfwidth == r.halfwidth


# ---------------------------------------------------------------------------
# server wiring: metrics snapshot, fault surfacing, traced parity
# ---------------------------------------------------------------------------

def _answer_key(results):
    return [(r.qid, repr(r.estimate), repr(r.lo), repr(r.hi),
             repr(r.latency), r.sched_outcome, r.rounds_resident,
             r.tuples_seen) for r in results]


def test_neutral_server_bit_exact_with_tracing_on(setup):
    _, store = setup
    cfg = EngineConfig(num_workers=2, seed=5)
    queries = [_q(f"q{i}", epsilon=0.05) for i in range(4)]

    def _run(tracer):
        srv = OLAWorkloadServer(
                  store, cfg,
                  options=ServerOptions(max_slots=2, tracer=tracer,
                      scheduler=WorkloadScheduler(NEUTRAL)))
        for i, q in enumerate(queries):
            srv.submit(q, arrival_t=1e-5 * i)
        res = srv.run()
        stats = (srv.rounds, srv.tuples_scanned, srv.t_model)
        srv.close()
        return res, stats, srv

    res_off, stats_off, _ = _run(None)
    res_on, stats_on, srv_on = _run(SpanTracer())
    assert _answer_key(res_on) == _answer_key(res_off)
    assert stats_on == stats_off
    doc = srv_on.tracer.to_chrome_trace()
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"round", "claims", "kernel", "merge", "estimate"} <= names


def test_metrics_snapshot_counts_lifecycle(setup):
    _, store = setup
    cfg = EngineConfig(num_workers=2, seed=5)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2,
                  scheduler=WorkloadScheduler(NEUTRAL)))
    for i in range(3):
        srv.submit(_q(f"q{i}", epsilon=0.05), arrival_t=1e-5 * i)
    res = srv.run()
    snap = srv.metrics_snapshot()
    srv.close()
    retired = sum(v for k, v in snap.items() if k.startswith("queries_total"))
    assert retired == len(res) == 3
    assert snap["server_rounds"] == srv.rounds > 0
    assert snap["server_tuples_scanned"] == srv.tuples_scanned
    assert snap["query_latency_s"]["count"] == 3
    assert snap["quarantine_log"] == []
    assert snap['admission_decisions{action="admitted"}'] >= 1
    # the text exposition renders the same registry without raising
    assert "server_rounds" in srv.metrics.to_prometheus()


def test_metrics_snapshot_surfaces_quarantine_and_faults():
    vals = make_synthetic_zipf(512, 8, seed=3)
    store = store_dataset(vals, 8, "ascii")
    cfg = EngineConfig(num_workers=2, seed=9, residency="stream")
    inj = FaultInjector(store, FaultConfig())
    srv = OLAWorkloadServer(
              inj, cfg,
              options=ServerOptions(max_slots=2,
                  scheduler=WorkloadScheduler(NEUTRAL)))
    if srv.engine.pipeline is not None:
        srv.engine.pipeline.retry = RetryPolicy(sleep=lambda s: None,
                                                max_attempts=2)
    lost = int(np.asarray(srv.state.schedule)[0])
    inj.config = FaultConfig(seed=7, lost_chunks=(lost,))
    srv.submit(_q("q0", epsilon=0.08), arrival_t=0.0)
    res = srv.run()
    snap = srv.metrics_snapshot()
    srv.close()
    assert snap["quarantine_log"] == [lost]
    assert snap["server_chunks_quarantined"] == 1
    assert snap['faults_injected{kind="lost"}'] >= 1
    # the quarantine round is recorded on the resident query's explain
    (r,) = res
    assert r.degraded
    deg = r.explain.degradation
    assert len(deg) == 1 and deg[0]["chunk_ids"] == [lost]


# ---------------------------------------------------------------------------
# prefetcher counter lifecycle (satellite: close() must not clear counters)
# ---------------------------------------------------------------------------

def test_prefetcher_counters_survive_close_reset_is_explicit(setup):
    _, store = setup
    cfg = EngineConfig(num_workers=2, seed=5, residency="stream")
    eng = OLAEngine(store, [_q("q0", epsilon=0.05)], cfg)
    state = eng.init_state()
    for _ in range(3):
        b = eng.budget_ladder(float(state.budget))
        state, data = eng.round_data(state)
        state, rep = eng.round_fn(b)(state, data, eng.speeds)
        if bool(rep.all_stopped) or bool(rep.exhausted):
            break
    pf = eng.pipeline
    reg = MetricsRegistry()
    pf.bind_metrics(reg)
    before = pf.counters()
    assert before["chunk_reads"] > 0
    assert reg.snapshot()["prefetch_chunk_reads"] == before["chunk_reads"]
    pf.close()
    # close() ends the reader thread but preserves the counters — a server
    # shutdown must not erase the telemetry about the run that just ended
    assert pf.counters() == before
    assert reg.snapshot()["prefetch_chunk_reads"] == before["chunk_reads"]
    pf.reset_counters()                      # the one explicit reset path
    after = pf.counters()
    assert after["chunk_reads"] == 0
    assert all(after[f] == 0 for f in pf.COUNTER_FIELDS)
    assert reg.snapshot()["prefetch_chunk_reads"] == 0
