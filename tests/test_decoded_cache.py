"""Parse-once decoded-chunk cache: the budgeted cache of decoded ``(rows,
C)`` float32 blocks between the prefetcher and the kernels, the
decoded-input slot-eval fast path, and the invariants that make it safe to
leave on:

* **kernel parity** — the decoded-input kernel equals gather+parse on the
  same window (decode is row-elementwise, so parse-then-gather and
  gather-then-parse are the same bits), and a mixed raw/decoded round with
  complementary budgets sums to the all-raw round bit-for-bit;
* **in-kernel synopsis-cache emission** — with ``cache_cap > 0`` the
  streaming kernel returns exactly ``(stats (W, S, 4), cache_rows
  (W, cap, C))`` and never re-emits the full decoded slab to HBM;
* **modeled-clock neutrality** — an engine run with the cache on is
  *bit-exact* vs off on the ref backend: estimates, synopsis cache, scan
  state, and the Eq. (4) ``t_io``/``t_cpu`` clock (decoded workers keep
  as-if-raw costs; only the host-side Eq. (4) pricing sees the discount,
  via ``decoded_fraction``);
* **budget, cost-aware eviction, version invalidation** — eviction scores
  ``extract_cost × touches / recency-age``, so ASCII blocks outlive binary
  ones at equal touch history; a ``content_version`` bump clears the cache
  (the rollup tier's invalidation contract);
* **zero-copy slab assembly** — the prefetcher's ring buffers alternate and
  ``readinto`` lands file bytes directly in the slab slice, with the direct
  path disabled under store wrappers (FaultInjector) so injection still
  intercepts reads;
* **quarantine** (tests/test_faults.py holds the estimator oracle) — a
  chunk quarantined mid-scan leaves the decoded cache and the
  ``decoded_fraction`` Eq. (4) discount re-prices over the survivors;
* **server e2e** — workload answers are bit-identical cache on/off.
"""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")
import jax

from repro.core.engine import EngineConfig, OLAEngine
from repro.core.queries import Linear, Query, Range
from repro.data.faults import FaultConfig, FaultInjector, RetryPolicy
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.data.pipeline import DecodedChunkCache, SlabPrefetcher
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kref
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions

COEF = tuple(1.0 / (k + 1) for k in range(8))


def _queries(eps=0.04):
    return [
        Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 0.6e8),
              epsilon=eps, name="q-sum"),
        Query(agg="count", pred=Range(1, 0.0, 7e7), epsilon=eps,
              name="q-count"),
        Query(agg="avg", expr=Linear(COEF), epsilon=eps, name="q-avg"),
    ]


def _store(t=2048, chunks=12, seed=3, directory=None, codec="ascii"):
    return store_dataset(make_synthetic_zipf(t, 8, seed=seed), chunks, codec,
                         uneven=True, directory=directory)


def _cfg(**kw):
    base = dict(num_workers=4, strategy="single_pass", budget_init=32,
                seed=5, cache_cap=16, residency="stream")
    base.update(kw)
    return EngineConfig(**base)


def _no_sleep_retry(**kw):
    return RetryPolicy(sleep=lambda s: None, **kw)


def _run(store, queries, cfg, max_rounds=600):
    eng = OLAEngine(store, queries, cfg)
    if eng.pipeline is not None:
        eng.pipeline.retry = _no_sleep_retry()
    try:
        state, _ = eng.run(max_rounds=max_rounds, collect_history=False)
        pf = eng.pipeline
        return {
            "ysum": np.asarray(state.stats.ysum),
            "m": np.asarray(state.stats.m),
            "cache": np.asarray(state.cache),
            "scan_m": np.asarray(state.scan_m),
            "t_cpu": float(state.t_cpu),
            "t_io": float(state.t_io),
            "quarantined": np.asarray(state.quarantined),
            "hits": pf.decoded_hits if pf is not None else 0,
            "fraction": pf.decoded_fraction() if pf is not None else 0.0,
            "qlog": list(eng.quarantine_log),
        }
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# DecodedChunkCache units: budget, cost-aware eviction, version invalidation
# ---------------------------------------------------------------------------

def _blk(rows, cols=8, fill=1.0):
    return np.full((rows, cols), fill, np.float32)


def test_cache_budget_admission_and_accounting():
    cache = DecodedChunkCache(budget_bytes=4 * 8 * 4 * 10)  # 10 8-col rows*4
    assert not cache.put(0, _blk(100))          # oversize: rejected outright
    assert cache.put(1, _blk(4))
    assert cache.put(2, _blk(4))
    assert 1 in cache and 2 in cache and len(cache) == 2
    assert cache.tuples_cached == 8
    assert cache.bytes_cached == 2 * 4 * 8 * 4
    assert cache.get(1) is not None and cache.get(99) is None
    assert cache.drop(1) and not cache.drop(1)
    assert cache.tuples_cached == 4


def test_cache_eviction_is_cost_aware():
    """At equal touch history an ASCII block (≈100× the re-extract cost)
    must outlive a binary one; the cheapest-to-rebuild block is the victim."""
    cache = DecodedChunkCache(budget_bytes=2 * 4 * 8 * 4)   # fits two blocks
    assert cache.put(0, _blk(4), cost_per_tuple=3360.0)     # ASCII
    assert cache.put(1, _blk(4), cost_per_tuple=32.0)       # binary
    assert cache.put(2, _blk(4), cost_per_tuple=3360.0)     # forces eviction
    assert cache.evictions == 1
    assert 1 not in cache and 0 in cache and 2 in cache


def test_cache_eviction_prefers_cold_blocks():
    cache = DecodedChunkCache(budget_bytes=2 * 4 * 8 * 4, cost_per_tuple=1.0)
    assert cache.put(0, _blk(4)) and cache.put(1, _blk(4))
    for _ in range(5):
        cache.get(0)                      # chunk 0 is hot, chunk 1 cold
    assert cache.put(2, _blk(4))
    assert 1 not in cache and 0 in cache


def test_cache_content_version_invalidation():
    cache = DecodedChunkCache(budget_bytes=1 << 20)
    cache.check_version(7)
    assert cache.put(0, _blk(4))
    cache.check_version(7)                # same version: no-op
    assert 0 in cache
    cache.check_version(8)                # re-ingest: everything distrusted
    assert len(cache) == 0 and cache.bytes_cached == 0


# ---------------------------------------------------------------------------
# Kernel parity: decoded-input eval vs raw EXTRACT vs the ref oracle
# ---------------------------------------------------------------------------

def _slab_and_dec(store, workers):
    """(slab (W, R, rec) u8, dec (W, R, C) f32, rows (W,)) for the first
    ``workers`` chunks, zero-padded to the store's max chunk rows."""
    rec = store.codec.record_bytes
    rows_max = int(store.max_chunk_tuples)
    slab = np.zeros((workers, rows_max, rec), np.uint8)
    dec = np.zeros((workers, rows_max, store.codec.num_cols), np.float32)
    rows = np.zeros(workers, np.int32)
    for w in range(workers):
        raw = np.asarray(store.chunk_bytes(w)).reshape(-1, rec)
        slab[w, :raw.shape[0]] = raw
        dec[w, :raw.shape[0]] = np.asarray(store.codec.decode_ref(
            jnp.asarray(raw)), np.float32)
        rows[w] = raw.shape[0]
    return jnp.asarray(slab), jnp.asarray(dec), rows


def _slot_params(s=3, c=8, seed=0):
    rng = np.random.default_rng(seed)
    coeffs = jnp.asarray(rng.normal(size=(s, c)), jnp.float32)
    lo = np.full((s, c), -1e30, np.float32)
    hi = np.full((s, c), 1e30, np.float32)
    lo[1, 0], hi[1, 0] = 0.0, 0.6e8      # one selective range slot
    is_count = jnp.asarray([0.0, 1.0, 0.0], jnp.float32)
    gate = jnp.ones((s,), jnp.float32)
    return coeffs, jnp.asarray(lo), jnp.asarray(hi), is_count, gate


@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
def test_decoded_kernel_matches_raw_and_oracle(backend):
    store = _store(t=1024, chunks=6)
    w = 4
    slab, dec, rows = _slab_and_dec(store, w)
    rng = np.random.default_rng(1)
    b = 48
    idx = jnp.asarray(rng.integers(0, rows[:, None], size=(w, b)), jnp.int32)
    b_eff = jnp.asarray(np.minimum(rows, [48, 31, 7, 0]), jnp.int32)
    params = _slot_params()

    raw_stats = kernel_ops.slot_extract_stream(slab, idx, b_eff, *params,
                                               backend=backend)
    dec_stats = kernel_ops.slot_eval_decoded(dec, idx, b_eff, *params,
                                             backend=backend)
    oracle = kref.slot_eval_decoded_ref(dec, idx, b_eff, *params)
    if backend == "ref":
        np.testing.assert_array_equal(np.asarray(dec_stats),
                                      np.asarray(oracle))
        np.testing.assert_array_equal(np.asarray(dec_stats),
                                      np.asarray(raw_stats))
    else:
        np.testing.assert_allclose(np.asarray(dec_stats), np.asarray(oracle),
                                   rtol=1e-6, atol=1e-4)
        np.testing.assert_allclose(np.asarray(dec_stats),
                                   np.asarray(raw_stats),
                                   rtol=1e-6, atol=1e-4)


@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
def test_mixed_round_complementary_budgets_sum_exactly(backend):
    """A mixed raw/decoded round — raw workers on ``b_raw = where(dec, 0,
    b)``, decoded workers on the complement — sums to the all-raw stats:
    zero-budget workers contribute exact float zeros, so the split is not
    just close, it is the same computation routed two ways."""
    store = _store(t=1024, chunks=6)
    w = 4
    slab, dec, rows = _slab_and_dec(store, w)
    rng = np.random.default_rng(2)
    idx = jnp.asarray(rng.integers(0, rows[:, None], size=(w, 32)), jnp.int32)
    b_eff = jnp.asarray(np.minimum(rows, 32), jnp.int32)
    is_dec = jnp.asarray([True, False, True, False])
    params = _slot_params()

    full = kernel_ops.slot_extract_stream(slab, idx, b_eff, *params,
                                          backend=backend)
    b_raw = jnp.where(is_dec, 0, b_eff)
    part_raw = kernel_ops.slot_extract_stream(slab, idx, b_raw, *params,
                                              backend=backend)
    part_dec = kernel_ops.slot_eval_decoded(dec, idx, b_eff - b_raw, *params,
                                            backend=backend)
    mixed = np.asarray(part_raw) + np.asarray(part_dec)
    if backend == "ref":
        np.testing.assert_array_equal(mixed, np.asarray(full))
    else:
        np.testing.assert_allclose(mixed, np.asarray(full),
                                   rtol=1e-6, atol=1e-4)


@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
def test_stream_cache_cap_output_spec(backend):
    """With ``cache_cap > 0`` the streaming kernel's entire HBM output is
    ``(stats (W, S, 4), cache_rows (W, cap, C))`` — the synopsis-cache
    scatter moved into the kernel, so enabling the cache no longer re-emits
    the whole decoded slab.  The rows themselves must match the ref
    emission oracle."""
    store = _store(t=1024, chunks=6)
    w, cap = 4, 8
    slab, dec, rows = _slab_and_dec(store, w)
    rng = np.random.default_rng(3)
    idx = jnp.asarray(rng.integers(0, rows[:, None], size=(w, 16)), jnp.int32)
    b_eff = jnp.asarray(np.minimum(rows, [16, 9, 3, 16]), jnp.int32)
    m_before = jnp.asarray([0, 3, 7, 20], jnp.int32)
    params = _slot_params()

    res = kernel_ops.slot_extract_stream(slab, idx, b_eff, *params,
                                         cache_cap=cap, m_before=m_before,
                                         backend=backend)
    assert isinstance(res, tuple) and len(res) == 2
    stats, cache_rows = res
    assert stats.shape == (w, 3, 4)
    assert cache_rows.shape == (w, cap, store.codec.num_cols)
    oracle = kref.stream_cache_rows_ref(slab, idx, b_eff, m_before, cap,
                                        store.codec.num_cols)
    if backend == "ref":
        np.testing.assert_array_equal(np.asarray(cache_rows),
                                      np.asarray(oracle))
    else:
        np.testing.assert_allclose(np.asarray(cache_rows),
                                   np.asarray(oracle), rtol=1e-6, atol=1e-4)
    # decoded-input flavor honors the same emission contract
    res_d = kernel_ops.slot_eval_decoded(dec, idx, b_eff, *params,
                                         cache_cap=cap, m_before=m_before,
                                         backend=backend)
    assert isinstance(res_d, tuple) and len(res_d) == 2
    assert res_d[1].shape == (w, cap, store.codec.num_cols)
    np.testing.assert_allclose(np.asarray(res_d[1]), np.asarray(oracle),
                               rtol=1e-6, atol=1e-4)


# ---------------------------------------------------------------------------
# Zero-copy slab assembly: ring buffers + direct readinto gating
# ---------------------------------------------------------------------------

def test_assemble_ring_alternates_and_counts_hits(tmp_path):
    store = _store(t=512, chunks=4, directory=str(tmp_path))
    pf = SlabPrefetcher(store, num_workers=2, lookahead=2,
                        decoded_cache_bytes=1 << 22)
    try:
        assert pf._direct_readinto       # plain disk store: zero-copy path
        act = np.array([True, True])
        a = pf.assemble(np.array([0, 1]), act)
        b = pf.assemble(np.array([1, 0]), act)   # swapped assignment
        raw_a, raw_b = np.asarray(a[0]), np.asarray(b[0])
        rec = store.codec.record_bytes
        for w, j in ((0, 0), (1, 1)):
            rows = int(store.chunk_sizes[j])
            np.testing.assert_array_equal(
                raw_a[w, :rows].reshape(-1),
                np.asarray(store.chunk_bytes(j)).reshape(-1)[:rows * rec])
        # second assemble served both chunks decoded, new holds counted
        assert pf.decoded_misses == 2 and pf.decoded_hits == 2
        assert pf.extract_tuples_avoided == int(store.chunk_sizes[:2].sum())
        assert bool(np.asarray(b[2]).all()) and b[3] is True
        # all-decoded rounds skip the raw ring: the raw leaf is the cached
        # zero-row slab, not a freshly zeroed + transferred buffer
        assert raw_b.shape == (2, 0, rec)
    finally:
        pf.close()


def test_direct_readinto_disabled_under_store_wrappers():
    """FaultInjector intercepts ``chunk_bytes`` only; the zero-copy
    ``read_chunk_into`` path must stay off under a wrapper or injection
    (and CRC checks riding it) would be silently bypassed."""
    store = _store(t=512, chunks=4)
    inj = FaultInjector(store, FaultConfig())
    pf_direct = SlabPrefetcher(store, num_workers=2, lookahead=2)
    pf_wrapped = SlabPrefetcher(inj, num_workers=2, lookahead=2)
    try:
        assert not pf_wrapped._direct_readinto
        a = pf_direct.assemble(np.array([0, 1]), np.array([True, True]))
        b = pf_wrapped.assemble(np.array([0, 1]), np.array([True, True]))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        pf_direct.close()
        pf_wrapped.close()


# ---------------------------------------------------------------------------
# Engine: cache on == cache off, bit-exact (ref), including the Eq. 4 clock
# ---------------------------------------------------------------------------

KEYS = ("ysum", "m", "cache", "scan_m", "t_cpu", "t_io")


def test_engine_stream_decoded_bit_exact_ref():
    store_kw = dict(t=2048, chunks=12, seed=3)
    queries = _queries()
    off = _run(_store(**store_kw), queries, _cfg(extract_backend="ref"))
    on = _run(_store(**store_kw), queries,
              _cfg(extract_backend="ref", decoded_cache_bytes=1 << 26))
    for k in KEYS:
        np.testing.assert_array_equal(np.asarray(off[k]), np.asarray(on[k]),
                                      err_msg=k)
    assert on["hits"] > 0                  # the fast path actually ran
    assert on["fraction"] > 0.0
    assert off["hits"] == 0 and off["fraction"] == 0.0


def test_engine_stream_decoded_close_pallas():
    store_kw = dict(t=2048, chunks=12, seed=3)
    queries = _queries()
    off = _run(_store(**store_kw), queries, _cfg(extract_backend="pallas"))
    on = _run(_store(**store_kw), queries,
              _cfg(extract_backend="pallas", decoded_cache_bytes=1 << 26))
    for k in KEYS:
        np.testing.assert_allclose(np.asarray(off[k]), np.asarray(on[k]),
                                   rtol=1e-6, atol=1e-4, err_msg=k)
    assert on["hits"] > 0


def test_engine_decoded_matches_packed_answers():
    """The decoded stream round answers the same queries as the packed
    plane: stats agree to float tolerance (different gather order)."""
    store_kw = dict(t=2048, chunks=12, seed=3)
    queries = _queries()
    packed = _run(_store(**store_kw), queries,
                  _cfg(extract_backend="ref", residency="packed"))
    dec = _run(_store(**store_kw), queries,
               _cfg(extract_backend="ref", decoded_cache_bytes=1 << 26))
    np.testing.assert_allclose(np.asarray(packed["ysum"]).sum(axis=-1),
                               np.asarray(dec["ysum"]).sum(axis=-1),
                               rtol=1e-5)


def test_tiny_budget_forces_mixed_rounds_still_bit_exact():
    """A budget fitting ~2 chunks keeps most workers raw while some run
    decoded — the mixed-mode kernel composition — and must still be
    bit-exact vs cache-off on the ref backend."""
    store_kw = dict(t=2048, chunks=12, seed=3)
    store = _store(**store_kw)
    blk_bytes = int(store.max_chunk_tuples) * 8 * 4
    queries = _queries()
    off = _run(_store(**store_kw), queries, _cfg(extract_backend="ref"))
    on = _run(_store(**store_kw), queries,
              _cfg(extract_backend="ref", decoded_cache_bytes=2 * blk_bytes))
    for k in KEYS:
        np.testing.assert_array_equal(np.asarray(off[k]), np.asarray(on[k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# Quarantine: a lost chunk leaves the decoded cache and re-prices Eq. (4)
# ---------------------------------------------------------------------------

def test_lost_chunk_drops_from_decoded_cache_and_reprices():
    lost = 3
    store = _store(t=2048, chunks=12, seed=3)
    inj = FaultInjector(store, FaultConfig(seed=7, lost_chunks=(lost,)))
    cfg = _cfg(extract_backend="ref", decoded_cache_bytes=1 << 26)
    eng = OLAEngine(inj, _queries(), cfg)
    eng.pipeline.retry = _no_sleep_retry(max_attempts=2)
    try:
        state, _ = eng.run(max_rounds=600, collect_history=False)
        assert eng.quarantine_log == [lost]
        assert lost not in eng.pipeline.decoded
        # decoded_fraction prices only the surviving coverage
        sizes = np.asarray(inj.chunk_sizes)
        frac = eng.pipeline.decoded_fraction()
        assert 0.0 < frac <= (sizes.sum() - sizes[lost]) / sizes.sum() + 1e-9
    finally:
        eng.close()


def test_lost_chunk_decoded_on_off_same_answers():
    """Fault + cache interplay: the quarantined-population answers are
    bit-identical whether the decoded cache was on or off."""
    lost = 3
    store_kw = dict(t=2048, chunks=12, seed=3)
    fc = FaultConfig(seed=7, lost_chunks=(lost,))
    queries = _queries()
    off = _run(FaultInjector(_store(**store_kw), fc), queries,
               _cfg(extract_backend="ref"))
    on = _run(FaultInjector(_store(**store_kw), fc), queries,
              _cfg(extract_backend="ref", decoded_cache_bytes=1 << 26))
    assert off["qlog"] == on["qlog"] == [lost]
    for k in KEYS:
        np.testing.assert_array_equal(np.asarray(off[k]), np.asarray(on[k]),
                                      err_msg=k)


def test_server_quarantine_drops_decoded_and_discount():
    """The server's quarantine hook (the same one the rollup/synopsis
    invalidation rides) evicts the chunk's decoded block and recomputes the
    Eq. (4) scan rate with the shrunken ``decoded_fraction``."""
    store = _store(t=2048, chunks=12, seed=3)
    cfg = _cfg(extract_backend="ref", decoded_cache_bytes=1 << 26,
               strategy="resource_aware")
    srv = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=2))
    try:
        for i, q in enumerate(_queries(eps=0.08)):
            srv.submit(q, arrival_t=1e-5 * i)
        srv.run()
        pf = srv.engine.pipeline
        cached = sorted(j for j in range(store.num_chunks) if j in pf.decoded)
        assert cached, "scan never populated the decoded cache"
        victim = cached[0]
        rate_before = srv._scan_rate
        frac_before = pf.decoded_fraction()
        srv.quarantine([victim])
        assert victim not in pf.decoded
        assert pf.decoded_fraction() < frac_before
        assert srv._scan_rate != rate_before   # re-priced over survivors
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Server e2e: answers bit-identical cache on/off
# ---------------------------------------------------------------------------

def test_server_answers_bit_identical_cache_on_off():
    store_kw = dict(t=2048, chunks=12, seed=3)
    workload = [(q, 1e-5 * i) for i, q in enumerate(_queries(eps=0.08))]

    def serve(decoded_bytes):
        cfg = _cfg(extract_backend="ref", strategy="resource_aware",
                   decoded_cache_bytes=decoded_bytes)
        srv = OLAWorkloadServer(
                  _store(**store_kw), cfg,
                  options=ServerOptions(max_slots=2))
        try:
            for q, at in workload:
                srv.submit(q, arrival_t=at)
            res = srv.run()
            return [(r.qid, r.estimate, r.lo, r.hi, r.err, r.tuples_seen)
                    for r in res]
        finally:
            srv.close()

    assert serve(1 << 26) == serve(0)


# ---------------------------------------------------------------------------
# SPMD: decoded rounds shard like raw rounds — cache on/off bit-exact,
# and SPMD == single-device with the cache on.  Subprocess because
# XLA_FLAGS must be set before jax initializes.
# ---------------------------------------------------------------------------

_SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np, jax
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.core.queries import Query, Linear, Range
from repro.core.engine import OLAEngine, EngineConfig
from repro.core.engine_spmd import SPMDEngine

store = store_dataset(make_synthetic_zipf(2048, 8, seed=3), 12, 'ascii',
                      uneven=True)
coef = tuple(1.0 / (k + 1) for k in range(8))
q = Query(agg='sum', expr=Linear(coef), pred=Range(0, 0.0, 0.6e8),
          epsilon=0.04, name='q-sum')

def cfg(dec):
    return EngineConfig(num_workers=4, strategy='single_pass', budget_init=32,
                        seed=5, cache_cap=16, residency='stream',
                        extract_backend='ref', decoded_cache_bytes=dec)

KEYS = ('ysum', 'm', 'cache', 'scan_m', 't_cpu', 't_io')

def run(make):
    eng = make()
    try:
        state, hist = eng.run(max_rounds=600, collect_history=True)
        ests = [float(r.estimate[0]) for r in hist]
        snap = {k: np.asarray(getattr(state.stats, k)
                              if hasattr(state.stats, k)
                              else getattr(state, k)) for k in KEYS}
        hits = eng.pipeline.decoded_hits if eng.pipeline else 0
        return ests, snap, hits
    finally:
        eng.close()

mesh = jax.make_mesh((4,), ('data',))
e_on, s_on, hits_on = run(lambda: SPMDEngine(store, [q], cfg(1 << 26), mesh))
e_off, s_off, _ = run(lambda: SPMDEngine(store, [q], cfg(0), mesh))
e_one, s_one, hits_one = run(lambda: OLAEngine(store, [q], cfg(1 << 26)))
print(json.dumps({
    "hits_on": int(hits_on),
    "hits_one": int(hits_one),
    "spmd_on_off_exact": e_on == e_off and all(
        np.array_equal(s_on[k], s_off[k]) for k in KEYS),
    "spmd_vs_single_exact": e_on == e_one and all(
        np.array_equal(s_on[k], s_one[k]) for k in KEYS),
}))
"""


@pytest.mark.slow
def test_spmd_decoded_rounds_bit_exact():
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["hits_on"] > 0 and res["hits_one"] > 0, res
    assert res["spmd_on_off_exact"], res
    assert res["spmd_vs_single_exact"], res
